"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps
on the deterministic synthetic pipeline, with checkpointing/auto-resume and
the full trainer stack (the same code path the pod launcher uses).

    PYTHONPATH=src python examples/train_lm.py --steps 300 [--resume]

On this CPU container a step takes a few seconds; kill it mid-run and
re-invoke to watch auto-resume continue from the latest checkpoint.
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.config import (
    HOST_MESH,
    ModelConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
)
from repro.data import DataConfig, make_pipeline
from repro.models.model import build_model
from repro.sharding.rules import Dist
from repro.train.trainer import Trainer

# ~100M params: 12L x 512d with a 32k vocab (embed 16.4M + blocks 44M + head 16.4M)
LM_100M = ModelConfig(
    name="lm_100m", family="dense", n_layers=12, d_model=512, n_heads=8,
    n_kv_heads=8, d_ff=2048, vocab_size=32_000, head_dim=64,
    remat="none", tie_embeddings=False,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    model = build_model(LM_100M)
    print(f"model: {model.n_params() / 1e6:.1f}M params")

    run = RunConfig(
        model=LM_100M,
        shape=ShapeConfig("example", args.seq, args.batch, "train"),
        mesh=HOST_MESH,
        optimizer=OptimizerConfig(lr=3e-4, warmup_steps=20,
                                  total_steps=args.steps, schedule="cosine"),
        micro_batches=2,
        checkpoint_dir=args.ckpt,
        checkpoint_every=50,
    )
    data = make_pipeline(DataConfig(
        vocab_size=LM_100M.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=0,
    ))

    trainer = Trainer(model=model, run=run, dist=Dist(), data=data, log_every=10)
    trainer.install_preemption_handler()
    if trainer.try_resume():
        print(f"resumed from step {trainer.step}")

    out = trainer.fit(args.steps)
    print(f"done: {out['steps']} steps, final loss {out['final_loss']:.4f}, "
          f"slow steps {out['slow_steps']}")
    for m in out["log"][-5:]:
        print(f"  step {m['step']:4d} loss {m['loss']:.4f} "
              f"({m['dt_s']*1e3:.0f} ms/step)")
    data.stop()


if __name__ == "__main__":
    main()
