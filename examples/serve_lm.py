"""Scan service demo: warm starts + coalesced requests over the PROSITE bank.

    PYTHONPATH=src python examples/serve_lm.py [--store DIR] [--requests 12]

Run it twice: the first run pays SFA construction once and persists every
artifact to the store directory; the second run warm-starts from disk and
compiles the whole bank with **zero construction rounds**. Each run then
fires a burst of small scan requests at the coalescing scheduler — all of
them ride one fused bank compile + scan and are demultiplexed per request,
bit-identical to scanning each request alone.

(This file previously demoed the LM-era continuous-batching engine; that
path still lives in ``repro.serve`` / ``launch/serve.py``. The scan domain
is the repo's north star, so the example now serves scans.)
"""

import argparse
import sys
import tempfile
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core.prosite import PROSITE_EXTRA, PROSITE_SAMPLES, synthetic_protein
from repro.engine import Scanner

BANK = [pid for pid in {**PROSITE_SAMPLES, **PROSITE_EXTRA}]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", default=None,
                    help="artifact store dir (default: a temp dir — use a "
                         "real path to see the second run warm-start)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--patterns-per-request", type=int, default=3)
    ap.add_argument("--docs-per-request", type=int, default=4)
    args = ap.parse_args()

    store_dir = args.store or tempfile.mkdtemp(prefix="scan-store-")
    rng = np.random.default_rng(0)

    with Scanner.service(store_dir) as svc:
        n = svc.warm_start()
        print(f"store: {store_dir} ({n} artifact(s) preloaded)")

        t0 = time.perf_counter()
        scanner = svc.scanner(BANK)
        dt = time.perf_counter() - t0
        r = scanner.construction_report
        label = "WARM (zero construction rounds)" if r.rounds == 0 else "cold"
        print(f"compiled {scanner.n_patterns} patterns in {dt:.2f}s — {label}: "
              f"{r.rounds} round(s), {r.cache_hits} cache hit(s), "
              f"{r.constructed} built, {r.blown} blown")

        # A burst of overlapping requests: all coalesce into one batch.
        tickets = []
        for i in range(args.requests):
            pats = [str(p) for p in rng.choice(
                BANK, size=args.patterns_per_request, replace=False)]
            docs = [synthetic_protein(240, seed=int(rng.integers(1 << 16)))
                    for _ in range(args.docs_per_request)]
            tickets.append((pats, svc.submit(pats, docs)))
        t0 = time.perf_counter()
        served = svc.flush()
        dt = time.perf_counter() - t0
        stats = svc.scheduler.stats
        print(f"served {served} coalesced request(s) in {dt:.3f}s "
              f"(union: {stats.union_patterns} patterns x "
              f"{stats.union_docs} docs in {stats.flushes} fused scan(s))")
        for pats, t in tickets[:3]:
            res = t.result()
            print(f"  {pats} -> counts {res.counts.tolist()} "
                  f"(rode a batch of {res.batch_size})")

    if not args.store:
        print("tip: pass --store ./scan_store and run twice to see the "
              "warm start")


if __name__ == "__main__":
    main()
