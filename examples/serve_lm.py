"""Batched serving demo: continuous batching over a small LM.

    PYTHONPATH=src python examples/serve_lm.py [--requests 8] [--slots 4]

Submits a queue of variable-length prompts; the engine prefills each into a
free slot and decodes all live slots in lockstep (one token per step across
the batch) — throughput stays flat as requests come and go.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.config import HOST_MESH, ModelConfig, RunConfig, ShapeConfig
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine
from repro.sharding.rules import Dist

TINY = ModelConfig(
    name="serve_demo", family="dense", n_layers=4, d_model=128, n_heads=8,
    n_kv_heads=4, d_ff=256, vocab_size=512, head_dim=16, remat="none",
    tie_embeddings=True,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    run = RunConfig(model=TINY, shape=ShapeConfig("serve", 128, args.slots, "decode"),
                    mesh=HOST_MESH)
    engine = ServeEngine(model, run, Dist(), params, n_slots=args.slots,
                         max_len=128, temperature=args.temperature)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        L = int(rng.integers(4, 24))
        engine.submit(Request(
            prompt=rng.integers(1, TINY.vocab_size, size=L).astype(np.int32),
            max_new_tokens=args.max_new, rid=i,
        ))
    done = engine.run_until_done()
    dt = time.perf_counter() - t0

    total_tokens = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests / {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s) on {args.slots} slots")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: prompt {len(r.prompt)} toks -> {r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
