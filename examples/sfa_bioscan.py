"""ScanProsite-style bulk scan (paper §IV) on the Scanner engine: the full
bundled signature bank matched over a synthetic protein database in one
batched program — pattern-parallel (the bank axis) × chunk-parallel (the SFA
axis), with ``auto`` mode giving each signature the paper's single-lookup
SFA inner loop when construction fits the budget, a per-pattern census, and
match localization for the hits.

    PYTHONPATH=src python examples/sfa_bioscan.py [--db-size 200] [--len 2000]
        [--mode auto|sfa|enumeration] [--backend xla|pallas|reference]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import load_bank, synthetic_protein
from repro.engine import ChunkPolicy, ScanPlan, Scanner

N_CHUNKS = 16


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--db-size", type=int, default=200)
    ap.add_argument("--len", dest="length", type=int, default=2000)
    ap.add_argument("--ids", nargs="*", default=None,
                    help="signature ids (default: the full bundled bank)")
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "sfa", "enumeration"])
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "reference"])
    args = ap.parse_args()

    length = (args.length // N_CHUNKS) * N_CHUNKS
    print(f"building database: {args.db_size} proteins x {length} residues")
    db = [synthetic_protein(length, seed=i) for i in range(args.db_size)]

    t0 = time.perf_counter()
    bank = load_bank(args.ids)
    scanner = Scanner.compile(
        bank,
        ScanPlan(mode=args.mode, backend=args.backend,
                 chunking=ChunkPolicy(n_chunks=N_CHUNKS)),
    )
    t_compile = time.perf_counter() - t0
    n_sfa = sum(1 for m in scanner.pattern_modes.values() if m == "sfa")
    print(f"bank: {bank.n_patterns} signatures, n_max={bank.n_max} states, "
          f"compiled in {t_compile*1e3:.0f} ms "
          f"({n_sfa} SFA-mode / {bank.n_patterns - n_sfa} enumeration)")

    # one batched program: every (pattern, protein, chunk) cell at once
    scanner.scan(db)  # warmup/compile
    t0 = time.perf_counter()
    result = scanner.scan(db)
    counts = result.counts
    t_scan = time.perf_counter() - t0

    chars = args.db_size * length * bank.n_patterns
    print(f"scanned {chars/1e6:.1f} Mchar-pattern in {t_scan:.2f} s "
          f"({chars/t_scan/1e6:.1f} Mchar-pattern/s)")
    print(f"{'id':10s} {'pattern':42s} {'mode':12s} {'hits':>5s}  first match")
    from repro.core.prosite import PROSITE_EXTRA, PROSITE_SAMPLES

    pool = {**PROSITE_SAMPLES, **PROSITE_EXTRA}
    for p, pid in enumerate(scanner.ids):
        first = ""
        hit_rows = np.flatnonzero(result.hits[p])
        if hit_rows.size:
            # localize the first hit with the two-pass position matcher
            i = int(hit_rows[0])
            flags = scanner.locate(db[i], pattern=pid)
            first = f"protein {i} @ {int(np.argmax(flags))}"
        pat = pool.get(pid, "?")
        print(f"{pid:10s} {pat:42s} {scanner.pattern_modes[pid]:12s} "
              f"{int(counts[p]):5d}  {first}")


if __name__ == "__main__":
    main()
