"""ScanProsite-style bulk scan (paper §IV): a batch of PROSITE signatures
matched over a synthetic protein database, chunk-parallel, with timing and
match localization.

    PYTHONPATH=src python examples/sfa_bioscan.py [--db-size 200] [--len 2000]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import PROSITE_SAMPLES, compile_prosite, construct_sfa, synthetic_protein
from repro.core import matching as mt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--db-size", type=int, default=200)
    ap.add_argument("--len", dest="length", type=int, default=2000)
    ap.add_argument("--patterns", nargs="*",
                    default=["PS00016", "PS00005", "PS00006", "PS00017"])
    args = ap.parse_args()

    print(f"building database: {args.db_size} proteins x {args.length} residues")
    db = [synthetic_protein(args.length, seed=i) for i in range(args.db_size)]

    for pid in args.patterns:
        pat = PROSITE_SAMPLES[pid]
        dfa = compile_prosite(pat)
        t0 = time.perf_counter()
        sfa = construct_sfa(dfa, max_states=500_000)
        t_build = time.perf_counter() - t0

        table = jnp.asarray(dfa.table)
        accepting = jnp.asarray(dfa.accepting)
        t0 = time.perf_counter()
        hits = []
        for i, prot in enumerate(db):
            syms = jnp.asarray(dfa.encode(prot))
            L = (len(prot) // 16) * 16
            flags = mt.find_matches_parallel(table, accepting, syms[:L], dfa.start, 16)
            if bool(flags.any()):
                hits.append((i, int(np.argmax(np.asarray(flags)))))
        t_scan = time.perf_counter() - t0
        chars = args.db_size * args.length
        print(f"{pid}  {pat}")
        print(f"  dfa={dfa.n_states} sfa={sfa.n_states} built in {t_build*1e3:.0f} ms")
        print(f"  scanned {chars/1e6:.1f} Mchar in {t_scan:.2f} s "
              f"({chars/t_scan/1e6:.1f} Mchar/s), {len(hits)} proteins hit")
        if hits:
            i, pos = hits[0]
            print(f"  first: protein {i} match ending at {pos}")


if __name__ == "__main__":
    main()
