"""ScanProsite-style bulk scan (paper §IV): the full bundled signature bank
matched over a synthetic protein database in one batched program —
pattern-parallel (the bank axis) × chunk-parallel (the SFA axis), with a
per-pattern census and match localization for the hits.

    PYTHONPATH=src python examples/sfa_bioscan.py [--db-size 200] [--len 2000]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import load_bank, synthetic_protein
from repro.core import matching as mt
from repro.core import multipattern as mp

N_CHUNKS = 16


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--db-size", type=int, default=200)
    ap.add_argument("--len", dest="length", type=int, default=2000)
    ap.add_argument("--ids", nargs="*", default=None,
                    help="signature ids (default: the full bundled bank)")
    args = ap.parse_args()

    length = (args.length // N_CHUNKS) * N_CHUNKS
    print(f"building database: {args.db_size} proteins x {length} residues")
    db = [synthetic_protein(length, seed=i) for i in range(args.db_size)]

    t0 = time.perf_counter()
    bank = load_bank(args.ids)
    t_bank = time.perf_counter() - t0
    print(f"bank: {bank.n_patterns} signatures, n_max={bank.n_max} states, "
          f"compiled in {t_bank*1e3:.0f} ms")

    corpus = jnp.asarray(np.stack([bank.encode(p) for p in db]))
    tables, accepting, starts = bank.device_arrays()

    # one batched program: every (pattern, protein, chunk) cell at once
    mp.bank_hits(tables, accepting, starts, corpus, N_CHUNKS).block_until_ready()
    t0 = time.perf_counter()
    hits = mp.bank_hits(tables, accepting, starts, corpus, N_CHUNKS)
    counts = jnp.sum(hits, axis=1, dtype=jnp.int32)
    counts.block_until_ready()
    t_scan = time.perf_counter() - t0

    chars = args.db_size * length * bank.n_patterns
    print(f"scanned {chars/1e6:.1f} Mchar-pattern in {t_scan:.2f} s "
          f"({chars/t_scan/1e6:.1f} Mchar-pattern/s)")
    print(f"{'id':10s} {'pattern':42s} {'dfa':>4s} {'hits':>5s}  first match")
    from repro.core.prosite import PROSITE_EXTRA, PROSITE_SAMPLES

    pool = {**PROSITE_SAMPLES, **PROSITE_EXTRA}
    hits_np = np.asarray(hits)
    for p, pid in enumerate(bank.ids):
        d = bank.dfa(p)
        first = ""
        hit_rows = np.flatnonzero(hits_np[p])
        if hit_rows.size:
            # localize the first hit with the two-pass position matcher
            i = int(hit_rows[0])
            flags = mt.find_matches_parallel(
                jnp.asarray(d.table), jnp.asarray(d.accepting),
                corpus[i], d.start, N_CHUNKS,
            )
            first = f"protein {i} @ {int(np.argmax(np.asarray(flags)))}"
        pat = pool.get(pid, "?")
        print(f"{pid:10s} {pat:42s} {d.n_states:4d} {int(counts[p]):5d}  {first}")


if __name__ == "__main__":
    main()
