"""Quickstart: the paper's pipeline in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Compile a PROSITE pattern to a minimal DFA, construct its SFA (Rabin
fingerprints + bulk dedup), and match a protein string in parallel chunks —
verifying against the sequential matcher.
"""

import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import (
    accepts_parallel,
    compile_prosite,
    construct_sfa,
    synthetic_protein,
)

# The P-loop NTP-binding motif: [AG]-x(4)-G-K-[ST]
dfa = compile_prosite("[AG]-x(4)-G-K-[ST]")
print(f"DFA: {dfa.n_states} states over {dfa.n_symbols} symbols")

sfa = construct_sfa(dfa, engine="vectorized")
print(f"SFA: {sfa.n_states} states "
      f"({sfa.stats.candidates} candidates fingerprinted, "
      f"{sfa.stats.exact_compares} exact compares, "
      f"{sfa.stats.wall_time_s * 1e3:.1f} ms)")

protein = synthetic_protein(100_000, seed=42)
protein = protein[:50_000] + "AGGGGGKT" + protein[50_008:]  # plant a P-loop

par = accepts_parallel(dfa, protein, n_chunks=16, sfa=sfa)
seq = dfa.accepts(protein)
print(f"parallel match: {par}   sequential match: {seq}")
assert par == seq == True
print("OK — chunk-parallel SFA matching agrees with the sequential DFA.")
