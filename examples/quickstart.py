"""Quickstart: the paper's pipeline through the Scanner engine.

    PYTHONPATH=src python examples/quickstart.py

One entry point covers every configuration: compile a PROSITE pattern under
an execution plan (``auto`` mode constructs the SFA — Rabin fingerprints +
bulk dedup — when it fits the state budget, and falls back to enumeration
otherwise), then match a protein string in parallel chunks and stream it in
bounded-memory blocks, verifying both against the sequential matcher.
"""

import sys
sys.path.insert(0, "src")

from repro.core import compile_prosite, synthetic_protein
from repro.engine import ChunkPolicy, ScanPlan, Scanner

# The P-loop NTP-binding motif: [AG]-x(4)-G-K-[ST]
PATTERN = "[AG]-x(4)-G-K-[ST]"
dfa = compile_prosite(PATTERN)
print(f"DFA: {dfa.n_states} states over {dfa.n_symbols} symbols")

scanner = Scanner.compile(
    PATTERN,
    ScanPlan(mode="auto", chunking=ChunkPolicy(n_chunks=16, block_len=4096)),
)
print(scanner.describe())
print(f"auto mode chose: {scanner.pattern_modes[PATTERN]}")

protein = synthetic_protein(100_000, seed=42)
protein = protein[:50_000] + "AGGGGGKT" + protein[50_008:]  # plant a P-loop

par = scanner.accepts(protein)
seq = dfa.accepts(protein)
print(f"parallel match: {par}   sequential match: {seq}")
assert par == seq == True

# The same scan, streamed in 10k-char pieces: memory stays one block wide and
# the running function-monoid prefix carries across calls.
streamed = scanner.stream(
    protein[i: i + 10_000] for i in range(0, len(protein), 10_000)
)
assert streamed.accepts == par
hit = scanner.locate(protein).argmax()
print(f"streamed match: {streamed.accepts} ({streamed.n_symbols} symbols); "
      f"first match ends at position {hit}")
print("OK — chunk-parallel and streamed SFA matching agree with the "
      "sequential DFA.")
