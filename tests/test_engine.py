"""The Scanner engine: one entry point, every configuration, one answer.

Differential tests pin the engine's core contract: every (mode, backend,
distribution, chunking) plan produces bit-identical results, ``auto`` mode
picks SFA exactly when construction fits the budget, ``stream()`` equals
``scan()`` on the concatenated input, and the executors module is the one
home of the parallel entry points (the PR-2 deprecation shims are gone).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _strategies import given, settings, st

from repro.compat import make_mesh
from repro.core.dfa import random_dfa
from repro.core.prosite import PROSITE_SAMPLES, compile_prosite, load_bank, synthetic_protein
from repro.core.sfa import StateBlowup, construct_sfa
from repro.engine import ChunkPolicy, ScanPlan, Scanner


def _random_docs(seed, n_docs, length, k):
    rng = np.random.default_rng(seed)
    return rng.integers(0, k, size=(n_docs, length)).astype(np.int32)


# --------------------------------------------------------------------------
# Plans / compilation
# --------------------------------------------------------------------------


def test_plan_validation():
    with pytest.raises(ValueError):
        ScanPlan(mode="magic").validate()
    with pytest.raises(ValueError):
        ScanPlan(backend="cuda").validate()
    with pytest.raises(ValueError):
        ScanPlan(distribution="shard_map", backend="pallas").validate()
    with pytest.raises(ValueError):
        ScanPlan(chunking=ChunkPolicy(n_chunks=0)).validate()
    assert ScanPlan().with_(mode="sfa").mode == "sfa"


def test_compile_accepts_all_pattern_forms():
    dfa = compile_prosite("R-G-D")
    bank = load_bank(["PS00016", "PS00001"])
    for pats, n in [
        ("PS00016", 1),                      # bundled PROSITE id
        ("R-G-D", 1),                        # PROSITE signature syntax
        (dfa, 1),                            # compiled DFA
        (bank, 2),                           # PatternBank
        (["PS00016", dfa], 2),               # mixed sequence
        ({"a": "R-G-D", "b": "C-x(2)-C"}, 2),  # mapping
    ]:
        sc = Scanner.compile(pats)
        assert sc.n_patterns == n
    assert Scanner.compile("PS00016").single
    assert not Scanner.compile(["PS00016"]).single


def test_auto_mode_respects_state_budget():
    """The acceptance criterion: SFA iff construction fits the budget."""
    sc = Scanner.compile(["PS00016", "PS00008"],
                         ScanPlan(mode="auto", sfa_state_budget=20))
    assert sc.pattern_modes["PS00016"] == "sfa"       # tiny SFA
    assert sc.pattern_modes["PS00008"] == "enumeration"  # blows the budget
    # the budget really is the boundary: PS00016's SFA fits in 20 states
    assert construct_sfa(compile_prosite(
        PROSITE_SAMPLES["PS00016"])).n_states <= 20
    with pytest.raises(StateBlowup):
        Scanner.compile("PS00008", ScanPlan(mode="sfa", sfa_state_budget=20))


# --------------------------------------------------------------------------
# auto == sfa == enumeration (property, random DFAs)
# --------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    cfg=st.tuples(st.integers(min_value=0, max_value=400),
                  st.sampled_from([1, 2, 4])),
    forced=st.one_of(st.sampled_from(["sfa"]), st.sampled_from(["enumeration"])),
)
def test_auto_agrees_with_forced_modes(cfg, forced):
    seed, n_chunks = cfg
    k = 5
    dfas = [random_dfa(3 + (seed + i) % 3, k, seed=seed * 7 + i) for i in range(3)]
    docs = _random_docs(seed, 4, 33, k)  # 33: exercises the ragged tail
    plan = ScanPlan(mode="auto", sfa_state_budget=10_000,
                    chunking=ChunkPolicy(n_chunks=n_chunks))
    auto = Scanner.compile(dfas, plan).scan(docs).hits
    other = Scanner.compile(dfas, plan.with_(mode=forced)).scan(docs).hits
    assert np.array_equal(auto, other)
    # and both agree with the plain sequential DFA
    for p, d in enumerate(dfas):
        for j in range(docs.shape[0]):
            assert auto[p, j] == bool(d.accepting[d.run(docs[j])]), (p, j)


# --------------------------------------------------------------------------
# Backends bit-identical on the bundled PROSITE bank
# --------------------------------------------------------------------------


def test_backends_bit_identical_on_bundled_bank():
    bank = load_bank()
    docs = [synthetic_protein(48, seed=i) for i in range(3)]
    plan = ScanPlan(mode="auto", chunking=ChunkPolicy(n_chunks=4))
    results = {}
    mappings = {}
    for backend in ("reference", "xla", "pallas"):
        sc = Scanner.compile(bank, plan.with_(backend=backend))
        results[backend] = sc.scan(docs).hits
        mappings[backend] = sc.mapping(docs[0])
    # mixed modes were actually exercised under the default budget
    sc = Scanner.compile(bank, plan)
    assert {"sfa", "enumeration"} <= set(sc.pattern_modes.values())
    assert np.array_equal(results["reference"], results["xla"])
    assert np.array_equal(results["xla"], results["pallas"])
    assert np.array_equal(mappings["reference"], mappings["xla"])
    assert np.array_equal(mappings["xla"], mappings["pallas"])


def test_shard_map_distribution_matches_local():
    k = 6
    dfas = [random_dfa(4 + i, k, seed=50 + i) for i in range(3)]
    docs = _random_docs(5, 4, 32, k)
    plan = ScanPlan(mode="auto", sfa_state_budget=10_000,
                    chunking=ChunkPolicy(n_chunks=4))
    local = Scanner.compile(dfas, plan).scan(docs).hits
    dist = Scanner.compile(
        dfas, plan.with_(distribution="shard_map",
                         mesh=make_mesh((1,), ("data",)))
    ).scan(docs).hits
    assert np.array_equal(local, dist)


def test_bucketed_plan_matches_unbucketed():
    k = 6
    dfas = [random_dfa(n, k, seed=60 + n) for n in (2, 3, 9, 17, 5)]
    docs = _random_docs(6, 3, 40, k)
    plan = ScanPlan(mode="enumeration", chunking=ChunkPolicy(n_chunks=4))
    plain = Scanner.compile(dfas, plan).scan(docs).hits
    bucketed = Scanner.compile(dfas, plan.with_(
        chunking=ChunkPolicy(n_chunks=4, bucket=True, bucket_edges=(4, 8, 16))
    )).scan(docs).hits
    assert np.array_equal(plain, bucketed)


# --------------------------------------------------------------------------
# stream() == scan() (property: arbitrary piece splits, every backend)
# --------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=200),
    sizes=st.lists(st.integers(min_value=1, max_value=57),
                   min_size=1, max_size=8),
    backend=st.sampled_from(["xla", "pallas", "reference"]),
)
def test_stream_equals_scan_on_concatenation(seed, sizes, backend):
    k = 5
    dfas = [random_dfa(3 + i, k, seed=seed * 3 + i) for i in range(2)]
    plan = ScanPlan(mode="auto", sfa_state_budget=10_000, backend=backend,
                    chunking=ChunkPolicy(n_chunks=2, block_len=8))
    sc = Scanner.compile(dfas, plan)
    # corpus >= 8x the (n_chunks * block_len) super-block, plus a ragged tail
    rng = np.random.default_rng(seed)
    total = 8 * (2 * 8) + int(rng.integers(0, 23))  # 8 super-blocks + tail
    corpus = rng.integers(0, k, size=total).astype(np.int32)
    # split the corpus into the drawn piece sizes (cycled to cover it all)
    pieces, lo, i = [], 0, 0
    while lo < total:
        hi = min(total, lo + sizes[i % len(sizes)])
        pieces.append(corpus[lo:hi])
        lo, i = hi, i + 1
    res = sc.stream(pieces)
    assert res.n_symbols == total
    assert np.array_equal(res.mapping, sc.mapping(corpus))
    assert np.array_equal(res.accepted, sc.scan([corpus]).hits[:, 0])


def test_stream_session_push_api_and_reuse_errors():
    sc = Scanner.compile("R-G-D", ScanPlan(
        chunking=ChunkPolicy(n_chunks=2, block_len=8)))
    text = synthetic_protein(200, seed=0) + "RGD"
    sess = sc.open_stream()
    for i in range(0, len(text), 31):
        sess.feed(text[i: i + 31])
    res = sess.finish()
    assert res.accepts is True
    assert res.single
    with pytest.raises(RuntimeError):
        sess.feed("AAA")
    with pytest.raises(RuntimeError):
        sess.finish()


def test_stream_matches_scan_on_long_corpus():
    """Acceptance: corpus >= 8x the chunk-block size, block-parallel path hot."""
    plan = ScanPlan(mode="auto", chunking=ChunkPolicy(n_chunks=4, block_len=16))
    sc = Scanner.compile(["PS00016", "PS00001"], plan)
    text = synthetic_protein(4 * 16 * 11 + 7, seed=3)   # 11 full super-blocks
    res = sc.stream(text[i: i + 100] for i in range(0, len(text), 100))
    assert np.array_equal(res.accepted, sc.scan([text]).hits[:, 0])
    assert np.array_equal(res.mapping, sc.mapping(text))


# --------------------------------------------------------------------------
# Legacy entry points: removed after the PR-2 deprecation window; executors
# is the single home and it agrees with the Scanner
# --------------------------------------------------------------------------

REMOVED_LEGACY_NAMES = [
    ("repro.core.matching", "match_parallel_enumeration"),
    ("repro.core.matching", "match_parallel_sfa"),
    ("repro.core.matching", "find_matches_parallel"),
    ("repro.core.matching", "accepts_parallel"),
    ("repro.core.matching", "distributed_match_fn"),
    ("repro.core.matching", "throughput_matcher"),
    ("repro.core.multipattern", "match_bank_parallel"),
    ("repro.core.multipattern", "bank_hits"),
    ("repro.core.multipattern", "census_bank"),
    ("repro.core.multipattern", "distributed_bank_matcher"),
    ("repro.core.multipattern", "distributed_census_fn"),
]


def test_legacy_shims_are_gone():
    """The PR-2 deprecation policy ran its course: two further PRs touched
    every call site, so the shims (and the warn-once machinery) are removed.
    The engine executors remain the single home of these entry points."""
    import importlib

    import repro.core
    from repro.engine import executors as X

    for module, name in REMOVED_LEGACY_NAMES:
        assert not hasattr(importlib.import_module(module), name), \
            f"{module}.{name} should be removed"
        assert not hasattr(repro.core, name), f"repro.core.{name}"
        assert callable(getattr(X, name)), f"executors.{name} must remain"
    with pytest.raises(ImportError):
        importlib.import_module("repro.engine.deprecation")


def test_executors_match_scanner():
    """The executors' free functions agree with the Scanner facade (the
    identity half of the old shim test, now shim-free)."""
    from repro.core.multipattern import PatternBank
    from repro.engine import executors as X

    k = 6
    dfas = [random_dfa(n, k, seed=70 + n) for n in (3, 5, 4)]
    bank = PatternBank.from_dfas(dfas)
    tables, accepting, starts = bank.device_arrays()
    rng = np.random.default_rng(7)
    syms = rng.integers(0, k, size=64).astype(np.int32)
    corpus = rng.integers(0, k, size=(4, 32)).astype(np.int32)
    d0 = dfas[0]
    sfa0 = construct_sfa(d0)
    mesh = make_mesh((1, 1), ("data", "model"))

    maps_enum = np.asarray(X.match_parallel_enumeration(
        jnp.asarray(d0.table), jnp.asarray(syms), 4))
    maps_sfa = np.asarray(X.match_parallel_sfa(
        jnp.asarray(sfa0.delta), jnp.asarray(sfa0.mappings),
        jnp.asarray(syms), 4))
    assert np.array_equal(maps_enum, maps_sfa)
    assert int(maps_sfa[d0.start]) == d0.run(syms)

    bank_maps = np.asarray(X.match_bank_parallel(tables, jnp.asarray(syms), 4))
    dist_maps = np.asarray(X.distributed_bank_matcher(mesh)(
        tables, jnp.asarray(syms), 4))
    assert np.array_equal(bank_maps, dist_maps)

    sc = Scanner.compile(dfas, ScanPlan(mode="enumeration",
                                        chunking=ChunkPolicy(n_chunks=4)))
    hits = np.asarray(X.bank_hits(tables, accepting, starts,
                                  jnp.asarray(corpus), 4))
    counts = np.asarray(X.census_bank(tables, accepting, starts,
                                      jnp.asarray(corpus), 4))
    dist_counts = np.asarray(X.distributed_census_fn(mesh, n_chunks=4)(
        tables, accepting, starts, jnp.asarray(corpus)))
    assert np.array_equal(hits, sc.scan(corpus).hits)
    assert np.array_equal(counts, sc.census(corpus))
    assert np.array_equal(dist_counts, sc.census(corpus))


# --------------------------------------------------------------------------
# kernels/ops block kwarg (satellite): blocked == unblocked
# --------------------------------------------------------------------------


@pytest.mark.parametrize("block_b", [1, 3, 8, 64])
def test_match_chunks_block_b_invariant(block_b):
    from repro.kernels import ops

    d = random_dfa(6, 5, seed=9)
    chunks = jnp.asarray(
        np.random.default_rng(9).integers(0, 5, size=(5, 12)), dtype=jnp.int32)
    want = ops.match_chunks(jnp.asarray(d.table), chunks, block_b=1,
                            interpret=True)
    got = ops.match_chunks(jnp.asarray(d.table), chunks, block_b=block_b,
                           interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_b", [1, 2, 8])
def test_match_bank_chunks_block_b_invariant(block_b):
    from repro.core.multipattern import PatternBank
    from repro.kernels import ops

    bank = PatternBank.from_dfas(
        [random_dfa(n, 4, seed=80 + n) for n in (3, 7)])
    chunks = jnp.asarray(
        np.random.default_rng(8).integers(0, 4, size=(3, 10)), dtype=jnp.int32)
    tables = jnp.asarray(bank.tables)
    want = ops.match_bank_chunks(tables, chunks, block_b=1, interpret=True)
    got = ops.match_bank_chunks(tables, chunks, block_b=block_b,
                                interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(want))
