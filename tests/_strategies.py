"""Property-testing shim: real ``hypothesis`` when installed, a seeded-random
fallback otherwise.

The test suite's property tests (`test_monoid`, `test_matching`, ...) are
written against the hypothesis API (``@given``/``@settings``/``st.*``).
Hypothesis is a great shrinker but it is an *optional* dev dependency
(see requirements-dev.txt); the offline CI container doesn't carry it, and a
missing import must not take six test modules down with it. So test modules
import ``given, settings, st`` from here:

* with hypothesis installed, these are hypothesis' own objects — full
  shrinking, example databases, the works;
* without it, ``@given`` runs the test body over deterministic seeded-random
  examples (seed derived from the test name + example index, so failures
  reproduce across runs and machines) for the strategies the suite actually
  uses: ``integers``, ``floats``, ``sampled_from``, ``lists``, ``text``,
  ``booleans``, ``tuples``, ``one_of``, ``dictionaries``.

The fallback deliberately does NOT do general shrinking — it exists to keep
the properties exercised offline, not to replace hypothesis. The one
exception is ``sampled_from``, whose failing draws re-try earlier elements
of the sample (hypothesis' own ordering convention: put simpler elements
first) so a falsifying example reports the simplest sampled value that
still fails — cheap, and it makes mode/backend matrix failures readable.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import random as _random

    _DEFAULT_EXAMPLES = 20
    _MAX_FALLBACK_EXAMPLES = 50  # cap: no shrinker, so bulk examples buy little

    class _Strategy:
        def __init__(self, draw, shrink=None):
            self._draw = draw
            self._shrink = shrink

        def draw(self, rng: _random.Random):
            return self._draw(rng)

        def shrink(self, value):
            """Candidate simpler replacements, simplest first (default none)."""
            return self._shrink(value) if self._shrink is not None else []

    class _StModule:
        """The subset of ``hypothesis.strategies`` this suite uses."""

        @staticmethod
        def integers(min_value=0, max_value=1 << 32):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, *, allow_nan=False,
                   allow_infinity=False, **_ignored):
            """Bounded uniform floats. The fallback never produces NaN/inf
            (pass explicit bounds — the suite's float properties all do)."""
            lo = 0.0 if min_value is None else float(min_value)
            hi = 1.0 if max_value is None else float(max_value)
            if not lo <= hi:
                raise ValueError(f"floats needs min_value <= max_value, "
                                 f"got [{lo}, {hi}]")
            return _Strategy(lambda rng: rng.uniform(lo, hi))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)

            def shrink(value):
                try:
                    idx = elements.index(value)
                except ValueError:
                    return []
                return elements[:idx]

            return _Strategy(lambda rng: rng.choice(elements), shrink)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.getrandbits(1)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                size = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(size)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

        @staticmethod
        def one_of(*strategies):
            choices = list(strategies)
            return _Strategy(lambda rng: rng.choice(choices).draw(rng))

        @staticmethod
        def dictionaries(keys, values, min_size=0, max_size=10):
            """Dict strategy: unique drawn keys -> drawn values (the subset
            of hypothesis semantics the construction-cache tests use)."""

            def draw(rng):
                size = rng.randint(min_size, max_size)
                out = {}
                attempts = 0
                while len(out) < size and attempts < size * 10 + 10:
                    out[keys.draw(rng)] = values.draw(rng)
                    attempts += 1
                return out

            return _Strategy(draw)

        @staticmethod
        def text(alphabet="abcdefghijklmnopqrstuvwxyz", min_size=0, max_size=20):
            chars = list(alphabet)

            def draw(rng):
                size = rng.randint(min_size, max_size)
                return "".join(rng.choice(chars) for _ in range(size))

            return _Strategy(draw)

    st = _StModule()

    def _shrink_failing(fn, strategies, kwargs):
        """Greedy per-argument shrink: swap in each strategy's simpler
        candidates (``sampled_from`` offers earlier sample elements) while
        the test keeps failing. Terminates because every accepted candidate
        strictly precedes the current value in its sample order."""
        improved = True
        while improved:
            improved = False
            for k, s in strategies.items():
                for cand in s.shrink(kwargs[k]):
                    trial = {**kwargs, k: cand}
                    try:
                        fn(**trial)
                    except Exception:
                        kwargs = trial
                        improved = True
                        break
                if improved:
                    break
        return kwargs

    def given(**strategies):
        def decorate(fn):
            def wrapper():
                n = min(
                    getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES),
                    _MAX_FALLBACK_EXAMPLES,
                )
                for i in range(n):
                    rng = _random.Random(f"{fn.__module__}.{fn.__name__}:{i}")
                    kwargs = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(**kwargs)
                    except Exception as e:
                        kwargs = _shrink_failing(fn, strategies, kwargs)
                        raise AssertionError(
                            f"falsifying example ({i + 1}/{n}): "
                            f"{fn.__name__}(**{kwargs!r})"
                        ) from e

            # Copy identity by hand: functools.wraps would set __wrapped__,
            # and pytest would then see the original signature and demand
            # fixtures named after the strategy kwargs.
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return decorate

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn

        return decorate
