"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""

import jax.numpy as jnp
import numpy as np
import pytest
from _strategies import given, settings, st

from repro.core.fingerprint import BarrettConstants
from repro.kernels import ops, ref

CONSTS = BarrettConstants.create()
RNG = np.random.default_rng(0)


@pytest.mark.parametrize("B,W", [(1, 1), (3, 2), (8, 5), (17, 9), (64, 32), (5, 47)])
def test_fingerprint_kernel_matches_ref(B, W):
    words = jnp.asarray(
        RNG.integers(0, 1 << 32, size=(B, W), dtype=np.uint64).astype(np.uint32)
    )
    got = ops.fingerprint(words, CONSTS, block_b=8, interpret=True)
    want = ref.fingerprint_ref(words, CONSTS)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_b", [1, 4, 16, 256])
def test_fingerprint_kernel_block_sizes(block_b):
    words = jnp.asarray(
        RNG.integers(0, 1 << 32, size=(13, 7), dtype=np.uint64).astype(np.uint32)
    )
    got = ops.fingerprint(words, CONSTS, block_b=block_b, interpret=True)
    want = ref.fingerprint_ref(words, CONSTS)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,n", [(1, 4), (3, 16), (5, 50), (2, 130), (1, 257)])
def test_compose_kernel_matches_gather(B, n):
    f = jnp.asarray(RNG.integers(0, n, size=(B, n)).astype(np.int32))
    g = jnp.asarray(RNG.integers(0, n, size=(B, n)).astype(np.int32))
    got = ops.compose(f, g, block_q=32, interpret=True)
    want = ref.compose_ref(f, g)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=24),
    seed=st.integers(min_value=0, max_value=999),
)
def test_compose_kernel_is_composition(n, seed):
    rng = np.random.default_rng(seed)
    f = rng.integers(0, n, size=(2, n)).astype(np.int32)
    g = rng.integers(0, n, size=(2, n)).astype(np.int32)
    got = np.asarray(ops.compose(jnp.asarray(f), jnp.asarray(g), interpret=True))
    for b in range(2):
        for q in range(n):
            assert got[b, q] == g[b, f[b, q]]


@pytest.mark.parametrize("n,k,B,L", [(3, 4, 2, 5), (6, 5, 3, 8), (16, 20, 2, 12), (31, 7, 1, 9)])
def test_match_kernel_matches_ref(n, k, B, L):
    table = jnp.asarray(RNG.integers(0, n, size=(n, k)).astype(np.int32))
    chunks = jnp.asarray(RNG.integers(0, k, size=(B, L)).astype(np.int32))
    got = ops.match_chunks(table, chunks, interpret=True)
    want = ref.match_chunks_ref(table, chunks)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_match_kernel_composes_with_compose_kernel():
    """match(chunk1+chunk2) == compose(match(chunk1), match(chunk2)) — the
    paper's chunk-combine property, on the kernels themselves."""
    n, k, L = 8, 5, 6
    table = jnp.asarray(RNG.integers(0, n, size=(n, k)).astype(np.int32))
    c1 = RNG.integers(0, k, size=(1, L)).astype(np.int32)
    c2 = RNG.integers(0, k, size=(1, L)).astype(np.int32)
    m1 = ops.match_chunks(table, jnp.asarray(c1), interpret=True)
    m2 = ops.match_chunks(table, jnp.asarray(c2), interpret=True)
    whole = ops.match_chunks(
        table, jnp.asarray(np.concatenate([c1, c2], axis=1)), interpret=True
    )
    composed = ops.compose(m1, m2, interpret=True)
    assert np.array_equal(np.asarray(whole), np.asarray(composed))
