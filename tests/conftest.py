import os
import sys

# Tests must see exactly ONE device (the dry-run sets 512 in its own
# process); also keep XLA from grabbing every core on shared CI boxes.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
