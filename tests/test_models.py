"""Per-architecture smoke tests (reduced configs) + decode consistency.

Every assigned architecture instantiates a REDUCED config of its family and
runs one forward + one train step on CPU, asserting output shapes and
finiteness; decode-with-cache must match the full-sequence forward.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import SHAPES, RunConfig, OptimizerConfig, HOST_MESH, reduced
from repro.configs import ARCH_IDS, get_config
from repro.models.model import build_model
from repro.optim import build_optimizer
from repro.sharding.rules import Dist
from repro.train.steps import make_train_step

LM_ARCHS = [a for a in ARCH_IDS if a != "paper_sfa"]


def _reduced_cfg(arch: str):
    cfg = get_config(arch)
    if arch == "mamba2_370m":
        return reduced(cfg, ssm_heads=4, ssm_head_dim=32, d_model=64, ssm_state=16)
    if arch == "recurrentgemma_9b":
        return reduced(cfg, n_layers=5, rglru_width=64, head_dim=16)
    return reduced(cfg)


def _batch_for(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(1, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16
        )
    if cfg.num_prefix_embeds:
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_prefix_embeds, cfg.d_model)), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = _reduced_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dist = Dist()
    B, S = 2, 16
    batch = _batch_for(cfg, B, S)

    kw = {k: batch[k] for k in ("frames", "prefix_embeds") if k in batch}
    logits, _, aux = model.forward(params, batch["tokens"], dist, mode="train", **kw)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"

    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], mesh=HOST_MESH,
                    optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1),
                    micro_batches=2)
    step_fn, opt = make_train_step(model, run, dist)
    opt_state = opt.init(params, model.param_specs())
    # step 1: past warmup, so lr > 0 and params must move
    params2, opt2, metrics = jax.jit(step_fn)(
        params, opt_state, jnp.asarray(1, jnp.int32), batch
    )
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert moved, f"{arch}: train step did not update params"


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_arch_decode_matches_forward(arch):
    cfg = _reduced_cfg(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    dist = Dist()
    B, S = 2, 12
    if cfg.family == "ssm":
        S = 16  # chunk divisibility for prefill
    batch = _batch_for(cfg, B, S + 4, seed=3)
    toks = batch["tokens"]
    kw = {k: batch[k] for k in ("frames", "prefix_embeds") if k in batch}

    full, _, _ = model.forward(params, toks[:, : S + 1], dist, mode="train", **kw)
    cache = model.init_cache(B, S + 8)
    _, cache2, _ = model.forward(params, toks[:, :S], dist, mode="prefill",
                                 cache=cache, **kw)
    dec, _, _ = model.forward(params, toks[:, S : S + 1], dist, mode="decode",
                              cache=cache2, cache_pos=jnp.asarray(S, jnp.int32))
    a = np.asarray(full[:, S], np.float32)
    b = np.asarray(dec[:, 0], np.float32)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-6)
    # rglru's exp-gated recurrence amplifies bf16 rounding (f32 verified
    # exact to 2e-7 in isolation); other families sit well under 3e-2.
    tol = 1e-1 if cfg.family == "hybrid" else 3e-2
    assert err < tol, f"{arch}: decode diverges from forward ({err:.3e})"


def test_param_counts_match_configs():
    """Declared ParamSpec trees roughly agree with the analytic count."""
    for arch in LM_ARCHS:
        cfg = get_config(arch)
        model = build_model(cfg)
        declared = model.n_params()
        analytic = cfg.param_count()
        ratio = declared / analytic
        assert 0.85 < ratio < 1.15, (arch, declared, analytic)
