"""Multi-pattern bank matching == per-pattern sequential matching.

Differential tests: every batched/banked/distributed path must agree exactly
with the plain per-pattern DFA loop, including banks that mix very different
pattern sizes (padded-table edge cases)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _strategies import given, settings, st

from repro.compat import make_mesh
from repro.core import multipattern as mp
from repro.engine import executors as X
from repro.core.dfa import random_dfa
from repro.core.matching import chunk_mapping_enumeration
from repro.core.prosite import load_bank, synthetic_protein
from repro.kernels import ops


def _random_bank(seed: int, sizes=(2, 5, 11, 3, 7), k: int = 6):
    dfas = [random_dfa(n, k, seed=seed * 31 + i) for i, n in enumerate(sizes)]
    return mp.PatternBank.from_dfas(dfas)


# --------------------------------------------------------------------------
# PatternBank construction / padding
# --------------------------------------------------------------------------


def test_bank_pads_with_self_loops():
    bank = _random_bank(0, sizes=(2, 9))
    assert bank.n_max == 9
    # pattern 0 padded: rows 2..8 must be self-loops on every symbol
    for j in range(2, 9):
        assert (bank.tables[0, j] == j).all()
    assert not bank.accepting[0, 2:].any()


def test_bank_dfa_roundtrip():
    bank = _random_bank(1, sizes=(4, 8, 3))
    for p, n in enumerate((4, 8, 3)):
        d = bank.dfa(p)
        assert d.n_states == n
        orig = random_dfa(n, 6, seed=1 * 31 + p)
        assert np.array_equal(d.table, orig.table)
        assert np.array_equal(d.accepting, orig.accepting)


def test_bank_rejects_mixed_alphabets():
    a = random_dfa(3, 4, seed=0)
    b = random_dfa(3, 5, seed=0)
    with pytest.raises(ValueError):
        mp.PatternBank.from_dfas([a, b])
    with pytest.raises(ValueError):
        mp.PatternBank.from_dfas([])


# --------------------------------------------------------------------------
# match_bank_parallel / census_bank vs the sequential per-pattern loop
# --------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=500),
       n_chunks=st.sampled_from([1, 2, 4, 8]))
def test_match_bank_equals_sequential_random(seed, n_chunks):
    bank = _random_bank(seed)
    tables, _, _ = bank.device_arrays()
    rng = np.random.default_rng(seed)
    syms = rng.integers(0, bank.n_symbols, size=64).astype(np.int32)
    maps = X.match_bank_parallel(tables, jnp.asarray(syms), n_chunks)
    for p in range(bank.n_patterns):
        d = bank.dfa(p)
        assert int(maps[p, d.start]) == d.run(syms), (p, bank.ids[p])


def test_match_bank_padded_entries_stay_identity():
    """Mapping rows beyond a pattern's true size must be identity (the
    self-loop padding invariant composition relies on)."""
    bank = _random_bank(7, sizes=(3, 12))
    tables, _, _ = bank.device_arrays()
    rng = np.random.default_rng(7)
    syms = rng.integers(0, bank.n_symbols, size=48).astype(np.int32)
    maps = np.asarray(X.match_bank_parallel(tables, jnp.asarray(syms), 4))
    n0 = int(bank.n_states[0])
    assert np.array_equal(maps[0, n0:], np.arange(n0, bank.n_max))


def test_census_bank_matches_sequential_on_prosite():
    """>= 16 real PROSITE signatures in one bank, exact census agreement."""
    bank = load_bank()
    assert bank.n_patterns >= 16
    tables, accepting, starts = bank.device_arrays()
    corpus = np.stack(
        [bank.encode(synthetic_protein(96, seed=i)) for i in range(12)]
    )
    counts = X.census_bank(tables, accepting, starts, jnp.asarray(corpus), 8)
    ref = mp.census_sequential(bank, corpus)
    assert np.array_equal(np.asarray(counts), ref)


def test_bank_hits_shape_and_dtype():
    bank = _random_bank(3)
    tables, accepting, starts = bank.device_arrays()
    corpus = jnp.asarray(
        np.random.default_rng(3).integers(0, bank.n_symbols, size=(5, 32)),
        dtype=jnp.int32,
    )
    hits = X.bank_hits(tables, accepting, starts, corpus, 4)
    assert hits.shape == (bank.n_patterns, 5)
    assert hits.dtype == jnp.bool_


# --------------------------------------------------------------------------
# Size-bucketed banks
# --------------------------------------------------------------------------


def test_bucket_by_size_partitions_and_agrees():
    sizes = (2, 3, 30, 9, 17, 5)
    dfas = [random_dfa(n, 6, seed=100 + i) for i, n in enumerate(sizes)]
    ids = [f"p{i}" for i in range(len(dfas))]
    buckets = mp.bucket_by_size(dfas, ids, edges=(8, 32))
    assert sorted(i for b in buckets for i in b.ids) == sorted(ids)
    assert all(b.n_max <= e for b, e in zip(buckets, (8, 32)))

    corpus = np.random.default_rng(9).integers(0, 6, size=(6, 40)).astype(np.int32)
    whole = mp.PatternBank.from_dfas(dfas, ids)
    ref = dict(zip(whole.ids, mp.census_sequential(whole, corpus)))
    for b in buckets:
        t, a, s = b.device_arrays()
        counts = np.asarray(X.census_bank(t, a, s, jnp.asarray(corpus), 4))
        for i, pid in enumerate(b.ids):
            assert counts[i] == ref[pid], pid


def test_bucket_by_size_rejects_oversized():
    with pytest.raises(ValueError):
        mp.bucket_by_size([random_dfa(50, 4, seed=0)], edges=(8, 16))


# --------------------------------------------------------------------------
# Pallas multi-automaton kernel vs the vmapped oracle
# --------------------------------------------------------------------------


@pytest.mark.parametrize("sizes,k,B,L", [
    ((3, 5), 4, 2, 6),
    ((2, 11, 7), 6, 3, 8),
    ((4, 4, 4, 4), 5, 1, 12),
])
def test_match_bank_kernel_matches_oracle(sizes, k, B, L):
    bank = _random_bank(42, sizes=sizes, k=k)
    tables, _, _ = bank.device_arrays()
    chunks = jnp.asarray(
        np.random.default_rng(42).integers(0, k, size=(B, L)), dtype=jnp.int32
    )
    got = ops.match_bank_chunks(tables, chunks, interpret=True)
    want = jax.vmap(
        lambda t: jax.vmap(lambda c: chunk_mapping_enumeration(t, c))(chunks)
    )(tables)
    assert got.shape == (bank.n_patterns, B, bank.n_max)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# Distributed (patterns x chunks over the mesh; 1-device degenerate mesh)
# --------------------------------------------------------------------------


def test_distributed_bank_matcher_single_device():
    bank = _random_bank(11, sizes=(3, 6, 9, 4))
    tables, _, _ = bank.device_arrays()
    rng = np.random.default_rng(11)
    syms = jnp.asarray(rng.integers(0, bank.n_symbols, size=128).astype(np.int32))
    mesh = make_mesh((1, 1), ("data", "model"))
    matcher = X.distributed_bank_matcher(mesh)
    got = matcher(tables, syms, sub_chunks=8)
    want = X.match_bank_parallel(tables, syms, 8)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_distributed_census_single_device():
    bank = _random_bank(13)
    tables, accepting, starts = bank.device_arrays()
    corpus = jnp.asarray(
        np.random.default_rng(13).integers(0, bank.n_symbols, size=(4, 32)),
        dtype=jnp.int32,
    )
    mesh = make_mesh((1, 1), ("data", "model"))
    census = X.distributed_census_fn(mesh, n_chunks=4)
    got = census(tables, accepting, starts, corpus)
    want = X.census_bank(tables, accepting, starts, corpus, 4)
    assert np.array_equal(np.asarray(got), np.asarray(want))
