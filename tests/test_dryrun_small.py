"""Scaled-down dry-run in a subprocess (8 host devices, 4×2 mesh).

The production 512-device dry-run runs via ``repro.launch.dryrun`` (results
checked into results/dryrun and reported in EXPERIMENTS.md); this test proves
the same lowering machinery end-to-end at CI scale — reduced configs, real
mesh, real compile, collective extraction — without touching this process's
single-device view.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import make_mesh
    from repro.config import ShapeConfig, RunConfig, OptimizerConfig, MeshConfig, reduced
    from repro.configs import get_config
    from repro.models import base as mbase
    from repro.models.model import build_model, input_specs
    from repro.sharding.rules import Dist, Rules
    from repro.train.steps import make_train_step
    from repro.analysis.hlo import analyze_module

    arch = sys.argv[1]
    mesh = make_mesh((4, 2), ("data", "model"))
    extra = {}
    if arch == "mamba2_370m":   # keep ssm dims consistent: H*P == 2*d_model
        extra = dict(ssm_heads=4, ssm_head_dim=32, ssm_state=16)
    cfg = reduced(get_config(arch), d_model=64, n_heads=4, n_kv_heads=2,
                  head_dim=16, vocab_size=256, **extra)
    rules = Rules(mesh_axes=("data", "model")).with_overrides(cfg.sharding_overrides)
    dist = Dist.for_mesh(mesh, rules)
    model = build_model(cfg)
    shape = ShapeConfig("t", 64, 8, "train")
    run = RunConfig(model=cfg, shape=shape,
                    mesh=MeshConfig((4, 2), ("data", "model")), micro_batches=2)
    step_fn, opt = make_train_step(model, run, dist)
    params = mbase.shape_structs(model.param_specs(), rules, mesh)
    opt_state = mbase.shape_structs(opt.state_specs(model.param_specs()), rules, mesh)
    step = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    inputs = input_specs(cfg, shape, mesh, rules)
    with mesh:
        lowered = jax.jit(step_fn).lower(params, opt_state, step, inputs)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    stats = analyze_module(compiled.as_text(), 8)
    print(json.dumps({
        "ok": True,
        "arg_bytes": mem.argument_size_in_bytes,
        "flops": stats.flops,
        "coll_count": stats.coll_count,
        "coll_bytes": stats.coll_operand_bytes,
    }))
""" % SRC)


@pytest.mark.parametrize("arch", ["qwen3_8b", "granite_moe_1b", "mamba2_370m",
                                  "recurrentgemma_9b", "whisper_base"])
def test_small_mesh_dryrun(arch):
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT, arch],
        capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"]
    assert out["flops"] > 0
    assert out["coll_count"] > 0, "sharded train step must communicate"


def test_production_dryrun_results_exist_and_pass():
    """The 512-device sweep artifacts: every runnable cell compiled."""
    results = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    cells = list(results.glob("*__pod.json"))
    if not cells:
        pytest.skip("production dry-run results not generated yet")
    bad = []
    for f in cells:
        d = json.loads(f.read_text())
        if d.get("status") not in ("ok", "skipped"):
            bad.append(f.name)
    assert not bad, bad
