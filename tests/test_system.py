"""End-to-end behaviour tests for the paper's system.

The full pipeline: PROSITE pattern -> minimal DFA -> SFA (all engines) ->
chunk-parallel matching over a synthetic protein database -> identical
answers to the sequential matcher; plus the LM-substrate integration (a tiny
protein LM trains on SFA-labeled data).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import HOST_MESH, ModelConfig, OptimizerConfig, RunConfig, ShapeConfig
from repro.core.prosite import PROSITE_SAMPLES, compile_prosite, synthetic_protein
from repro.core.sfa import construct_sfa
from repro.engine import executors as X
from repro.data import DataConfig, make_pipeline
from repro.models.model import build_model
from repro.sharding.rules import Dist
from repro.train.trainer import Trainer


def test_prosite_to_parallel_scan_end_to_end():
    """The ScanProsite workload (paper §IV): pattern -> SFA -> parallel scan."""
    dfa = compile_prosite(PROSITE_SAMPLES["PS00016"])  # R-G-D
    sfa = construct_sfa(dfa, engine="vectorized")
    assert sfa.n_states >= dfa.n_states

    rng = np.random.default_rng(0)
    hits = 0
    for i in range(10):
        text = synthetic_protein(997, seed=i)  # deliberately not chunk-aligned
        if i % 2:
            pos = int(rng.integers(0, 990))
            text = text[:pos] + "RGD" + text[pos + 3:]
        seq = X.accepts_parallel(dfa, text, n_chunks=8, sfa=sfa)
        enm = X.accepts_parallel(dfa, text, n_chunks=8)
        ref = dfa.accepts(text)
        assert seq == enm == ref, i
        hits += int(ref)
    assert hits >= 5


def test_sfa_construction_engines_cross_check_on_prosite():
    dfa = compile_prosite(PROSITE_SAMPLES["PS00005"])
    a = construct_sfa(dfa, engine="sequential")
    b = construct_sfa(dfa, engine="vectorized")
    assert a.n_states == b.n_states
    assert np.array_equal(a.delta, b.delta)


def test_match_localization_matches_python_re():
    import re as pyre

    dfa = compile_prosite("R-G-D")
    text = synthetic_protein(256, seed=3)
    text = text[:40] + "RGD" + text[43:120] + "RGD" + text[123:]
    syms = jnp.asarray(dfa.encode(text))
    flags = np.asarray(
        X.find_matches_parallel(
            jnp.asarray(dfa.table), jnp.asarray(dfa.accepting), syms, dfa.start, 8
        )
    )
    first_end = int(np.argmax(flags))
    m = pyre.search("RGD", text)
    assert m is not None and first_end == m.end() - 1


def test_protein_lm_trains_on_sfa_labeled_data(tmp_path):
    """The paper's technique as a data-pipeline stage feeding LM training."""
    cfg = ModelConfig(
        name="protein_lm", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=21, head_dim=16, remat="none",
        tie_embeddings=True,
    )
    run = RunConfig(
        model=cfg, shape=ShapeConfig("t", 48, 8, "train"), mesh=HOST_MESH,
        optimizer=OptimizerConfig(lr=3e-3, warmup_steps=2, schedule="constant"),
        checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=1000,
        async_checkpoint=False,
    )
    data = make_pipeline(
        DataConfig(vocab_size=21, seq_len=48, global_batch=8, seed=3,
                   source="protein"),
        prefetch=False,
    )
    tr = Trainer(model=build_model(cfg), run=run, dist=Dist(), data=data,
                 log_every=5)
    out = tr.fit(20)
    losses = [m["loss"] for m in out["log"]]
    assert losses[-1] < losses[0]
