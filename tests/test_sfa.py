"""SFA construction: the paper's worked example, engine equivalence, the
Fig. 4 ablation toggles, and the simultaneity semantics."""

import numpy as np
import pytest
from _strategies import given, settings, st

from repro.core.dfa import example_fa, random_dfa
from repro.core.prosite import compile_prosite, synthetic_protein
from repro.core.sfa import (
    SFA,
    StateBlowup,
    construct_sfa,
    construct_sfa_sequential,
    construct_sfa_vectorized,
)


def test_paper_example_six_states():
    """Paper Fig. 2: the 'contains RG' FA yields exactly 6 SFA states."""
    sfa = construct_sfa(example_fa())
    assert sfa.n_states == 6
    # start state is the identity mapping
    assert np.array_equal(sfa.mappings[0], np.arange(3))


def test_engines_bit_identical_on_example():
    dfa = example_fa()
    a = construct_sfa(dfa, engine="sequential")
    b = construct_sfa(dfa, engine="vectorized")
    c = construct_sfa(dfa, engine="jax", max_states=64, tile=4)
    for x in (b, c):
        assert np.array_equal(a.mappings, x.mappings)
        assert np.array_equal(a.delta, x.delta)
        assert np.array_equal(a.fingerprints, x.fingerprints)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=6),
    k=st.integers(min_value=2, max_value=6),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_engines_agree_on_random_dfas(n, k, seed):
    d = random_dfa(n, k, seed=seed)
    a = construct_sfa(d, engine="sequential")
    b = construct_sfa(d, engine="vectorized")
    assert np.array_equal(a.mappings, b.mappings)
    assert np.array_equal(a.delta, b.delta)


def test_ablation_toggles_identical_results():
    """Fingerprints/hashing change speed, never the SFA (paper §III-A)."""
    d = random_dfa(5, 5, seed=11)
    base = construct_sfa_sequential(d, use_fingerprints=False, use_hashing=False)
    f = construct_sfa_sequential(d, use_fingerprints=True, use_hashing=False)
    fh = construct_sfa_sequential(d, use_fingerprints=True, use_hashing=True)
    assert np.array_equal(base.mappings, f.mappings)
    assert np.array_equal(base.mappings, fh.mappings)
    assert np.array_equal(base.delta, fh.delta)
    # and hashing actually reduces comparisons
    assert fh.stats.exact_compares < base.stats.exact_compares


def test_hashing_requires_fingerprints():
    with pytest.raises(ValueError):
        construct_sfa_sequential(example_fa(), use_fingerprints=False, use_hashing=True)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=500))
def test_simultaneity_semantics(seed):
    """The SFA mapping of a string == running the DFA from every state."""
    d = random_dfa(4, 5, seed=seed)
    sfa = construct_sfa(d)
    rng = np.random.default_rng(seed)
    syms = rng.integers(0, 5, size=50).astype(np.int32)
    mapping = sfa.mapping_of(syms)
    for q in range(d.n_states):
        assert mapping[q] == d.run(syms, state=q)


def test_accepting_states_match_paper_definition():
    d = example_fa()
    sfa = construct_sfa(d)
    acc = sfa.accepting_states()
    for i in range(sfa.n_states):
        assert acc[i] == d.accepting[sfa.mappings[i, d.start]]


def test_blowup_cap():
    d = random_dfa(8, 8, seed=1)
    with pytest.raises(StateBlowup):
        construct_sfa(d, engine="vectorized", max_states=10)


def test_prosite_sfa_runs_like_dfa():
    d = compile_prosite("R-G-D")
    sfa = construct_sfa(d)
    text = synthetic_protein(300, seed=2) + "RGD" + synthetic_protein(10, seed=3)
    syms = d.encode(text)
    assert bool(sfa.accepting_states()[sfa.run(syms)]) == d.accepts(text) == True


def test_stats_recorded():
    s = construct_sfa(example_fa(), engine="vectorized")
    assert s.stats.candidates == 6 * 20
    assert s.stats.wall_time_s > 0
