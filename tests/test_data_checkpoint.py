"""Data pipeline determinism/restore + checkpoint manager semantics."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, save_tree
from repro.data import DataConfig, make_pipeline
from repro.data.protein import ProteinCorpus, protein_batch


def _cfg(**kw):
    base = dict(vocab_size=256, seq_len=32, global_batch=4, seed=7)
    base.update(kw)
    return DataConfig(**base)


def test_determinism_same_step_same_batch():
    a = make_pipeline(_cfg(), prefetch=False)
    b = make_pipeline(_cfg(), prefetch=False)
    for _ in range(3):
        ba, bb = next(a), next(b)
        assert np.array_equal(ba["tokens"], bb["tokens"])
        assert np.array_equal(ba["labels"], bb["labels"])


def test_sharded_rows_slice_global_batch():
    full = make_pipeline(_cfg(), prefetch=False)
    part = make_pipeline(_cfg(row_start=2, rows_local=2), prefetch=False)
    bf, bp = next(full), next(part)
    assert np.array_equal(bf["tokens"][2:4], bp["tokens"])


def test_restore_resumes_exactly():
    it = make_pipeline(_cfg(), prefetch=False)
    next(it)
    state = it.state()
    b1 = next(it)
    it2 = make_pipeline(_cfg(), prefetch=False).restore(state)
    b2 = next(it2)
    assert np.array_equal(b1["tokens"], b2["tokens"])


def test_prefetch_matches_sync():
    sync = make_pipeline(_cfg(), prefetch=False)
    pre = make_pipeline(_cfg(), prefetch=True)
    try:
        for _ in range(3):
            assert np.array_equal(next(sync)["tokens"], next(pre)["tokens"])
    finally:
        pre.stop()


def test_labels_shift_tokens():
    b = next(make_pipeline(_cfg(), prefetch=False))
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)


def test_protein_labels_agree_with_dfa():
    corpus = ProteinCorpus()
    rng = np.random.default_rng(0)
    hits = 0
    for _ in range(20):
        seq, label = corpus.sample(rng, 64)
        text = "".join("ACDEFGHIKLMNPQRSTVWY"[i] for i in seq)
        assert corpus.dfa.accepts(text) == label
        hits += int(label)
    assert hits > 0  # planting works


def test_protein_batch_format():
    cfg = _cfg(vocab_size=21, seq_len=24, source="protein")
    b = protein_batch(cfg, 0)
    assert b["tokens"].shape == (4, 24)
    assert b["motif_label"].shape == (4,)


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------


def _tree():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones(3)},
        "opt": {"m": jnp.zeros((3, 4))},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    tree = _tree()
    mgr.save(5, tree, extra={"data": {"step": 5, "seed": 7}})
    step, restored, extra = mgr.restore(tree)
    assert step == 5
    assert extra["data"]["step"] == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_keep_n_garbage_collection(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree())
    steps = sorted(int(p.name.split("_")[1]) for p in Path(tmp_path).iterdir())
    assert steps == [3, 4]


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
    mgr.save(1, _tree())
    mgr.wait()
    assert latest_step(tmp_path) == 1


def test_atomicity_no_partial_checkpoints(tmp_path):
    save_tree(tmp_path, 3, _tree())
    # a stale tmp dir from a crashed save must not be visible as a checkpoint
    (tmp_path / "step_00000009.tmp").mkdir()
    assert latest_step(tmp_path) == 3


def test_restore_with_shardings_resharding(tmp_path):
    from repro.compat import make_mesh

    mesh = make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(tmp_path, async_save=False)
    tree = _tree()
    mgr.save(1, tree)
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    step, restored, _ = mgr.restore(tree, shardings=shardings)
    leaf = jax.tree.leaves(restored)[0]
    assert isinstance(leaf, jax.Array) and leaf.sharding is not None


def test_latest_of_empty_dir(tmp_path):
    assert latest_step(tmp_path / "nope") is None
    mgr = CheckpointManager(tmp_path / "nope2")
    assert mgr.restore(_tree()) is None
