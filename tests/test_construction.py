"""The construction subsystem: batched bank closure, stores, cache, kernels.

Pins the PR's contracts:

* ``construct_bank`` is bit-identical to per-pattern ``construct_sfa`` on
  all 23 bundled PROSITE signatures, against all three single-pattern
  engines (vectorized over the full exact bank; sequential and jax under a
  shared budget, including blowup agreement);
* the content-addressed ``SFACache``: hit/miss/eviction semantics, budget-
  dependent blowup answers, and the Scanner acceptance criterion — a second
  ``Scanner.compile`` of the same patterns performs **zero** construction
  rounds, answered entirely by the cache's hit counter;
* a forced fingerprint collision inside a batch retries only the collided
  pattern (fresh polynomial) while the other patterns keep their progress;
* the pattern-axis Pallas fingerprint kernel matches the NumPy fold;
* the fixed-shape compile schedule: a repeat same-shape ``construct_bank``
  performs **zero** new jit traces/XLA compiles (answered by the process-
  wide round compile cache), and the Pallas fingerprint stage is
  bit-identical to the reference fold on all 23 bundled signatures;
* size-bucketed construction (``bucketing="size"``/``"auto"``) and the
  gather/Pallas frontier-expansion backends are bit-identical to the flat
  batched path — against the unbucketed bank, against all three sequential
  membership stores, on random size-skewed banks (property test), with
  per-pattern stats attribution intact and zero new lowerings on a repeat
  bucketed bank.
"""

import logging

import numpy as np
import pytest
from _strategies import given, settings, st

from repro.compat import make_mesh
from repro.construction import (
    BankConstructionResult,
    SFACache,
    StateBlowup,
    construct_bank,
    construct_sfa,
    construct_sfa_sequential,
    construct_sfa_vectorized,
    dfa_cache_key,
    round_compile_cache,
    round_schedule,
)
from repro.core.dfa import random_dfa
from repro.core.fingerprint import (
    BarrettConstants,
    fold_weights_u32,
    nth_poly_low,
)
from repro.core.prosite import load_bank, synthetic_protein
from repro.engine import ConstructionPolicy, ScanPlan, Scanner

FULL_BANK_CAP = 7300   # all 23 bundled signatures close below this
SHARED_BUDGET = 160    # splits the bank into closers and blowers


@pytest.fixture(scope="module")
def prosite_bank():
    return load_bank()


@pytest.fixture(scope="module")
def full_bank_result(prosite_bank):
    """One exact batched construction of the whole bundled bank."""
    res = construct_bank(prosite_bank, max_states=FULL_BANK_CAP, tile=256)
    assert not res.blown.any()
    return res


def _assert_sfa_equal(a, b, ctx):
    assert np.array_equal(a.mappings, b.mappings), ctx
    assert np.array_equal(a.delta, b.delta), ctx
    assert np.array_equal(a.fingerprints, b.fingerprints), ctx


# --------------------------------------------------------------------------
# construct_bank == per-pattern construct_sfa (all 23 signatures, 3 engines)
# --------------------------------------------------------------------------


def test_bank_bit_identical_to_vectorized_all_prosite(prosite_bank,
                                                      full_bank_result):
    """Acceptance: the batched bank equals per-pattern construction on every
    bundled signature — mappings, delta table, and fingerprints."""
    for p in range(prosite_bank.n_patterns):
        ref = construct_sfa(prosite_bank.dfa(p), engine="vectorized",
                            max_states=FULL_BANK_CAP)
        _assert_sfa_equal(full_bank_result.sfas[p], ref, prosite_bank.ids[p])


def test_bank_agrees_with_sequential_engine_under_budget(prosite_bank,
                                                         full_bank_result):
    """Sequential engine leg: every signature either closes under the shared
    budget with the bit-identical SFA, or blows up exactly when the bank's
    exact state count exceeds the budget."""
    closed = blown = 0
    for p in range(prosite_bank.n_patterns):
        d = prosite_bank.dfa(p)
        try:
            ref = construct_sfa(d, engine="sequential",
                                max_states=SHARED_BUDGET)
        except StateBlowup:
            blown += 1
            assert full_bank_result.sfas[p].n_states > SHARED_BUDGET
            continue
        closed += 1
        _assert_sfa_equal(full_bank_result.sfas[p], ref, prosite_bank.ids[p])
    assert closed >= 10 and blown >= 3  # the budget really splits the bank


def test_bank_agrees_with_jax_engine_under_budget(prosite_bank,
                                                  full_bank_result):
    """Jax engine leg: same budget split as the sequential leg. (The jax
    engine *is* the P=1 batched round, so this pins the padding/masking
    story: per-pattern construction on the unpadded DFA equals the padded
    bank rows.)"""
    closed = blown = 0
    for p in range(prosite_bank.n_patterns):
        d = prosite_bank.dfa(p)
        if d.n_states > 10:
            continue  # bound jit-compile variety; sizes n<=10 cover 17/23
        try:
            ref = construct_sfa(d, engine="jax", max_states=SHARED_BUDGET,
                                tile=32)
        except StateBlowup:
            blown += 1
            assert full_bank_result.sfas[p].n_states > SHARED_BUDGET
            continue
        closed += 1
        _assert_sfa_equal(full_bank_result.sfas[p], ref, prosite_bank.ids[p])
    assert closed >= 10


def test_bank_methods_and_shard_map_agree():
    """batched == loop == shard_map-distributed batched, bit for bit."""
    dfas = [random_dfa(n, 5, seed=200 + i) for i, n in enumerate((3, 5, 4, 2))]
    batched = construct_bank(dfas, max_states=2000, tile=32)
    loop = construct_bank(dfas, max_states=2000, method="loop")
    sharded = construct_bank(
        dfas, max_states=2000, tile=32, distribution="shard_map",
        mesh=make_mesh((1,), ("pattern",)),
    )
    assert batched.stats.method == "batched" and loop.stats.method == "loop"
    for p in range(len(dfas)):
        _assert_sfa_equal(batched.sfas[p], loop.sfas[p], p)
        _assert_sfa_equal(batched.sfas[p], sharded.sfas[p], p)


def test_bank_capacity_growth_is_bit_exact():
    """Buffers start small and grow geometrically toward the cap; results
    must be capacity-invariant (a big budget is not a big allocation)."""
    dfas = [random_dfa(3, 5, seed=100), random_dfa(6, 5, seed=103)]
    # seed 103's SFA has ~5.4k states: far beyond the initial capacity, so
    # construction crosses several growth tiers on the way.
    res = construct_bank(dfas, max_states=6000, tile=64)
    assert not res.blown.any()
    assert res.sfas[1].n_states > 2048
    for p, d in enumerate(dfas):
        _assert_sfa_equal(res.sfas[p],
                          construct_sfa(d, engine="vectorized",
                                        max_states=6000), p)


def test_bank_blowup_flags_and_raise():
    dfas = [random_dfa(2, 8, seed=1), random_dfa(8, 8, seed=1)]
    res = construct_bank(dfas, max_states=12, tile=8)
    assert list(res.blown) == [False, True]
    assert res.sfas[0] is not None and res.sfas[1] is None
    # flags agree with the per-pattern engine's verdict
    construct_sfa(dfas[0], max_states=12)
    with pytest.raises(StateBlowup):
        construct_sfa(dfas[1], max_states=12)
    with pytest.raises(StateBlowup):
        construct_bank(dfas, max_states=12, tile=8, on_blowup="raise")


def test_bank_input_validation():
    with pytest.raises(ValueError):
        construct_bank([])
    with pytest.raises(ValueError):
        construct_bank([random_dfa(3, 4, seed=0)], method="parallel")
    with pytest.raises(ValueError):
        construct_bank([random_dfa(3, 4, seed=0)], distribution="pmap")


# --------------------------------------------------------------------------
# Forced fingerprint collision: one pattern retries, the rest don't re-run
# --------------------------------------------------------------------------


def test_forced_collision_retries_only_the_collided_pattern():
    dfas = [random_dfa(n, 5, seed=300 + i) for i, n in enumerate((4, 5, 3))]
    kwargs = dict(max_states=4000, tile=16)
    clean = construct_bank(dfas, **kwargs)
    assert not clean.stats.retries.any()

    def sabotaged_weights(p, attempt, n_words, consts):
        w = np.asarray(fold_weights_u32(n_words, consts))
        if p == 1 and attempt == 0:
            return np.zeros_like(w)  # all fingerprints equal -> collision
        return w

    res = construct_bank(dfas, _weight_fn=sabotaged_weights, **kwargs)
    assert list(res.stats.retries) == [0, 1, 0]
    # The collided pattern restarted (strictly more rounds than clean); the
    # passengers kept their progress (round counts unchanged).
    assert res.stats.pattern_rounds[1] > clean.stats.pattern_rounds[1]
    assert res.stats.pattern_rounds[0] == clean.stats.pattern_rounds[0]
    assert res.stats.pattern_rounds[2] == clean.stats.pattern_rounds[2]
    # Untouched patterns: bit-identical to the clean run (polynomial 0).
    _assert_sfa_equal(res.sfas[0], clean.sfas[0], 0)
    _assert_sfa_equal(res.sfas[2], clean.sfas[2], 2)
    # The retried pattern lands on polynomial index 1 — same SFA, the
    # fingerprints of the retry polynomial.
    retry_ref = construct_sfa_vectorized(dfas[1], poly_index=1,
                                         max_states=4000)
    _assert_sfa_equal(res.sfas[1], retry_ref, 1)
    assert not np.array_equal(res.sfas[1].fingerprints,
                              clean.sfas[1].fingerprints)


# --------------------------------------------------------------------------
# SFACache semantics
# --------------------------------------------------------------------------


def test_cache_hit_miss_and_budget_semantics():
    cache = SFACache()
    d = random_dfa(4, 5, seed=7)
    assert cache.lookup(d, max_states=100) == (None, None)
    sfa = construct_sfa(d, max_states=10_000)
    cache.store(d, sfa)
    kind, got = cache.lookup(d, max_states=10_000)
    assert kind == "sfa" and got is sfa
    # a positive entry answers "blowup" for budgets below its exact size
    assert cache.lookup(d, max_states=sfa.n_states - 1) == ("blowup", None)
    assert cache.lookup(d, max_states=sfa.n_states)[0] == "sfa"
    # a different DFA (different content hash) misses
    other = random_dfa(4, 5, seed=8)
    assert cache.lookup(other, max_states=100) == (None, None)
    assert dfa_cache_key(d) != dfa_cache_key(other)
    assert cache.info.hits == 3 and cache.info.misses == 2


def test_cache_blowup_markers_upgrade_but_never_downgrade():
    cache = SFACache()
    d = random_dfa(6, 6, seed=9)
    cache.store_blowup(d, 50)
    assert cache.lookup(d, max_states=50) == ("blowup", None)
    assert cache.lookup(d, max_states=40) == ("blowup", None)
    # larger budget: unknown -> miss, then the marker upgrades
    assert cache.lookup(d, max_states=80) == (None, None)
    cache.store_blowup(d, 80)
    assert cache.lookup(d, max_states=80) == ("blowup", None)
    # a positive entry wins over any later marker
    sfa = construct_sfa(d, max_states=1_000_000)
    cache.store(d, sfa)
    cache.store_blowup(d, 10)
    assert cache.lookup(d, max_states=1_000_000)[0] == "sfa"


def test_cache_lru_eviction_by_entries_and_bytes():
    cache = SFACache(max_entries=2)
    ds = [random_dfa(3, 4, seed=20 + i) for i in range(3)]
    sfas = [construct_sfa(d) for d in ds]
    cache.store(ds[0], sfas[0])
    cache.store(ds[1], sfas[1])
    assert len(cache) == 2
    # touch ds[0] so ds[1] is the LRU victim
    assert cache.lookup(ds[0], max_states=10_000)[0] == "sfa"
    cache.store(ds[2], sfas[2])
    assert cache.info.evictions == 1
    assert cache.lookup(ds[1], max_states=10_000) == (None, None)  # evicted
    assert cache.lookup(ds[0], max_states=10_000)[0] == "sfa"      # kept

    # byte-budget eviction: room for either SFA alone, never both
    tiny = SFACache(max_entries=64,
                    max_bytes=sfas[0].nbytes() + sfas[1].nbytes() - 1)
    tiny.store(ds[0], sfas[0])
    tiny.store(ds[1], sfas[1])          # pushes the first out by bytes
    assert tiny.info.evictions == 1
    assert tiny.info.current_bytes <= tiny.max_bytes
    assert tiny.lookup(ds[1], max_states=10_000)[0] == "sfa"
    assert tiny.lookup(ds[0], max_states=10_000) == (None, None)
    with pytest.raises(ValueError):
        SFACache(max_entries=0)


@settings(max_examples=10, deadline=None)
@given(spec=st.dictionaries(st.integers(min_value=0, max_value=5),
                            st.integers(min_value=4, max_value=60),
                            min_size=1, max_size=5))
def test_cache_property_answers_match_direct_construction(spec):
    """Property (via st.dictionaries): for any {seed: budget} workload, the
    cache answers exactly like direct construction — "sfa" iff the exact SFA
    fits the budget, with the bit-identical SFA object on every later hit."""
    cache = SFACache()
    exact = {}
    for seed in spec:
        d = random_dfa(3 + seed % 3, 4, seed=seed)
        exact[seed] = construct_sfa(d, max_states=100_000)
        cache.store(d, exact[seed])
    for seed, budget in spec.items():
        d = random_dfa(3 + seed % 3, 4, seed=seed)
        kind, got = cache.lookup(d, max_states=budget)
        if exact[seed].n_states <= budget:
            assert kind == "sfa"
            assert np.array_equal(got.delta, exact[seed].delta)
        else:
            assert kind == "blowup"


# --------------------------------------------------------------------------
# Scanner integration: zero construction rounds on recompile (acceptance)
# --------------------------------------------------------------------------


def test_scanner_recompile_hits_cache_zero_rounds(prosite_bank):
    """Acceptance: a second Scanner.compile of the same patterns performs
    zero construction rounds, reported via the cache's hit counter."""
    cache = SFACache()
    plan = ScanPlan(construction=ConstructionPolicy(cache=cache,
                                                    method="batched"))
    sc1 = Scanner.compile(prosite_bank, plan)
    r1 = sc1.construction_report
    assert r1.cache_misses == prosite_bank.n_patterns
    assert r1.rounds > 0 and r1.method == "batched"
    assert {"sfa", "enumeration"} <= set(sc1.pattern_modes.values())
    hits_before = cache.info.hits

    sc2 = Scanner.compile(prosite_bank, plan)
    r2 = sc2.construction_report
    assert r2.rounds == 0 and r2.constructed == 0
    assert r2.cache_hits == prosite_bank.n_patterns
    assert cache.info.hits - hits_before == prosite_bank.n_patterns
    assert sc2.pattern_modes == sc1.pattern_modes

    docs = [synthetic_protein(64, seed=i) for i in range(4)]
    assert np.array_equal(sc1.scan(docs).hits, sc2.scan(docs).hits)


def test_scanner_construction_policy_controls():
    d = [random_dfa(4, 5, seed=i) for i in range(2)]
    # cache="off": every compile reconstructs
    plan = ScanPlan(construction=ConstructionPolicy(cache="off"))
    r1 = Scanner.compile(d, plan).construction_report
    r2 = Scanner.compile(d, plan).construction_report
    assert r1.rounds > 0 and r2.rounds > 0 and r2.cache_hits == 0
    # loop method is reported as such
    plan = ScanPlan(construction=ConstructionPolicy(cache="off", method="loop"))
    assert Scanner.compile(d, plan).construction_report.method == "loop"
    # validation
    with pytest.raises(ValueError):
        ConstructionPolicy(method="magic").validate()
    with pytest.raises(ValueError):
        ConstructionPolicy(engine="numpy").validate()
    with pytest.raises(ValueError):
        ConstructionPolicy(tile=0).validate()
    with pytest.raises(ValueError):
        ConstructionPolicy(cache=42).validate()
    with pytest.raises(ValueError):
        ScanPlan(construction=ConstructionPolicy(max_retries=0)).validate()
    with pytest.raises(ValueError):
        ConstructionPolicy(fingerprint_backend="avx2").validate()
    with pytest.raises(ValueError):
        ConstructionPolicy(bucket_growth=1).validate()
    with pytest.raises(ValueError):
        ConstructionPolicy(expand_backend="avx2").validate()
    with pytest.raises(ValueError):
        ConstructionPolicy(bucketing="columns").validate()
    assert ConstructionPolicy().with_(method="batched").method == "batched"
    p = ConstructionPolicy().with_(fingerprint_backend="xla", bucket_growth=8)
    p.validate()
    assert p.fingerprint_backend == "xla" and p.bucket_growth == 8
    p = ConstructionPolicy().with_(expand_backend="xla", bucketing="size")
    p.validate()
    assert p.expand_backend == "xla" and p.bucketing == "size"


def test_scanner_shard_map_construction_matches_local():
    dfas = [random_dfa(3 + i, 5, seed=40 + i) for i in range(4)]
    docs = np.random.default_rng(3).integers(0, 5, size=(3, 32)).astype(np.int32)
    local = Scanner.compile(dfas, ScanPlan(
        construction=ConstructionPolicy(cache="off", method="batched")))
    sharded = Scanner.compile(dfas, ScanPlan(
        construction=ConstructionPolicy(
            cache="off", method="batched", distribution="shard_map",
            mesh=make_mesh((1,), ("pattern",)))))
    assert np.array_equal(local.scan(docs).hits, sharded.scan(docs).hits)
    assert np.array_equal(local.mapping(docs[0]), sharded.mapping(docs[0]))


# --------------------------------------------------------------------------
# Pattern-axis fingerprint kernel (kernels satellite)
# --------------------------------------------------------------------------


def test_fingerprint_bank_kernel_matches_numpy_fold():
    from repro.core.fingerprint import fingerprint_states_np, pack_states_np
    import jax.numpy as jnp
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    P, B, n = 3, 10, 7
    states = rng.integers(0, 1 << 14, size=(P, B, n)).astype(np.int32)
    words = pack_states_np(states)
    consts = [BarrettConstants.cached(nth_poly_low(i)) for i in range(P)]
    got = np.asarray(ops.fingerprint_bank(jnp.asarray(words), consts,
                                          block_b=4, interpret=True))
    for p in range(P):
        assert np.array_equal(got[p], fingerprint_states_np(states[p],
                                                            consts[p])), p
    with pytest.raises(ValueError):
        ops.fingerprint_bank(jnp.asarray(words), consts[:2], interpret=True)


# --------------------------------------------------------------------------
# Fixed-shape compile schedule + process-wide round compile cache
# --------------------------------------------------------------------------


def test_round_schedule_is_static_and_covering():
    """The schedule is derived from static quantities only, its tiers are
    ascending, and its lookups always land inside the precomputed set."""
    sched = round_schedule(tile=64, n=6, k=5, max_states=6000, P=23)
    assert sched.capacities == tuple(sorted(set(sched.capacities)))
    assert sched.capacities[-1] == 6000 + 64          # full cap + tile slack
    assert sched.buckets == (1, 2, 6, 23)             # P shrinking by 4
    # every lookup answer is a member of the precomputed set
    for worst in (0, 1, 1024, 1025, 5000, 10**9):
        assert sched.capacity_for(worst) in sched.capacities
        assert sched.capacity_for(worst) >= min(worst, sched.capacities[-1])
    for n_active in (1, 2, 3, 7, 23, 99):
        b = sched.bucket_for(n_active)
        assert b in sched.buckets and b >= min(n_active, 23)
    assert len(sched.shapes) == len(sched.capacities) * len(sched.buckets)
    # tiny automata never allocate the budget: capacity caps at n^n + tile
    tiny = round_schedule(tile=8, n=3, k=4, max_states=100_000, P=2)
    assert tiny.capacities[-1] == 3 ** 3 + 8
    # a mesh quantum rounds every bucket up to the pattern-axis size
    q = round_schedule(tile=64, n=6, k=5, max_states=6000, P=23, quantum=4)
    assert all(b % 4 == 0 for b in q.buckets) and q.buckets[-1] >= 23
    # growth control: 2 keeps the classic halving ladder
    h = round_schedule(tile=64, n=6, k=5, max_states=6000, P=23,
                       bucket_growth=2)
    assert h.buckets == (1, 2, 3, 6, 12, 23)
    with pytest.raises(ValueError):
        round_schedule(tile=64, n=6, k=5, max_states=6000, P=23,
                       bucket_growth=1)


class _CompileLog(logging.Handler):
    """Captures jax's compile/trace log lines (``jax.log_compiles`` promotes
    them to WARNING on the ``jax._src.dispatch`` logger)."""

    def __init__(self):
        super().__init__()
        self.messages = []

    def emit(self, record):
        self.messages.append(record.getMessage())

    @property
    def compiles(self):
        return [m for m in self.messages if "Finished XLA compilation" in m]

    @property
    def traces(self):
        return [m for m in self.messages
                if "Finished tracing + transforming" in m]


def _logged_compiles(fn):
    """Run ``fn`` with jax compile logging captured -> (result, handler)."""
    import jax

    handler = _CompileLog()
    logger = logging.getLogger("jax")
    logger.addHandler(handler)
    try:
        with jax.log_compiles(True):
            out = fn()
    finally:
        logger.removeHandler(handler)
    return out, handler


def test_repeat_same_shape_bank_zero_new_compiles():
    """Acceptance: a second ``construct_bank`` of the same bank — which
    revisits exactly the same (capacity, bucket) schedule — performs zero
    new jit traces and zero new XLA compiles, with zero new lowerings in the
    round compile cache. (This is the SFACache-evicted case: the *result*
    cache is cold, only the *compile* cache answers.)"""
    dfas = [random_dfa(n, 5, seed=500 + i) for i, n in enumerate((4, 5, 3, 5))]
    kwargs = dict(max_states=3000, tile=32)
    first = construct_bank(dfas, **kwargs)      # pays any cold compiles
    assert not first.blown.any()                # incl. a 2.3k-state pattern:
    # the repeat crosses a capacity-growth tier, not just the starting shape
    before = round_compile_cache().info.snapshot()

    second, log = _logged_compiles(lambda: construct_bank(dfas, **kwargs))
    after = round_compile_cache().info.snapshot()

    assert log.compiles == []
    assert log.traces == []
    assert after["lowerings"] == before["lowerings"]
    assert after["hits"] > before["hits"]       # the rounds came from cache
    for p in range(len(dfas)):
        _assert_sfa_equal(first.sfas[p], second.sfas[p], p)


def test_bank_stats_per_pattern_attribution(full_bank_result):
    """Satellite: bank wall time lives on BankStats only; each pattern's
    SFAStats reports a rounds-weighted *share*, and candidate counts are
    per-pattern exact (summing to the bank total)."""
    stats = full_bank_result.stats
    P = len(full_bank_result.sfas)
    assert stats.pattern_candidates.shape == (P,)
    assert stats.candidates == int(stats.pattern_candidates.sum())
    total_rounds = int(stats.pattern_rounds.sum())
    share_sum = 0.0
    for p, sfa in enumerate(full_bank_result.sfas):
        assert sfa.stats.candidates == int(stats.pattern_candidates[p])
        assert sfa.stats.rounds == int(stats.pattern_rounds[p])
        expect = stats.wall_time_s * int(stats.pattern_rounds[p]) / total_rounds
        assert sfa.stats.wall_time_s == pytest.approx(expect)
        # no pattern is billed the whole bank's wall clock (the old bug)
        assert sfa.stats.wall_time_s < stats.wall_time_s
        share_sum += sfa.stats.wall_time_s
    assert share_sum == pytest.approx(stats.wall_time_s)


# --------------------------------------------------------------------------
# Pallas fingerprint stage: bit-identical to the reference fold
# --------------------------------------------------------------------------


def test_pallas_fingerprint_stage_bit_identical_all_prosite(prosite_bank,
                                                            full_bank_result):
    """Acceptance: the Pallas Rabin fold equals the reference fold on real
    construction traffic — padded+masked state vectors of every bundled
    signature's exact SFA, exactly as the batched round feeds the kernel."""
    import jax.numpy as jnp

    from repro.construction.batched import _limbs_of, _word_mask
    from repro.core.fingerprint import pack_states_np
    from repro.kernels import ops

    P, n_max = prosite_bank.n_patterns, prosite_bank.n_max
    W = (n_max + 1) // 2
    consts = BarrettConstants.cached(nth_poly_low(0))
    B = 64
    identity = np.arange(n_max, dtype=np.int32)
    words = np.zeros((P, B, W), dtype=np.uint32)
    expect = np.zeros((P, B, 2), dtype=np.uint32)
    for p in range(P):
        sfa = full_bank_result.sfas[p]
        rows = np.arange(B) % sfa.n_states          # cycle: fill all B slots
        n_true = sfa.mappings.shape[1]
        padded = np.tile(identity, (B, 1))
        padded[:, :n_true] = sfa.mappings[rows]
        words[p] = pack_states_np(padded) & _word_mask(n_true, n_max)[None, :]
        expect[p] = sfa.fingerprints[rows]
    weights = np.broadcast_to(
        np.asarray(fold_weights_u32(W, consts)), (P, W, 2))
    limbs = np.broadcast_to(_limbs_of(consts), (P, 4))
    got = np.asarray(ops.fingerprint_bank_stacked(
        jnp.asarray(words), jnp.asarray(weights), jnp.asarray(limbs),
        block_b=32, interpret=True))
    assert got.shape == (P, B, 2)
    for p in range(P):
        assert np.array_equal(got[p], expect[p]), prosite_bank.ids[p]


def test_pallas_backend_round_is_bit_identical():
    """A full construction with the Pallas fingerprint stage selected equals
    the XLA-fold default, bit for bit — the backend knob changes the
    execution path, never the artifact."""
    dfas = [random_dfa(n, 5, seed=600 + i) for i, n in enumerate((4, 5, 3, 6))]
    kwargs = dict(max_states=3000, tile=32)
    ref = construct_bank(dfas, fingerprint_backend="xla", **kwargs)
    pal = construct_bank(dfas, fingerprint_backend="pallas", **kwargs)
    for p in range(len(dfas)):
        _assert_sfa_equal(ref.sfas[p], pal.sfas[p], p)
    with pytest.raises(ValueError):
        construct_bank(dfas, fingerprint_backend="avx2", **kwargs)
    with pytest.raises(ValueError):
        construct_bank(dfas, bucket_growth=1, **kwargs)


# --------------------------------------------------------------------------
# Size-bucketed banks + gather/Pallas frontier expansion
# --------------------------------------------------------------------------

#: Six small (<=8 states) + four mid-size DFAs: two merged size buckets.
_SKEWED_SIZES = (3, 4, 3, 5, 4, 3, 9, 10, 11, 12)


def _skewed_bank(seed0, sizes=_SKEWED_SIZES, k=5):
    return [random_dfa(n, k, seed=seed0 + i) for i, n in enumerate(sizes)]


def test_bucketed_bank_bit_identical_to_unbucketed(prosite_bank,
                                                   full_bank_result):
    """Tentpole acceptance: the bundled bank auto-buckets (P=23, sizes
    4..87) and is bit-identical to the flat batched path — SFAs, blowup
    flags, and per-pattern round/candidate attribution."""
    buckets = full_bank_result.stats.buckets
    assert len(buckets) >= 2                      # the fixture bank bucketed
    assert sum(b.n_patterns for b in buckets) == prosite_bank.n_patterns
    assert full_bank_result.stats.rounds == sum(b.rounds for b in buckets)
    assert sum(b.blown for b in buckets) == int(full_bank_result.blown.sum())
    # bucket-local padding really is narrower than the bank's n_max
    assert min(b.n_max for b in buckets) < prosite_bank.n_max

    flat = construct_bank(prosite_bank, max_states=FULL_BANK_CAP, tile=256,
                          bucketing="off")
    assert not flat.stats.buckets
    assert np.array_equal(full_bank_result.blown, flat.blown)
    assert np.array_equal(full_bank_result.stats.pattern_rounds,
                          flat.stats.pattern_rounds)
    assert np.array_equal(full_bank_result.stats.pattern_candidates,
                          flat.stats.pattern_candidates)
    for p in range(prosite_bank.n_patterns):
        _assert_sfa_equal(full_bank_result.sfas[p], flat.sfas[p],
                          prosite_bank.ids[p])


def test_bucketed_bank_agrees_with_all_membership_stores(prosite_bank,
                                                         full_bank_result):
    """Satellite: the (bucketed) bank agrees with the sequential engine
    under every membership store — exhaustive vector compare, fingerprint
    linear scan, and fingerprint hash chains — under the shared budget,
    including the blowup verdict."""
    assert len(full_bank_result.stats.buckets) >= 2
    for use_fp, use_hash in ((False, False), (True, False), (True, True)):
        closed = blown = 0
        for p in range(prosite_bank.n_patterns):
            try:
                ref = construct_sfa_sequential(
                    prosite_bank.dfa(p), max_states=SHARED_BUDGET,
                    use_fingerprints=use_fp, use_hashing=use_hash)
            except StateBlowup:
                blown += 1
                assert full_bank_result.sfas[p].n_states > SHARED_BUDGET
                continue
            closed += 1
            got = full_bank_result.sfas[p]
            ctx = (prosite_bank.ids[p], use_fp, use_hash)
            assert np.array_equal(got.mappings, ref.mappings), ctx
            assert np.array_equal(got.delta, ref.delta), ctx
            if use_fp:   # the exhaustive store never fingerprints
                assert np.array_equal(got.fingerprints, ref.fingerprints), ctx
        assert closed >= 10 and blown >= 3


def test_repeat_bucketed_bank_zero_new_compiles():
    """Acceptance: a repeat same-shape *bucketed* bank — same partition,
    same bucket-local schedules — performs zero new jit traces, XLA
    compiles, or round-cache lowerings."""
    dfas = _skewed_bank(820)
    kwargs = dict(max_states=500, tile=16, bucketing="size")
    first = construct_bank(dfas, **kwargs)        # pays any cold compiles
    assert len(first.stats.buckets) >= 2
    before = round_compile_cache().info.snapshot()

    second, log = _logged_compiles(lambda: construct_bank(dfas, **kwargs))
    after = round_compile_cache().info.snapshot()

    assert log.compiles == []
    assert log.traces == []
    assert after["lowerings"] == before["lowerings"]
    assert after["hits"] > before["hits"]
    assert np.array_equal(first.blown, second.blown)
    for p in range(len(dfas)):
        if not first.blown[p]:
            _assert_sfa_equal(first.sfas[p], second.sfas[p], p)


def test_expand_backend_pallas_bit_identical():
    """The gather stage's backends agree bit for bit — XLA ``jnp.take``
    vs the Pallas one-hot MXU kernel, flat and bucketed — and the kernel
    itself matches the gather oracle on random tables."""
    import jax.numpy as jnp

    from repro.kernels import ops

    dfas = _skewed_bank(840, sizes=(3, 4, 5, 3, 4, 5, 9, 10))
    kwargs = dict(max_states=500, tile=16)
    ref = construct_bank(dfas, expand_backend="xla", **kwargs)
    pal = construct_bank(dfas, expand_backend="pallas", **kwargs)
    pal_b = construct_bank(dfas, expand_backend="pallas", bucketing="size",
                           **kwargs)
    assert np.array_equal(ref.blown, pal.blown)
    assert np.array_equal(ref.blown, pal_b.blown)
    for p in range(len(dfas)):
        if not ref.blown[p]:
            _assert_sfa_equal(ref.sfas[p], pal.sfas[p], p)
            _assert_sfa_equal(ref.sfas[p], pal_b.sfas[p], p)
    with pytest.raises(ValueError):
        construct_bank(dfas, expand_backend="avx2", **kwargs)
    with pytest.raises(ValueError):
        construct_bank(dfas, bucketing="columns", **kwargs)

    # kernel-level oracle: out[b, t*k + a, q] == tables[b, ft[b, t, q], a]
    rng = np.random.default_rng(7)
    B, T, n, k = 3, 16, 11, 5
    tables = rng.integers(0, 60_000, size=(B, n, k)).astype(np.int32)
    ft = rng.integers(0, n, size=(B, T, n)).astype(np.int32)
    got = np.asarray(ops.expand_frontier_bank(
        jnp.asarray(tables), jnp.asarray(ft), interpret=True))
    assert got.shape == (B, T * k, n)
    for b in range(B):
        expect = np.swapaxes(tables[b][ft[b]], 1, 2).reshape(T * k, n)
        assert np.array_equal(got[b], expect), b


@settings(max_examples=4, deadline=None)
@given(sizes=st.lists(st.sampled_from((2, 3, 4, 5, 6, 10, 12, 14)),
                      min_size=8, max_size=8),
       seed=st.integers(min_value=0, max_value=9))
def test_property_size_skewed_banks_bucket_roundtrip(sizes, seed):
    """Property: random size-skewed banks round-trip through bucketing —
    ``bucketing="size"`` equals ``"off"`` bit for bit (including blowup
    verdicts under a tight budget), and per-pattern stats attribution
    survives the scatter back to bank order."""
    dfas = [random_dfa(n, 4, seed=1000 + 17 * seed + i)
            for i, n in enumerate(sizes)]
    kwargs = dict(max_states=500, tile=16)
    flat = construct_bank(dfas, bucketing="off", **kwargs)
    bkt = construct_bank(dfas, bucketing="size", **kwargs)

    assert np.array_equal(bkt.blown, flat.blown)
    assert np.array_equal(bkt.stats.pattern_rounds, flat.stats.pattern_rounds)
    assert np.array_equal(bkt.stats.pattern_candidates,
                          flat.stats.pattern_candidates)
    total_rounds = int(bkt.stats.pattern_rounds.sum())
    share_sum = closed_rounds = 0
    for p in range(len(dfas)):
        if bkt.blown[p]:
            assert bkt.sfas[p] is None and flat.sfas[p] is None
            continue
        _assert_sfa_equal(bkt.sfas[p], flat.sfas[p], (sizes, p))
        assert bkt.sfas[p].stats.rounds == int(bkt.stats.pattern_rounds[p])
        assert (bkt.sfas[p].stats.candidates
                == int(bkt.stats.pattern_candidates[p]))
        share_sum += bkt.sfas[p].stats.wall_time_s
        closed_rounds += int(bkt.stats.pattern_rounds[p])
    # shares are rounds-weighted splits of the *bank* wall clock
    assert share_sum == pytest.approx(
        bkt.stats.wall_time_s * closed_rounds / total_rounds)
    if bkt.stats.buckets:
        assert sum(b.n_patterns for b in bkt.stats.buckets) == len(dfas)
        assert bkt.stats.rounds == sum(b.rounds for b in bkt.stats.buckets)
