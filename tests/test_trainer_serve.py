"""End-to-end trainer (resume, straggler, preemption plumbing) + serving."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (
    HOST_MESH,
    ModelConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
)
from repro.data import DataConfig, make_pipeline
from repro.models.model import build_model
from repro.serve.engine import Request, ServeEngine
from repro.sharding.rules import Dist
from repro.train.trainer import StragglerMonitor, Trainer

TINY = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=128, head_dim=16, remat="none", tie_embeddings=True,
)


def _mk_trainer(tmp_path, steps_cfg=None):
    shape = ShapeConfig("tiny_train", 32, 8, "train")
    run = RunConfig(
        model=TINY, shape=shape, mesh=HOST_MESH,
        optimizer=OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=200,
                                  schedule="constant"),
        micro_batches=2,
        checkpoint_dir=str(tmp_path / "ckpt"),
        checkpoint_every=10,
        async_checkpoint=False,
    )
    data = make_pipeline(
        DataConfig(vocab_size=TINY.vocab_size, seq_len=32, global_batch=8, seed=1),
        prefetch=False,
    )
    return Trainer(model=build_model(TINY), run=run, dist=Dist(), data=data,
                   log_every=5)


def test_training_reduces_loss(tmp_path):
    tr = _mk_trainer(tmp_path)
    out = tr.fit(30)
    losses = [m["loss"] for m in out["log"]]
    assert losses[-1] < losses[0], losses
    assert np.isfinite(losses[-1])


def test_resume_continues_from_checkpoint(tmp_path):
    tr = _mk_trainer(tmp_path)
    tr.fit(10)
    ref_params = jax.tree.leaves(tr.params)[0].copy()

    tr2 = _mk_trainer(tmp_path)
    assert tr2.try_resume()
    assert tr2.step == 10
    assert tr2.data.step == tr.data.step
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(tr2.params)[0]), np.asarray(ref_params)
    )
    out = tr2.fit(15)
    assert out["steps"] == 15


def test_straggler_monitor():
    m = StragglerMonitor(factor=2.0)
    assert not m.observe(1.0)
    assert not m.observe(1.0)
    for _ in range(3):
        assert not m.observe(1.0)
    assert m.observe(10.0)          # 10x the EWMA
    assert m.slow_steps == 1


def test_serving_engine_continuous_batching():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    shape = ShapeConfig("serve", 64, 2, "decode")
    run = RunConfig(model=TINY, shape=shape, mesh=HOST_MESH)
    eng = ServeEngine(model, run, Dist(), params, n_slots=2, max_len=64,
                      temperature=0.0)
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(1, 128, size=L).astype(np.int32),
                max_new_tokens=6, rid=i)
        for i, L in enumerate([5, 9, 3, 7, 4])
    ]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_done(max_steps=200)
    assert len(done) == 5
    for r in done:
        assert 1 <= len(r.out_tokens) <= 6
        assert all(0 <= t < 128 for t in r.out_tokens)


def test_greedy_decode_matches_forward_argmax():
    """Engine's prefill+decode greedy tokens == argmax over full forwards —
    including MULTI-SLOT continuous batching with ragged prompt lengths
    (per-slot cache positions must isolate each sequence exactly)."""
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    dist = Dist()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, 128, size=L).astype(np.int32) for L in (8, 13, 5)]

    def ref_greedy(prompt, n):
        seq = list(prompt)
        for _ in range(n):
            logits, _, _ = model.forward(
                params, jnp.asarray(np.asarray(seq)[None], jnp.int32), dist,
                mode="train",
            )
            seq.append(int(jnp.argmax(logits[0, -1])))
        return seq[len(prompt):]

    want = [ref_greedy(p, 4) for p in prompts]

    shape = ShapeConfig("serve", 64, 2, "decode")
    run = RunConfig(model=TINY, shape=shape, mesh=HOST_MESH)
    eng = ServeEngine(model, run, dist, params, n_slots=2, max_len=64,
                      temperature=0.0)
    for i, p in enumerate(prompts):
        eng.submit(Request(prompt=p, max_new_tokens=4, rid=i))
    done = {r.rid: r.out_tokens for r in eng.run_until_done()}
    for i in range(len(prompts)):
        assert done[i] == want[i], (i, done[i], want[i])
