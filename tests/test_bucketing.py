"""The shared size-bucketing helper (``repro.core.bucketing``).

One partition implementation now serves the matcher (``bucket_by_size``),
the Scanner's group partition, and bucketed batched construction — these
tests pin the helper's contracts (edge ladder, stable partition, overflow
policies, small-bucket merging) plus the two pre-existing wrappers'
behavior on top of it.
"""

import numpy as np
import pytest

from repro.core.bucketing import (
    geometric_edges,
    merge_small_buckets,
    partition_by_size,
)
from repro.core.dfa import random_dfa
from repro.core.multipattern import bucket_by_size
from repro.engine.scanner import _size_partition


# --------------------------------------------------------------------------
# geometric_edges
# --------------------------------------------------------------------------


def test_geometric_edges_cover_max_size():
    assert geometric_edges(1) == (8,)
    assert geometric_edges(8) == (8,)
    assert geometric_edges(9) == (8, 16)
    assert geometric_edges(87) == (8, 16, 32, 64, 128)
    assert geometric_edges(100, start=4, growth=4) == (4, 16, 64, 256)
    # the ladder is O(log(max_size)) long and always holds max_size
    for m in (1, 7, 64, 1000, 12345):
        edges = geometric_edges(m)
        assert edges[-1] >= m
        assert len(edges) <= 16


def test_geometric_edges_validation():
    with pytest.raises(ValueError):
        geometric_edges(0)
    with pytest.raises(ValueError):
        geometric_edges(10, start=0)
    with pytest.raises(ValueError):
        geometric_edges(10, growth=1)


# --------------------------------------------------------------------------
# partition_by_size
# --------------------------------------------------------------------------


def test_partition_groups_by_smallest_holding_edge():
    sizes = [3, 9, 8, 17, 2, 16]
    parts = partition_by_size(sizes, (8, 16, 32))
    assert parts == [(8, [0, 2, 4]), (16, [1, 5]), (32, [3])]


def test_partition_preserves_input_order_and_drops_empty_buckets():
    parts = partition_by_size([30, 1, 29], (8, 16, 32))
    # no size lands in (8, 16]; that bucket must not appear
    assert parts == [(8, [1]), (32, [0, 2])]


def test_partition_overflow_policies():
    with pytest.raises(ValueError, match="size 99"):
        partition_by_size([1, 99], (8, 16))
    parts = partition_by_size([1, 99, 100], (8, 16), overflow="extend")
    assert parts == [(8, [0]), (float("inf"), [1, 2])]
    with pytest.raises(ValueError, match="overflow"):
        partition_by_size([1], (8,), overflow="bogus")
    with pytest.raises(ValueError, match="edge"):
        partition_by_size([1], ())


def test_partition_unsorted_edges():
    assert partition_by_size([5, 20], (32, 8)) == [(8, [0]), (32, [1])]


# --------------------------------------------------------------------------
# merge_small_buckets
# --------------------------------------------------------------------------


def test_merge_small_buckets_merges_upward():
    parts = [(8, [0, 1]), (16, [2, 3, 4, 5]), (32, [6, 7, 8, 9])]
    merged = merge_small_buckets(parts, 4)
    # the undersized <=8 bucket joins <=16; its items come first
    assert merged == [(16, [0, 1, 2, 3, 4, 5]), (32, [6, 7, 8, 9])]


def test_merge_small_buckets_largest_merges_downward_widening_edge():
    parts = [(8, [0, 1, 2, 3]), (64, [4])]
    merged = merge_small_buckets(parts, 4)
    # the undersized largest bucket widens the one below to its own edge
    assert merged == [(64, [0, 1, 2, 3, 4])]


def test_merge_small_buckets_terminates_at_one_bucket():
    parts = [(8, [0]), (16, [1]), (32, [2])]
    assert merge_small_buckets(parts, 4) == [(32, [0, 1, 2])]


def test_merge_small_buckets_noop_cases():
    parts = [(8, [0, 1]), (16, [2, 3])]
    assert merge_small_buckets(parts, 1) == parts
    assert merge_small_buckets(parts, 2) == parts
    assert merge_small_buckets([], 4) == []
    with pytest.raises(ValueError):
        merge_small_buckets(parts, 0)


# --------------------------------------------------------------------------
# the wrappers ride the shared helper
# --------------------------------------------------------------------------


def test_bucket_by_size_banks_match_shared_partition():
    dfas = [random_dfa(n, 4, seed=900 + i)
            for i, n in enumerate((3, 9, 8, 17, 2, 16))]
    edges = (8, 16, 32)
    banks = bucket_by_size(dfas, edges=edges)
    parts = partition_by_size([d.n_states for d in dfas], edges)
    assert len(banks) == len(parts)
    for bank, (edge, idx) in zip(banks, parts):
        assert list(bank.ids) == [f"pattern_{i}" for i in idx]
        assert bank.n_max <= edge
        for j, i in enumerate(idx):
            assert np.array_equal(bank.dfa(j).table, dfas[i].table)


def test_bucket_by_size_raises_on_oversize_pattern():
    dfas = [random_dfa(20, 4, seed=42)]
    with pytest.raises(ValueError, match="pattern"):
        bucket_by_size(dfas, edges=(8, 16))


def test_scanner_size_partition_extends_for_oversize():
    assert _size_partition([3, 99, 9], (8, 16)) == [[0], [2], [1]]
