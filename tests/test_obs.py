"""Observability tests: registry semantics, exporters, span tracing, and
the integration contract.

Acceptance pins (ISSUE 9):
* disabled observability is a **true no-op**: scan/construct results on a
  bundled pattern bank are bit-identical with obs on and off, and disabled
  mutators change nothing;
* histogram bucket placement follows the Prometheus ``le`` convention and
  both exporters round-trip a live snapshot;
* a request's trace id propagates from :meth:`BatchScheduler.submit`
  through the worker's flush into the construction spans, and
  :meth:`ScanService.metrics` returns one correlated snapshot keyed by it.

Plus the fleet-telemetry layer (ISSUE 10): cross-process snapshot
aggregation (:mod:`repro.obs.aggregate` + its CLI), the flight recorder's
rotated delta trail, and the delta-additivity property that merging
per-shard snapshot deltas reproduces the whole-run delta bit-exactly.
"""

import json
import os
import socket
import sys

import numpy as np
import pytest
from _strategies import given, settings, st

from repro import obs
from repro.construction import SFACache
from repro.core.prosite import synthetic_protein
from repro.engine import ConstructionPolicy, ScanPlan, Scanner
from repro.obs import parse_prometheus, render_prometheus, snapshot_delta
from repro.obs.aggregate import (
    DEFAULT_GAUGE_POLICIES,
    main as aggregate_main,
    merge_records,
    merge_snapshots,
)
from repro.obs.export import read_jsonl, snapshot_record, span_records, \
    write_jsonl
from repro.obs.flight import FlightRecorder, read_flight
from repro.obs.tracing import _NOOP_SPAN
from repro.scanservice import BatchScheduler, ScanService

PATTERNS = ["PS00016", "PS00005"]


@pytest.fixture(autouse=True)
def obs_enabled():
    """Every test starts and ends with observability on (the default)."""
    obs.enable()
    yield
    obs.enable()


@pytest.fixture(scope="module")
def docs():
    return [synthetic_protein(120, seed=i) for i in range(4)]


@pytest.fixture(scope="module")
def shared_cache():
    """One warm SFA cache for the tests that don't need cold construction."""
    return SFACache()


def _plan(cache):
    return ScanPlan(construction=ConstructionPolicy(cache=cache,
                                                    method="batched"))


# --------------------------------------------------------------------------
# Registry: kinds, bucket edges, snapshots, the disabled fast path
# --------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    c = obs.counter("t.obs.c")
    base = c.value
    c.inc()
    c.inc(4)
    assert c.value == base + 5
    g = obs.gauge("t.obs.g")
    g.set(2)
    g.set(1.5)
    assert g.value == 1.5
    # get-or-create: same name -> same object
    assert obs.counter("t.obs.c") is c
    assert obs.gauge("t.obs.g") is g


def test_kind_and_edges_mismatch_raise():
    obs.counter("t.obs.kind")
    with pytest.raises(TypeError):
        obs.gauge("t.obs.kind")
    with pytest.raises(TypeError):
        obs.histogram("t.obs.kind")
    h = obs.histogram("t.obs.edges", edges=(1.0, 2.0))
    with pytest.raises(ValueError):
        obs.histogram("t.obs.edges", edges=(1.0, 3.0))
    assert obs.histogram("t.obs.edges") is h   # edges=None reuses
    with pytest.raises(ValueError):
        obs.histogram("t.obs.bad_edges", edges=(2.0, 2.0))  # not increasing
    with pytest.raises(ValueError):
        obs.histogram("t.obs.no_edges", edges=())


def test_histogram_bucket_placement_le_semantics():
    h = obs.histogram("t.obs.hist", edges=(1.0, 2.0, 5.0))
    for v in (0.5, 1.0, 1.5, 5.0, 7.0):
        h.observe(v)
    # v == edge lands in that edge's bucket (Prometheus le); 7.0 overflows
    # into the implicit +Inf bucket.
    assert h.counts == (2, 1, 1, 1)
    assert h.count == 5
    assert h.sum == pytest.approx(15.0)


def test_snapshot_prefix_reset_and_delta():
    obs.counter("t.obsdelta.a").inc(3)
    obs.gauge("t.obsdelta.b").set(7.0)
    before = obs.snapshot("t.obsdelta")
    assert before == {"t.obsdelta.a": 3, "t.obsdelta.b": 7.0}
    obs.counter("t.obsdelta.a").inc(2)
    obs.histogram("t.obsdelta.h", edges=(1.0,)).observe(0.5)
    delta = snapshot_delta(before, obs.snapshot("t.obsdelta"))
    # unchanged names drop; the counter subtracts; the new histogram passes
    assert delta["t.obsdelta.a"] == 2 and "t.obsdelta.b" not in delta
    assert delta["t.obsdelta.h"]["count"] == 1
    obs.reset()
    snap = obs.snapshot("t.obsdelta")
    assert snap["t.obsdelta.a"] == 0 and snap["t.obsdelta.h"]["count"] == 0


def test_disabled_mutators_and_span_are_noops():
    obs.disable()
    try:
        obs.counter("t.obs.off_c").inc(10)
        obs.gauge("t.obs.off_g").set(3.0)
        obs.histogram("t.obs.off_h", edges=(1.0,)).observe(0.5)
        # span() hands back one shared no-op context manager
        assert obs.span("a") is obs.span("b") is _NOOP_SPAN
        with obs.span("t.obs.off_span") as handle:
            assert handle is None
            assert obs.current_trace_id() is None
    finally:
        obs.enable()
    assert obs.counter("t.obs.off_c").value == 0
    assert obs.gauge("t.obs.off_g").value == 0.0
    assert obs.histogram("t.obs.off_h").count == 0
    assert all(s.name != "t.obs.off_span" for s in obs.recent_spans(50))


# --------------------------------------------------------------------------
# Exporters: Prometheus text and JSONL round-trips
# --------------------------------------------------------------------------


def test_prometheus_round_trip():
    obs.counter("t.prom.hits").inc(42)
    obs.gauge("t.prom.rate").set(0.75)
    h = obs.histogram("t.prom.wall", edges=(0.1, 1.0))
    for v in (0.05, 0.5, 3.0):
        h.observe(v)
    snap = obs.snapshot("t.prom")
    text = render_prometheus(snap)
    assert "# TYPE t_prom_hits counter" in text
    assert 't_prom_wall_bucket{le="+Inf"} 3' in text
    back = parse_prometheus(text)
    assert back["t_prom_hits"] == 42
    assert back["t_prom_rate"] == 0.75
    assert back["t_prom_wall"] == {
        "edges": [0.1, 1.0], "counts": [1, 1, 1],
        "sum": pytest.approx(3.55), "count": 3,
    }


def test_jsonl_round_trip(tmp_path):
    path = tmp_path / "events.jsonl"
    obs.counter("t.jsonl.c").inc(7)
    with obs.span("t.jsonl.span", k=1):
        pass
    write_jsonl(path, [snapshot_record(obs.snapshot("t.jsonl"), label="x")])
    write_jsonl(path, span_records(
        s for s in obs.recent_spans(10) if s.name == "t.jsonl.span"))
    records = read_jsonl(path)
    assert records[0]["kind"] == "metrics" and records[0]["label"] == "x"
    assert records[0]["metrics"]["t.jsonl.c"] == 7
    assert records[-1]["kind"] == "span"
    assert records[-1]["name"] == "t.jsonl.span"
    assert records[-1]["attrs"] == {"k": 1}


# --------------------------------------------------------------------------
# Tracing: nesting, inheritance, re-rooting, error capture
# --------------------------------------------------------------------------


def test_span_nesting_and_trace_inheritance():
    with obs.span("t.span.outer", who="outer") as outer:
        assert obs.current_trace_id() == outer.trace_id
        with obs.span("t.span.inner") as inner:
            assert inner.trace_id == outer.trace_id
            assert inner.parent_id == outer.span_id
        # explicit trace_id re-roots (the cross-thread contract)
        with obs.span("t.span.rerooted", trace_id="t-explicit") as re:
            assert re.trace_id == "t-explicit"
    assert obs.current_trace_id() is None
    summ = obs.trace_summary(outer.trace_id)
    names = [s["name"] for s in summ["spans"]]
    assert names == ["t.span.outer", "t.span.inner"]  # start order
    assert summ["wall_s"] >= summ["spans"][1]["wall_s"]


def test_span_records_error_attribute():
    with pytest.raises(RuntimeError):
        with obs.span("t.span.err"):
            raise RuntimeError("boom")
    sp = obs.recent_spans(1)[0]
    assert sp.name == "t.span.err" and sp.attrs["error"] == "RuntimeError"
    assert obs.current_trace_id() is None   # the stack unwound


# --------------------------------------------------------------------------
# Integration: bit-identity, trace propagation, correlated snapshots
# --------------------------------------------------------------------------


def test_scan_and_construct_bit_identical_obs_on_off(docs):
    """Acceptance: obs off must change bookkeeping only, never results."""
    on = Scanner.compile(PATTERNS, _plan(SFACache()))
    hits_on = on.scan(docs).hits
    assert on.last_trace_id is not None
    assert "last trace" in on.describe()
    obs.disable()
    try:
        off = Scanner.compile(PATTERNS, _plan(SFACache()))
        hits_off = off.scan(docs).hits
        assert off.last_trace_id is None
    finally:
        obs.enable()
    assert off.pattern_modes == on.pattern_modes
    assert np.array_equal(hits_on, hits_off)


def test_trace_id_propagates_submit_to_construction(docs):
    """Acceptance: one trace id correlates scheduler -> scanner ->
    construction, across the cold compile a cache-missing submit causes."""
    before = obs.snapshot("construction")
    sched = BatchScheduler(_plan(SFACache()))   # cold: flush must construct
    ticket = sched.submit(PATTERNS, docs)
    assert ticket.trace_id is not None
    sched.flush()
    ticket.result()
    assert sched.last_trace_id == ticket.trace_id
    summ = obs.trace_summary(ticket.trace_id)
    names = {s["name"] for s in summ["spans"]}
    assert {"scheduler.submit", "scheduler.flush", "scanner.compile",
            "construct_bank"} <= names
    assert all(s["trace_id"] == ticket.trace_id for s in summ["spans"])
    delta = snapshot_delta(before, obs.snapshot("construction"))
    assert delta["construction.banks"] >= 1
    assert delta["construction.rounds"] >= 1


def test_thread_driver_tickets_carry_trace_ids(docs, shared_cache):
    Scanner.compile(PATTERNS, _plan(shared_cache))   # warm the cache
    sched = BatchScheduler(_plan(shared_cache), driver="thread",
                           window_s=0.01, max_batch=8)
    try:
        t1 = sched.submit(PATTERNS[:1], docs)
        t2 = sched.submit(PATTERNS[1:], docs)
        t1.result(), t2.result()
    finally:
        sched.close()
    assert t1.trace_id and t2.trace_id and t1.trace_id != t2.trace_id
    # the worker re-rooted its flush span on a submitted request's trace
    assert sched.last_trace_id in {t1.trace_id, t2.trace_id}
    flushes = [s for s in obs.recent_spans(200)
               if s.name == "scheduler.flush"
               and s.trace_id in {t1.trace_id, t2.trace_id}]
    assert flushes
    covered = {f.trace_id for f in flushes}
    for f in flushes:
        covered.update(f.attrs.get("coalesced_trace_ids", ()))
    assert {t1.trace_id, t2.trace_id} <= covered


def test_scheduler_stats_property_is_atomic_copy(docs, shared_cache):
    sched = BatchScheduler(_plan(shared_cache))
    sched.submit(PATTERNS[:1], docs)
    sched.flush()
    s1 = sched.stats
    s1.requests += 100            # mutating the copy must not leak back
    assert sched.stats.requests == s1.requests - 100
    # the registry mirrors the dataclass view
    snap = obs.snapshot("scheduler")
    assert snap["scheduler.requests"] >= sched.stats.requests


def test_service_metrics_is_one_correlated_snapshot(docs, shared_cache):
    with ScanService(plan=_plan(shared_cache), cache=shared_cache) as svc:
        ticket = svc.submit(PATTERNS, docs)
        svc.flush()
        ticket.result()
        m = svc.metrics()
    assert set(m) == {"trace", "cache", "scheduler", "registry"}
    assert m["trace"]["trace_id"] == ticket.trace_id
    assert {s["name"] for s in m["trace"]["spans"]} >= {"scheduler.flush"}
    assert m["scheduler"]["requests"] >= 1
    assert m["registry"]["scheduler.flushes"] >= 1
    assert 0.0 <= m["cache"]["hit_rate"] <= 1.0
    # an explicit trace id is honored
    assert svc.metrics(ticket.trace_id)["trace"]["trace_id"] == \
        ticket.trace_id


# --------------------------------------------------------------------------
# HELP descriptions and record attribution (host/pid)
# --------------------------------------------------------------------------


def test_help_lines_render_and_round_trip():
    obs.counter("t.help.c", help="counted things")
    obs.gauge("t.help.g", help="a level")
    obs.histogram("t.help.h", edges=(1.0,), help="a spread\nsecond line")
    obs.counter("t.help.c", help="a later, losing description")
    text = obs.render_prometheus(obs.snapshot("t.help"))
    assert "# HELP t_help_c counted things" in text
    assert "# HELP t_help_g a level" in text
    assert "# HELP t_help_h a spread\\nsecond line" in text   # escaped
    assert "losing description" not in text   # first registration wins
    # the round-trip contract survives HELP lines
    back = parse_prometheus(text)
    assert back["t_help_c"] == 0
    assert back["t_help_h"]["edges"] == [1.0]


def test_snapshot_record_carries_host_and_pid():
    rec = snapshot_record({"t.rec.c": 1}, label="w")
    assert rec["host"] == socket.gethostname()
    assert rec["pid"] == os.getpid()
    assert rec["kind"] == "metrics" and rec["label"] == "w"


# --------------------------------------------------------------------------
# Aggregation: merge semantics, per-metric gauge policies, the CLI
# --------------------------------------------------------------------------


def test_merge_snapshots_counters_histograms_gauges():
    h = {"edges": [1.0, 2.0], "counts": [1, 0, 2], "sum": 7.0, "count": 3}
    a = {"c": 3, "g": 1.5, "h": h}
    b = {"c": 4, "g": 2.5,
         "h": {"edges": [1.0, 2.0], "counts": [0, 5, 1], "sum": 9.0,
               "count": 6}}
    m = merge_snapshots([a, b])
    assert m["c"] == 7
    assert m["g"] == 2.5   # default policy: last
    assert m["h"] == {"edges": [1.0, 2.0], "counts": [1, 5, 3],
                      "sum": 16.0, "count": 9}
    # inputs unmutated
    assert a["h"]["counts"] == [1, 0, 2]
    # per-metric and default policies
    assert merge_snapshots([{"g": 5.0}, {"g": 2.0}],
                           gauge_policy="max")["g"] == 5.0
    assert merge_snapshots([{"g": 5.0}, {"g": 2.0}],
                           gauge_policies={"g": "sum"})["g"] == 7.0
    assert DEFAULT_GAUGE_POLICIES["scheduler.max_coalesced"] == "max"
    assert merge_snapshots([{"scheduler.max_coalesced": 9.0},
                            {"scheduler.max_coalesced": 4.0}]
                           )["scheduler.max_coalesced"] == 9.0


def test_merge_snapshots_rejects_incompatible_schemas():
    with pytest.raises(TypeError):
        merge_snapshots([{"x": 1}, {"x": 1.5}])   # counter vs gauge
    h1 = {"edges": [1.0], "counts": [0, 0], "sum": 0.0, "count": 0}
    h2 = {"edges": [2.0], "counts": [0, 0], "sum": 0.0, "count": 0}
    with pytest.raises(ValueError):
        merge_snapshots([{"h": h1}, {"h": h2}])   # edge mismatch
    with pytest.raises(ValueError):
        merge_snapshots([{"h": {"edges": [1.0], "counts": [0],
                                "sum": 0.0, "count": 0}}])  # counts != edges+1
    with pytest.raises(ValueError):
        merge_snapshots([], gauge_policy="median")
    with pytest.raises(ValueError):
        merge_snapshots([], gauge_policies={"g": "median"})
    with pytest.raises(TypeError):
        merge_snapshots([{"x": True}])


def test_merge_records_orders_by_ts_and_attributes_sources():
    r1 = snapshot_record({"c": 1, "g": 10.0}, label="w0")
    r2 = snapshot_record({"c": 2, "g": 20.0}, label="w1")
    r1["host"], r1["pid"], r1["ts"] = "hostA", 1, 200.0
    r2["host"], r2["pid"], r2["ts"] = "hostB", 2, 100.0
    # pass newest first: ts ordering must still make hostA's gauge win
    fleet = merge_records([r1, r2, {"kind": "span", "name": "x"}])
    assert fleet["kind"] == "fleet" and fleet["n_records"] == 2
    assert fleet["ts"] == 200.0
    assert fleet["metrics"] == {"c": 3, "g": 10.0}
    assert {(s["host"], s["pid"]) for s in fleet["sources"]} == \
        {("hostA", 1), ("hostB", 2)}
    # prefix restricts the namespace
    r3 = snapshot_record({"jobs.n": 1, "other.n": 1})
    assert merge_records([r3], prefix="jobs")["metrics"] == {"jobs.n": 1}


def test_aggregate_cli_merges_worker_files(tmp_path, capsys):
    w0, w1 = tmp_path / "w0.jsonl", tmp_path / "w1.jsonl"
    write_jsonl(w0, [snapshot_record({"jobs.n": 3, "other": 1.0})])
    write_jsonl(w1, [snapshot_record({"jobs.n": 4})])
    with open(w1, "a") as f:
        f.write('{"torn": ')   # a killed writer's partial line
    out = tmp_path / "fleet.json"
    assert aggregate_main([str(w0), str(w1), "-o", str(out)]) == 0
    fleet = json.loads(out.read_text())
    assert fleet["metrics"]["jobs.n"] == 7 and fleet["n_records"] == 2
    # --format prom emits parseable exposition text
    assert aggregate_main([str(w0), str(w1), "--format", "prom",
                           "--prefix", "jobs"]) == 0
    text = capsys.readouterr().out
    assert parse_prometheus(text) == {"jobs_n": 7}
    # missing file -> 1; no metric records -> 2
    assert aggregate_main([str(tmp_path / "nope.jsonl")]) == 1
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert aggregate_main([str(empty)]) == 2


def test_aggregate_module_is_runnable(tmp_path):
    """`python -m repro.obs.aggregate` works without a runpy double-import
    warning (the package re-exports lazily for exactly this reason)."""
    import subprocess
    w = tmp_path / "w.jsonl"
    write_jsonl(w, [snapshot_record({"jobs.n": 5})])
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-W", "error::RuntimeWarning",
         "-m", "repro.obs.aggregate", str(w)],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.abspath(src)},
    )
    assert proc.returncode == 0, proc.stderr
    assert json.loads(proc.stdout)["metrics"]["jobs.n"] == 5


# --------------------------------------------------------------------------
# Flight recorder: delta trail, rotation, idle skip, torn tails
# --------------------------------------------------------------------------


def test_flight_recorder_records_deltas_and_spans(tmp_path):
    path = tmp_path / "flight" / "flight.jsonl"
    obs.counter("t.flight.pre").inc(5)   # before the recorder: not its story
    fr = FlightRecorder(path, label="worker")
    obs.counter("t.flight.c").inc(2)
    with obs.span("t.flight.span"):
        pass
    rec = fr.record(shard=3)
    assert rec["kind"] == "flight" and rec["label"] == "worker"
    assert rec["shard"] == 3
    assert rec["metrics"]["t.flight.c"] == 2
    assert "t.flight.pre" not in rec["metrics"]
    assert rec["host"] == socket.gethostname() and rec["pid"] == os.getpid()
    records = read_flight(path)
    assert [r["kind"] for r in records] == ["flight", "span"]
    assert records[1]["name"] == "t.flight.span"
    # idle tick: force=False skips, force=True writes an empty delta
    assert fr.record(force=False) is None
    assert fr.record(force=True)["metrics"] == {}


def test_flight_recorder_rotation_bounds_disk(tmp_path):
    path = tmp_path / "flight.jsonl"
    fr = FlightRecorder(path, max_bytes=400, max_files=3)
    for i in range(60):
        obs.counter("t.flightrot.c").inc()
        fr.record(i=i)
    files = sorted(p.name for p in tmp_path.iterdir())
    assert files == ["flight.jsonl", "flight.jsonl.1", "flight.jsonl.2"]
    assert all(p.stat().st_size < 400 + 300 for p in tmp_path.iterdir())
    records = [r for r in read_flight(path) if r["kind"] == "flight"]
    # oldest files dropped, order preserved, newest retained
    idx = [r["i"] for r in records]
    assert idx == sorted(idx) and idx[-1] == 59
    # the retained contiguous stretch still merges exactly
    merged = merge_records(records, prefix="t.flightrot")
    assert merged["metrics"]["t.flightrot.c"] == len(records)


def test_flight_recorder_periodic_thread_and_close(tmp_path):
    path = tmp_path / "flight.jsonl"
    with pytest.raises(ValueError):
        FlightRecorder(path).start()   # periodic mode needs interval_s
    with FlightRecorder(path, interval_s=0.01) as fr:
        fr.start()
        obs.counter("t.flightbg.c").inc(4)
        import time
        deadline = time.time() + 5.0
        while time.time() < deadline:
            recs = [r for r in read_flight(path)
                    if r.get("kind") == "flight"
                    and "t.flightbg.c" in r.get("metrics", {})]
            if recs:
                break
            time.sleep(0.01)
        assert recs, "periodic thread never recorded the delta"
    assert fr._thread is None   # close() joined the thread


def test_read_flight_skips_torn_tail(tmp_path):
    path = tmp_path / "flight.jsonl"
    fr = FlightRecorder(path)
    obs.counter("t.flighttorn.c").inc()
    fr.record()
    with open(path, "a") as f:
        f.write('{"kind": "flight", "metr')   # the kill -9 tail
    records = read_flight(path)
    assert len(records) == 1
    assert records[0]["metrics"]["t.flighttorn.c"] == 1


# --------------------------------------------------------------------------
# The additivity property: per-shard deltas merge to the whole-run delta
# --------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(shards=st.lists(
    st.lists(st.tuples(st.integers(min_value=0, max_value=2),
                       st.integers(min_value=1, max_value=50)),
             min_size=0, max_size=6),
    min_size=1, max_size=5,
))
def test_merged_shard_deltas_equal_whole_run_snapshot(shards):
    """Acceptance: snapshot deltas taken per shard merge (bit-exactly, for
    counters and histograms) to the delta of the uninterrupted run —
    however the work was cut into shards."""
    start = obs.snapshot("t.prop")
    prev = start
    deltas = []
    for ops in shards:
        for which, amount in ops:
            obs.counter(f"t.prop.c{which}").inc(amount)
            obs.histogram("t.prop.h", edges=(8.0, 32.0)).observe(
                float(amount))
        cur = obs.snapshot("t.prop")
        deltas.append(snapshot_delta(prev, cur))
        prev = cur
    whole = snapshot_delta(start, obs.snapshot("t.prop"))
    assert merge_snapshots(deltas) == whole
