"""Observability tests: registry semantics, exporters, span tracing, and
the integration contract.

Acceptance pins (ISSUE 9):
* disabled observability is a **true no-op**: scan/construct results on a
  bundled pattern bank are bit-identical with obs on and off, and disabled
  mutators change nothing;
* histogram bucket placement follows the Prometheus ``le`` convention and
  both exporters round-trip a live snapshot;
* a request's trace id propagates from :meth:`BatchScheduler.submit`
  through the worker's flush into the construction spans, and
  :meth:`ScanService.metrics` returns one correlated snapshot keyed by it.
"""

import numpy as np
import pytest

from repro import obs
from repro.construction import SFACache
from repro.core.prosite import synthetic_protein
from repro.engine import ConstructionPolicy, ScanPlan, Scanner
from repro.obs import parse_prometheus, render_prometheus, snapshot_delta
from repro.obs.export import read_jsonl, snapshot_record, span_records, \
    write_jsonl
from repro.obs.tracing import _NOOP_SPAN
from repro.scanservice import BatchScheduler, ScanService

PATTERNS = ["PS00016", "PS00005"]


@pytest.fixture(autouse=True)
def obs_enabled():
    """Every test starts and ends with observability on (the default)."""
    obs.enable()
    yield
    obs.enable()


@pytest.fixture(scope="module")
def docs():
    return [synthetic_protein(120, seed=i) for i in range(4)]


@pytest.fixture(scope="module")
def shared_cache():
    """One warm SFA cache for the tests that don't need cold construction."""
    return SFACache()


def _plan(cache):
    return ScanPlan(construction=ConstructionPolicy(cache=cache,
                                                    method="batched"))


# --------------------------------------------------------------------------
# Registry: kinds, bucket edges, snapshots, the disabled fast path
# --------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    c = obs.counter("t.obs.c")
    base = c.value
    c.inc()
    c.inc(4)
    assert c.value == base + 5
    g = obs.gauge("t.obs.g")
    g.set(2)
    g.set(1.5)
    assert g.value == 1.5
    # get-or-create: same name -> same object
    assert obs.counter("t.obs.c") is c
    assert obs.gauge("t.obs.g") is g


def test_kind_and_edges_mismatch_raise():
    obs.counter("t.obs.kind")
    with pytest.raises(TypeError):
        obs.gauge("t.obs.kind")
    with pytest.raises(TypeError):
        obs.histogram("t.obs.kind")
    h = obs.histogram("t.obs.edges", edges=(1.0, 2.0))
    with pytest.raises(ValueError):
        obs.histogram("t.obs.edges", edges=(1.0, 3.0))
    assert obs.histogram("t.obs.edges") is h   # edges=None reuses
    with pytest.raises(ValueError):
        obs.histogram("t.obs.bad_edges", edges=(2.0, 2.0))  # not increasing
    with pytest.raises(ValueError):
        obs.histogram("t.obs.no_edges", edges=())


def test_histogram_bucket_placement_le_semantics():
    h = obs.histogram("t.obs.hist", edges=(1.0, 2.0, 5.0))
    for v in (0.5, 1.0, 1.5, 5.0, 7.0):
        h.observe(v)
    # v == edge lands in that edge's bucket (Prometheus le); 7.0 overflows
    # into the implicit +Inf bucket.
    assert h.counts == (2, 1, 1, 1)
    assert h.count == 5
    assert h.sum == pytest.approx(15.0)


def test_snapshot_prefix_reset_and_delta():
    obs.counter("t.obsdelta.a").inc(3)
    obs.gauge("t.obsdelta.b").set(7.0)
    before = obs.snapshot("t.obsdelta")
    assert before == {"t.obsdelta.a": 3, "t.obsdelta.b": 7.0}
    obs.counter("t.obsdelta.a").inc(2)
    obs.histogram("t.obsdelta.h", edges=(1.0,)).observe(0.5)
    delta = snapshot_delta(before, obs.snapshot("t.obsdelta"))
    # unchanged names drop; the counter subtracts; the new histogram passes
    assert delta["t.obsdelta.a"] == 2 and "t.obsdelta.b" not in delta
    assert delta["t.obsdelta.h"]["count"] == 1
    obs.reset()
    snap = obs.snapshot("t.obsdelta")
    assert snap["t.obsdelta.a"] == 0 and snap["t.obsdelta.h"]["count"] == 0


def test_disabled_mutators_and_span_are_noops():
    obs.disable()
    try:
        obs.counter("t.obs.off_c").inc(10)
        obs.gauge("t.obs.off_g").set(3.0)
        obs.histogram("t.obs.off_h", edges=(1.0,)).observe(0.5)
        # span() hands back one shared no-op context manager
        assert obs.span("a") is obs.span("b") is _NOOP_SPAN
        with obs.span("t.obs.off_span") as handle:
            assert handle is None
            assert obs.current_trace_id() is None
    finally:
        obs.enable()
    assert obs.counter("t.obs.off_c").value == 0
    assert obs.gauge("t.obs.off_g").value == 0.0
    assert obs.histogram("t.obs.off_h").count == 0
    assert all(s.name != "t.obs.off_span" for s in obs.recent_spans(50))


# --------------------------------------------------------------------------
# Exporters: Prometheus text and JSONL round-trips
# --------------------------------------------------------------------------


def test_prometheus_round_trip():
    obs.counter("t.prom.hits").inc(42)
    obs.gauge("t.prom.rate").set(0.75)
    h = obs.histogram("t.prom.wall", edges=(0.1, 1.0))
    for v in (0.05, 0.5, 3.0):
        h.observe(v)
    snap = obs.snapshot("t.prom")
    text = render_prometheus(snap)
    assert "# TYPE t_prom_hits counter" in text
    assert 't_prom_wall_bucket{le="+Inf"} 3' in text
    back = parse_prometheus(text)
    assert back["t_prom_hits"] == 42
    assert back["t_prom_rate"] == 0.75
    assert back["t_prom_wall"] == {
        "edges": [0.1, 1.0], "counts": [1, 1, 1],
        "sum": pytest.approx(3.55), "count": 3,
    }


def test_jsonl_round_trip(tmp_path):
    path = tmp_path / "events.jsonl"
    obs.counter("t.jsonl.c").inc(7)
    with obs.span("t.jsonl.span", k=1):
        pass
    write_jsonl(path, [snapshot_record(obs.snapshot("t.jsonl"), label="x")])
    write_jsonl(path, span_records(
        s for s in obs.recent_spans(10) if s.name == "t.jsonl.span"))
    records = read_jsonl(path)
    assert records[0]["kind"] == "metrics" and records[0]["label"] == "x"
    assert records[0]["metrics"]["t.jsonl.c"] == 7
    assert records[-1]["kind"] == "span"
    assert records[-1]["name"] == "t.jsonl.span"
    assert records[-1]["attrs"] == {"k": 1}


# --------------------------------------------------------------------------
# Tracing: nesting, inheritance, re-rooting, error capture
# --------------------------------------------------------------------------


def test_span_nesting_and_trace_inheritance():
    with obs.span("t.span.outer", who="outer") as outer:
        assert obs.current_trace_id() == outer.trace_id
        with obs.span("t.span.inner") as inner:
            assert inner.trace_id == outer.trace_id
            assert inner.parent_id == outer.span_id
        # explicit trace_id re-roots (the cross-thread contract)
        with obs.span("t.span.rerooted", trace_id="t-explicit") as re:
            assert re.trace_id == "t-explicit"
    assert obs.current_trace_id() is None
    summ = obs.trace_summary(outer.trace_id)
    names = [s["name"] for s in summ["spans"]]
    assert names == ["t.span.outer", "t.span.inner"]  # start order
    assert summ["wall_s"] >= summ["spans"][1]["wall_s"]


def test_span_records_error_attribute():
    with pytest.raises(RuntimeError):
        with obs.span("t.span.err"):
            raise RuntimeError("boom")
    sp = obs.recent_spans(1)[0]
    assert sp.name == "t.span.err" and sp.attrs["error"] == "RuntimeError"
    assert obs.current_trace_id() is None   # the stack unwound


# --------------------------------------------------------------------------
# Integration: bit-identity, trace propagation, correlated snapshots
# --------------------------------------------------------------------------


def test_scan_and_construct_bit_identical_obs_on_off(docs):
    """Acceptance: obs off must change bookkeeping only, never results."""
    on = Scanner.compile(PATTERNS, _plan(SFACache()))
    hits_on = on.scan(docs).hits
    assert on.last_trace_id is not None
    assert "last trace" in on.describe()
    obs.disable()
    try:
        off = Scanner.compile(PATTERNS, _plan(SFACache()))
        hits_off = off.scan(docs).hits
        assert off.last_trace_id is None
    finally:
        obs.enable()
    assert off.pattern_modes == on.pattern_modes
    assert np.array_equal(hits_on, hits_off)


def test_trace_id_propagates_submit_to_construction(docs):
    """Acceptance: one trace id correlates scheduler -> scanner ->
    construction, across the cold compile a cache-missing submit causes."""
    before = obs.snapshot("construction")
    sched = BatchScheduler(_plan(SFACache()))   # cold: flush must construct
    ticket = sched.submit(PATTERNS, docs)
    assert ticket.trace_id is not None
    sched.flush()
    ticket.result()
    assert sched.last_trace_id == ticket.trace_id
    summ = obs.trace_summary(ticket.trace_id)
    names = {s["name"] for s in summ["spans"]}
    assert {"scheduler.submit", "scheduler.flush", "scanner.compile",
            "construct_bank"} <= names
    assert all(s["trace_id"] == ticket.trace_id for s in summ["spans"])
    delta = snapshot_delta(before, obs.snapshot("construction"))
    assert delta["construction.banks"] >= 1
    assert delta["construction.rounds"] >= 1


def test_thread_driver_tickets_carry_trace_ids(docs, shared_cache):
    Scanner.compile(PATTERNS, _plan(shared_cache))   # warm the cache
    sched = BatchScheduler(_plan(shared_cache), driver="thread",
                           window_s=0.01, max_batch=8)
    try:
        t1 = sched.submit(PATTERNS[:1], docs)
        t2 = sched.submit(PATTERNS[1:], docs)
        t1.result(), t2.result()
    finally:
        sched.close()
    assert t1.trace_id and t2.trace_id and t1.trace_id != t2.trace_id
    # the worker re-rooted its flush span on a submitted request's trace
    assert sched.last_trace_id in {t1.trace_id, t2.trace_id}
    flushes = [s for s in obs.recent_spans(200)
               if s.name == "scheduler.flush"
               and s.trace_id in {t1.trace_id, t2.trace_id}]
    assert flushes
    covered = {f.trace_id for f in flushes}
    for f in flushes:
        covered.update(f.attrs.get("coalesced_trace_ids", ()))
    assert {t1.trace_id, t2.trace_id} <= covered


def test_scheduler_stats_property_is_atomic_copy(docs, shared_cache):
    sched = BatchScheduler(_plan(shared_cache))
    sched.submit(PATTERNS[:1], docs)
    sched.flush()
    s1 = sched.stats
    s1.requests += 100            # mutating the copy must not leak back
    assert sched.stats.requests == s1.requests - 100
    # the registry mirrors the dataclass view
    snap = obs.snapshot("scheduler")
    assert snap["scheduler.requests"] >= sched.stats.requests


def test_service_metrics_is_one_correlated_snapshot(docs, shared_cache):
    with ScanService(plan=_plan(shared_cache), cache=shared_cache) as svc:
        ticket = svc.submit(PATTERNS, docs)
        svc.flush()
        ticket.result()
        m = svc.metrics()
    assert set(m) == {"trace", "cache", "scheduler", "registry"}
    assert m["trace"]["trace_id"] == ticket.trace_id
    assert {s["name"] for s in m["trace"]["spans"]} >= {"scheduler.flush"}
    assert m["scheduler"]["requests"] >= 1
    assert m["registry"]["scheduler.flushes"] >= 1
    assert 0.0 <= m["cache"]["hit_rate"] <= 1.0
    # an explicit trace id is honored
    assert svc.metrics(ticket.trace_id)["trace"]["trace_id"] == \
        ticket.trace_id
