"""Monoid laws (hypothesis) + scan correctness for the three instances."""

import jax.numpy as jnp
import numpy as np
from _strategies import given, settings, st

from repro.core import monoid as M

FN = M.function_monoid()
AFF = M.affine_monoid()
SM = M.softmax_monoid()


def _rand_fn(rng, n=6):
    return jnp.asarray(rng.integers(0, n, size=n).astype(np.int32))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_function_monoid_laws(seed):
    rng = np.random.default_rng(seed)
    a, b, c = (_rand_fn(rng) for _ in range(3))
    lhs = FN.combine(FN.combine(a, b), c)
    rhs = FN.combine(a, FN.combine(b, c))
    assert jnp.array_equal(lhs, rhs)
    e = FN.identity(a)
    assert jnp.array_equal(FN.combine(e, a), a)
    assert jnp.array_equal(FN.combine(a, e), a)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_affine_monoid_laws(seed):
    rng = np.random.default_rng(seed)
    mk = lambda: (jnp.asarray(rng.uniform(0.1, 1.0, 4)), jnp.asarray(rng.normal(size=4)))
    a, b, c = mk(), mk(), mk()
    lhs = AFF.combine(AFF.combine(a, b), c)
    rhs = AFF.combine(a, AFF.combine(b, c))
    for l, r in zip(lhs, rhs):
        np.testing.assert_allclose(l, r, rtol=1e-4, atol=1e-6)  # f32 reassociation
    e = AFF.identity(a)
    out = AFF.combine(e, a)
    np.testing.assert_allclose(out[0], a[0], rtol=1e-6)
    np.testing.assert_allclose(out[1], a[1], rtol=1e-6)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_softmax_monoid_laws_and_commutativity(seed):
    rng = np.random.default_rng(seed)
    mk = lambda: (
        jnp.asarray(rng.normal(size=3)),
        jnp.asarray(rng.uniform(0.1, 2.0, 3)),
        jnp.asarray(rng.normal(size=3)),
    )
    a, b, c = mk(), mk(), mk()
    lhs = SM.combine(SM.combine(a, b), c)
    rhs = SM.combine(a, SM.combine(b, c))
    for l, r in zip(lhs, rhs):
        np.testing.assert_allclose(l, r, rtol=1e-4, atol=1e-6)  # f32 reassociation
    ab, ba = SM.combine(a, b), SM.combine(b, a)
    for l, r in zip(ab, ba):
        np.testing.assert_allclose(l, r, rtol=1e-4, atol=1e-6)


def test_function_scan_is_prefix_composition():
    rng = np.random.default_rng(0)
    fs = jnp.asarray(rng.integers(0, 5, size=(7, 5)).astype(np.int32))
    inc = M.scan(FN, fs, axis=0)
    acc = fs[0]
    for i in range(7):
        if i:
            acc = FN.combine(acc, fs[i])
        assert jnp.array_equal(inc[i], acc), i


def test_exclusive_scan_shifts_with_identity():
    rng = np.random.default_rng(1)
    fs = jnp.asarray(rng.integers(0, 4, size=(5, 4)).astype(np.int32))
    ex = M.exclusive_scan(FN, fs, axis=0)
    assert jnp.array_equal(ex[0], jnp.arange(4))
    inc = M.scan(FN, fs, axis=0)
    for i in range(1, 5):
        assert jnp.array_equal(ex[i], inc[i - 1])


def test_reduce_equals_fold():
    rng = np.random.default_rng(2)
    fs = jnp.asarray(rng.integers(0, 6, size=(9, 6)).astype(np.int32))
    red = M.reduce(FN, fs, axis=0)
    acc = fs[0]
    for i in range(1, 9):
        acc = FN.combine(acc, fs[i])
    assert jnp.array_equal(red, acc)


def test_softmax_monoid_computes_softmax():
    """Chunked (m, s, o) combining == direct softmax-weighted sum."""
    rng = np.random.default_rng(3)
    scores = rng.normal(size=16).astype(np.float32)
    values = rng.normal(size=16).astype(np.float32)
    want = (np.exp(scores - scores.max()) / np.exp(scores - scores.max()).sum() * values).sum()
    elems = (
        jnp.asarray(scores)[:, None],
        jnp.ones((16, 1)),
        jnp.asarray(values)[:, None],
    )
    m, s, o = M.reduce(SM, elems, axis=0)
    np.testing.assert_allclose(float(o[0] / s[0]), want, rtol=1e-5)
