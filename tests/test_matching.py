"""Parallel matching == sequential matching, in every mode."""

import jax
import jax.numpy as jnp
import numpy as np
from _strategies import given, settings, st

from repro.compat import make_mesh
from repro.core import matching as mt
from repro.core.dfa import example_fa, random_dfa
from repro.engine import executors as X
from repro.core.prosite import compile_prosite, synthetic_protein
from repro.core.sfa import construct_sfa


def _mesh1():
    return make_mesh((1,), ("data",))


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=1000),
    n_chunks=st.sampled_from([1, 2, 4, 8]),
)
def test_enumeration_parallel_equals_sequential(seed, n_chunks):
    d = random_dfa(5, 6, seed=seed)
    rng = np.random.default_rng(seed)
    syms = jnp.asarray(rng.integers(0, 6, size=64).astype(np.int32))
    mapping = X.match_parallel_enumeration(jnp.asarray(d.table), syms, n_chunks)
    assert int(mapping[d.start]) == d.run(np.asarray(syms))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=300))
def test_sfa_parallel_equals_sequential(seed):
    d = random_dfa(4, 5, seed=seed)
    sfa = construct_sfa(d)
    rng = np.random.default_rng(seed)
    syms = jnp.asarray(rng.integers(0, 5, size=60).astype(np.int32))
    mapping = X.match_parallel_sfa(
        jnp.asarray(sfa.delta), jnp.asarray(sfa.mappings), syms, 4
    )
    assert int(mapping[d.start]) == d.run(np.asarray(syms))


def test_find_matches_parallel_equals_trace():
    d = example_fa()
    text = synthetic_protein(512, seed=5)
    text = text[:100] + "RG" + text[102:]
    syms = jnp.asarray(d.encode(text))
    flags = X.find_matches_parallel(
        jnp.asarray(d.table), jnp.asarray(d.accepting), syms, d.start, 8
    )
    ref = mt.match_ends_sequential(d, np.asarray(syms))
    assert np.array_equal(np.asarray(flags), ref)


def test_accepts_parallel_handles_ragged_lengths():
    d = compile_prosite("R-G-D")
    for L in [5, 17, 64, 100, 129]:
        text = synthetic_protein(L, seed=L)
        assert X.accepts_parallel(d, text, n_chunks=8) == d.accepts(text), L
    planted = synthetic_protein(50, seed=1) + "RGD"
    assert X.accepts_parallel(d, planted, n_chunks=8)


def test_distributed_match_single_device_mesh():
    d = example_fa()
    text = synthetic_protein(1024, seed=9)[:1000] + "RG" + "AAAAAAAAAAAAAAAAAAAAAA"
    syms = jnp.asarray(d.encode(text))
    matcher = X.distributed_match_fn(_mesh1(), d.table.shape)
    mapping = matcher(jnp.asarray(d.table), syms, sub_chunks=8)
    assert int(mapping[d.start]) == d.run(np.asarray(syms))


def test_throughput_matcher():
    d = example_fa()
    rng = np.random.default_rng(0)
    rows = []
    want = []
    for i in range(4):
        t = synthetic_protein(128, seed=i)
        if i % 2:
            t = t[:60] + "RG" + t[62:]
        rows.append(d.encode(t))
        want.append(d.accepts(t))
    batch = jnp.asarray(np.stack(rows))
    matcher = X.throughput_matcher(_mesh1(), start=d.start)
    got = matcher(jnp.asarray(d.table), jnp.asarray(d.accepting), batch)
    assert [bool(x) for x in np.asarray(got)] == want
