"""Speculative scanning: bit-identity under any speculation quality.

The subsystem's whole contract is that speculation moves *work*, never
*results*: a perfect hot-state profile settles every chunk in one pass, an
adversarial profile repairs every chunk (or falls back to enumeration), and
the hit matrix is identical either way. These tests pin that down on the
full bundled PROSITE bank, on random DFAs over ragged corpora, on inputs
engineered to force a 0% speculation hit rate, through streaming, and
through shard_map — plus the stats invariants and the profile persistence
path in the artifact store.
"""

import numpy as np
import pytest
from _strategies import given, settings, st

from repro.compat import make_mesh
from repro.core.dfa import DFA, random_dfa
from repro.core.prosite import PROSITE_EXTRA, PROSITE_SAMPLES, synthetic_protein
from repro.engine import ScanPlan, Scanner, SpeculationPolicy
from repro.scanservice import ArtifactStore, ScanService
from repro.speculative import (
    HotStateProfile,
    SpeculationStats,
    profile_hot_states,
    stack_profile_states,
)

ALL_BUNDLED = sorted({**PROSITE_SAMPLES, **PROSITE_EXTRA})


def _random_docs(seed, n_docs, length, k):
    rng = np.random.default_rng(seed)
    return rng.integers(0, k, size=(n_docs, length)).astype(np.int32)


def _two_state_dfa(n_states=6, k=4):
    """Only states {0, 1} are reachable (they alternate on every symbol);
    states 2..n-1 exist purely so a profile can speculate unreachable ones.
    """
    table = np.zeros((n_states, k), dtype=np.int32)
    table[0, :] = 1
    table[1, :] = 0
    for s in range(2, n_states):
        table[s, :] = s
    accepting = np.zeros(n_states, dtype=bool)
    accepting[1] = True
    return DFA(table=table, start=0, accepting=accepting, alphabet="abcd"[:k])


# --------------------------------------------------------------------------
# Policy validation
# --------------------------------------------------------------------------


def test_speculation_policy_validation():
    for bad in [
        dict(m=0),
        dict(sample_frac=0.0),
        dict(sample_frac=1.5),
        dict(max_sample=0),
        dict(max_repair_rounds=0),
        dict(auto_states=0),
        dict(profile_source="magic"),
        dict(profile_source=42),
    ]:
        with pytest.raises(ValueError):
            SpeculationPolicy(**bad).validate()
    assert ScanPlan(mode="speculative").validate().speculation.m == 8
    pol = SpeculationPolicy().with_(m=4, profile_source="store")
    assert (pol.m, pol.profile_source) == (4, "store")
    # explicit sources validate: sequences and mappings both pass
    SpeculationPolicy(profile_source=[0, 1, 2]).validate()
    SpeculationPolicy(profile_source={"p": [0]}).validate()


# --------------------------------------------------------------------------
# Bit-identity: bundled bank, random DFAs, forced misspeculation
# --------------------------------------------------------------------------


def test_bundled_bank_bit_identity():
    """The acceptance criterion: mode='speculative' == mode='enumeration'
    on the full bundled PROSITE bank."""
    docs = [synthetic_protein(60 + 17 * i, seed=i) for i in range(8)]
    sp = Scanner.compile(ALL_BUNDLED, ScanPlan(mode="speculative"))
    en = Scanner.compile(ALL_BUNDLED, ScanPlan(mode="enumeration"))
    rs, re = sp.scan(docs), en.scan(docs)
    assert np.array_equal(rs.hits, re.hits)
    st_ = rs.speculation
    assert isinstance(st_, SpeculationStats)
    assert st_.total_chunks > 0
    assert sp.last_speculation is st_
    assert "speculation" in sp.describe()


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    n_states=st.integers(min_value=2, max_value=40),
    m=st.integers(min_value=1, max_value=6),
    sample_frac=st.floats(min_value=0.01, max_value=1.0),
)
def test_speculative_equals_enumeration_random(seed, n_states, m, sample_frac):
    """Property: random DFAs, ragged doc lengths (incl. sub-chunk and empty
    docs), any m / sample size -> identical hit matrices and stats sanity."""
    k = 5
    dfas = [random_dfa(n_states, k, seed=seed + j) for j in range(3)]
    rng = np.random.default_rng(seed)
    docs = [rng.integers(0, k, size=int(L)).astype(np.int32)
            for L in [0, 3, 17, 64, 64, 129]]
    plan = ScanPlan(
        mode="speculative",
        speculation=SpeculationPolicy(m=m, sample_frac=sample_frac),
    )
    rs = Scanner.compile(dfas, plan).scan(docs)
    re = Scanner.compile(dfas, ScanPlan(mode="enumeration")).scan(docs)
    assert np.array_equal(rs.hits, re.hits)
    s = rs.speculation
    assert s.repaired_chunks <= s.total_chunks
    assert 0.0 <= s.hit_rate <= 1.0
    if s.fallback_lanes == 0:
        # every chunk was settled by exactly one of the two cheap paths
        assert s.hit_chunks + s.repaired_chunks == s.total_chunks
    if s.hit_rate == 1.0:
        assert s.repair_rounds == 0


def test_forced_misspeculation_repairs_everything():
    """An unreachable-state profile forces a 0% hit rate: every chunk goes
    through the repair pass, and the result is still exact."""
    dfa = _two_state_dfa()
    docs = _random_docs(0, 4, 80, 4)  # 80 = 10 per chunk x 8 chunks
    plan = ScanPlan(
        mode="speculative",
        speculation=SpeculationPolicy(
            m=2, profile_source=np.asarray([2, 3]), max_repair_rounds=8
        ),
    )
    sp = Scanner.compile([dfa], plan)
    rs = sp.scan(docs)
    re = Scanner.compile([dfa], ScanPlan(mode="enumeration")).scan(docs)
    assert np.array_equal(rs.hits, re.hits)
    s = rs.speculation
    assert s.hit_chunks == 0 and s.hit_rate == 0.0
    assert s.fallback_lanes == 0
    assert s.repaired_chunks == s.total_chunks  # the repair-everything path
    assert s.repair_rounds == 8  # one chunk per lane per round, 8 chunks


def test_repair_bound_falls_back_to_enumeration():
    """With the repair budget too small to converge, unresolved lanes take
    the guaranteed enumeration fallback — results identical regardless."""
    dfa = _two_state_dfa()
    docs = _random_docs(1, 4, 80, 4)
    plan = ScanPlan(
        mode="speculative",
        speculation=SpeculationPolicy(
            m=2, profile_source=np.asarray([2, 3]), max_repair_rounds=1
        ),
    )
    rs = Scanner.compile([dfa], plan).scan(docs)
    re = Scanner.compile([dfa], ScanPlan(mode="enumeration")).scan(docs)
    assert np.array_equal(rs.hits, re.hits)
    assert rs.speculation.fallback_lanes > 0
    assert rs.speculation.repair_rounds == 1


def test_perfect_profile_hits_everything():
    """Speculating *all* states is a perfect profile: hit rate 1, zero
    repair rounds (the stats invariant's other edge)."""
    dfa = _two_state_dfa(n_states=4)
    docs = _random_docs(2, 3, 40, 4)
    plan = ScanPlan(
        mode="speculative",
        speculation=SpeculationPolicy(m=4, profile_source=np.arange(4)),
    )
    rs = Scanner.compile([dfa], plan).scan(docs)
    s = rs.speculation
    assert s.hit_rate == 1.0
    assert s.repair_rounds == 0
    assert s.repaired_chunks == 0 and s.fallback_lanes == 0


def test_explicit_profile_sources():
    dfa = _two_state_dfa()
    docs = _random_docs(3, 2, 40, 4)
    by_id = Scanner.compile(
        {"p": dfa},
        ScanPlan(mode="speculative",
                 speculation=SpeculationPolicy(m=2, profile_source={"p": [0, 1]})),
    ).scan(docs)
    re = Scanner.compile({"p": dfa}, ScanPlan(mode="enumeration")).scan(docs)
    assert np.array_equal(by_id.hits, re.hits)
    with pytest.raises(ValueError, match="missing pattern"):
        Scanner.compile(
            {"p": dfa},
            ScanPlan(mode="speculative",
                     speculation=SpeculationPolicy(profile_source={"q": [0]})),
        ).scan(docs)
    with pytest.raises(ValueError, match="non-empty"):
        Scanner.compile(
            {"p": dfa},
            ScanPlan(mode="speculative",
                     speculation=SpeculationPolicy(profile_source=[])),
        ).scan(docs)


# --------------------------------------------------------------------------
# Streaming and shard_map
# --------------------------------------------------------------------------


def test_stream_equals_scan_speculative():
    """stream() under speculation carries exact states across blocks: its
    accepts/finals equal the enumeration stream's (and scan's) bit for bit;
    the whole-input mapping is unavailable by design (None)."""
    patterns = ["PS00001", "PS00007", "PS00010"]
    text = synthetic_protein(7000, seed=42)
    pieces = [text[i:i + 1234] for i in range(0, len(text), 1234)]
    sp = Scanner.compile(patterns, ScanPlan(mode="speculative"))
    en = Scanner.compile(patterns, ScanPlan(mode="enumeration"))
    rs, re = sp.stream(pieces), en.stream(pieces)
    assert rs.mapping is None and re.mapping is not None
    assert np.array_equal(rs.final_states, re.final_states)
    assert np.array_equal(rs.accepted, re.accepted)
    assert isinstance(rs.speculation, SpeculationStats)
    assert rs.speculation.total_chunks > 0
    # and the stream equals a whole-corpus scan of the concatenation
    assert np.array_equal(rs.accepted, sp.scan([text]).hits[:, 0])


def test_misspeculated_stream_still_exact():
    """A block whose speculation misses entirely (profile of unreachable
    states, repair budget 0-ish) exercises the stream's per-block
    enumeration fallback."""
    dfa = _two_state_dfa()
    rng = np.random.default_rng(9)
    syms = rng.integers(0, 4, size=5000).astype(np.int32)
    plan = ScanPlan(
        mode="speculative",
        speculation=SpeculationPolicy(
            m=2, profile_source=np.asarray([2, 3]), max_repair_rounds=1
        ),
    )
    sp = Scanner.compile([dfa], plan)
    en = Scanner.compile([dfa], ScanPlan(mode="enumeration"))
    rs = sp.stream([syms[:2600], syms[2600:]])
    re = en.stream([syms[:2600], syms[2600:]])
    assert np.array_equal(rs.final_states, re.final_states)
    assert np.array_equal(rs.accepted, re.accepted)
    assert rs.speculation.fallback_lanes > 0


def test_shard_map_equals_local():
    mesh = make_mesh((1,), ("data",))
    plan = ScanPlan(mode="speculative")
    dist = plan.with_(distribution="shard_map", mesh=mesh)
    docs = _random_docs(5, 4, 96, 20)
    patterns = ["PS00007", "PS00010"]
    r_local = Scanner.compile(patterns, plan).scan(docs)
    r_dist = Scanner.compile(patterns, dist).scan(docs)
    assert np.array_equal(r_local.hits, r_dist.hits)
    assert r_dist.speculation.total_chunks == r_local.speculation.total_chunks


# --------------------------------------------------------------------------
# auto-mode tiering
# --------------------------------------------------------------------------


def test_auto_tier_routes_by_dfa_size():
    """auto's blowup tier: budget-blowing patterns go speculative iff their
    DFA has >= auto_states states; the bundled bank's blowup patterns are
    all smaller than the default threshold, so default plans are unchanged."""
    big = random_dfa(150, 20, seed=3)
    small = random_dfa(30, 20, seed=4)
    sc = Scanner.compile({"big": big, "small": small},
                         ScanPlan(mode="auto", sfa_state_budget=5))
    assert sc.pattern_modes["big"] == "speculative"
    assert sc.pattern_modes["small"] == "enumeration"
    # the threshold is the policy knob
    sc2 = Scanner.compile(
        {"big": big, "small": small},
        ScanPlan(mode="auto", sfa_state_budget=5,
                 speculation=SpeculationPolicy(auto_states=20)),
    )
    assert sc2.pattern_modes["small"] == "speculative"
    # default bundled bank: no speculative tier engaged
    default = Scanner.compile(ALL_BUNDLED, ScanPlan())
    assert "speculative" not in set(default.pattern_modes.values())
    # and the mixed auto scan stays exact
    docs = _random_docs(6, 3, 100, 20)
    r_auto = sc.scan(docs)
    r_enum = Scanner.compile({"big": big, "small": small},
                             ScanPlan(mode="enumeration")).scan(docs)
    assert np.array_equal(r_auto.hits, r_enum.hits)


# --------------------------------------------------------------------------
# Profiler unit behavior
# --------------------------------------------------------------------------


def test_profiler_top_m_and_stacking():
    dfa = _two_state_dfa(n_states=6)
    tables = dfa.table[None].astype(np.int32)
    sample = np.zeros(99, dtype=np.int32)  # alternates 0 -> 1 -> 0 -> ...
    [prof] = profile_hot_states(tables, np.asarray([0]), sample, m=3)
    # states 0 and 1 split all visits; unvisited states pad in id order
    assert set(prof.states[:2]) == {0, 1}
    assert prof.states[2] == 2
    assert prof.weights[0] >= prof.weights[1] > prof.weights[2] == 0.0
    assert prof.sample_len == 99
    # JSON round-trip and m-normalization (truncate / pad / clip)
    back = HotStateProfile.from_json(prof.to_json())
    assert np.array_equal(back.states, prof.states)
    stacked = stack_profile_states([back], m=5, n_max=4)
    assert stacked.shape == (1, 5)
    assert stacked.max() <= 3
    assert HotStateProfile.from_json({"garbage": 1}) is None


# --------------------------------------------------------------------------
# Profile persistence (store tier)
# --------------------------------------------------------------------------


def test_store_profile_roundtrip_and_isolation(tmp_path):
    store = ArtifactStore(tmp_path)
    prof = HotStateProfile(
        states=np.asarray([3, 1], dtype=np.int32),
        weights=np.asarray([0.7, 0.2]), sample_len=10,
    )
    store.put_profile("ab" + "0" * 62, prof.to_json())
    meta = store.get_profile("ab" + "0" * 62)
    assert meta is not None and meta["states"] == [3, 1]
    assert store.get_profile("cd" + "0" * 62) is None
    # profiles live outside the artifact namespace: nothing leaks into the
    # SFA walks, eviction, or len()
    assert len(store) == 0
    assert store.keys() == []
    assert list(store.entries()) == []
    assert store.profile_keys() == ["ab" + "0" * 62]
    # corrupt profile degrades to a miss
    store._profile_path("ab" + "0" * 62).write_text("{broken")
    assert store.get_profile("ab" + "0" * 62) is None


def test_store_backed_profile_source(tmp_path):
    """profile_source='store': first scan samples and persists, a fresh
    scanner reuses the persisted profile, results always exact."""
    from repro.engine import ConstructionPolicy

    dfa = random_dfa(40, 5, seed=11)
    docs = _random_docs(12, 3, 64, 5)
    plan = ScanPlan(
        mode="speculative",
        construction=ConstructionPolicy(store=tmp_path),
        speculation=SpeculationPolicy(profile_source="store"),
    )
    sc1 = Scanner.compile([dfa], plan)
    r1 = sc1.scan(docs)
    store = ArtifactStore(tmp_path)
    assert len(store.profile_keys()) == 1
    persisted = store.get_profile(store.profile_keys()[0])
    # a fresh scanner resolves the persisted profile (and memoizes it)
    sc2 = Scanner.compile([dfa], plan)
    r2 = sc2.scan(docs)
    g = next(g for g in sc2.groups if g.mode == "speculative")
    assert g._spec_profile is not None
    assert np.array_equal(
        g._spec_profile[0][: len(persisted["states"])],
        np.asarray(persisted["states"], dtype=np.int32),
    )
    re = Scanner.compile([dfa], ScanPlan(mode="enumeration")).scan(docs)
    assert np.array_equal(r1.hits, re.hits)
    assert np.array_equal(r2.hits, re.hits)


def test_service_upgrades_profile_source(tmp_path):
    with ScanService(store_dir=tmp_path) as svc:
        assert svc.plan.speculation.profile_source == "store"
    with ScanService() as svc:
        assert svc.plan.speculation.profile_source == "sample"
    # an explicit source is respected, store or not
    plan = ScanPlan(speculation=SpeculationPolicy(profile_source=[0, 1]))
    with ScanService(store_dir=tmp_path, plan=plan) as svc:
        assert list(svc.plan.speculation.profile_source) == [0, 1]


def test_scheduler_counts_speculative_patterns(tmp_path):
    """The service path end to end: an over-budget pattern routed to the
    speculative tier by auto mode is served and counted."""
    big = random_dfa(150, 20, seed=21)
    plan = ScanPlan(mode="auto", sfa_state_budget=5)
    with ScanService(store_dir=tmp_path, plan=plan) as svc:
        t = svc.submit([big, "PS00016"], ["ACDEFGHIKLMNPQRSTVWY" * 5])
        res = t.result()
        assert res.hits.shape == (2, 1)
        assert svc.scheduler.stats.speculative_patterns == 1
        ref = Scanner.compile(
            [big, "PS00016"], ScanPlan(mode="enumeration")
        ).scan(["ACDEFGHIKLMNPQRSTVWY" * 5])
        assert np.array_equal(res.hits, ref.hits)
