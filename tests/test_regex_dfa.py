"""Regex -> NFA -> DFA pipeline, PROSITE translation, minimization."""

import re as pyre

import numpy as np
import pytest
from _strategies import given, settings, st

from repro.core.dfa import compile_dfa, example_fa, minimize, random_dfa, subset_construct
from repro.core.prosite import PROSITE_SAMPLES, PrositeSyntaxError, compile_prosite, translate
from repro.core.regex import AMINO_ACIDS, RegexSyntaxError, compile_nfa, parse


def test_example_fa_matches_paper():
    """Paper Fig. 1: 'contains RG' FA has 3 states, accepts iff RG occurs."""
    dfa = example_fa()
    assert dfa.n_states == 3
    assert dfa.accepts("AARGA")
    assert dfa.accepts("RG")
    assert not dfa.accepts("RRRR")
    assert not dfa.accepts("GR")


def test_transition_table_shape_and_completeness():
    dfa = example_fa()
    assert dfa.table.shape == (3, 20)
    assert dfa.table.min() >= 0 and dfa.table.max() < 3
    assert np.array_equal(dfa.transposed(), dfa.table.T)


@pytest.mark.parametrize("pattern,yes,no", [
    ("A", "A", "C"),
    ("AC", "DAC", "CA"),
    ("A|C", "A", "D"),
    ("A*C", "AAAC", "AAA"),
    ("A+C", "AC", "C"),
    ("[AC]G", "CG", "GG"),
    ("[^A]G", "CG", "AG"),
    ("A{2,3}G", "AAG", "AG"),
    ("(AC)+G", "ACACG", "AG"),
    ("A.C", "ADC", "AC"),
])
def test_search_semantics(pattern, yes, no):
    dfa = compile_dfa(pattern)
    assert dfa.accepts(yes), (pattern, yes)
    assert not dfa.accepts(no), (pattern, no)


def test_syntax_errors():
    # note: "A|" is VALID in this grammar (trailing empty alternative = ε)
    for bad in ["(", "[", "a", "A{3,1}", "*A"]:
        with pytest.raises(RegexSyntaxError):
            compile_dfa(bad)
    assert compile_dfa("A|", search=False).accepts("")


_PATTERN_ATOMS = st.sampled_from(
    ["A", "C", "G", "R", "[AC]", "[^RG]", ".", "A*", "C+", "G?", "(RG)", "R{2}",
     "[ILV]", "A{1,2}"]
)


@settings(max_examples=60, deadline=None)
@given(
    atoms=st.lists(_PATTERN_ATOMS, min_size=1, max_size=5),
    text=st.text(alphabet=AMINO_ACIDS, min_size=0, max_size=40),
)
def test_dfa_agrees_with_python_re(atoms, text):
    """Property: our DFA (search semantics) == python re.search."""
    pattern = "".join(atoms)
    dfa = compile_dfa(pattern)
    want = pyre.search(pattern, text) is not None
    assert dfa.accepts(text) == want, (pattern, text)


def test_minimization_preserves_language_and_shrinks():
    raw = subset_construct(compile_nfa("(.*)((AC)|(AG))"))
    mini = minimize(raw)
    assert mini.n_states <= raw.n_states
    rng = np.random.default_rng(0)
    for _ in range(100):
        s = "".join(AMINO_ACIDS[i] for i in rng.integers(0, 20, size=12))
        assert raw.accepts(s) == mini.accepts(s)


def test_prosite_translation():
    tr = translate("<A-x-[ST](2)-{V}>")
    assert tr.regex == "A.[ST]{2}[^V]"
    assert tr.anchored_start and tr.anchored_end
    tr2 = translate("R-G-D")
    assert tr2.regex == "RGD" and not tr2.anchored_start


def test_prosite_samples_compile():
    for pid, pat in PROSITE_SAMPLES.items():
        dfa = compile_prosite(pat)
        assert dfa.n_states >= 2, pid


def test_prosite_rgd():
    dfa = compile_prosite("R-G-D")
    assert dfa.accepts("AAARGDAAA")
    assert not dfa.accepts("RGA")


def test_prosite_errors():
    for bad in ["", "A-B2", "A-(2)", "[Z]"]:
        with pytest.raises(PrositeSyntaxError):
            compile_prosite(bad)


def test_random_dfa_complete():
    d = random_dfa(10, 5, seed=3)
    assert d.table.shape == (10, 5)
    assert (d.table >= 0).all() and (d.table < 10).all()
