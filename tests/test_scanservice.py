"""Scan service tests: persistent artifact store, coalescing scheduler,
resumable corpus jobs, and the prefix-scan census.

Acceptance pins (ISSUE 4):
* a second process — simulated by a fresh ``SFACache`` pointed at the same
  store directory — compiling the same pattern set performs **zero
  construction rounds**, asserted via ``construction_report``;
* coalesced scheduler results are bit-identical to per-request
  ``Scanner.scan``;
* a corpus job killed after N shards resumes and produces a byte-identical
  aggregate census to an uninterrupted run.
"""

import json

import numpy as np
import pytest
from _strategies import given, settings, st

from repro.construction import SFACache, construct_sfa, dfa_cache_key
from repro.core.dfa import random_dfa
from repro.core.prosite import synthetic_protein
from repro.engine import ConstructionPolicy, ScanPlan, Scanner
from repro.scanservice import (
    ArtifactStore,
    BatchScheduler,
    CorpusJob,
    CorpusManifest,
    ScanService,
    scan_shard,
)
from repro.scanservice.store import STORE_VERSION

PATTERNS = ["PS00016", "PS00005", "PS00001", "PS00006"]


@pytest.fixture(scope="module")
def docs():
    return [synthetic_protein(160, seed=i) for i in range(6)]


def _plan(cache, **kw):
    return ScanPlan(construction=ConstructionPolicy(cache=cache,
                                                    method="batched", **kw))


# --------------------------------------------------------------------------
# Artifact store: cold vs warm process (acceptance), corruption, LRU
# --------------------------------------------------------------------------


def test_cold_then_warm_process_zero_rounds(tmp_path, docs):
    """Acceptance: fresh SFACache + same store dir -> zero rounds."""
    cold = SFACache(backing=ArtifactStore(tmp_path / "store"))
    sc1 = Scanner.compile(PATTERNS, _plan(cold))
    r1 = sc1.construction_report
    assert r1.rounds > 0 and r1.cache_misses == len(PATTERNS)

    # "Second process": a fresh in-memory tier over the same directory.
    warm = SFACache(backing=ArtifactStore(tmp_path / "store"))
    sc2 = Scanner.compile(PATTERNS, _plan(warm))
    r2 = sc2.construction_report
    assert r2.rounds == 0 and r2.constructed == 0
    assert r2.cache_hits == len(PATTERNS)
    assert warm.info.disk_hits == len(PATTERNS)
    assert sc2.pattern_modes == sc1.pattern_modes
    assert np.array_equal(sc1.scan(docs).hits, sc2.scan(docs).hits)


def test_store_via_plan_path_plumbing(tmp_path):
    """ConstructionPolicy(store=<path>) wires the disk tier without any
    explicit ArtifactStore handling by the caller."""
    plan = ScanPlan(construction=ConstructionPolicy(
        cache=SFACache(), store=str(tmp_path / "s")))
    assert Scanner.compile(PATTERNS[:2], plan).construction_report.rounds > 0
    plan2 = ScanPlan(construction=ConstructionPolicy(
        cache=SFACache(), store=str(tmp_path / "s")))
    assert Scanner.compile(PATTERNS[:2], plan2).construction_report.rounds == 0


def test_store_blowup_markers_persist(tmp_path):
    store = ArtifactStore(tmp_path)
    d = random_dfa(6, 4, seed=3)
    key = dfa_cache_key(d)
    store.put_blowup(key, 10)
    assert store.get(key) == ("blowup", 10)
    store.put_blowup(key, 4)          # never downgrades
    assert store.get(key) == ("blowup", 10)
    # a fresh cache over the store answers the known blowup without work,
    # but a bigger budget is a miss (the closure might fit)
    cache = SFACache(backing=ArtifactStore(tmp_path))
    assert cache.lookup(d, max_states=8) == ("blowup", None)
    assert cache.lookup(d, max_states=100) == (None, None)
    # a positive artifact always wins over a marker
    sfa = construct_sfa(d)
    store.put_sfa(key, sfa)
    store.put_blowup(key, 10**6)
    kind, got = ArtifactStore(tmp_path).get(key)
    assert kind == "sfa" and got.n_states == sfa.n_states


def test_corrupt_and_partial_artifacts_are_misses_not_fatal(tmp_path):
    store = ArtifactStore(tmp_path)
    d = random_dfa(5, 4, seed=1)
    key = dfa_cache_key(d)
    sfa = construct_sfa(d)
    store.put_sfa(key, sfa)
    assert store.get(key) is not None

    # truncated payload (a crashed writer could never publish this — the
    # sidecar commits last — but disks corrupt): miss, not an exception
    payload = store._payload_path(key)
    payload.write_bytes(payload.read_bytes()[:20])
    assert store.get(key) is None

    # garbage sidecar: miss
    store.put_sfa(key, sfa)
    store._sidecar_path(key).write_text("{not json")
    assert store.get(key) is None

    # foreign format version: miss (stale store degrades to cold)
    store.put_sfa(key, sfa)
    side = store._sidecar_path(key)
    meta = json.loads(side.read_text())
    meta["version"] = STORE_VERSION + 1
    side.write_text(json.dumps(meta))
    assert store.get(key) is None

    # payload missing entirely (sidecar orphaned): miss
    store.put_sfa(key, sfa)
    store._payload_path(key).unlink()
    assert store.get(key) is None

    # and the whole cache stack shrugs: reconstruction, no raise
    cache = SFACache(backing=store)
    kind, got = cache.lookup(d, max_states=1000)
    assert (kind, got) == (None, None)
    sc = Scanner.compile([d], ScanPlan(
        sfa_state_budget=5 ** 5,
        construction=ConstructionPolicy(cache=cache),
    ))
    assert sc.construction_report.constructed == 1


def test_store_lru_eviction_by_bytes(tmp_path):
    dfas = [random_dfa(6, 4, seed=s) for s in range(4)]
    sfas = [construct_sfa(d) for d in dfas]
    keys = [dfa_cache_key(d) for d in dfas]
    scratch = ArtifactStore(tmp_path / "scratch")
    scratch.put_sfa(keys[3], sfas[3])
    fourth_bytes = scratch.total_bytes()

    store = ArtifactStore(tmp_path / "store", max_bytes=1 << 30)
    for k, s in zip(keys[:3], sfas[:3]):
        store.put_sfa(k, s)
    assert len(store) == 3
    store.get(keys[0])                     # refresh 0: now 1 is the LRU
    # Shrink the budget so the 4th insert overflows by one byte — exactly
    # the oldest-touched artifact must go.
    store.max_bytes = store.total_bytes() + fourth_bytes - 1
    store.put_sfa(keys[3], sfas[3])
    remaining = set(store.keys())
    assert keys[1] not in remaining
    assert {keys[0], keys[2], keys[3]} <= remaining
    assert store.total_bytes() <= store.max_bytes


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=60),
       n=st.integers(min_value=2, max_value=7),
       k=st.integers(min_value=2, max_value=5))
def test_store_round_trip_property(seed, n, k):
    """put_sfa -> get reproduces every array bit for bit.

    No pytest fixtures here: the ``_strategies`` fallback ``@given``
    cannot inject them, so the temp dir is managed by hand.
    """
    import shutil
    import tempfile

    d = random_dfa(n, k, seed=seed)
    sfa = construct_sfa(d)
    root = tempfile.mkdtemp(prefix="store-rt-")
    try:
        store = ArtifactStore(root)
        key = dfa_cache_key(d)
        store.put_sfa(key, sfa)
        kind, got = store.get(key)
        assert kind == "sfa"
        assert np.array_equal(got.mappings, sfa.mappings)
        assert np.array_equal(got.delta, sfa.delta)
        assert np.array_equal(got.fingerprints, sfa.fingerprints)
        assert np.array_equal(got.dfa.table, d.table)
        assert np.array_equal(got.dfa.accepting, d.accepting)
        assert got.dfa.start == d.start and got.dfa.alphabet == d.alphabet
    finally:
        shutil.rmtree(root, ignore_errors=True)


def test_store_entries_lru_order_and_limited_preload(tmp_path):
    dfas = [random_dfa(4, 3, seed=s) for s in range(3)]
    sfas = [construct_sfa(d) for d in dfas]
    keys = [dfa_cache_key(d) for d in dfas]
    store = ArtifactStore(tmp_path)
    for k, s in zip(keys, sfas):
        store.put_sfa(k, s)
    store.get(keys[0])                       # 0 becomes the hottest
    assert [k for k, _, _ in store.entries()] == [keys[1], keys[2], keys[0]]
    # a capped preload keeps the most-recently-used artifacts
    cache = SFACache(backing=ArtifactStore(tmp_path))
    assert cache.preload(max_entries=1) == 1
    assert list(cache._entries) == [keys[0]]


def test_warm_start_preload(tmp_path, docs):
    svc = ScanService(tmp_path / "store")
    svc.scanner(PATTERNS)                  # cold: populate the store
    svc.close()

    svc2 = ScanService(tmp_path / "store")
    assert svc2.warm_start() == len(PATTERNS)
    sc = svc2.scanner(PATTERNS)
    r = sc.construction_report
    # preload already promoted everything into memory: zero rounds AND the
    # per-compile lookups never even touch the disk tier again
    disk_after_preload = svc2.cache.info.disk_hits
    assert r.rounds == 0 and r.cache_hits == len(PATTERNS)
    assert svc2.cache.info.disk_hits == disk_after_preload
    svc2.close()


# --------------------------------------------------------------------------
# Coalescing scheduler
# --------------------------------------------------------------------------


def test_coalesced_results_bit_identical_to_per_request(docs):
    """Acceptance: demuxed batch slices == per-request Scanner.scan."""
    cache = SFACache()
    sched = BatchScheduler(_plan(cache))
    requests = [
        (PATTERNS[:2], docs[:3]),
        (PATTERNS[1:], docs[2:]),
        ([PATTERNS[0], PATTERNS[3]], [docs[0], docs[5]]),
    ]
    tickets = [sched.submit(p, d) for p, d in requests]
    assert sched.flush() == len(requests)
    assert sched.stats.flushes == 1
    assert sched.stats.union_patterns == len(PATTERNS)   # dedup across reqs
    assert sched.stats.union_docs == len(docs)
    for t, (p, d) in zip(tickets, requests):
        ref = Scanner.compile(p, _plan(cache)).scan(d)
        got = t.result()
        assert got.batch_size == len(requests)
        assert got.ids == ref.ids
        assert np.array_equal(got.hits, ref.hits)
        assert np.array_equal(got.counts, ref.counts)


def test_sync_driver_result_and_max_batch_autoflush(docs):
    sched = BatchScheduler(_plan(SFACache()), max_batch=2)
    t1 = sched.submit(PATTERNS[0], docs[0])
    assert not t1.done()
    t2 = sched.submit(PATTERNS[1], docs[1])   # hits max_batch -> autoflush
    assert t1.done() and t2.done()
    t3 = sched.submit(PATTERNS[0], docs[2])
    assert t3.result().hits.shape == (1, 1)   # result() flushes on demand
    assert sched.stats.flushes == 2


def test_scheduler_validation_and_close(docs):
    with pytest.raises(ValueError):
        BatchScheduler(driver="fiber")
    sched = BatchScheduler(_plan(SFACache()))
    with pytest.raises(ValueError):
        sched.submit([], docs[0])
    with pytest.raises(TypeError):
        sched.submit([object()], docs[0])
    sched.close()
    with pytest.raises(RuntimeError):
        sched.submit(PATTERNS[0], docs[0])


def test_scanner_memo_is_lru_bounded(docs):
    """Satellite: the scheduler's union-bank Scanner memo no longer grows
    without bound — it is an LRU capped at ``max_scanners``, evictions are
    counted, and an evicted key recompiles to bit-identical results."""
    cache = SFACache()
    sched = BatchScheduler(_plan(cache), max_scanners=2)
    union_sets = [PATTERNS[:2], PATTERNS[1:3], PATTERNS[2:]]

    first = {}
    for i, pats in enumerate(union_sets):     # three distinct union keys
        first[i] = sched.submit(pats, docs[:2]).result()
    assert len(sched._scanners) == 2          # capped, not 3
    assert sched.stats.scanner_evictions == 1  # union_sets[0] fell out
    assert sched.stats.scanner_memo_hits == 0

    # the hottest key answers from the memo — no eviction, one hit
    again = sched.submit(union_sets[2], docs[:2]).result()
    assert sched.stats.scanner_memo_hits == 1
    assert sched.stats.scanner_evictions == 1
    assert np.array_equal(again.hits, first[2].hits)

    # the evicted key recompiles (evicting the new LRU) bit-identically
    re0 = sched.submit(union_sets[0], docs[:2]).result()
    assert sched.stats.scanner_memo_hits == 1
    assert sched.stats.scanner_evictions == 2
    assert len(sched._scanners) == 2
    assert np.array_equal(re0.hits, first[0].hits)
    # the recompile was served by the SFA cache, not reconstruction
    assert cache.info.hits > 0

    with pytest.raises(ValueError):
        BatchScheduler(_plan(SFACache()), max_scanners=0)
    sched.close()


def test_thread_driver_coalesces_and_matches(docs):
    with BatchScheduler(_plan(SFACache()), driver="thread",
                        window_s=0.05) as sched:
        tickets = [sched.submit(PATTERNS[:2], [d]) for d in docs[:3]]
        results = [t.result(timeout=60) for t in tickets]
    ref = Scanner.compile(PATTERNS[:2], _plan(SFACache())).scan(docs[:3])
    for i, res in enumerate(results):
        assert np.array_equal(res.hits[:, 0], ref.hits[:, i])


# --------------------------------------------------------------------------
# Prefix-scan census
# --------------------------------------------------------------------------


def test_census_windows_bit_identical_to_materialized(docs):
    seq = synthetic_protein(400, seed=42)
    sc = Scanner.compile(PATTERNS, _plan(SFACache()))
    for window, stride in [(40, 8), (60, 60), (24, 12)]:
        res = sc.census_windows(seq, window, stride)
        n_win = (len(seq) - window) // stride + 1
        naive = sc.scan([seq[i * stride: i * stride + window]
                         for i in range(n_win)])
        assert res.hits.shape == (len(PATTERNS), n_win)
        assert np.array_equal(res.hits, naive.hits)
        assert np.array_equal(res.counts, naive.counts)


def test_census_windows_validation_and_edges():
    sc = Scanner.compile(PATTERNS[:1], _plan(SFACache()))
    with pytest.raises(ValueError):
        sc.census_windows("ACDEF", window=4, stride=3)   # 3 doesn't divide 4
    with pytest.raises(ValueError):
        sc.census_windows("ACDEF", window=0)
    empty = sc.census_windows("ACD", window=8)           # shorter than window
    assert empty.hits.shape == (1, 0)


# --------------------------------------------------------------------------
# Resumable corpus jobs
# --------------------------------------------------------------------------


def test_corpus_job_kill_and_resume_byte_identical(tmp_path, docs):
    """Acceptance: killed-after-N-shards resume == uninterrupted run."""
    cache = SFACache()
    man = CorpusManifest.from_docs(docs, shard_docs=2)
    assert man.n_shards == 3

    job = CorpusJob(PATTERNS, man, tmp_path / "interrupted", _plan(cache))
    rep = job.run(max_shards=1)            # "killed" after one shard
    assert rep.scanned == 1 and not rep.complete
    with pytest.raises(RuntimeError):
        job.aggregate()

    resumed = CorpusJob(PATTERNS, man, tmp_path / "interrupted", _plan(cache))
    rep2 = resumed.run()
    assert rep2.done_before == 1 and rep2.scanned == 2 and rep2.complete

    uninterrupted = CorpusJob(PATTERNS, man, tmp_path / "straight",
                              _plan(cache))
    assert uninterrupted.run().complete
    a, b = resumed.aggregate(), uninterrupted.aggregate()
    assert np.array_equal(a.hits, b.hits)
    assert a.hits.tobytes() == b.hits.tobytes()          # byte-identical
    assert resumed.census().tobytes() == uninterrupted.census().tobytes()
    # sanity: the aggregate equals one flat scan of the corpus
    flat = Scanner.compile(PATTERNS, _plan(cache)).scan(docs)
    assert np.array_equal(a.hits, flat.hits)


def test_corpus_job_rejects_foreign_workdir(tmp_path, docs):
    man = CorpusManifest.from_docs(docs, shard_docs=3)
    CorpusJob(PATTERNS, man, tmp_path / "w", _plan(SFACache()))
    other = CorpusManifest.from_docs(docs[:4], shard_docs=2)
    with pytest.raises(ValueError):
        CorpusJob(PATTERNS, other, tmp_path / "w", _plan(SFACache()))


def test_corpus_job_corrupt_shard_checkpoint_rescans(tmp_path, docs):
    man = CorpusManifest.from_docs(docs, shard_docs=2)
    job = CorpusJob(PATTERNS, man, tmp_path / "j", _plan(SFACache()))
    job.run()
    job._shard_path(1).write_bytes(b"\x00\x01partial")
    resumed = CorpusJob(PATTERNS, man, tmp_path / "j", _plan(SFACache()))
    assert resumed.pending() == [1]
    assert resumed.run().scanned == 1
    flat = Scanner.compile(PATTERNS, _plan(SFACache())).scan(docs)
    assert np.array_equal(resumed.aggregate().hits, flat.hits)


def test_corpus_job_streaming_path_matches_scan(tmp_path):
    """Docs past the stream threshold go through Scanner.stream; hits are
    bit-identical to the batch scan path."""
    mix = [synthetic_protein(L, seed=L) for L in (30, 500, 64, 700)]
    man = CorpusManifest.from_docs(mix, shard_docs=4)
    sc = Scanner.compile(PATTERNS, _plan(SFACache()))
    streamed = scan_shard(sc, man, 0, stream_threshold=200)
    assert np.array_equal(streamed, sc.scan(mix).hits)


def test_windowed_corpus_job_census_path(tmp_path):
    """Sliding-window manifests census through census_windows per shard and
    aggregate bit-identically to one whole-sequence prefix-scan census."""
    seq = synthetic_protein(600, seed=7)
    cache = SFACache()
    man = CorpusManifest.sliding(seq, window=48, stride=16, shard_windows=9)
    assert man.n_shards > 1
    job = CorpusJob(PATTERNS, man, tmp_path / "wj", _plan(cache))
    job.run(max_shards=1)                  # interruption on the window path
    job = CorpusJob(PATTERNS, man, tmp_path / "wj", _plan(cache))
    job.run()
    whole = Scanner.compile(PATTERNS, _plan(cache)).census_windows(
        seq, 48, 16)
    assert np.array_equal(job.aggregate().hits, whole.hits)
    assert job.census().tobytes() == whole.counts.tobytes()


def test_corpus_job_shard_map_distribution_matches_local(tmp_path, docs):
    cache = SFACache()
    man = CorpusManifest.from_docs(docs[:4], shard_docs=2)
    local = CorpusJob(PATTERNS, man, tmp_path / "loc", _plan(cache))
    local.run()
    dist_plan = _plan(cache).with_(distribution="shard_map")
    dist = CorpusJob(PATTERNS, man, tmp_path / "dist", dist_plan)
    dist.run()
    assert np.array_equal(local.aggregate().hits, dist.aggregate().hits)


def test_manifest_validation():
    with pytest.raises(ValueError):
        CorpusManifest.from_docs([])
    with pytest.raises(ValueError):
        CorpusManifest.from_docs(["ACD"], shard_docs=0)
    with pytest.raises(ValueError):
        CorpusManifest.sliding("ACDACD", window=4, stride=3)
    with pytest.raises(ValueError):
        CorpusManifest.sliding("ACD", window=8)
    man = CorpusManifest.from_docs(["ACD", "DCA", "CAD"], shard_docs=2)
    assert man.n_shards == 2 and man.shard_range(1) == (2, 3)
    with pytest.raises(IndexError):
        man.shard_range(2)


# --------------------------------------------------------------------------
# The service facade / engine hook
# --------------------------------------------------------------------------


def test_scanner_service_hook_end_to_end(tmp_path, docs):
    with Scanner.service(tmp_path / "store") as svc:
        t = svc.submit(PATTERNS[:2], docs[:2])
        svc.flush()
        first = t.result()
    with Scanner.service(tmp_path / "store") as svc2:
        assert svc2.warm_start() >= 2
        sc = svc2.scanner(PATTERNS[:2])
        assert sc.construction_report.rounds == 0
        assert np.array_equal(sc.scan(docs[:2]).hits, first.hits)
