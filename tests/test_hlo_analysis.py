"""HLO analyzer: shape parsing, collective accounting, trip-count correction."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import _shape_bytes, analyze_module
from repro.analysis.roofline import HW, model_flops, roofline_terms
from repro.compat import cost_analysis
from repro.config import SHAPES
from repro.configs import get_config


def test_shape_bytes():
    assert _shape_bytes("f32[128,128]{1,0}") == 128 * 128 * 4
    assert _shape_bytes("bf16[16,4096]") == 16 * 4096 * 2
    assert _shape_bytes("(f32[8], s32[])") == 36
    assert _shape_bytes("pred[10]") == 10
    assert _shape_bytes("f32[]") == 4


def test_trip_count_correction_exact():
    """A scanned matmul must report trip × per-iteration flops."""

    def scanned(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None

        out, _ = jax.lax.scan(body, x, w)
        return out

    W = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    X = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    co = jax.jit(scanned).lower(W, X).compile()
    st = analyze_module(co.as_text(), 1)
    assert st.flops == 8 * 2 * 64**3
    # raw cost_analysis counts the body once — our whole reason to exist
    assert cost_analysis(co)["flops"] < st.flops


def test_nested_scan_multiplies():
    def nested(w, x):
        def outer(c, wi):
            def inner(ci, _):
                return jnp.tanh(ci @ wi), None

            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None

        out, _ = jax.lax.scan(outer, x, w)
        return out

    W = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)
    X = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    co = jax.jit(nested).lower(W, X).compile()
    st = analyze_module(co.as_text(), 1)
    assert st.flops == 4 * 3 * 2 * 32**3


def test_traffic_excludes_fusion_internals():
    """Fused elementwise chains must not inflate HBM traffic."""

    def chain(x):
        for _ in range(10):
            x = jnp.tanh(x) * 1.1 + 0.5
        return x

    X = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    co = jax.jit(chain).lower(X).compile()
    st = analyze_module(co.as_text(), 1)
    nbytes = 1024 * 1024 * 4
    # in + out (+ small slack); NOT 10 roundtrips
    assert st.traffic_bytes <= 4 * nbytes, st.traffic_bytes


def test_model_flops_formulas():
    cfg = get_config("qwen3_8b")
    train = model_flops(cfg, SHAPES["train_4k"])
    prefill = model_flops(cfg, SHAPES["prefill_32k"])
    assert train == 6 * cfg.active_param_count() * 256 * 4096
    assert prefill == 2 * cfg.active_param_count() * 32 * 32768


def test_moe_uses_active_params():
    cfg = get_config("grok1_314b")
    assert cfg.active_param_count() < 0.3 * cfg.param_count()


def test_roofline_dominant_pick():
    cfg = get_config("qwen3_8b")
    r = roofline_terms(
        cfg, SHAPES["train_4k"],
        per_device_flops=1e12, per_device_bytes=1e9, per_device_coll_bytes=1e9,
        n_chips=256,
    )
    # 1e12/197e12 ≈ 5e-3 vs 1e9/819e9 ≈ 1.2e-3 vs 1e9/50e9 = 2e-2
    assert r.dominant == "collective"
    assert r.collective_s > r.compute_s > r.memory_s
