"""Rabin fingerprints: Barrett reduction vs naive GF(2) mod, limb paths."""

import jax.numpy as jnp
import numpy as np
from _strategies import given, settings, st

from repro.core import fingerprint as fp


CONSTS = fp.BarrettConstants.create()


@settings(max_examples=200, deadline=None)
@given(a=st.integers(min_value=0, max_value=(1 << 128) - 1))
def test_barrett_matches_naive_mod(a):
    assert fp.barrett_reduce_int(a, CONSTS) == fp.poly_mod_int(a, CONSTS.poly)


@settings(max_examples=50, deadline=None)
@given(
    a=st.integers(min_value=0, max_value=(1 << 64) - 1),
    b=st.integers(min_value=0, max_value=(1 << 64) - 1),
)
def test_clmul_linearity(a, b):
    # carry-less multiplication distributes over XOR
    c = 0x123456789ABCDEF
    assert fp.clmul_int(a ^ b, c) == fp.clmul_int(a, c) ^ fp.clmul_int(b, c)


def test_default_poly_is_irreducible():
    assert fp.is_irreducible((1 << 64) | fp.DEFAULT_POLY_LOW)


def test_random_irreducible():
    p = fp.random_irreducible_poly64(7)
    assert p >> 64 == 1 and fp.is_irreducible(p)


def test_known_reducible_rejected():
    # x^64 alone factors as x * x^63
    assert not fp.is_irreducible(1 << 64 | 0)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=33),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_np_jax_int_agree(n, seed):
    rng = np.random.default_rng(seed)
    states = rng.integers(0, 1 << 16, size=(3, n)).astype(np.int32)
    fnp = fp.fingerprint_states_np(states, CONSTS)
    fjx = np.asarray(fp.fingerprint_states(jnp.asarray(states), CONSTS))
    assert np.array_equal(fnp, fjx)
    packed = np.asarray(fp.pack_states_u32(jnp.asarray(states)))
    for b in range(3):
        want = fp.fingerprint_int(packed[b], CONSTS)
        got = (int(fnp[b, 0]) << 32) | int(fnp[b, 1])
        assert got == want


def test_no_collisions_on_bulk_random_vectors():
    """Paper's collision bound: P < n^2 m / 2^64 — astronomically small here;
    10k random 64-state vectors must produce 10k distinct fingerprints."""
    rng = np.random.default_rng(0)
    states = rng.integers(0, 1 << 16, size=(10_000, 64)).astype(np.int32)
    fps = fp.fingerprint_states_np(states, CONSTS)
    packed = fps[:, 0].astype(np.uint64) << np.uint64(32) | fps[:, 1].astype(np.uint64)
    assert len(np.unique(packed)) == 10_000


def test_fingerprint_depends_on_position():
    # permuting the vector must (virtually always) change the fingerprint
    a = np.asarray([[1, 2, 3, 4]], dtype=np.int32)
    b = np.asarray([[4, 3, 2, 1]], dtype=np.int32)
    fa = fp.fingerprint_states_np(a, CONSTS)
    fb = fp.fingerprint_states_np(b, CONSTS)
    assert not np.array_equal(fa, fb)
