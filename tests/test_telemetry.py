"""Fleet-telemetry tests: the HTTP front and the corpus-job flight trail.

Acceptance pins (ISSUE 10):
* a live ``/metrics`` scrape taken **during** an active coalesced
  scheduler burst parses with ``obs.parse_prometheus`` and round-trips —
  concurrent scrapes from several threads included;
* merging the per-shard flight-recorder deltas of a corpus job that was
  killed after N shards and resumed by a fresh process reproduces the
  uninterrupted job's deterministic ``jobs.*`` counter and histogram
  totals exactly.
"""

import json
import threading
import urllib.error
from urllib.request import urlopen

import numpy as np
import pytest

from repro import obs
from repro.construction import SFACache
from repro.core.prosite import synthetic_protein
from repro.engine import ConstructionPolicy, ScanPlan, Scanner
from repro.scanservice import (
    CorpusJob,
    CorpusManifest,
    ScanService,
    TelemetryServer,
)
from repro.scanservice.telemetry import PROM_CONTENT_TYPE

PATTERNS = ["PS00016", "PS00005"]


@pytest.fixture(autouse=True)
def obs_enabled():
    obs.enable()
    yield
    obs.enable()


@pytest.fixture(scope="module")
def docs():
    return [synthetic_protein(120, seed=i) for i in range(4)]


@pytest.fixture(scope="module")
def shared_cache():
    return SFACache()


def _plan(cache):
    return ScanPlan(construction=ConstructionPolicy(cache=cache,
                                                    method="batched"))


def _get(url: str):
    """-> (status, content-type, body text)."""
    with urlopen(url, timeout=10) as resp:
        return resp.status, resp.headers.get("Content-Type"), \
            resp.read().decode("utf-8")


# --------------------------------------------------------------------------
# TelemetryServer: lifecycle and the three endpoints
# --------------------------------------------------------------------------


def test_server_lifecycle_and_metrics_endpoint():
    obs.counter("t.tele.c", help="a described counter").inc(3)
    srv = TelemetryServer()
    assert not srv.running and srv.port is None and srv.url is None
    with srv:
        assert srv.running and srv.port > 0
        assert srv.start() is srv   # idempotent
        status, ctype, body = _get(f"{srv.url}/metrics")
        assert status == 200 and ctype == PROM_CONTENT_TYPE
        assert "# HELP t_tele_c a described counter" in body
        parsed = obs.parse_prometheus(body)
        assert parsed["t_tele_c"] == 3
        # the parse->render->parse fixpoint (the round-trip contract, over
        # the live scrape rather than a hand-built snapshot)
        assert obs.parse_prometheus(obs.render_prometheus(parsed)) == parsed
    assert not srv.running
    srv.close()   # idempotent after close


def test_healthz_without_service_and_traces_and_404():
    with TelemetryServer() as srv:
        status, ctype, body = _get(f"{srv.url}/healthz")
        health = json.loads(body)
        assert status == 200 and ctype == "application/json"
        assert health["status"] == "ok" and health["pid"] > 0
        assert "scheduler" not in health   # bare server: process identity only

        with obs.span("t.tele.span"):
            pass
        status, _, body = _get(f"{srv.url}/traces?limit=5")
        traces = json.loads(body)
        assert status == 200
        assert any("t.tele.span" in t["names"] for t in traces["traces"])
        assert len(traces["traces"]) <= 5

        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(f"{srv.url}/nope")
        assert ei.value.code == 404
        assert "/metrics" in json.loads(ei.value.read().decode())["routes"]
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(f"{srv.url}/traces?limit=banana")
        assert ei.value.code == 400


def test_service_owns_telemetry_and_healthz_reports_state(tmp_path, docs,
                                                          shared_cache):
    svc = ScanService(tmp_path / "store", plan=_plan(shared_cache),
                      cache=shared_cache)
    with svc:
        srv = svc.serve_telemetry()
        assert svc.telemetry is srv and srv.running
        with pytest.raises(RuntimeError):
            svc.serve_telemetry()   # one server per service
        ticket = svc.submit(PATTERNS, docs)
        svc.flush()
        ticket.result()
        health = json.loads(_get(f"{srv.url}/healthz")[2])
        assert health["status"] == "ok"
        assert health["scheduler"]["requests"] >= 1
        assert health["scheduler"]["driver"] == "sync"
        assert 0.0 <= health["cache"]["hit_rate"] <= 1.0
        assert health["store"]["entries"] >= 0
        assert health["store"]["root"] == str(tmp_path / "store")
        url = srv.url
    # close() stopped the server and released the slot
    assert svc.telemetry is None and not srv.running
    with pytest.raises(OSError):
        _get(f"{url}/healthz")


def test_concurrent_scrapes_during_active_bursts(docs, shared_cache):
    """Acceptance: /metrics stays parseable while the scheduler is mid-
    burst, under several scraping threads — every scrape round-trips."""
    svc = ScanService(plan=_plan(shared_cache), cache=shared_cache,
                      driver="thread", window_s=0.001, max_batch=8)
    with svc:
        srv = svc.serve_telemetry()
        stop = threading.Event()
        failures: list = []

        def scrape_loop():
            while not stop.is_set():
                try:
                    _, ctype, body = _get(f"{srv.url}/metrics")
                    assert ctype == PROM_CONTENT_TYPE
                    parsed = obs.parse_prometheus(body)
                    assert obs.parse_prometheus(
                        obs.render_prometheus(parsed)) == parsed
                except Exception as e:   # pragma: no cover - failure path
                    failures.append(e)
                    return

        scrapers = [threading.Thread(target=scrape_loop) for _ in range(3)]
        for t in scrapers:
            t.start()
        try:
            # Keep the scheduler genuinely busy under the scrapes.
            tickets = [svc.submit(PATTERNS[i % 2:i % 2 + 1], docs)
                       for i in range(12)]
            results = [t.result() for t in tickets]
        finally:
            stop.set()
            for t in scrapers:
                t.join()
        assert not failures, failures[0]
        # coalescing under scrapes stayed bit-identical to direct scans
        direct = Scanner.compile(PATTERNS, _plan(shared_cache))
        full = direct.scan(docs).hits
        for i, res in enumerate(results):
            assert np.array_equal(res.hits, full[i % 2:i % 2 + 1])
        snap = obs.parse_prometheus(_get(f"{srv.url}/metrics")[2])
        assert snap["scheduler_requests"] >= 12


# --------------------------------------------------------------------------
# CorpusJob flight trail: kill/resume merges to the whole-job view
# --------------------------------------------------------------------------


def _job(tmp_path, name, cache, docs, **kwargs):
    man = CorpusManifest.from_docs(docs, shard_docs=3)
    return CorpusJob(PATTERNS, man, tmp_path / name, plan=_plan(cache),
                     **kwargs)


def test_killed_and_resumed_job_flight_merge_is_exact(tmp_path,
                                                      shared_cache):
    """Acceptance: per-shard flight deltas of a killed-then-resumed job
    merge to the uninterrupted job's jobs.* totals bit-exactly."""
    docs = [synthetic_protein(60, seed=i) for i in range(20)]

    straight = _job(tmp_path, "straight", shared_cache, docs)
    assert straight.run().complete
    want = straight.flight_totals()["metrics"]
    assert want["jobs.shards_scanned"] == straight.manifest.n_shards
    assert want["jobs.items_scanned"] == len(docs)
    assert want["jobs.shard_items"]["count"] == straight.manifest.n_shards

    # "Kill" after 3 shards; a fresh job object (fresh process stand-in,
    # same workdir) resumes and appends to the same flight trail.
    first = _job(tmp_path, "resumed", shared_cache, docs)
    first.run(max_shards=3)
    assert not first.complete
    second = _job(tmp_path, "resumed", shared_cache, docs)
    assert second.run().complete
    got = second.flight_totals()["metrics"]
    assert got == want   # counters AND histogram, bit-exact

    # the scan results match too (the pre-existing kill/resume contract)
    assert np.array_equal(straight.aggregate().hits,
                          second.aggregate().hits)


def test_flight_records_are_per_shard_and_attributed(tmp_path, shared_cache,
                                                     docs):
    job = _job(tmp_path, "attributed", shared_cache, docs)
    job.run()
    shard_recs = [r for r in job.flight_records()
                  if r.get("kind") == "flight" and "shard" in r]
    assert len(shard_recs) == job.manifest.n_shards
    for rec in shard_recs:
        start, stop = job.manifest.shard_range(rec["shard"])
        assert rec["items"] == stop - start
        assert rec["metrics"]["jobs.shards_scanned"] == 1
        assert rec["metrics"]["jobs.items_scanned"] == stop - start
        assert rec["host"] and rec["pid"] > 0
    # each shard's spans rode along on the trail
    span_names = {r["name"] for r in job.flight_records()
                  if r.get("kind") == "span"}
    assert "jobs.shard" in span_names


def test_flight_can_be_disabled(tmp_path, shared_cache, docs):
    job = _job(tmp_path, "noflight", shared_cache, docs, flight=False)
    assert job.flight is None
    job.run()
    assert not job.flight_path.exists()
    assert job.flight_records() == []
