"""Optimizers: convergence on a quadratic, int8-state fidelity, adafactor."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig
from repro.models.base import ParamSpec, init_params
from repro.optim import build_optimizer, make_schedule
from repro.optim.api import _dq8, _q8, clip_by_global_norm


SPECS = {"w": ParamSpec((4, 256), ("embed", "mlp")), "b": ParamSpec((4,), (None,))}


def _fit(opt_name, steps=200, lr=0.05):
    cfg = OptimizerConfig(name=opt_name, lr=lr, warmup_steps=5, total_steps=steps,
                          schedule="constant", weight_decay=0.0)
    opt = build_optimizer(cfg)
    params = init_params(SPECS, jax.random.PRNGKey(0))
    target = jax.tree.map(lambda x: jnp.ones_like(x) * 0.5, params)
    state = opt.init(params, SPECS)

    def loss_fn(p):
        return sum(
            jnp.sum(jnp.square(a - b))
            for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(target))
        )

    for step in range(steps):
        grads = jax.grad(loss_fn)(params)
        params, state, stats = opt.update(
            grads, state, params, jnp.asarray(step), SPECS
        )
    return float(loss_fn(params))


@pytest.mark.parametrize("name", ["adamw", "adamw8bit", "adafactor"])
def test_optimizers_converge_on_quadratic(name):
    final = _fit(name)
    # adafactor's factored second moment + RMS update clipping leave it
    # bouncing near the optimum on this tiny quadratic (initial loss ~237);
    # adam variants drive it to ~0.
    tol = 2.0 if name == "adafactor" else 1e-2
    assert final < tol, (name, final)


def test_int8_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 512)).astype(np.float32)) * 0.01
    codes, scales = _q8(x)
    assert codes.dtype == jnp.int8
    back = _dq8(codes, scales)
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.02, rel


def test_adamw8bit_state_is_quantized():
    cfg = OptimizerConfig(name="adamw8bit")
    opt = build_optimizer(cfg)
    state_specs = opt.state_specs(SPECS)
    assert state_specs["w"]["m_q"].dtype == "int8"
    assert state_specs["b"]["m"].dtype == "float32"  # small params stay f32


def test_adafactor_state_is_factored():
    cfg = OptimizerConfig(name="adafactor")
    opt = build_optimizer(cfg)
    ss = opt.state_specs(SPECS)
    assert ss["w"]["vr"].shape == (4,)
    assert ss["w"]["vc"].shape == (256,)


def test_global_norm_clip():
    grads = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) > 30
    out_norm = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    np.testing.assert_allclose(out_norm, 1.0, rtol=1e-5)


def test_schedule_shapes():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100, schedule="cosine")
    s = make_schedule(cfg)
    assert float(s(0)) == 0.0
    assert float(s(10)) <= 1e-3 + 1e-9
    np.testing.assert_allclose(float(s(5)), 5e-4, rtol=1e-5)
    assert float(s(100)) < 1e-4


def test_weight_decay_only_on_matrices():
    cfg = OptimizerConfig(name="adamw", lr=1e-2, weight_decay=0.5,
                          schedule="constant", warmup_steps=0)
    opt = build_optimizer(cfg)
    params = init_params(SPECS, jax.random.PRNGKey(1))
    state = opt.init(params, SPECS)
    zero_grads = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = opt.update(zero_grads, state, params, jnp.asarray(1), SPECS)
    # matrix decayed, bias untouched
    assert float(jnp.max(jnp.abs(p2["w"]))) < float(jnp.max(jnp.abs(params["w"])))
    np.testing.assert_allclose(np.asarray(p2["b"]), np.asarray(params["b"]))
