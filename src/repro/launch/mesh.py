"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax init, and unit
tests must keep seeing one device.
"""

from __future__ import annotations

from repro.compat import make_mesh
from repro.config import MeshConfig, MULTI_POD, SINGLE_POD


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices the host actually has (tests)."""
    return make_mesh((data, model), ("data", "model"))
