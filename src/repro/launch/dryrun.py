import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count at first
init, and only the dry-run may see 512 placeholder devices (tests and
benches see 1).

Per cell this driver:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. resolves the arch's sharding rules (+ per-cell fixes, e.g. batch=1 on
     long_500k cannot shard the data axes),
  3. lowers the cell's step function against ShapeDtypeStruct stand-ins
     (params, optimizer state, caches — zero bytes allocated),
  4. compiles, prints ``memory_analysis()`` (proves it fits) and
     ``cost_analysis()``,
  5. runs the trip-corrected HLO analysis and the three-term roofline, and
  6. writes everything to ``results/dryrun/<cell>.json`` for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3_8b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]   # subprocess per cell
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def build_cell(arch: str, shape_name: str, multi_pod: bool, rule_overrides=None):
    """Returns (lowered, meta) for one cell."""
    from repro.config import SHAPES
    from repro.configs import get_run
    from repro.launch.mesh import make_production_mesh, mesh_config
    from repro.models import base as mbase
    from repro.models.model import build_model, input_specs
    from repro.optim import build_optimizer
    from repro.serve.steps import make_decode_step, make_prefill_step
    from repro.sharding.rules import Dist, Rules
    from repro.train.steps import make_train_step

    run = get_run(arch, shape_name, mesh_config(multi_pod=multi_pod))
    cfg, shape = run.model, run.shape
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_data = 1
    for ax in mesh.axis_names:
        if ax != "model":
            n_data *= mesh.shape[ax]

    rules = Rules(mesh_axes=tuple(mesh.axis_names)).with_overrides(cfg.sharding_overrides)
    if shape.global_batch % n_data:
        # batch can't shard the data axes (long_500k: B=1) — replicate it.
        rules = rules.with_overrides({"batch": None, "cache_batch": None})
    if rule_overrides:
        rules = rules.with_overrides(rule_overrides)
    dist = Dist.for_mesh(mesh, rules)

    model = build_model(cfg)
    param_specs = model.param_specs()
    params = mbase.shape_structs(param_specs, rules, mesh)
    inputs = input_specs(cfg, shape, mesh, rules)

    meta = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.size,
        "params_b": model.n_params() / 1e9,
        "kind": shape.kind,
    }

    with mesh:
        if shape.kind == "train":
            # donate params + opt state: the update aliases them in place
            # (without donation the optimizer temporarily doubles the f32
            # param + grad buffers — the difference between grok fitting
            # 16 GB and not).
            step_fn, opt = make_train_step(model, run, dist)
            opt_specs = opt.state_specs(param_specs)
            opt_state = mbase.shape_structs(opt_specs, rules, mesh)
            step_ct = jax.ShapeDtypeStruct((), jnp.int32,
                                           sharding=NamedSharding(mesh, P()))
            lowered = jax.jit(step_fn, donate_argnums=(0, 1)).lower(
                params, opt_state, step_ct, inputs
            )
        elif shape.kind == "prefill":
            step_fn = make_prefill_step(model, run, dist)
            cache = model.cache_structs(shape.global_batch, run.max_cache_len, rules, mesh)
            lowered = jax.jit(step_fn, donate_argnums=(1,)).lower(params, cache, inputs)
        else:  # decode
            step_fn = make_decode_step(model, run, dist)
            cache = model.cache_structs(shape.global_batch, run.max_cache_len, rules, mesh)
            lowered = jax.jit(step_fn, donate_argnums=(1,)).lower(
                params, cache, inputs["tokens"], inputs["cache_pos"]
            )
    return lowered, meta, mesh, cfg, shape


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path) -> dict:
    from repro.analysis.hlo import analyze_module
    from repro.analysis.roofline import roofline_terms

    t0 = time.time()
    lowered, meta, mesh, cfg, shape = build_cell(arch, shape_name, multi_pod)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    print(f"[{arch} x {shape_name}] memory_analysis: {mem}")
    from repro.compat import cost_analysis

    cost = cost_analysis(compiled)
    print(f"[{arch} x {shape_name}] cost_analysis flops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e} (while bodies counted once)")

    hlo = compiled.as_text()
    hlo_dir = out_dir / "hlo"
    hlo_dir.mkdir(parents=True, exist_ok=True)
    import gzip
    tag = "multipod" if multi_pod else "pod"
    with gzip.open(hlo_dir / f"{arch}__{shape_name}__{tag}.hlo.gz", "wt") as f:
        f.write(hlo)
    stats = analyze_module(hlo, mesh.size)
    roof = roofline_terms(
        cfg, shape,
        per_device_flops=stats.flops,
        per_device_bytes=stats.traffic_bytes,
        per_device_coll_bytes=stats.coll_operand_bytes,
        n_chips=mesh.size,
    )

    arg_gb = mem.argument_size_in_bytes / 1e9
    temp_gb = mem.temp_size_in_bytes / 1e9
    fits = (arg_gb + temp_gb) < 16.0
    result = {
        **meta,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_gb": arg_gb,
            "temp_gb": temp_gb,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
            "fits_16gb": fits,
        },
        "cost_analysis": {
            "flops_body_once": cost.get("flops", 0.0),
            "bytes_body_once": cost.get("bytes accessed", 0.0),
        },
        "hlo_stats": stats.to_json(),
        "roofline": roof.to_json(),
        "status": "ok",
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    cell = f"{arch}__{shape_name}__{'multipod' if multi_pod else 'pod'}.json"
    (out_dir / cell).write_text(json.dumps(result, indent=2))
    print(f"[{arch} x {shape_name}] OK  lower {t_lower:.0f}s compile {t_compile:.0f}s "
          f"args {arg_gb:.2f}GB temp {temp_gb:.2f}GB fits16={fits} "
          f"dominant={roof.dominant} "
          f"terms(c/m/x)=({roof.compute_s:.3e},{roof.memory_s:.3e},{roof.collective_s:.3e})s")
    return result


def all_cells():
    from repro.config import SHAPES
    from repro.configs import ARCH_IDS, get_config, shape_applicable

    for arch in ARCH_IDS:
        if arch == "paper_sfa":
            continue
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            yield arch, shape_name, ok, why


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(RESULTS))
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.all:
        failures = []
        for arch, shape_name, ok, why in all_cells():
            tag = "multipod" if args.multi_pod else "pod"
            cell_file = out_dir / f"{arch}__{shape_name}__{tag}.json"
            if not ok:
                out_dir.mkdir(parents=True, exist_ok=True)
                cell_file.write_text(json.dumps(
                    {"arch": arch, "shape": shape_name, "status": "skipped",
                     "reason": why}, indent=2))
                print(f"[{arch} x {shape_name}] SKIP: {why}")
                continue
            if cell_file.exists() and json.loads(cell_file.read_text()).get("status") == "ok":
                print(f"[{arch} x {shape_name}] cached")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name, "--out", str(out_dir)]
            if args.multi_pod:
                cmd.append("--multi-pod")
            r = subprocess.run(cmd, timeout=args.timeout)
            if r.returncode != 0:
                failures.append((arch, shape_name))
                cell_file.write_text(json.dumps(
                    {"arch": arch, "shape": shape_name, "status": "failed"}, indent=2))
        print(f"\n=== dry-run sweep done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    try:
        run_cell(args.arch, args.shape, args.multi_pod, out_dir)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
