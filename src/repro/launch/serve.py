"""Serving launcher: bring up the continuous-batching engine for an arch.

    python -m repro.launch.serve --arch qwen1p5_0p5b --requests 16

Production notes: on a pod, params restore from the latest checkpoint with
the serving rules (bf16, cache sequence-sharded over "model"); here the demo
initializes random params at a reduced size unless a checkpoint exists.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="serve the smoke-scale config (CPU dev box)")
    args = ap.parse_args()

    from repro.config import HOST_MESH, RunConfig, ShapeConfig, reduced
    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.serve.engine import Request, ServeEngine
    from repro.sharding.rules import Dist

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    run = RunConfig(model=cfg, shape=ShapeConfig("serve", 128, args.slots, "decode"),
                    mesh=HOST_MESH)
    engine = ServeEngine(model, run, Dist(), params, n_slots=args.slots,
                         max_len=128, temperature=0.7)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        L = int(rng.integers(4, 20))
        engine.submit(Request(
            prompt=rng.integers(1, cfg.vocab_size, size=L).astype(np.int32),
            max_new_tokens=args.max_new, rid=i,
        ))
    done = engine.run_until_done()
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {toks} tokens")


if __name__ == "__main__":
    main()
