"""Pod training launcher.

    python -m repro.launch.train --arch qwen3_8b --steps 1000 \
        [--coordinator <addr> --num-processes N --process-id I]

On a real TPU pod each host runs this with its process id;
``jax.distributed.initialize`` wires the runtime together and
``make_production_mesh`` lays the global device mesh. On a dev box it runs
on whatever devices exist. See launch/run_pod.sh for the per-host wrapper.
"""

from __future__ import annotations

import argparse

import jax


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    args = ap.parse_args()

    if args.coordinator:
        jax.distributed.initialize(
            coordinator_address=args.coordinator,
            num_processes=args.num_processes,
            process_id=args.process_id,
        )

    from repro.configs import get_run
    from repro.data import DataConfig, make_pipeline
    from repro.launch.mesh import make_host_mesh, make_production_mesh, mesh_config
    from repro.models.model import build_model
    from repro.sharding.rules import Dist, Rules
    from repro.train.trainer import Trainer

    n_dev = len(jax.devices())
    if n_dev >= 512:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        run = get_run(args.arch, args.shape, mesh_config(multi_pod=args.multi_pod))
    else:
        # elastic: whatever devices this deployment actually has
        model_par = 1
        mesh = make_host_mesh(n_dev // model_par, model_par)
        run = get_run(args.arch, args.shape)
    if args.checkpoint_dir:
        run = run.replace(checkpoint_dir=args.checkpoint_dir)

    cfg = run.model
    rules = Rules(mesh_axes=tuple(mesh.axis_names)).with_overrides(cfg.sharding_overrides)
    dist = Dist.for_mesh(mesh, rules)
    model = build_model(cfg)

    # per-host data sharding: this host produces only its rows
    rows_total = run.shape.global_batch
    per_host = rows_total // max(jax.process_count(), 1)
    data = make_pipeline(DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=run.shape.seq_len,
        global_batch=rows_total,
        row_start=jax.process_index() * per_host,
        rows_local=-1 if jax.process_count() == 1 else per_host,
        seed=run.seed,
    ))

    trainer = Trainer(model=model, run=run, dist=dist, data=data)
    trainer.install_preemption_handler()
    with mesh:
        out = trainer.fit(args.steps)
    print(f"final loss {out['final_loss']}")
    data.stop()


if __name__ == "__main__":
    main()
