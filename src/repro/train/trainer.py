"""Training driver: auto-resume, async checkpoints, preemption handling,
straggler monitoring, elastic restart.

Fault-tolerance model (designed for 1000+ chips, exercised here on CPU):
  * **checkpoint/restart** — CheckpointManager (atomic, async, keep-N);
    params + optimizer state + data-iterator step all restore exactly, so a
    killed job resumes bit-identically (tested in tests/test_trainer.py).
  * **preemption** — SIGTERM triggers a final checkpoint before exit (TPU
    maintenance events surface as SIGTERM on Cloud TPU hosts).
  * **straggler mitigation** — per-step wall-time EWMA; a step slower than
    ``straggler_factor×`` EWMA increments a counter and (configurably)
    forces an early checkpoint so an external supervisor can reschedule the
    job around the slow host. In SPMD you cannot drop a chip mid-step;
    detect-and-relaunch *is* the production mitigation.
  * **elastic scaling** — checkpoints are topology-agnostic (full arrays);
    on restart the trainer re-shards onto whatever mesh it finds, so the
    same job continues on a different chip count.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import RunConfig
from repro.data import DataIterator
from repro.models import base as mbase
from repro.models.model import Model
from repro.sharding.rules import Dist

from .steps import make_train_step


@dataclass
class StragglerMonitor:
    factor: float = 3.0
    alpha: float = 0.1
    ewma_s: float = 0.0
    slow_steps: int = 0
    _n: int = 0

    def observe(self, dt: float) -> bool:
        self._n += 1
        if self._n <= 2:           # warmup: ignore compile step
            self.ewma_s = dt
            return False
        slow = dt > self.factor * self.ewma_s
        if slow:
            self.slow_steps += 1
        self.ewma_s = (1 - self.alpha) * self.ewma_s + self.alpha * dt
        return slow


@dataclass
class Trainer:
    model: Model
    run: RunConfig
    dist: Dist
    data: DataIterator
    log_every: int = 10
    checkpoint_on_straggler: bool = False

    step: int = 0
    params: dict | None = None
    opt_state: dict | None = None
    metrics_log: list = field(default_factory=list)
    _preempted: bool = field(default=False, repr=False)

    def __post_init__(self):
        self.ckpt = CheckpointManager(
            self.run.checkpoint_dir,
            keep=self.run.keep_checkpoints,
            async_save=self.run.async_checkpoint,
        )
        self.train_step_fn, self.opt = make_train_step(self.model, self.run, self.dist)
        self._jit_step = jax.jit(self.train_step_fn, donate_argnums=(0, 1))
        self.monitor = StragglerMonitor()
        self.param_specs = self.model.param_specs()

    # -- state ------------------------------------------------------------------
    def init_state(self):
        rng = jax.random.PRNGKey(self.run.seed)
        self.params = self.model.init(rng)
        self.opt_state = self.opt.init(self.params, self.param_specs)
        self.step = 0

    def try_resume(self) -> bool:
        """Auto-resume from the latest checkpoint (elastic: re-shards onto
        the current mesh via the Dist rules)."""
        like = {
            "params": self.params if self.params is not None else self.model.init(
                jax.random.PRNGKey(self.run.seed)
            ),
        }
        if self.opt_state is None:
            self.opt_state = self.opt.init(like["params"], self.param_specs)
        like["opt"] = self.opt_state
        res = self.ckpt.restore(like)
        if res is None:
            return False
        step, tree, extra = res
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.step = step
        if "data" in extra:
            self.data.restore(extra["data"])
        return True

    def save(self):
        self.ckpt.save(
            self.step,
            {"params": self.params, "opt": self.opt_state},
            extra={"data": self.data.state()},
        )

    # -- preemption ---------------------------------------------------------------
    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)

    # -- loop ------------------------------------------------------------------
    def fit(self, total_steps: int) -> dict:
        if self.params is None:
            if not self.try_resume():
                self.init_state()
        last_loss = None
        while self.step < total_steps:
            batch = next(self.data)
            batch = {k: jnp.asarray(v) for k, v in batch.items()
                     if k in ("tokens", "labels", "frames", "prefix_embeds")}
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._jit_step(
                self.params, self.opt_state, jnp.asarray(self.step, jnp.int32), batch
            )
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            slow = self.monitor.observe(dt)
            self.step += 1
            last_loss = loss
            if self.step % self.log_every == 0 or self.step == total_steps:
                self.metrics_log.append(
                    {"step": self.step, "loss": loss, "dt_s": dt,
                     "grad_norm": float(metrics["grad_norm"])}
                )
            if slow and self.checkpoint_on_straggler:
                self.save()
            if self.step % self.run.checkpoint_every == 0:
                self.save()
            if self._preempted:
                self.save()
                self.ckpt.wait()
                raise SystemExit(143)
        self.save()
        self.ckpt.wait()
        return {"final_loss": last_loss, "steps": self.step,
                "slow_steps": self.monitor.slow_steps,
                "log": self.metrics_log}
