"""Step functions: the units the launcher jits and the dry-run lowers.

``make_train_step`` builds a pure function
    (params, opt_state, step, batch) -> (params, opt_state, metrics)
with gradient accumulation over ``run.micro_batches`` microbatches (a
``lax.scan`` — activation memory stays at one microbatch), mixed-precision
params→bf16 casting inside the loss, MoE aux-loss folding, clipping and the
optimizer update. Sharding comes entirely from the in/out shardings the
launcher attaches (params FSDP×TP, batch DP) — the body is layout-free.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.config import RunConfig
from repro.models.layers import cross_entropy
from repro.models.model import Model
from repro.optim import build_optimizer
from repro.sharding.rules import Dist

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def _model_kwargs(batch: dict) -> dict:
    kw = {}
    if "frames" in batch:
        kw["frames"] = batch["frames"]
    if "prefix_embeds" in batch:
        kw["prefix_embeds"] = batch["prefix_embeds"]
    return kw


def make_train_step(model: Model, run: RunConfig, dist: Dist):
    opt = build_optimizer(run.optimizer)
    param_specs = model.param_specs()

    def loss_fn(params, micro):
        logits, _, aux = model.forward(
            params, micro["tokens"], dist, mode="train", **_model_kwargs(micro)
        )
        loss = cross_entropy(logits, micro["labels"])
        return loss + AUX_WEIGHT * aux, (loss, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _gather_once(params):
        """bf16 compute copy, replicated over the data axes (ZeRO-1). The
        constraint sits OUTSIDE the microbatch scan so XLA gathers once per
        step; its transpose is a single reduce-scatter of the bf16 grads."""
        from repro.models.base import is_spec
        from repro.sharding.rules import Rules

        data_axes = set(dist.data_axes)

        def one(p, spec):
            dtype = jnp.bfloat16 if p.dtype == jnp.float32 and p.ndim >= 2 else p.dtype
            x = p.astype(dtype)
            resolved = [dist.rules.resolve(a) for a in spec.logical]
            drop = tuple(
                None if (r in data_axes or (isinstance(r, tuple) and set(r) & data_axes))
                else r
                for r in resolved
            )
            from jax.sharding import PartitionSpec as P

            try:
                return jax.lax.with_sharding_constraint(x, P(*drop))
            except (ValueError, RuntimeError):
                return x

        return jax.tree.map(one, params, param_specs, is_leaf=is_spec)

    def train_step(params, opt_state, step, batch):
        n_micro = run.micro_batches
        # loss params: either the stored (FSDP-sharded f32) tree, or the
        # once-gathered bf16 compute copy (ZeRO-1 mode).
        loss_params = _gather_once(params) if run.gather_params_once else params

        if n_micro == 1:
            (total, (loss, aux)), grads = grad_fn(loss_params, batch)
        else:
            B = batch["tokens"].shape[0]
            assert B % n_micro == 0
            mb = B // n_micro

            def slice_micro(i):
                return {
                    k: jax.lax.dynamic_slice_in_dim(v, i * mb, mb, 0)
                    for k, v in batch.items()
                }

            acc_dtype = jnp.dtype(run.grad_accum_dtype)

            def body(carry, i):
                g_acc, l_acc, a_acc = carry
                (_, (loss, aux)), g = grad_fn(loss_params, slice_micro(i))
                g_acc = jax.tree.map(lambda a, b: a + b.astype(acc_dtype), g_acc, g)
                return (g_acc, l_acc + loss, a_acc + aux), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), loss_params)
            (grads, loss, aux), _ = jax.lax.scan(
                body, (g0, jnp.zeros(()), jnp.zeros(())), jnp.arange(n_micro)
            )
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            aux = aux / n_micro

        if run.gather_params_once:
            # re-shard grads to the parameter layout: the transpose of the
            # step-level gather — one reduce-scatter, not micro_batches of them
            from repro.models.base import is_spec, pspec_tree

            pspecs = pspec_tree(param_specs, dist.rules)

            def reshard(g, spec):
                try:
                    return jax.lax.with_sharding_constraint(
                        g.astype(jnp.float32), spec
                    )
                except (ValueError, RuntimeError):
                    return g.astype(jnp.float32)

            grads = jax.tree.map(reshard, grads, pspecs)

        new_params, new_opt, stats = opt.update(
            grads, opt_state, params, step, param_specs
        )
        metrics = {"loss": loss, "aux_loss": aux, **stats}
        return new_params, new_opt, metrics

    return train_step, opt


def make_eval_step(model: Model, run: RunConfig, dist: Dist):
    def eval_step(params, batch):
        logits, _, _ = model.forward(
            params, batch["tokens"], dist, mode="train", **_model_kwargs(batch)
        )
        return cross_entropy(logits, batch["labels"])

    return eval_step
