"""h2o-danube-1.8b [dense] — llama+mistral mix with sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818].
SWA window 4096 (mistral-style) → bounded KV cache, so the ``long_500k``
decode cell runs with a 4096-slot ring cache.
"""

from repro.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="h2o_danube_1p8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        head_dim=80,
        sliding_window=4096,
        layer_pattern=("swa",),
        tie_embeddings=False,
        remat="full",
        subquadratic=True,   # bounded attention window
    )
