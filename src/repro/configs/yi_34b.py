"""yi-34b [dense] — llama-arch GQA. 60L d_model=7168 56H (kv=8) d_ff=20480
vocab=64000 [arXiv:2403.04652].

56 heads do not divide the 16-way model axis. The naive fix — shard
``head_dim`` (128/16 = 8) — is catastrophic for training: every S×S attention
score block becomes a partial sum that GSPMD all-reduces (measured: 259k
all-reduces, 15.5 TB/device/step — EXPERIMENTS.md §Perf iteration 2-REFUTED).

Production layout instead: **no tensor parallelism**. Weights shard 2-D over
(data × model) = 256-way pure FSDP (0.54 GB/chip f32), activations shard
batch over ``data`` and *sequence over ``model``* (ring-attention style:
the per-layer K/V all-gather is 8 MB where score all-reduces were 3.5 TB).
"""

from repro.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="yi_34b",
        family="dense",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab_size=64000,
        head_dim=128,
        rope_theta=5_000_000.0,
        tie_embeddings=False,
        remat="full",
        subquadratic=False,
        sharding_overrides={
            # attention: FSDP over data only, heads unsharded (56 ∤ 16); the
            # model axis duplicates attention compute (~21% of FLOPs) — far
            # cheaper than score all-reduces (see §Perf iterations 2–4)
            "heads": None,
            "head_dim": None,
            "heads_act": None,
            # MLP + vocab: classic TP (20480/16, 64000/16)
            "mlp": "model",
            "vocab": "model",
            # activations: DP × sequence-parallel residual stream; attention
            # internally gathers seq (attn_seq default None)
            "seq_act": "model",
            "cache_head_dim": None,   # decode cache shards seq over model
        },
        # serving wants the opposite trade: no S×S scores exist at decode, so
        # head_dim TP is cheap there and keeps weights model-sharded
        # (args 5.1 -> 4.3 GB, decode fits 16 GB; §Perf)
        serving_overrides={
            "heads": None,
            "head_dim": "model",
            "heads_act": None,
            "cache_head_dim": None,
        },
    )
