"""granite-moe-1b-a400m [moe] — 32 experts, top-8 (400M active).

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155
[hf:ibm-granite/granite-3.0-1b-a400m-base]. Vocab padded to 49408.
Tiny per-expert FFN (512) with many experts — the routing-bound regime;
the sort-based dispatch path dominates, which is why this config is one of
the §Perf hillclimb candidates.
"""

from repro.config import ModelConfig
from repro.configs import pad_vocab


def get_config() -> ModelConfig:
    return ModelConfig(
        name="granite_moe_1b",
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=pad_vocab(49155),
        head_dim=64,
        n_experts=32,
        experts_per_token=8,
        moe_capacity_factor=1.25,
        tie_embeddings=True,
        remat="full",
        subquadratic=False,
        sharding_overrides={"mlp": None},  # f=512: TP slice (32) below MXU tile
    )
