"""qwen1.5-0.5b [dense] — QKV bias, MHA-ish GQA (kv=16). 24L d_model=1024
16H (kv=16) d_ff=2816 vocab=151936 [hf:Qwen/Qwen1.5-0.5B]."""

from repro.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1p5_0p5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        vocab_size=151936,
        head_dim=64,
        qkv_bias=True,
        tie_embeddings=True,
        remat="full",
        subquadratic=False,
        # kv == heads == 16: shard both over model; cache shards heads (one
        # "model" mapping per spec, so cache_seq stays unsharded)
        sharding_overrides={
            "kv_heads": "model", "cache_kv_heads": "model", "cache_seq": None,
        },
    )
