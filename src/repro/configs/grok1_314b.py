"""grok-1-314b [moe] — 8 experts, top-2.

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072 [hf:xai-org/grok-1].
Memory plan for v5e-16GB × 256 (§Perf iterations 7–8): params stored bf16
2-D sharded FSDP(data)×TP(model) (1.23 GB/chip), Adafactor stats (factored —
tiny, update in f32 from bf16 params, T5X low-memory style), bf16 gradient
accumulation, remat=full, 16 microbatches on train_4k. Attention logit
soft-capping at 30 as in the released model.
"""

from repro.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="grok1_314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        head_dim=128,
        n_experts=8,
        experts_per_token=2,
        moe_capacity_factor=1.25,
        attn_logit_softcap=30.0,
        tie_embeddings=False,
        remat="full",
        param_dtype="bfloat16",
        subquadratic=False,
        # FSDP over the pod axis too: on the 2-pod mesh params/optimizer
        # shard 512-way (the "pod" entry is dropped on single-pod meshes)
        sharding_overrides={"embed": ("pod", "data")},
    )
