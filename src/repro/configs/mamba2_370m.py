"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1024 d_ff=0 vocab=50280 ssm_state=128 [arXiv:2405.21060].
d_inner = 2e·d = 2048, head_dim 64 → 32 SSD heads. Vocab padded to 50432 for
model-axis sharding (multiple of 256). 370M params; weights FSDP over the
data axis only — tensor-parallel splits of a d=1024 model waste ICI (see
DESIGN.md §4 sharding note). The SSD inter-chunk recurrence runs on
``core.monoid`` — the paper's technique, directly.
"""

from repro.config import ModelConfig
from repro.configs import pad_vocab


def get_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2_370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=16,          # unused (attention-free); kept for param_count API
        n_kv_heads=16,
        d_ff=0,
        vocab_size=pad_vocab(50280),
        head_dim=64,
        layer_pattern=("mamba2",),
        ssm_state=128,
        ssm_heads=32,
        ssm_head_dim=64,
        ssm_expand=2,
        ssm_chunk=256,
        tie_embeddings=True,
        # remat="dots" was tried and REFUTED (§Perf mamba2 iteration B): the
        # saved dot outputs stack across the 48-layer scan (+3.4x traffic);
        # full recompute is cheaper for a 370M model.
        remat="full",
        subquadratic=True,
        sharding_overrides={"rnn": None, "heads": None, "state": None},
    )
