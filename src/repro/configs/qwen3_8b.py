"""qwen3-8b [dense] — qk-norm, GQA. 36L d_model=4096 32H (kv=8) d_ff=12288
vocab=151936 [hf:Qwen/Qwen3-8B]."""

from repro.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3_8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=12288,
        vocab_size=151936,
        head_dim=128,
        qk_norm=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        remat="full",
        subquadratic=False,
    )
