"""paper_sfa — the paper's own workload as a selectable "architecture".

Not an LM: ``get_config()`` returns the SFA workload description the
benchmarks and the distributed-matching launcher consume (PROSITE pattern
set, input-string sizing, construction engine). Kept in the same registry so
``--arch paper_sfa`` drives the paper-faithful pipeline end to end.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class SFAWorkload:
    name: str = "paper_sfa"
    family: str = "sfa"
    patterns: tuple = (
        "PS00001", "PS00004", "PS00005", "PS00006", "PS00007", "PS00008",
        "PS00009", "PS00016", "PS00017", "PS00029",
    )
    engine: str = "vectorized"
    match_length: int = 10_000_000   # paper Fig. 6 uses 1e10; scaled to CPU
    n_chunks: int = 64
    max_states: int = 2_000_000


def get_config() -> SFAWorkload:
    return SFAWorkload()
