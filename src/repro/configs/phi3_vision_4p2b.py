"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP frontend (stubbed).

32L d_model=3072 32H (kv=32, i.e. MHA) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct]. The vision tower is a stub per the
assignment: ``input_specs`` supplies 256 precomputed patch embeddings that
overwrite the first 256 token positions. LongRoPE approximated as linear
position scaling (DESIGN.md §8).
"""

from repro.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="phi3_vision_4p2b",
        family="vlm",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        head_dim=96,
        rope_scaling=16.0,
        num_prefix_embeds=256,
        tie_embeddings=False,
        remat="full",
        subquadratic=False,
        # MHA: kv heads shard over model like q heads (32 % 16 == 0); the
        # cache then shards heads, not sequence (one "model" mapping per spec)
        sharding_overrides={
            "kv_heads": "model", "cache_kv_heads": "model", "cache_seq": None,
        },
    )
