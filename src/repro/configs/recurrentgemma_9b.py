"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 pattern.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000 [arXiv:2402.19427].
38 layers = 12 × (rglru, rglru, local-attn) + 2 rglru tail. The RG-LRU
recurrence runs on ``core.monoid`` (affine scan) — the paper technique's
second direct instantiation; local attention window 2048 bounds the cache,
so ``long_500k`` runs.
"""

from repro.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma_9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        d_ff=12288,
        vocab_size=256_000,
        head_dim=256,
        layer_pattern=("rglru", "rglru", "lattn"),
        rglru_width=4096,
        local_attn_window=2048,
        ssm_conv_width=4,
        tie_embeddings=True,
        remat="full",
        subquadratic=True,
    )
