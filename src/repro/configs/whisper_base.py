"""whisper-base [audio] — encoder-decoder, conv frontend stubbed.

6L (enc) + 6L (dec) d_model=512 8H d_ff=2048 vocab=51865 [arXiv:2212.04356].
``input_specs`` provides precomputed frame embeddings (B, 1500, 512) — the
conv1d×2 + GELU frontend is the documented stub. Vocab padded to 51968.
72M params on a 256-chip mesh: attention is replicated (8 heads < 16-way
axis); only MLP (f=2048) and vocab shard over ``model`` (DESIGN.md §4).
Decode cells run the *decoder* with a self-attn cache of the assigned length
(whisper's real 448 ctx is a training detail, not an architecture limit).
"""

from repro.config import ModelConfig
from repro.configs import pad_vocab


def get_config() -> ModelConfig:
    return ModelConfig(
        name="whisper_base",
        family="audio",
        n_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab_size=pad_vocab(51865),
        head_dim=64,
        mlp_variant="gelu",
        is_encoder_decoder=True,
        n_encoder_layers=6,
        encoder_seq=1500,
        tie_embeddings=True,
        remat="none",        # 72M params: recompute buys nothing
        subquadratic=False,
        sharding_overrides={"heads": None, "kv_heads": None, "heads_act": None},
    )
