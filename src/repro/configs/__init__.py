"""Architecture registry: ``--arch <id>`` resolves here.

Each module exposes ``get_config() -> ModelConfig`` with the exact assigned
hyperparameters, plus per-arch sharding overrides and training micro-batch
counts tuned for the production mesh (see DESIGN.md §6 / EXPERIMENTS.md).
"""

from __future__ import annotations

import importlib

from repro.config import (
    SHAPES,
    SINGLE_POD,
    MULTI_POD,
    MeshConfig,
    ModelConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
)

ARCH_IDS = [
    "phi3_vision_4p2b",
    "mamba2_370m",
    "grok1_314b",
    "granite_moe_1b",
    "h2o_danube_1p8b",
    "qwen3_8b",
    "qwen1p5_0p5b",
    "yi_34b",
    "whisper_base",
    "recurrentgemma_9b",
    "paper_sfa",          # the paper's own "architecture": SFA workloads
]

# micro-batch counts for train_4k on the production mesh (memory plan;
# validated by compiled.memory_analysis() in EXPERIMENTS.md §Dry-run)
TRAIN_MICROBATCHES = {
    "phi3_vision_4p2b": 8,
    "mamba2_370m": 8,     # SSD intra-chunk scores are the activation peak
    "grok1_314b": 16,
    "granite_moe_1b": 4,
    "h2o_danube_1p8b": 4,
    "qwen3_8b": 8,
    "qwen1p5_0p5b": 2,
    "yi_34b": 8,          # §Perf: sequence-parallel stash is tiny; fewer
                          # microbatches halve the per-step weight gathers
    "whisper_base": 2,
    "recurrentgemma_9b": 8,
}

# per-arch optimizer (the 314B MoE uses factored stats to fit HBM)
OPTIMIZERS = {
    "grok1_314b": OptimizerConfig(name="adafactor", lr=1e-4),
    # yi: attention weights are model-replicated (56 heads ∤ 16), so int8
    # Adam's transient f32 dequant of m/v peaks at ~14 GB — factored stats
    # sidestep it (§Perf iteration 6)
    "yi_34b": OptimizerConfig(name="adafactor", lr=1.5e-4),
    "recurrentgemma_9b": OptimizerConfig(name="adamw8bit", lr=2e-4),
}

# per-arch train-step flags (§Perf hillclimb outcomes; see EXPERIMENTS.md).
# gather-once (ZeRO-1) was tried for yi_34b and REFUTED — the collective cost
# was score all-reduces from head_dim TP, not weight gathers; see §Perf.
TRAIN_FLAGS: dict = {
    "grok1_314b": {"grad_accum_dtype": "bfloat16"},
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.get_config()


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple:
    """(runnable, reason). long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full attention: O(S^2)/unbounded cache at 500k (DESIGN.md §4)"
    return True, ""


def get_run(arch: str, shape_name: str, mesh: MeshConfig = SINGLE_POD) -> RunConfig:
    import dataclasses

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{arch} × {shape_name} skipped: {why}")
    if shape.kind != "train":
        # inference serves bf16 weights (standard practice; halves HBM),
        # optionally with a serving-specific sharding layout
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
        if cfg.serving_overrides:
            cfg = dataclasses.replace(cfg, sharding_overrides=cfg.serving_overrides)
    micro = TRAIN_MICROBATCHES.get(arch, 1) if shape.kind == "train" else 1
    # each microbatch must still shard over the data axes
    n_data = 1
    for s, a in zip(mesh.shape, mesh.axes):
        if a != "model":
            n_data *= s
    micro = max(1, min(micro, shape.global_batch // max(n_data, 1)))
    opt = OPTIMIZERS.get(arch, OptimizerConfig())
    flags = TRAIN_FLAGS.get(arch, {}) if shape.kind == "train" else {}
    return RunConfig(model=cfg, shape=shape, mesh=mesh, optimizer=opt,
                     micro_batches=micro, max_cache_len=shape.seq_len, **flags)


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Pad vocab to a multiple so the vocab axis shards over model=16
    (standard practice; the tail ids are never produced by the tokenizer)."""
    return -(-v // multiple) * multiple
