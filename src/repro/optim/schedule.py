"""Learning-rate schedules (warmup + cosine/linear/constant decay)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.config import OptimizerConfig


def make_schedule(cfg: OptimizerConfig):
    warmup = max(cfg.warmup_steps, 1)
    total = max(cfg.total_steps, warmup + 1)

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = cfg.lr * step / warmup
        frac = jnp.clip((step - warmup) / (total - warmup), 0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        elif cfg.schedule == "linear":
            decay = cfg.lr * (1.0 - frac)
        else:
            decay = jnp.full_like(frac, cfg.lr)
        return jnp.where(step < warmup, warm, decay)

    return schedule
