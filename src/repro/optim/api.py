"""Optimizer API (built from scratch — no optax in this environment).

An ``Optimizer`` exposes:
  * ``state_specs(param_specs)`` — a ParamSpec tree for its state, so the
    dry-run can lower with ShapeDtypeStruct stand-ins and checkpointing can
    save/restore without materializing params first;
  * ``init(params)`` — real state;
  * ``update(grads, state, params, step)`` -> (new_params, new_state, stats).

All states inherit the parameter's sharding (ZeRO-1 falls out of the FSDP
parameter sharding rules: optimizer state is sharded exactly like the
params, i.e. split over ``data`` × ``model``).

Implementations: AdamW, AdamW with block-quantized int8 moments (the 314B
config's memory plan), and Adafactor (factored second moments).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig
from repro.models.base import ParamSpec, is_spec

from .schedule import make_schedule

QBLOCK = 256  # int8 quantization block (along the last dim)


@dataclass(frozen=True)
class Optimizer:
    cfg: OptimizerConfig
    state_specs: Callable
    init: Callable
    update: Callable


def build_optimizer(cfg: OptimizerConfig) -> Optimizer:
    if cfg.name == "adamw":
        return _adamw(cfg)
    if cfg.name == "adamw8bit":
        return _adamw(cfg, quantized=True)
    if cfg.name == "adafactor":
        return _adafactor(cfg)
    raise ValueError(f"unknown optimizer {cfg.name}")


# --------------------------------------------------------------------------
# shared pieces
# --------------------------------------------------------------------------


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def _wd_mask(spec: ParamSpec) -> bool:
    """Decay matrices only (skip norms/biases/1-D params)."""
    return len(spec.shape) >= 2


def _layerwise(one, g, s, p, spec: ParamSpec, enabled: bool = False):
    """Optionally update scan-over-layers leaves via ``lax.map`` over the
    layer axis. Tried for the 314B config and REFUTED: the map's xs/ys
    double-buffering (+5.6 GB) outweighed the per-layer transient savings
    (§Perf iteration 7a). Kept behind a flag, default off."""
    if enabled and spec.logical and spec.logical[0] == "layers" and len(spec.shape) >= 3:
        inner_spec = ParamSpec(spec.shape[1:], spec.logical[1:], spec.dtype,
                               spec.init, spec.scale)
        return jax.lax.map(lambda t: one(t[0], t[1], t[2], inner_spec), (g, s, p))
    return one(g, s, p, spec)


def clip_scale(grads, max_norm: float):
    """Global-norm clip as a (scalar, norm) pair — the scale folds into the
    per-leaf update instead of materializing a scaled copy of the whole
    gradient tree (a full f32 tree = 4.9 GB/device on the 314B config)."""
    norm = global_norm(grads)
    return jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12)), norm


# --------------------------------------------------------------------------
# AdamW (f32 or int8-blocked moments)
# --------------------------------------------------------------------------


def _quantizable(spec: ParamSpec) -> bool:
    return len(spec.shape) >= 2 and spec.shape[-1] % QBLOCK == 0


def _q8(x: jnp.ndarray) -> tuple:
    """Block-quantize along the last dim -> (int8 codes, f32 scales)."""
    blocked = x.reshape(*x.shape[:-1], x.shape[-1] // QBLOCK, QBLOCK)
    scale = jnp.max(jnp.abs(blocked), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(blocked / scale), -127, 127).astype(jnp.int8)
    return codes.reshape(x.shape), scale[..., 0]


def _dq8(codes: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    blocked = codes.reshape(*codes.shape[:-1], codes.shape[-1] // QBLOCK, QBLOCK)
    return (blocked.astype(jnp.float32) * scale[..., None]).reshape(codes.shape)


def _adamw(cfg: OptimizerConfig, quantized: bool = False) -> Optimizer:
    schedule = make_schedule(cfg)

    def state_specs(param_specs):
        def one(s: ParamSpec):
            if quantized and _quantizable(s):
                scale_shape = (*s.shape[:-1], s.shape[-1] // QBLOCK)
                scale_logical = (*s.logical[:-1], None)
                return {
                    "m_q": ParamSpec(s.shape, s.logical, "int8", "zeros"),
                    "m_s": ParamSpec(scale_shape, scale_logical, "float32", "zeros"),
                    "v_q": ParamSpec(s.shape, s.logical, "int8", "zeros"),
                    "v_s": ParamSpec(scale_shape, scale_logical, "float32", "zeros"),
                }
            return {
                "m": ParamSpec(s.shape, s.logical, "float32", "zeros"),
                "v": ParamSpec(s.shape, s.logical, "float32", "zeros"),
            }

        return jax.tree.map(one, param_specs, is_leaf=is_spec)

    def init(params, param_specs):
        from repro.models.base import init_params

        return init_params(state_specs(param_specs), jax.random.PRNGKey(0))

    def update(grads, state, params, step, param_specs):
        scale, gnorm = clip_scale(grads, cfg.grad_clip)
        lr = schedule(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        def one(g, s, p, spec):
            g = g.astype(jnp.float32) * scale
            if quantized and _quantizable(spec):
                m = _dq8(s["m_q"], s["m_s"])
                v = _dq8(s["v_q"], s["v_s"])
            else:
                m, v = s["m"], s["v"]
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            if _wd_mask(spec):
                upd = upd + cfg.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            if quantized and _quantizable(spec):
                mq, ms = _q8(m)
                vq, vs = _q8(v)
                return new_p, {"m_q": mq, "m_s": ms, "v_q": vq, "v_s": vs}
            return new_p, {"m": m, "v": v}

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_s = treedef.flatten_up_to(state)
        flat_spec = jax.tree.leaves(param_specs, is_leaf=is_spec)
        outs = [one(g, s, p, sp) for g, s, p, sp in zip(flat_g, flat_s, flat_p, flat_spec)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_state = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

    return Optimizer(cfg, state_specs, init, update)


# --------------------------------------------------------------------------
# Adafactor (factored second moments; the 314B default)
# --------------------------------------------------------------------------


def _adafactor(cfg: OptimizerConfig) -> Optimizer:
    schedule = make_schedule(cfg)

    def factored(spec: ParamSpec) -> bool:
        return len(spec.shape) >= 2

    def state_specs(param_specs):
        def one(s: ParamSpec):
            if factored(s):
                return {
                    "vr": ParamSpec(s.shape[:-1], s.logical[:-1], "float32", "zeros"),
                    "vc": ParamSpec(
                        (*s.shape[:-2], s.shape[-1]), (*s.logical[:-2], s.logical[-1]),
                        "float32", "zeros",
                    ),
                }
            return {"v": ParamSpec(s.shape, s.logical, "float32", "zeros")}

        return jax.tree.map(one, param_specs, is_leaf=is_spec)

    def init(params, param_specs):
        from repro.models.base import init_params

        return init_params(state_specs(param_specs), jax.random.PRNGKey(0))

    def update(grads, state, params, step, param_specs):
        scale, gnorm = clip_scale(grads, cfg.grad_clip)
        lr = schedule(step)
        decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8  # beta2 schedule

        def one(g, s, p, spec):
            g = g.astype(jnp.float32) * scale
            g2 = jnp.square(g) + 1e-30
            if factored(spec):
                vr = decay * s["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * s["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = (
                    vr[..., None] / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                ) * vc[..., None, :]
                upd = g * jax.lax.rsqrt(denom + 1e-30)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = decay * s["v"] + (1 - decay) * g2
                upd = g * jax.lax.rsqrt(v + 1e-30)
                new_s = {"v": v}
            # update clipping (Shazeer & Stern): RMS(upd) <= 1
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms)
            if _wd_mask(spec):
                upd = upd + cfg.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            return new_p, new_s

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_s = treedef.flatten_up_to(state)
        flat_spec = jax.tree.leaves(param_specs, is_leaf=is_spec)
        outs = [one(g, s, p, sp) for g, s, p, sp in zip(flat_g, flat_s, flat_p, flat_spec)]
        return (
            jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]),
            {"grad_norm": gnorm, "lr": lr},
        )

    return Optimizer(cfg, state_specs, init, update)
