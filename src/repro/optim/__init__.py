from .api import Optimizer, build_optimizer
from .schedule import make_schedule

__all__ = ["Optimizer", "build_optimizer", "make_schedule"]
