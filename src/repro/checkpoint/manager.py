"""Sharded, async, atomic checkpointing with topology-agnostic restore.

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per tree leaf (path-encoded
file names) plus ``meta.json`` (tree structure, dtypes, step, data-iterator
state). Writes go to ``step_<N>.tmp`` and are atomically renamed — a crashed
save can never shadow a good checkpoint (fault tolerance requirement #1).

* **async**: device→host transfer happens synchronously (cheap, snapshot
  semantics), file IO on a worker thread; ``wait()`` joins before the next
  save or program exit.
* **topology-agnostic**: leaves are stored unsharded; ``restore_tree``
  re-shards onto whatever mesh/sharding the restarted job uses
  (``device_put`` with the target sharding) — elastic scaling requirement.
  On a real pod each host writes only the shards it owns (addressable
  shards); this host-local variant stores full arrays, same format.
* **keep-N** garbage collection.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

_SEP = "__"


def _flatten_with_paths(tree) -> list:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = _SEP.join(_key_str(k) for k in path)
        out.append((name, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"idx{k.idx}"
    return str(k)


def save_tree(ckpt_dir: Path, step: int, tree, extra: dict | None = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    names = []
    for name, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"{name}.npy", arr)
        names.append(name)
    meta = {"step": step, "leaves": names, "extra": extra or {}}
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(m.group(1))
        for p in ckpt_dir.iterdir()
        if (m := re.fullmatch(r"step_(\d+)", p.name)) and (p / "meta.json").exists()
    ]
    return max(steps) if steps else None


def restore_tree(ckpt_dir: Path, step: int, like_tree, shardings=None) -> tuple:
    """Restore into the structure of ``like_tree``; optionally device_put
    each leaf with the matching target sharding (reshard-on-load)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    meta = json.loads((d / "meta.json").read_text())
    flat = _flatten_with_paths(like_tree)
    treedef = jax.tree.structure(like_tree)
    shard_flat = (
        [s for _, s in _flatten_with_paths(shardings)] if shardings is not None
        else [None] * len(flat)
    )
    leaves = []
    for (name, like), shard in zip(flat, shard_flat):
        arr = np.load(d / f"{name}.npy")
        want_shape = tuple(like.shape)
        assert tuple(arr.shape) == want_shape, (name, arr.shape, want_shape)
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(arr)
    return jax.tree.unflatten(treedef, leaves), meta["extra"]


class CheckpointManager:
    def __init__(self, ckpt_dir, keep: int = 3, async_save: bool = True):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._last_error: Exception | None = None

    def save(self, step: int, tree, extra: dict | None = None):
        # Snapshot to host synchronously so mutation after save() is safe.
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()

        def work():
            try:
                save_tree(self.dir, step, host_tree, extra)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._last_error = e

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_if_failed()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def latest(self) -> int | None:
        return latest_step(self.dir)

    def restore(self, like_tree, shardings=None, step: int | None = None):
        step = self.latest() if step is None else step
        if step is None:
            return None
        tree, extra = restore_tree(self.dir, step, like_tree, shardings)
        return step, tree, extra

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for p in self.dir.iterdir()
            if (m := re.fullmatch(r"step_(\d+)", p.name))
        )
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
