"""Hot-state profiler: where does each automaton actually live?

Speculation only pays when the ``m`` states a chunk is run from cover the
states the automaton really occupies at chunk boundaries. On real inputs
DFAs are heavily skewed — a search automaton for ``Σ* pattern Σ*`` spends
almost all its time in the start state and the first few prefix states
(1210.5093's empirical basis) — so a small sample of the input pins the
distribution down well.

:func:`profile_hot_states` advances every pattern's DFA over one shared
symbol sample (vectorized across the pattern axis; one NumPy gather per
sample symbol) while histogramming visited states, then takes each
pattern's top-``m`` by visit count. Chunk boundaries are just positions,
so the position-state distribution *is* the boundary-state distribution,
independent of how the scan plan chunks the input.

Profiles are plain data (:class:`HotStateProfile`, JSON round-trip via
``to_json``/``from_json``) so the scan service can persist them next to
SFA artifacts in the :class:`~repro.scanservice.ArtifactStore` under the
same ``dfa_cache_key`` — a corpus profiled once seeds speculation for
every later process.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class HotStateProfile:
    """Top-``m`` boundary-state distribution of one pattern.

    ``states`` is most-frequent-first; ``weights`` are the matching visit
    frequencies (normalized over the whole sample, so they need not sum
    to 1 when ``m < n_states``). The profile is advisory only — a stale or
    even adversarial profile costs repair rounds, never correctness.
    """

    states: np.ndarray        # (m,) int32, most frequent first
    weights: np.ndarray       # (m,) float64 visit frequencies
    sample_len: int           # symbols profiled

    def to_json(self) -> dict:
        return {
            "m": int(len(self.states)),
            "states": [int(s) for s in self.states],
            "weights": [float(w) for w in self.weights],
            "sample_len": int(self.sample_len),
        }

    @classmethod
    def from_json(cls, meta: dict) -> "HotStateProfile | None":
        """Parse a persisted profile; anything malformed is a miss (None)."""
        try:
            states = np.asarray(meta["states"], dtype=np.int32)
            weights = np.asarray(meta["weights"], dtype=np.float64)
            sample_len = int(meta["sample_len"])
        except (KeyError, TypeError, ValueError, OverflowError):
            return None
        if states.ndim != 1 or states.shape != weights.shape or not len(states):
            return None
        return cls(states=states, weights=weights, sample_len=sample_len)


def profile_hot_states(tables, starts, sample, m: int) -> list:
    """Profile every pattern of a bank over one shared symbol sample.

    ``tables`` (P, n, k) int — padded enumeration tables (padding rows are
    self-loops, which the histogram never reaches from a true start);
    ``starts`` (P,); ``sample`` (S,) encoded symbols. -> list of P
    :class:`HotStateProfile` with exactly ``m`` states each: the top-``m``
    visited states (count desc, state id asc — deterministic), padded out
    with the remaining state ids (or repeats when ``m > n``). Extra states
    only widen speculation coverage; duplicates are harmless.
    """
    tables = np.asarray(tables)
    P, n, _ = tables.shape
    rows = np.arange(P)
    states = np.asarray(starts, dtype=np.int64).copy()
    counts = np.zeros((P, n), dtype=np.int64)
    counts[rows, states] += 1        # the entry state is itself a boundary state
    for sym in np.asarray(sample, dtype=np.int64):
        states = tables[rows, states, sym].astype(np.int64)
        counts[rows, states] += 1
    total = max(1, int(np.asarray(sample).size) + 1)
    profiles = []
    order_tail = np.arange(n)
    for p in range(P):
        order = np.lexsort((order_tail, -counts[p]))
        if m <= n:
            top = order[:m]
        else:
            top = np.concatenate([order, np.full(m - n, order[-1])])
        profiles.append(HotStateProfile(
            states=top.astype(np.int32),
            weights=(counts[p][top] / total).astype(np.float64),
            sample_len=int(np.asarray(sample).size),
        ))
    return profiles


def stack_profile_states(profiles, m: int, n_max: int) -> np.ndarray:
    """Normalize per-pattern profiles to one (P, m) int32 speculation stack.

    Profiles persisted with a different ``m`` are truncated (they are
    ordered most-frequent-first) or padded by repeating their last state;
    states are clipped into the padded table range so speculative gathers
    can never go out of bounds (a clipped state is just a lane that never
    validates — exactness is unaffected).
    """
    out = np.empty((len(profiles), m), dtype=np.int32)
    for p, prof in enumerate(profiles):
        s = np.asarray(prof.states, dtype=np.int32)
        if len(s) >= m:
            s = s[:m]
        else:
            s = np.concatenate([s, np.full(m - len(s), s[-1], dtype=np.int32)])
        out[p] = s
    return np.clip(out, 0, max(0, n_max - 1))
