"""Speculative scanning: parallel matching for blowup-regime patterns.

The paper's SFA construction is bounded by the ``n^n`` state blowup, so any
pattern over the plan's ``sfa_state_budget`` used to fall back to full
``n``-state enumeration per chunk — the engine's slowest path for exactly the
large automata users most want parallelized. The speculative subsystem is the
third way (*A Speculative Parallel DFA Membership Test*, arXiv:1210.5093, and
*PaREM*, arXiv:1412.1741): instead of running all ``n`` states per chunk,
run each chunk from a small set of ``m`` *likely* boundary states — a
hot-state profile measured from a sampled prefix of the input or persisted
corpus statistics — then validate every chunk's speculated entry against its
predecessor's exact exit and re-scan only the chunks whose speculation
missed. Cost is ``O(L·m)`` plus one chunk per repair instead of ``O(L·n)``,
and the result is **bit-identical to enumeration by construction**: a chunk's
result is only ever used when its entry state was verified exactly, and
lanes the repair bound leaves unresolved fall back to the enumeration
executor.

Layout:

* :mod:`.profile`  — the hot-state profiler (:class:`HotStateProfile`,
  :func:`profile_hot_states`): top-``m`` boundary-state distributions per
  pattern, persistable next to SFA artifacts in the
  :class:`repro.scanservice.ArtifactStore`;
* :mod:`.executor` — the jitted speculative executor
  (:func:`speculative_bank_finals`): one batched pass over a stacked
  ``(m, chunks)`` state axis, an ``O(C)`` validation scan, and a fixed-shape
  repair loop bounded by ``max_repair_rounds``, plus the ``shard_map``
  distributed builder; :class:`SpeculationStats` reports hit rate, repair
  rounds, and repaired/fallback counts per scan.

The engine plumbing lives in :mod:`repro.engine`:
``ScanPlan(mode="speculative", speculation=SpeculationPolicy(...))`` forces
every pattern through this subsystem, and ``mode="auto"`` routes a pattern
here when its SFA blows the state budget *and* its DFA has at least
``SpeculationPolicy.auto_states`` states — the tier between sfa and
enumeration.
"""

from .executor import (
    SpeculationStats,
    distributed_speculative_finals_fn,
    speculative_bank_finals,
)
from .profile import (
    HotStateProfile,
    profile_hot_states,
    stack_profile_states,
)

__all__ = [
    "HotStateProfile",
    "SpeculationStats",
    "distributed_speculative_finals_fn",
    "profile_hot_states",
    "speculative_bank_finals",
    "stack_profile_states",
]
