"""The jitted speculative executor: m-wide chunk passes + validate/repair.

Enumeration resolves a chunk's transition function for *all* ``n`` states —
``O(L·n)`` gathers per pattern — because it cannot know the chunk's entry
state before its predecessor finishes. Speculation breaks the chain the
other way (1210.5093 / PaREM 1412.1741): run every chunk from ``m`` *likely*
entry states in one batched pass (a stacked ``(m, chunks)`` state axis —
the same shape trick as enumeration, just ``m`` lanes instead of ``n``),
then walk the chunks once, cheaply, to check each chunk's true entry state
(its predecessor's exact exit) against the speculated set:

* **hit** — the entry was speculated; adopt that lane's precomputed exit.
  The adopted exit is exactly what a sequential run would produce, so
  correctness propagates chunk to chunk by induction.
* **miss** — re-scan *only* the first missed chunk of each broken
  (pattern, doc) lane from its now-known entry (a fixed-shape
  ``(P, D, chunk_len)`` repair pass — one chunk per lane per round), and
  re-validate. Each round resolves at least one more chunk per unresolved
  lane, so ``max_rounds`` bounds the loop; anything still unresolved is
  reported for the caller's guaranteed enumeration fallback.

Everything is fixed-shape: the validation walk is a ``lax.scan`` over the
chunk axis on ``(P, D)`` lanes, and the repair loop is a ``lax.while_loop``
whose body re-runs the same two fixed-shape stages — one compiled program
per (bank shape, corpus shape), no recompiles across rounds.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from ..compat import shard_map as compat_shard_map


@dataclass(frozen=True)
class SpeculationStats:
    """What one speculative scan actually did.

    ``total_chunks`` counts every (pattern, doc, chunk) cell the executor
    resolved; ``hit_chunks`` of those were settled by speculation alone and
    ``repaired_chunks`` by targeted re-scans (on a fully resolved scan,
    ``hit_chunks + repaired_chunks == total_chunks``). ``repair_rounds`` is
    the deepest validate/repair iteration count any executor invocation
    needed (0 when every chunk's entry was speculated), and
    ``fallback_lanes`` counts (pattern, doc) lanes the round bound left for
    the enumeration fallback — still bit-identical, just not cheap.
    """

    total_chunks: int = 0
    hit_chunks: int = 0
    repaired_chunks: int = 0
    repair_rounds: int = 0
    fallback_lanes: int = 0

    @property
    def hit_rate(self) -> float:
        """Fraction of chunks settled by speculation alone (1.0 when empty)."""
        if not self.total_chunks:
            return 1.0
        return self.hit_chunks / self.total_chunks

    def merged(self, other: "SpeculationStats") -> "SpeculationStats":
        """Combine stats across pattern groups / length batches of one scan."""
        return replace(
            self,
            total_chunks=self.total_chunks + other.total_chunks,
            hit_chunks=self.hit_chunks + other.hit_chunks,
            repaired_chunks=self.repaired_chunks + other.repaired_chunks,
            repair_rounds=max(self.repair_rounds, other.repair_rounds),
            fallback_lanes=self.fallback_lanes + other.fallback_lanes,
        )


# --------------------------------------------------------------------------
# The core (traced once; shared by the local jit and the shard_map body)
# --------------------------------------------------------------------------


def _run_chunk_states(table, states, chunk):
    """Advance a vector of states through one chunk: (n, k), (m,), (Lc,) -> (m,)."""
    def step(sv, sym):
        return table[sv, sym], None

    out, _ = jax.lax.scan(step, states, chunk)
    return out


def _speculative_core(tables, spec_states, starts, corpus,
                      n_chunks: int, max_rounds: int):
    """-> (finals (P, D) int32, resolved (P, D) bool, hit_chunks, repaired,
    rounds) — finals are exact wherever ``resolved``; unresolved lanes keep
    their last verified state and MUST be recomputed by the caller."""
    Pn = tables.shape[0]
    D, L = corpus.shape
    C = n_chunks
    Lc = L // C
    chunks = corpus.reshape(D, C, Lc)
    starts = jnp.broadcast_to(starts.astype(jnp.int32)[:, None], (Pn, D))

    # Stage 1 — the one batched speculative pass: every (pattern, doc, chunk)
    # cell runs from all m speculated states at once. O(L·m) per pattern,
    # the whole reason this beats the O(L·n) enumeration gathers.
    exits = jax.vmap(
        lambda t, sp: jax.vmap(
            jax.vmap(lambda ch: _run_chunk_states(t, sp, ch))
        )(chunks)
    )(tables, spec_states)                               # (P, D, C, m)

    c_idx = jnp.arange(C, dtype=jnp.int32)

    def validate(rep_exit, rep_mask):
        """Walk the chunk axis once, threading exact entry states.

        ``rep_exit``/``rep_mask`` (P, D, C) carry repaired chunks from
        earlier rounds — a repaired chunk's exit overrides speculation.
        Returns (finals, resolved, miss_c, miss_entry, hit_chunks) where
        ``miss_c``/``miss_entry`` locate the first unrepaired miss of each
        still-broken lane (the next round's repair target).
        """
        def step(carry, xs):
            cur, alive, miss_c, miss_entry, hits = carry
            ex_c, rep_e, rep_m, c = xs
            match = spec_states[:, None, :] == cur[:, :, None]   # (P, D, m)
            hit = jnp.any(match, axis=-1)
            lane = jnp.argmax(match, axis=-1)
            spec_exit = jnp.take_along_axis(
                ex_c, lane[..., None], axis=-1
            )[..., 0]
            ok = rep_m | hit
            nxt = jnp.where(rep_m, rep_e, spec_exit)
            newly_missed = alive & ~ok
            hits = hits + jnp.sum(alive & ~rep_m & hit, dtype=jnp.int32)
            miss_c = jnp.where(newly_missed, c, miss_c)
            miss_entry = jnp.where(newly_missed, cur, miss_entry)
            cur = jnp.where(alive & ok, nxt, cur)
            alive = alive & ok
            return (cur, alive, miss_c, miss_entry, hits), None

        init = (
            starts,
            jnp.ones((Pn, D), dtype=bool),
            jnp.full((Pn, D), C, dtype=jnp.int32),
            jnp.zeros((Pn, D), dtype=jnp.int32),
            jnp.zeros((), dtype=jnp.int32),
        )
        xs = (
            jnp.moveaxis(exits, 2, 0),          # (C, P, D, m)
            jnp.moveaxis(rep_exit, 2, 0),       # (C, P, D)
            jnp.moveaxis(rep_mask, 2, 0),
            c_idx,
        )
        carry, _ = jax.lax.scan(step, init, xs)
        return carry

    d_idx = jnp.arange(D)

    def repair(rep_exit, rep_mask, alive, miss_c, miss_entry):
        """Re-scan the first missed chunk of every broken lane from its
        exact entry — one (P, D, Lc) fixed-shape pass per round."""
        c = jnp.minimum(miss_c, C - 1)                   # (P, D); clip is inert
        lane_chunks = chunks[d_idx[None, :], c]          # (P, D, Lc)

        def run_lane(t, ch, e):
            def step(s, sym):
                return t[s, sym], None

            out, _ = jax.lax.scan(step, e, ch)
            return out

        exact = jax.vmap(
            lambda t, chs, es: jax.vmap(
                lambda ch, e: run_lane(t, ch, e)
            )(chs, es)
        )(tables, lane_chunks, miss_entry)               # (P, D)
        sel = (c_idx[None, None, :] == c[:, :, None]) & (~alive)[:, :, None]
        rep_exit = jnp.where(sel, exact[:, :, None], rep_exit)
        rep_mask = rep_mask | sel
        return rep_exit, rep_mask

    def cond(state):
        _, _, rounds, alive, _, _, _, _ = state
        return (~jnp.all(alive)) & (rounds < max_rounds)

    def body(state):
        rep_exit, rep_mask, rounds, alive, miss_c, miss_entry, _, _ = state
        rep_exit, rep_mask = repair(rep_exit, rep_mask, alive, miss_c, miss_entry)
        cur, alive, miss_c, miss_entry, hits = validate(rep_exit, rep_mask)
        return (rep_exit, rep_mask, rounds + 1, alive, miss_c, miss_entry,
                cur, hits)

    rep_exit = jnp.zeros((Pn, D, C), dtype=jnp.int32)
    rep_mask = jnp.zeros((Pn, D, C), dtype=bool)
    cur, alive, miss_c, miss_entry, hits = validate(rep_exit, rep_mask)
    state = (rep_exit, rep_mask, jnp.zeros((), dtype=jnp.int32),
             alive, miss_c, miss_entry, cur, hits)
    state = jax.lax.while_loop(cond, body, state)
    rep_exit, rep_mask, rounds, alive, miss_c, miss_entry, cur, hits = state
    repaired = jnp.sum(rep_mask, dtype=jnp.int32)
    return cur, alive, hits, repaired, rounds


@functools.partial(jax.jit, static_argnames=("n_chunks", "max_rounds"))
def speculative_bank_finals(tables: jnp.ndarray, spec_states: jnp.ndarray,
                            starts: jnp.ndarray, corpus: jnp.ndarray,
                            n_chunks: int = 8, max_rounds: int = 8):
    """Speculative final states of every (pattern, doc).

    ``tables`` (P, n, k) padded enumeration tables; ``spec_states`` (P, m)
    speculated boundary states (a hot-state profile stack); ``starts`` (P,);
    ``corpus`` (D, L) with ``L`` divisible by ``n_chunks``.

    -> ``(finals (P, D) int32, resolved (P, D) bool, hit_chunks, repaired,
    rounds)``. ``finals[p, d]`` is **exact** wherever ``resolved[p, d]`` —
    every adopted chunk exit was validated against the true entry state —
    and callers must recompute unresolved lanes (the enumeration fallback
    in ``Scanner``). The speculation quality only moves work between the
    hit/repaired/fallback buckets, never the result.
    """
    return _speculative_core(tables, spec_states, starts, corpus,
                             n_chunks, max_rounds)


# --------------------------------------------------------------------------
# shard_map distribution (docs over the data axis, like the mapping path)
# --------------------------------------------------------------------------


def distributed_speculative_finals_fn(mesh: Mesh, data_axis: str = "data",
                                      n_chunks: int = 8, max_rounds: int = 8):
    """Scanner's shard_map path for speculative mode: docs shard over
    ``data_axis`` (tables/profiles replicated), each device runs the full
    local validate/repair loop on its shard — trip counts may differ per
    device; there are no collectives inside the loop, so that is fine —
    then finals/resolved gather on the doc axis and the counters combine
    (psum for chunk counts, pmax for the round depth). Returns a jitted
    ``fn(tables, spec_states, starts, corpus)`` with the local output
    contract of :func:`speculative_bank_finals`.
    """

    def local(tables, spec_states, starts, corpus_shard):
        finals, resolved, hits, repaired, rounds = _speculative_core(
            tables, spec_states, starts, corpus_shard, n_chunks, max_rounds
        )
        finals = jax.lax.all_gather(finals, data_axis, axis=1, tiled=True)
        resolved = jax.lax.all_gather(resolved, data_axis, axis=1, tiled=True)
        hits = jax.lax.psum(hits, data_axis)
        repaired = jax.lax.psum(repaired, data_axis)
        rounds = jax.lax.pmax(rounds, data_axis)
        return finals, resolved, hits, repaired, rounds

    @jax.jit
    def fn(tables, spec_states, starts, corpus):
        return compat_shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(), P(), P(data_axis)),
            out_specs=(P(), P(), P(), P(), P()),
            check_vma=False,
        )(tables, spec_states, starts, corpus)

    return fn
