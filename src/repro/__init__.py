"""repro: SFA construction with Rabin fingerprints (CS.DC 2015) as a
multi-pod JAX training/serving framework. See README.md / DESIGN.md."""

__version__ = "1.0.0"
