"""Coalescing batch scheduler: many concurrent scan requests, one bank.

A serving process sees a stream of small, overlapping requests — a few
patterns each against a few documents. Compiling and scanning each request
alone wastes exactly what the paper says to amortize: automaton setup and
per-call dispatch. The scheduler coalesces every request that lands inside a
micro-batch window into **one** compile of the union pattern bank (all cache
misses constructed in a single :func:`repro.construction.construct_bank`
call, size-bucketed through the plan's chunking policy) and **one** fused
bank scan over the union document set, then demultiplexes the hit matrix
back per request. Since every backend computes the same exact automaton
semantics and documents scan independently, the demuxed slices are
bit-identical to per-request ``Scanner.scan`` — coalescing is pure
amortization, never an approximation.

Two drivers share the batching core:

* ``driver="sync"`` — requests queue until :meth:`BatchScheduler.flush`
  (or a full ``max_batch``, or ``Ticket.result()``) processes them on the
  calling thread. No threads anywhere — the deterministic driver the test
  suite uses.
* ``driver="thread"`` — a worker thread closes each batch ``window_s``
  after its first request (earlier when ``max_batch`` fills);
  ``submit`` returns immediately and ``Ticket.result()`` blocks.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass, replace

import numpy as np

from .. import obs
from ..construction import dfa_cache_key
from ..core.dfa import DFA
from ..engine import ChunkPolicy, ConstructionPolicy, ScanPlan, Scanner

DRIVERS = ("sync", "thread")


def _default_plan() -> ScanPlan:
    # Union banks coalesce many requests' patterns, so they are exactly the
    # big, size-skewed banks size-bucketed construction exists for — submit
    # them bucketed explicitly rather than leaning on the "auto" heuristic.
    return ScanPlan(
        chunking=ChunkPolicy(bucket=True),
        construction=ConstructionPolicy(method="batched", bucketing="size"),
    )


@dataclass(frozen=True)
class RequestResult:
    """One request's demuxed slice of a coalesced batch scan."""

    hits: np.ndarray      # (P_req, D_req) bool
    ids: tuple            # this request's pattern ids
    batch_size: int       # requests that shared the flush

    @property
    def counts(self) -> np.ndarray:
        return np.sum(self.hits, axis=1, dtype=np.int32)


class Ticket:
    """Handle for one submitted request; redeem with :meth:`result`.

    ``trace_id`` is the request's observability correlation key (captured
    at submit time, None with tracing disabled): every span the request's
    flush produces — scheduler.flush, scanner.compile, construct_bank
    rounds, store gets — carries it, so ``obs.trace_summary(t.trace_id)``
    reconstructs where this request's time went.
    """

    def __init__(self, scheduler: "BatchScheduler",
                 trace_id: str | None = None):
        self._scheduler = scheduler
        self.trace_id = trace_id
        self._event = threading.Event()
        self._result: RequestResult | None = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> RequestResult:
        """The request's :class:`RequestResult`. Under the sync driver an
        unflushed ticket flushes the scheduler first; under the thread
        driver this blocks until the worker closes the batch."""
        if not self._event.is_set() and self._scheduler.driver == "sync":
            self._scheduler.flush()
        if not self._event.wait(timeout):
            raise TimeoutError("scan request still pending")
        if self._error is not None:
            raise self._error
        return self._result

    def _resolve(self, result: RequestResult) -> None:
        self._result = result
        self._event.set()

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self._event.set()


@dataclass
class SchedulerStats:
    """Point-in-time scheduler counters.

    ``BatchScheduler.stats`` returns an **atomic copy** taken under the
    scheduler's stats lock — under the thread driver, the worker increments
    these concurrently with readers, and a field-by-field read of a live
    object could see e.g. ``flushes`` from one flush and ``union_docs``
    from the next. Every mutation also mirrors into the process-wide
    ``scheduler.*`` registry metrics.
    """

    requests: int = 0
    flushes: int = 0
    max_coalesced: int = 0
    union_patterns: int = 0   # pattern columns actually compiled/scanned
    union_docs: int = 0       # documents actually scanned
    scanner_memo_hits: int = 0   # union batches answered by the scanner memo
    scanner_evictions: int = 0   # scanners dropped by the memo's LRU lid
    speculative_patterns: int = 0  # union columns routed to speculation


#: ``# HELP`` text for the mirrored ``scheduler.*`` counters (the gauge
#: describes itself at its callsite).
_STAT_HELP = {
    "requests": "scan requests submitted",
    "flushes": "coalesced batch flushes executed",
    "union_patterns": "distinct pattern columns compiled/scanned in "
                      "union banks",
    "union_docs": "distinct documents scanned in union batches",
    "scanner_memo_hits": "union batches answered by the memoized scanner",
    "scanner_evictions": "scanners dropped by the memo's LRU lid",
    "speculative_patterns": "union columns routed through speculation",
}


class _Request:
    __slots__ = ("keys", "ids", "specs", "doc_keys", "docs", "ticket")

    def __init__(self, keys, ids, specs, doc_keys, docs, ticket):
        self.keys = keys
        self.ids = ids
        self.specs = specs
        self.doc_keys = doc_keys
        self.docs = docs
        self.ticket = ticket


def _spec_key(spec) -> tuple:
    if isinstance(spec, str):
        return ("str", spec)
    if isinstance(spec, DFA):
        return ("dfa", dfa_cache_key(spec))
    raise TypeError(
        f"scheduler pattern specs must be str or DFA, got {type(spec).__name__}"
    )


def _doc_key(doc) -> tuple:
    if isinstance(doc, str):
        return ("str", doc)
    arr = np.asarray(doc, dtype=np.int32)
    return ("arr", arr.tobytes())


class BatchScheduler:
    """Coalesce concurrent ``submit(patterns, docs)`` calls into fused
    bank compiles + scans (see module docstring)."""

    def __init__(self, plan: ScanPlan | None = None, *, driver: str = "sync",
                 window_s: float = 0.002, max_batch: int = 64,
                 max_scanners: int = 32):
        if driver not in DRIVERS:
            raise ValueError(f"driver must be one of {DRIVERS}, got {driver!r}")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if window_s < 0:
            raise ValueError("window_s must be >= 0")
        if max_scanners < 1:
            raise ValueError("max_scanners must be >= 1")
        self.plan = (plan or _default_plan()).validate()
        self.driver = driver
        self.window_s = window_s
        self.max_batch = max_batch
        self.max_scanners = max_scanners
        # All counter mutations go through _bump under this lock; the
        # ``stats`` property copies atomically under it (satisfying the
        # thread-driver snapshot-consistency contract).
        self._stats = SchedulerStats()
        self._stats_lock = threading.Lock()
        #: trace id of the most recent flush (None before any, or with
        #: tracing disabled) — what ``ScanService.metrics`` correlates on.
        self.last_trace_id: str | None = None
        self._pending: list = []
        self._cond = threading.Condition()
        self._first_ts: float | None = None
        self._stop = False
        # LRU memo of union-bank Scanners, bounded by ``max_scanners`` like
        # the SFA cache is bounded: a long-lived service sees an unbounded
        # stream of distinct union keys, and each Scanner pins device tables.
        # Guarded by its own lock — ``_run_batch`` runs outside ``_cond``.
        self._scanners: OrderedDict = OrderedDict()
        self._scanners_lock = threading.Lock()
        self._worker = None
        if driver == "thread":
            self._worker = threading.Thread(
                target=self._worker_loop, name="scan-batcher", daemon=True
            )
            self._worker.start()

    # -- stats ---------------------------------------------------------------

    @property
    def stats(self) -> SchedulerStats:
        """An atomic copy of the counters (see :class:`SchedulerStats`)."""
        with self._stats_lock:
            return replace(self._stats)

    @property
    def closed(self) -> bool:
        """True once :meth:`close` ran — new submits are refused. The
        telemetry ``/healthz`` endpoint reports this as the status."""
        with self._cond:
            return self._stop

    def _bump(self, **deltas) -> None:
        """Apply counter deltas atomically and mirror them into the
        ``scheduler.*`` registry namespace (``max_coalesced`` is a running
        max, exported as a gauge)."""
        with self._stats_lock:
            for name, d in deltas.items():
                if name == "max_coalesced":
                    self._stats.max_coalesced = max(
                        self._stats.max_coalesced, d
                    )
                    obs.gauge("scheduler.max_coalesced",
                              help="largest request count coalesced into "
                                   "one flush (running max; fleet merges "
                                   "by max)").set(
                        self._stats.max_coalesced
                    )
                else:
                    setattr(self._stats, name, getattr(self._stats, name) + d)
                    obs.counter(f"scheduler.{name}",
                                help=_STAT_HELP.get(name)).inc(d)

    # -- submission ----------------------------------------------------------

    def submit(self, patterns, docs) -> Ticket:
        """Enqueue one request: ``patterns`` is a str/DFA or a sequence of
        them, ``docs`` a str/encoded array or a sequence. -> :class:`Ticket`.
        """
        if isinstance(patterns, (str, DFA)):
            patterns = [patterns]
        patterns = list(patterns)
        if isinstance(docs, str) or (
            isinstance(docs, np.ndarray) and docs.ndim == 1
        ):
            docs = [docs]
        docs = list(docs)
        if not patterns or not docs:
            raise ValueError("submit needs at least one pattern and one doc")
        keys = tuple(_spec_key(p) for p in patterns)
        ids = tuple(
            p if isinstance(p, str) else f"pattern_{i}"
            for i, p in enumerate(patterns)
        )
        # Capture the request's trace id on the *caller's* thread: the
        # thread driver's worker has its own context, so _run_batch re-roots
        # its spans with this id explicitly.
        with obs.span("scheduler.submit", patterns=len(patterns),
                      docs=len(docs)) as sub_span:
            trace_id = sub_span.trace_id if sub_span is not None else None
        req = _Request(
            keys, ids, patterns, tuple(_doc_key(d) for d in docs), docs,
            Ticket(self, trace_id),
        )
        with self._cond:
            if self._stop:
                raise RuntimeError("scheduler is closed")
            self._pending.append(req)
            # Nested under _cond deliberately: the request must be counted
            # before any flush that could serve it counts its own stats.
            self._bump(requests=1)
            if self._first_ts is None:
                self._first_ts = time.monotonic()
            self._cond.notify_all()
            full = len(self._pending) >= self.max_batch
        if self.driver == "sync" and full:
            self.flush()
        return req.ticket

    def flush(self) -> int:
        """Process everything pending as one coalesced batch (on the calling
        thread). -> number of requests served."""
        with self._cond:
            batch, self._pending = self._pending, []
            self._first_ts = None
        if batch:
            self._run_batch(batch)
        return len(batch)

    # -- the coalescing core -------------------------------------------------

    def _run_batch(self, batch: list) -> None:
        try:
            # Union patterns and docs, deduplicated by content.
            col_of: dict = {}
            union_specs: list = []
            for req in batch:
                for key, spec in zip(req.keys, req.specs):
                    if key not in col_of:
                        col_of[key] = len(union_specs)
                        union_specs.append(spec)
            doc_of: dict = {}
            union_docs: list = []
            for req in batch:
                for key, doc in zip(req.doc_keys, req.docs):
                    if key not in doc_of:
                        doc_of[key] = len(union_docs)
                        union_docs.append(doc)

            # Re-root the flush's spans on the first request's trace id
            # (submit captured it on the caller's thread; the thread
            # driver's worker doesn't inherit contextvars). The other
            # coalesced requests ride along as an attribute.
            trace_ids = [
                r.ticket.trace_id for r in batch
                if r.ticket.trace_id is not None
            ]
            with obs.span(
                "scheduler.flush",
                trace_id=trace_ids[0] if trace_ids else None,
                requests=len(batch),
                coalesced_trace_ids=tuple(trace_ids[1:]),
            ):
                self.last_trace_id = obs.current_trace_id()
                scanner = self._scanner_for(tuple(col_of), union_specs)
                result = scanner.scan(union_docs)   # ONE fused bank scan

            self._bump(
                flushes=1,
                max_coalesced=len(batch),
                union_patterns=len(union_specs),
                union_docs=len(union_docs),
                # Over-budget patterns route to the speculative tier through
                # the plan's auto mode (see repro.speculative); count what
                # this batch actually served speculatively.
                speculative_patterns=sum(
                    1 for m in scanner.pattern_modes.values()
                    if m == "speculative"
                ),
            )
            obs.counter("scheduler.coalesced_requests",
                        help="requests answered by a coalesced union-bank "
                             "flush").inc(len(batch))

            for req in batch:
                rows = np.asarray([col_of[k] for k in req.keys])
                cols = np.asarray([doc_of[k] for k in req.doc_keys])
                req.ticket._resolve(RequestResult(
                    hits=result.hits[np.ix_(rows, cols)].copy(),
                    ids=req.ids,
                    batch_size=len(batch),
                ))
        except BaseException as exc:  # propagate to every waiter
            for req in batch:
                req.ticket._fail(exc)
            if self.driver == "sync":
                raise

    def _scanner_for(self, key_tuple: tuple, specs: list) -> Scanner:
        """LRU-memoized union-bank compile. Cold pattern sets still answer
        most construction from the plan's SFA cache tiers; this memo
        additionally skips re-stacking device tables for repeat batches. An
        evicted key recompiles (cheaply, through the SFA and round-compile
        caches) on its next batch."""
        with self._scanners_lock:
            sc = self._scanners.get(key_tuple)
            if sc is not None:
                self._scanners.move_to_end(key_tuple)
                hit = True
            else:
                hit = False
        if hit:
            self._bump(scanner_memo_hits=1)
            return sc
        sc = Scanner.compile(specs, self.plan)   # compile outside the lock
        evicted = 0
        with self._scanners_lock:
            self._scanners[key_tuple] = sc
            self._scanners.move_to_end(key_tuple)
            while len(self._scanners) > self.max_scanners:
                self._scanners.popitem(last=False)
                evicted += 1
        if evicted:
            self._bump(scanner_evictions=evicted)
        return sc

    # -- thread driver -------------------------------------------------------

    def _worker_loop(self) -> None:
        while True:
            with self._cond:
                while not self._pending and not self._stop:
                    self._cond.wait()
                if not self._pending and self._stop:
                    return
                # Window: wait for stragglers until the deadline/batch cap.
                while not self._stop and len(self._pending) < self.max_batch:
                    remaining = self._first_ts + self.window_s - time.monotonic()
                    if remaining <= 0:
                        break
                    self._cond.wait(timeout=remaining)
                batch, self._pending = self._pending, []
                self._first_ts = None
            self._run_batch(batch)

    def close(self) -> None:
        """Serve any queued requests, then stop accepting new ones."""
        if self.driver == "thread":
            with self._cond:
                self._stop = True
                self._cond.notify_all()
            self._worker.join()
        else:
            self.flush()
            self._stop = True

    def __enter__(self) -> "BatchScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
