"""HTTP telemetry front: ``/metrics``, ``/healthz``, ``/traces``.

A serving process needs to be *scrapeable* — Prometheus pulls, load
balancers probe, operators curl. :class:`TelemetryServer` is that front:
a stdlib ``http.server`` (no new dependencies) running on its own daemon
thread, serving three read-only endpoints over the process-wide
:mod:`repro.obs` state:

* ``GET /metrics`` — ``obs.render_prometheus`` of the live registry
  snapshot (with registered ``# HELP`` descriptions). Scrapes are safe at
  any moment — every metric read takes its own lock, so a scrape during an
  active coalesced scheduler burst sees a consistent per-metric view
  without ever blocking the burst.
* ``GET /healthz`` — liveness + the service's operational state as JSON:
  scheduler counters (the atomic :class:`SchedulerStats` copy), two-tier
  cache counters with the derived hit rate, and artifact-store occupancy.
  A server constructed without a service still answers (process identity
  and uptime only) — the benchmark sweep uses that mode.
* ``GET /traces`` — recent span activity grouped per trace id (newest
  first, ``?limit=N`` traces): span count, wall, and the span names in
  start order — the "what were the last requests doing" drill-down.

Ownership: :meth:`repro.scanservice.ScanService.serve_telemetry` starts
one bound to the service and ``ScanService.close()`` stops it; a bare
``TelemetryServer().start()`` serves registry + traces for any process
(e.g. a corpus-shard worker). ``port=0`` binds an ephemeral port,
published as ``server.port`` / ``server.url`` after :meth:`start`.
"""

from __future__ import annotations

import json
import os
import socket
import threading
import time
from collections import OrderedDict
from dataclasses import asdict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlsplit

from .. import obs

#: Prometheus text exposition content type (version pinned per spec).
PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class TelemetryServer:
    """One process's scrape endpoint. See module docstring."""

    def __init__(self, service=None, *, host: str = "127.0.0.1",
                 port: int = 0):
        self.service = service
        self.host = host
        self._port_req = port
        self._httpd: ThreadingHTTPServer | None = None
        self._thread: threading.Thread | None = None
        self._t_start: float | None = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "TelemetryServer":
        """Bind and serve on a daemon thread (idempotent). -> self."""
        if self._httpd is not None:
            return self
        handler = type("_BoundHandler", (_Handler,), {"telemetry": self})
        self._httpd = ThreadingHTTPServer((self.host, self._port_req),
                                          handler)
        self._httpd.daemon_threads = True
        self._t_start = time.time()
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="telemetry", daemon=True
        )
        self._thread.start()
        return self

    @property
    def running(self) -> bool:
        return self._httpd is not None

    @property
    def port(self) -> int | None:
        """The bound port (the real one when constructed with ``port=0``),
        or None before :meth:`start`."""
        return self._httpd.server_address[1] if self._httpd else None

    @property
    def url(self) -> str | None:
        return f"http://{self.host}:{self.port}" if self._httpd else None

    def close(self) -> None:
        """Stop serving and release the port (idempotent). In-flight
        requests finish; new connections are refused."""
        if self._httpd is None:
            return
        self._httpd.shutdown()
        self._thread.join()
        self._httpd.server_close()
        self._httpd = None
        self._thread = None

    def __enter__(self) -> "TelemetryServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- endpoint payloads (also callable directly, e.g. from tests) ---------

    def metrics_text(self) -> str:
        return obs.render_prometheus(obs.snapshot())

    def healthz(self) -> dict:
        payload = {
            "status": "ok",
            "host": socket.gethostname(),
            "pid": os.getpid(),
            "uptime_s": (time.time() - self._t_start
                         if self._t_start is not None else 0.0),
            "obs_enabled": obs.enabled(),
        }
        svc = self.service
        if svc is None:
            return payload
        sched = asdict(svc.scheduler.stats)
        sched["driver"] = svc.scheduler.driver
        sched["closed"] = svc.scheduler.closed
        if sched["closed"]:
            payload["status"] = "closing"
        info = svc.cache.info.snapshot()
        looked = info["hits"] + info["misses"]
        payload["scheduler"] = sched
        payload["cache"] = {
            **info, "hit_rate": info["hits"] / looked if looked else 0.0,
        }
        if svc.store is not None:
            payload["store"] = {
                "root": str(svc.store.root),
                "entries": len(svc.store),
                "bytes": svc.store.total_bytes(),
                "max_bytes": svc.store.max_bytes,
            }
        return payload

    def traces(self, limit: int = 20) -> dict:
        """Recent span activity summarized per trace, newest trace first."""
        by_trace: OrderedDict = OrderedDict()
        for s in obs.recent_spans(4096):
            t = by_trace.setdefault(s.trace_id, {
                "trace_id": s.trace_id, "n_spans": 0,
                "t_start": s.t_start, "t_end": s.t_end, "names": [],
            })
            t["n_spans"] += 1
            t["t_start"] = min(t["t_start"], s.t_start)
            t["t_end"] = max(t["t_end"], s.t_end)
            if s.name not in t["names"]:
                t["names"].append(s.name)
        traces = []
        for t in reversed(by_trace.values()):
            if len(traces) >= max(limit, 0):
                break
            traces.append({
                "trace_id": t["trace_id"], "n_spans": t["n_spans"],
                "wall_s": t["t_end"] - t["t_start"], "names": t["names"],
            })
        return {"traces": traces, "retained_traces": len(by_trace)}


class _Handler(BaseHTTPRequestHandler):
    """Routes one GET; the bound :class:`TelemetryServer` rides the class
    attribute ``telemetry`` (set by ``start()``'s subclass-per-server)."""

    server_version = "repro-telemetry"
    telemetry: TelemetryServer

    def log_message(self, *args) -> None:   # scrapes are not access-log news
        pass

    def do_GET(self) -> None:
        url = urlsplit(self.path)
        route = url.path.rstrip("/") or "/"
        try:
            if route == "/metrics":
                self._send(200, self.telemetry.metrics_text(),
                           PROM_CONTENT_TYPE)
            elif route == "/healthz":
                self._send_json(200, self.telemetry.healthz())
            elif route == "/traces":
                try:
                    limit = int(parse_qs(url.query).get("limit", ["20"])[0])
                except ValueError:
                    self._send_json(400, {"error": "limit must be an int"})
                    return
                self._send_json(200, self.telemetry.traces(limit))
            else:
                self._send_json(404, {
                    "error": f"no route {route!r}",
                    "routes": ["/metrics", "/healthz", "/traces"],
                })
        except Exception as e:   # a broken scrape must not kill the server
            try:
                self._send_json(500, {"error": f"{type(e).__name__}: {e}"})
            except OSError:
                pass   # client hung up mid-reply

    def _send(self, code: int, body: str, content_type: str) -> None:
        data = body.encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _send_json(self, code: int, payload: dict) -> None:
        self._send(code, json.dumps(payload, indent=1, sort_keys=True),
                   "application/json")
