"""The :class:`ScanService` facade: store + scheduler + jobs as one object.

This is the serving layer's front door (also reachable as
``Scanner.service(...)``). It owns:

* an :class:`~repro.scanservice.ArtifactStore` (when ``store_dir`` is
  given) attached as the persistent tier under one
  :class:`~repro.construction.SFACache`, so every compile the service
  performs — direct, coalesced, or inside a corpus job — reads and writes
  the same two-tier cache. A fresh process pointed at the same store
  compiles previously-seen patterns with zero construction rounds;
  :meth:`ScanService.warm_start` bulk-promotes the store into memory up
  front so even first requests skip the disk tier.
* a :class:`~repro.scanservice.BatchScheduler` coalescing concurrent
  ``submit`` calls into fused bank compiles + scans;
* a :class:`~repro.scanservice.CorpusJob` factory binding jobs to the
  service's plan (and therefore its cache tiers).
"""

from __future__ import annotations

from dataclasses import asdict

from .. import obs
from ..construction import SFACache
from ..engine import ChunkPolicy, ConstructionPolicy, ScanPlan, Scanner
from .corpus import CorpusManifest
from .jobs import CorpusJob
from .scheduler import BatchScheduler, Ticket
from .store import ArtifactStore
from .telemetry import TelemetryServer


class ScanService:
    """A scan-serving endpoint. See module docstring."""

    def __init__(self, store_dir=None, plan: ScanPlan | None = None, *,
                 cache: SFACache | None = None,
                 store_max_bytes: int = 1 << 30,
                 driver: str = "sync", window_s: float = 0.002,
                 max_batch: int = 64, max_scanners: int = 32):
        if store_dir is None:
            self.store = None
        elif isinstance(store_dir, ArtifactStore):
            self.store = store_dir
        else:
            self.store = ArtifactStore(store_dir, max_bytes=store_max_bytes)
        self.cache = cache if cache is not None else SFACache()
        self.cache.attach_backing(self.store)
        if plan is not None:
            # Respect the caller's plan, but reroute it through the
            # service's cache tiers — including its store: a plan naming a
            # *different* store would silently rebind the service's cache
            # away from `self.store` on the first compile.
            overrides = {"cache": self.cache}
            if self.store is not None:
                overrides["store"] = self.store
            self.plan = plan.with_(
                construction=plan.construction.with_(**overrides)
            )
        else:
            self.plan = ScanPlan(
                chunking=ChunkPolicy(bucket=True),
                construction=ConstructionPolicy(
                    cache=self.cache, method="batched"
                ),
            ).validate()
        if self.store is not None and \
                self.plan.speculation.profile_source == "sample":
            # A persistent store upgrades speculation to persisted hot-state
            # profiles (keyed like the SFA artifacts): patterns profiled by
            # any earlier process speculate well from the first request.
            self.plan = self.plan.with_(
                speculation=self.plan.speculation.with_(profile_source="store")
            )
        self.scheduler = BatchScheduler(
            self.plan, driver=driver, window_s=window_s, max_batch=max_batch,
            max_scanners=max_scanners,
        )
        self.telemetry: TelemetryServer | None = None

    # -- cache tiers ---------------------------------------------------------

    def warm_start(self, max_entries: int | None = None) -> int:
        """Preload the persistent tier into memory. -> entries promoted."""
        return self.cache.preload(max_entries)

    def scanner(self, patterns, **overrides) -> Scanner:
        """Compile patterns through the service's plan and cache tiers."""
        return Scanner.compile(patterns, self.plan, **overrides)

    # -- request path --------------------------------------------------------

    def submit(self, patterns, docs) -> Ticket:
        return self.scheduler.submit(patterns, docs)

    def flush(self) -> int:
        return self.scheduler.flush()

    # -- observability -------------------------------------------------------

    def serve_telemetry(self, port: int = 0,
                        host: str = "127.0.0.1") -> TelemetryServer:
        """Start the HTTP telemetry front (``/metrics``, ``/healthz``,
        ``/traces``) bound to this service. ``port=0`` picks an ephemeral
        port — read it off the returned server's ``.port``/``.url``. The
        server stops with :meth:`close` (or its own ``.close()``); starting
        a second one while the first runs raises."""
        if self.telemetry is not None and self.telemetry.running:
            raise RuntimeError(
                f"telemetry already serving on {self.telemetry.url}; "
                "close it before starting another"
            )
        self.telemetry = TelemetryServer(self, host=host, port=port).start()
        return self.telemetry

    def metrics(self, trace_id: str | None = None) -> dict:
        """One correlated observability snapshot of the whole service.

        Everything in the returned dict is read at the same moment:

        * ``"cache"`` — the two-tier SFA cache counters plus the derived
          hit rate;
        * ``"scheduler"`` — an atomic :class:`SchedulerStats` copy (see the
          thread-driver consistency contract there);
        * ``"registry"`` — the full process-wide metric snapshot
          (``construction.*``, ``speculative.*``, ``store.artifact.*`` …);
        * ``"trace"`` — the span summary for ``trace_id`` (default: the
          last flush's trace), with two pre-digested views: per-bucket
          construction rounds/walls (from the ``construct_bank.bucket``
          spans) and the speculative span walls — the "where did this
          request's time go" answer, keyed by the same trace id the
          request's :class:`Ticket` carries.
        """
        if trace_id is None:
            trace_id = self.scheduler.last_trace_id
        info = self.cache.info.snapshot()
        looked = info["hits"] + info["misses"]
        cache = {**info,
                 "hit_rate": info["hits"] / looked if looked else 0.0}
        trace = (obs.trace_summary(trace_id) if trace_id is not None
                 else {"trace_id": None, "spans": [], "wall_s": 0.0})
        buckets = [
            {**sp["attrs"], "wall_s": sp["wall_s"]}
            for sp in trace["spans"] if sp["name"] == "construct_bank.bucket"
        ]
        speculative = [
            {**sp["attrs"], "wall_s": sp["wall_s"]}
            for sp in trace["spans"]
            if sp["name"].startswith("speculative.")
        ]
        return {
            "trace": {**trace, "construction_buckets": buckets,
                      "speculative_spans": speculative},
            "cache": cache,
            "scheduler": asdict(self.scheduler.stats),
            "registry": obs.snapshot(),
        }

    # -- corpus jobs ---------------------------------------------------------

    def corpus_job(self, patterns, manifest: CorpusManifest, workdir,
                   **kwargs) -> CorpusJob:
        """A resumable job running under the service's plan (and cache)."""
        return CorpusJob(patterns, manifest, workdir, plan=self.plan, **kwargs)

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        if self.telemetry is not None:
            self.telemetry.close()
            self.telemetry = None
        self.scheduler.close()

    def __enter__(self) -> "ScanService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
