"""The :class:`ScanService` facade: store + scheduler + jobs as one object.

This is the serving layer's front door (also reachable as
``Scanner.service(...)``). It owns:

* an :class:`~repro.scanservice.ArtifactStore` (when ``store_dir`` is
  given) attached as the persistent tier under one
  :class:`~repro.construction.SFACache`, so every compile the service
  performs — direct, coalesced, or inside a corpus job — reads and writes
  the same two-tier cache. A fresh process pointed at the same store
  compiles previously-seen patterns with zero construction rounds;
  :meth:`ScanService.warm_start` bulk-promotes the store into memory up
  front so even first requests skip the disk tier.
* a :class:`~repro.scanservice.BatchScheduler` coalescing concurrent
  ``submit`` calls into fused bank compiles + scans;
* a :class:`~repro.scanservice.CorpusJob` factory binding jobs to the
  service's plan (and therefore its cache tiers).
"""

from __future__ import annotations

from ..construction import SFACache
from ..engine import ChunkPolicy, ConstructionPolicy, ScanPlan, Scanner
from .corpus import CorpusManifest
from .jobs import CorpusJob
from .scheduler import BatchScheduler, Ticket
from .store import ArtifactStore


class ScanService:
    """A scan-serving endpoint. See module docstring."""

    def __init__(self, store_dir=None, plan: ScanPlan | None = None, *,
                 cache: SFACache | None = None,
                 store_max_bytes: int = 1 << 30,
                 driver: str = "sync", window_s: float = 0.002,
                 max_batch: int = 64, max_scanners: int = 32):
        if store_dir is None:
            self.store = None
        elif isinstance(store_dir, ArtifactStore):
            self.store = store_dir
        else:
            self.store = ArtifactStore(store_dir, max_bytes=store_max_bytes)
        self.cache = cache if cache is not None else SFACache()
        self.cache.attach_backing(self.store)
        if plan is not None:
            # Respect the caller's plan, but reroute it through the
            # service's cache tiers — including its store: a plan naming a
            # *different* store would silently rebind the service's cache
            # away from `self.store` on the first compile.
            overrides = {"cache": self.cache}
            if self.store is not None:
                overrides["store"] = self.store
            self.plan = plan.with_(
                construction=plan.construction.with_(**overrides)
            )
        else:
            self.plan = ScanPlan(
                chunking=ChunkPolicy(bucket=True),
                construction=ConstructionPolicy(
                    cache=self.cache, method="batched"
                ),
            ).validate()
        if self.store is not None and \
                self.plan.speculation.profile_source == "sample":
            # A persistent store upgrades speculation to persisted hot-state
            # profiles (keyed like the SFA artifacts): patterns profiled by
            # any earlier process speculate well from the first request.
            self.plan = self.plan.with_(
                speculation=self.plan.speculation.with_(profile_source="store")
            )
        self.scheduler = BatchScheduler(
            self.plan, driver=driver, window_s=window_s, max_batch=max_batch,
            max_scanners=max_scanners,
        )

    # -- cache tiers ---------------------------------------------------------

    def warm_start(self, max_entries: int | None = None) -> int:
        """Preload the persistent tier into memory. -> entries promoted."""
        return self.cache.preload(max_entries)

    def scanner(self, patterns, **overrides) -> Scanner:
        """Compile patterns through the service's plan and cache tiers."""
        return Scanner.compile(patterns, self.plan, **overrides)

    # -- request path --------------------------------------------------------

    def submit(self, patterns, docs) -> Ticket:
        return self.scheduler.submit(patterns, docs)

    def flush(self) -> int:
        return self.scheduler.flush()

    # -- corpus jobs ---------------------------------------------------------

    def corpus_job(self, patterns, manifest: CorpusManifest, workdir,
                   **kwargs) -> CorpusJob:
        """A resumable job running under the service's plan (and cache)."""
        return CorpusJob(patterns, manifest, workdir, plan=self.plan, **kwargs)

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        self.scheduler.close()

    def __enter__(self) -> "ScanService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
