"""Sharded corpus manifests: the unit of resumable scan work.

A manifest describes one corpus cut into shards — either a document corpus
(``kind="docs"``: explicit documents, ``shard_docs`` per shard) or a
windowed sequence (``kind="windows"``: all sliding windows of one long
sequence, ``shard_windows`` per shard — the genome-scan workload of Memeti &
Pllana's large-scale DNA studies). Shards are the checkpoint granularity of
:class:`repro.scanservice.CorpusJob`: each one scans independently and its
hit matrix lands in its own atomic artifact, so a killed job resumes at the
first unfinished shard.

:func:`scan_shard` is the single execution path both job kinds share:

* document shards scan through ``Scanner.scan``, except documents at or
  above ``stream_threshold`` symbols, which go through the engine's
  streaming path (``Scanner.stream`` — fixed-shape ``(n_chunks, block_len)``
  blocks, memory high-water mark independent of document length);
* window shards scan through the prefix-scan census
  (``Scanner.census_windows``): each shard re-derives only its own slice of
  the sequence, and every stride-block's transition function is computed
  once per shard instead of once per overlapping window.

Both paths compute the same exact automaton semantics, so shard results are
bit-identical however the corpus is cut — the property that makes resumed
and uninterrupted runs byte-identical.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass

import numpy as np

from ..engine import Scanner


@dataclass(frozen=True)
class CorpusManifest:
    """One corpus, sharded. Build via :meth:`from_docs` / :meth:`sliding`."""

    kind: str                 # "docs" | "windows"
    bounds: tuple             # (n_shards + 1,) cumulative item offsets
    docs: tuple = ()          # kind="docs": the documents
    seq: str = ""             # kind="windows": the underlying sequence
    window: int = 0
    stride: int = 0

    # -- construction --------------------------------------------------------

    @classmethod
    def from_docs(cls, docs, shard_docs: int = 8) -> "CorpusManifest":
        """Shard an explicit document corpus, ``shard_docs`` per shard."""
        docs = tuple(docs)
        if not docs:
            raise ValueError("empty corpus")
        if shard_docs < 1:
            raise ValueError("shard_docs must be >= 1")
        bounds = tuple(range(0, len(docs), shard_docs)) + (len(docs),)
        return cls(kind="docs", bounds=bounds, docs=docs)

    @classmethod
    def sliding(cls, seq: str, window: int, stride: int | None = None,
                shard_windows: int = 64) -> "CorpusManifest":
        """All sliding windows of ``seq``, ``shard_windows`` per shard.
        ``stride`` must divide ``window`` (default: disjoint windows)."""
        stride = window if stride is None else stride
        if window < 1 or stride < 1 or window % stride:
            raise ValueError("need stride >= 1 dividing window")
        if shard_windows < 1:
            raise ValueError("shard_windows must be >= 1")
        n_windows = (len(seq) - window) // stride + 1 if len(seq) >= window else 0
        if n_windows < 1:
            raise ValueError(
                f"sequence ({len(seq)} symbols) shorter than one "
                f"{window}-symbol window"
            )
        bounds = tuple(range(0, n_windows, shard_windows)) + (n_windows,)
        return cls(kind="windows", bounds=bounds, seq=seq,
                   window=window, stride=stride)

    # -- shape ---------------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return len(self.bounds) - 1

    @property
    def n_items(self) -> int:
        """Total scan items (documents or windows) across all shards."""
        return self.bounds[-1]

    def shard_range(self, shard: int) -> tuple:
        """Half-open item range ``[start, stop)`` of one shard."""
        if not 0 <= shard < self.n_shards:
            raise IndexError(f"shard {shard} of {self.n_shards}")
        return self.bounds[shard], self.bounds[shard + 1]

    def digest(self) -> str:
        """Content hash of the corpus + sharding — the resume-safety check
        that a job directory is only ever reused for the same work."""
        h = hashlib.sha256()
        h.update(f"corpus-v1|{self.kind}|{self.window}|{self.stride}|".encode())
        h.update(",".join(str(b) for b in self.bounds).encode())
        if self.kind == "docs":
            for d in self.docs:
                h.update(b"|")
                h.update(d.encode() if isinstance(d, str)
                         else np.asarray(d, dtype=np.int32).tobytes())
        else:
            h.update(b"|")
            h.update(self.seq.encode())
        return h.hexdigest()


def default_stream_threshold(scanner: Scanner) -> int:
    """Documents at/above this length scan via the streaming path: four
    full ``(n_chunks, block_len)`` blocks — short enough to exercise the
    bounded-memory path on real corpora, long enough that block dispatch
    amortizes."""
    pol = scanner.plan.chunking
    return 4 * pol.n_chunks * pol.block_len


def scan_shard(scanner: Scanner, manifest: CorpusManifest, shard: int,
               stream_threshold: int | None = None) -> np.ndarray:
    """Scan one shard -> its ``(P, shard_items)`` hit matrix (bool)."""
    start, stop = manifest.shard_range(shard)
    if manifest.kind == "windows":
        lo = start * manifest.stride
        hi = (stop - 1) * manifest.stride + manifest.window
        return scanner.census_windows(
            manifest.seq[lo:hi], manifest.window, manifest.stride
        ).hits

    docs = list(manifest.docs[start:stop])
    thr = (default_stream_threshold(scanner)
           if stream_threshold is None else stream_threshold)
    hits = np.zeros((scanner.n_patterns, len(docs)), dtype=bool)
    short = [i for i, d in enumerate(docs) if len(d) < thr]
    if short:
        hits[:, short] = scanner.scan([docs[i] for i in short]).hits
    for i in (i for i, d in enumerate(docs) if len(d) >= thr):
        hits[:, i] = scanner.stream([docs[i]]).accepted
    return hits
