"""The scan service: a serving layer on top of the ``Scanner`` engine.

The paper's speedups come from never recomputing what can be cached —
fingerprints stand in for state sets so construction work is done once and
reused. :mod:`repro.engine` realizes that within a process (the
content-addressed :class:`~repro.construction.SFACache`); this package
extends it across processes, requests, and corpora:

* :mod:`.store` — :class:`ArtifactStore`, the persistent disk tier under
  the SFA cache: atomic versioned npz+sidecar artifacts keyed by the
  canonical DFA hash + base polynomial, blowup markers, LRU by bytes, and
  warm-start preloading. A fresh process compiling previously-seen patterns
  performs zero construction rounds.
* :mod:`.scheduler` — :class:`BatchScheduler`, the coalescing micro-batch
  scheduler: concurrent ``submit(patterns, docs)`` requests become one
  union-bank compile (one :func:`~repro.construction.construct_bank` call
  for all cache misses) plus one fused, size-bucketed bank scan, demuxed
  per request bit-identically to per-request ``Scanner.scan``.
* :mod:`.corpus` / :mod:`.jobs` — :class:`CorpusManifest` +
  :class:`CorpusJob`, resumable corpus scans: sharded manifests (document
  corpora or sliding-window sequences), per-shard execution through the
  streaming and prefix-scan-census paths, atomically checkpointed shard
  results, and byte-identical aggregates across kill/resume.
* :mod:`.service` — :class:`ScanService`, the facade tying the three
  together (also reachable as ``Scanner.service(...)``).
* :mod:`.telemetry` — :class:`TelemetryServer`, the stdlib HTTP front
  serving ``/metrics`` (Prometheus text of the live registry),
  ``/healthz`` (scheduler + cache + store state), and ``/traces``
  (recent per-trace span summaries); owned via
  ``ScanService.serve_telemetry(port=...)``.
"""

from .corpus import CorpusManifest, default_stream_threshold, scan_shard
from .jobs import JOB_VERSION, CorpusJob, JobReport
from .scheduler import (
    DRIVERS,
    BatchScheduler,
    RequestResult,
    SchedulerStats,
    Ticket,
)
from .service import ScanService
from .store import STORE_VERSION, ArtifactStore
from .telemetry import TelemetryServer

__all__ = [
    "ArtifactStore",
    "BatchScheduler",
    "CorpusJob",
    "CorpusManifest",
    "DRIVERS",
    "JOB_VERSION",
    "JobReport",
    "RequestResult",
    "STORE_VERSION",
    "ScanService",
    "SchedulerStats",
    "TelemetryServer",
    "Ticket",
    "default_stream_threshold",
    "scan_shard",
]
