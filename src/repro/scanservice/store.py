"""Persistent SFA artifact store: the disk tier under :class:`SFACache`.

Construction results are pure functions of (DFA, base polynomial) — exactly
what :func:`repro.construction.dfa_cache_key` hashes — so they can outlive
the process that built them. The store keeps one artifact per key:

* a **positive** artifact is an ``.npz`` payload (the SFA's mapping stack,
  delta table, fingerprints, and the source DFA's table/accepting — enough
  to rebuild the full :class:`~repro.construction.SFA`) committed by a JSON
  sidecar;
* a **blowup marker** is a sidecar alone recording the state budget that
  failed (the same never-downgrade semantics as the in-memory tier).

Writes are atomic (write to a same-directory temp file, then
``os.replace``), and the sidecar is written *last* — its presence is the
commit point, so a crashed writer can never publish a partial payload.
Readers treat anything unreadable (truncated npz, garbage JSON, unknown
format version) as a miss, never an error: a corrupted artifact costs one
reconstruction, not an outage.

Eviction is LRU over a byte budget: every hit touches the sidecar's mtime
(through a strictly-increasing per-store clock, so ordering survives coarse
filesystem timestamps), and :meth:`ArtifactStore.put_sfa` evicts
oldest-touched artifacts until the store fits ``max_bytes`` again.

The store implements the backing protocol :class:`SFACache` speaks
(``get`` / ``put_sfa`` / ``put_blowup`` / ``entries``): attach one via
``SFACache(backing=ArtifactStore(dir))`` — or just
``ConstructionPolicy(store=dir)`` — and a fresh process compiling
previously-seen patterns performs zero construction rounds.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

from .. import obs
from ..construction.types import SFA, SFAStats
from ..core.dfa import DFA

# /metrics HELP descriptions, registered once; hot paths increment by name.
obs.counter("store.artifact.hits",
            help="artifact-store gets that found a valid artifact")
obs.counter("store.artifact.misses",
            help="artifact-store gets that missed (or hit a broken file)")
obs.counter("store.artifact.puts", help="artifacts written to the store")
obs.counter("store.artifact.evictions",
            help="artifacts evicted by the byte-budget LRU")

#: On-disk format version. Bump on any layout change; readers ignore
#: artifacts from other versions (a stale store degrades to a cold one).
STORE_VERSION = 1


class ArtifactStore:
    """Content-addressed on-disk SFA artifacts under one root directory."""

    def __init__(self, root, *, max_bytes: int = 1 << 30):
        if max_bytes < 1:
            raise ValueError("max_bytes must be >= 1")
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.max_bytes = max_bytes
        # Monotonic LRU clock: strictly increasing mtimes even on
        # filesystems with 1s timestamp resolution.
        self._clock = time.time()

    # -- paths --------------------------------------------------------------

    def _dir(self, key: str) -> Path:
        return self.root / key[:2]

    def _payload_path(self, key: str) -> Path:
        return self._dir(key) / f"{key}.npz"

    def _sidecar_path(self, key: str) -> Path:
        return self._dir(key) / f"{key}.json"

    def _touch(self, path: Path) -> None:
        self._clock = max(self._clock + 1e-3, time.time())
        try:
            os.utime(path, (self._clock, self._clock))
        except OSError:
            pass

    # -- the backing protocol ------------------------------------------------

    def get(self, key: str):
        """-> ``("sfa", SFA)`` | ``("blowup", budget)`` | ``None``.

        Any unreadable artifact — missing payload, truncated npz, invalid
        JSON, foreign format version — is a miss, never an exception.
        """
        with obs.span("store.artifact.get", key=key[:12]):
            side = self._sidecar_path(key)
            try:
                meta = json.loads(side.read_text())
            except (OSError, ValueError):
                obs.counter("store.artifact.misses").inc()
                return None
            if not isinstance(meta, dict) \
                    or meta.get("version") != STORE_VERSION:
                obs.counter("store.artifact.misses").inc()
                return None
            kind = meta.get("kind")
            if kind == "blowup":
                budget = meta.get("budget")
                if not isinstance(budget, int):
                    obs.counter("store.artifact.misses").inc()
                    return None
                self._touch(side)
                obs.counter("store.artifact.hits").inc()
                return "blowup", budget
            if kind != "sfa":
                obs.counter("store.artifact.misses").inc()
                return None
            try:
                with np.load(self._payload_path(key)) as z:
                    sfa = SFA(
                        mappings=np.asarray(z["mappings"], dtype=np.int32),
                        delta=np.asarray(z["delta"], dtype=np.int32),
                        fingerprints=np.asarray(
                            z["fingerprints"], dtype=np.uint32
                        ),
                        dfa=DFA(
                            table=np.asarray(z["dfa_table"], dtype=np.int32),
                            start=int(meta["start"]),
                            accepting=np.asarray(
                                z["dfa_accepting"], dtype=bool
                            ),
                            alphabet=str(meta["alphabet"]),
                        ),
                        stats=SFAStats(engine=str(meta.get("engine", "store"))),
                    )
            except Exception:
                # partial/corrupt payload: reconstruct instead
                obs.counter("store.artifact.misses").inc()
                return None
            self._touch(side)
            obs.counter("store.artifact.hits").inc()
            return "sfa", sfa

    def put_sfa(self, key: str, sfa: SFA) -> None:
        """Persist a positive artifact (idempotent; last write wins)."""
        with obs.span("store.artifact.put", key=key[:12],
                      nbytes=sfa.nbytes()):
            d = self._dir(key)
            d.mkdir(parents=True, exist_ok=True)
            payload = self._payload_path(key)
            self._atomic_write(
                payload,
                lambda f: np.savez(
                    f,
                    mappings=sfa.mappings.astype(np.int32, copy=False),
                    delta=sfa.delta.astype(np.int32, copy=False),
                    fingerprints=sfa.fingerprints.astype(
                        np.uint32, copy=False
                    ),
                    dfa_table=sfa.dfa.table.astype(np.int32, copy=False),
                    dfa_accepting=sfa.dfa.accepting.astype(bool, copy=False),
                ),
            )
            meta = {
                "version": STORE_VERSION,
                "kind": "sfa",
                "n_states": sfa.n_states,
                "start": int(sfa.dfa.start),
                "alphabet": sfa.dfa.alphabet,
                "engine": sfa.stats.engine,
                "nbytes": sfa.nbytes(),
            }
            self._write_sidecar(key, meta)  # commit point
            evicted = self._evict()
        obs.counter("store.artifact.puts").inc()
        if evicted:
            obs.counter("store.artifact.evictions").inc(evicted)

    def put_blowup(self, key: str, budget: int) -> None:
        """Persist/upgrade a blowup marker (never downgrades; a positive
        artifact always wins over a marker)."""
        existing = None
        try:
            existing = json.loads(self._sidecar_path(key).read_text())
        except (OSError, ValueError):
            pass
        if isinstance(existing, dict) and existing.get("version") == STORE_VERSION:
            if existing.get("kind") == "sfa":
                return
            old = existing.get("budget")
            if isinstance(old, int) and old >= budget:
                return
        self._dir(key).mkdir(parents=True, exist_ok=True)
        self._write_sidecar(
            key, {"version": STORE_VERSION, "kind": "blowup", "budget": int(budget)}
        )

    # -- hot-state profiles ---------------------------------------------------
    #
    # Speculative scanning's per-pattern boundary-state profiles persist
    # next to the SFA artifacts under the same ``dfa_cache_key`` — a corpus
    # profiled once seeds speculation for every later process. Profiles are
    # tiny JSON documents in their own ``profiles/`` subtree (one directory
    # level deeper than artifacts, so the artifact walks — ``entries``,
    # ``keys``, ``total_bytes``, eviction — never see them), written with
    # the same atomic replace and the same read-anything-broken-as-a-miss
    # contract. They are advisory data: a lost or stale profile costs
    # repair rounds on the next scan, never correctness.

    def _profile_path(self, key: str) -> Path:
        return self.root / "profiles" / key[:2] / f"{key}.json"

    def get_profile(self, key: str):
        """-> the persisted profile dict for ``key``, or None. Unreadable
        or foreign-version profiles are a miss, never an error."""
        try:
            meta = json.loads(self._profile_path(key).read_text())
        except (OSError, ValueError):
            return None
        if not isinstance(meta, dict) or meta.get("version") != STORE_VERSION \
                or meta.get("kind") != "profile":
            return None
        return meta

    def put_profile(self, key: str, profile: dict) -> None:
        """Persist one hot-state profile (idempotent; last write wins)."""
        path = self._profile_path(key)
        path.parent.mkdir(parents=True, exist_ok=True)
        meta = {"version": STORE_VERSION, "kind": "profile", **profile}
        self._atomic_write(path, lambda f: f.write(json.dumps(meta).encode()))

    def profile_keys(self) -> list:
        return sorted(p.stem for p in self.root.glob("profiles/*/*.json"))

    def entries(self):
        """Yield ``(key, kind, payload)`` for every readable artifact in
        LRU order (least-recently-touched first) — the warm-start preload
        walk, ordered so promotion preserves recency in the memory tier.
        Unreadable artifacts are skipped."""
        def mtime(p: Path) -> float:
            try:
                return p.stat().st_mtime
            except OSError:
                return 0.0

        for side in sorted(self.root.glob("*/*.json"), key=mtime):
            key = side.stem
            got = self.get(key)
            if got is not None:
                yield (key, *got)

    # -- maintenance ---------------------------------------------------------

    def keys(self) -> list:
        return sorted(p.stem for p in self.root.glob("*/*.json"))

    def __len__(self) -> int:
        return len(self.keys())

    def __contains__(self, key: str) -> bool:
        return self._sidecar_path(key).exists()

    def __eq__(self, other) -> bool:
        return isinstance(other, ArtifactStore) and \
            self.root.resolve() == other.root.resolve()

    def total_bytes(self) -> int:
        """Payload + sidecar bytes currently on disk."""
        return sum(
            p.stat().st_size
            for pat in ("*/*.json", "*/*.npz")
            for p in self.root.glob(pat)
            if p.exists()
        )

    def remove(self, key: str) -> None:
        for p in (self._sidecar_path(key), self._payload_path(key)):
            try:
                p.unlink()
            except OSError:
                pass

    def _evict(self) -> int:
        """Drop oldest-touched artifacts until the store fits ``max_bytes``.
        Blowup markers are near-free and never evicted. -> artifacts removed."""
        total = self.total_bytes()
        if total <= self.max_bytes:
            return 0
        victims = sorted(
            (p for p in self.root.glob("*/*.npz")),
            key=lambda p: self._sidecar_path(p.stem).stat().st_mtime
            if self._sidecar_path(p.stem).exists() else 0.0,
        )
        removed = 0
        for payload in victims:
            if total <= self.max_bytes:
                break
            key = payload.stem
            total -= payload.stat().st_size
            side = self._sidecar_path(key)
            if side.exists():
                total -= side.stat().st_size
            self.remove(key)
            removed += 1
        return removed

    # -- write helpers -------------------------------------------------------

    def _atomic_write(self, path: Path, write_fn) -> None:
        tmp = path.with_suffix(path.suffix + f".tmp.{os.getpid()}")
        try:
            with open(tmp, "wb") as f:
                write_fn(f)
            os.replace(tmp, path)
        finally:
            if tmp.exists():
                tmp.unlink()

    def _write_sidecar(self, key: str, meta: dict) -> None:
        side = self._sidecar_path(key)
        self._atomic_write(side, lambda f: f.write(json.dumps(meta).encode()))
        self._touch(side)
