"""Resumable corpus jobs: checkpointed shard-by-shard scans.

A :class:`CorpusJob` executes one :class:`~repro.scanservice.CorpusManifest`
against one compiled pattern set, writing each shard's hit matrix to its own
atomically-renamed ``.npz`` the moment it finishes. Killing the process
between shards (or mid-write — the rename is the commit point) loses at most
the shard in flight: a new ``CorpusJob`` pointed at the same work directory
verifies it is resuming the *same* work (content digest over corpus +
patterns recorded in ``job.json``), skips every finished shard, and scans
only the remainder. Because every shard scans independently through the same
exact automaton semantics, the aggregated hit matrix and census are
byte-identical whether the job ran straight through or was killed and
resumed — and even if the resuming process picked a different backend, since
all backends are bit-identical by the engine's core property.

The job digest deliberately excludes the execution plan: plans change *how*
(backend, distribution, chunking), never *what*, so a resume may e.g. move
from ``distribution="local"`` to ``"shard_map"`` without invalidating
finished shards.

Every shard checkpoint also carries its *telemetry*: a
:class:`~repro.obs.FlightRecorder` in the work directory appends one
registry delta record per scanned shard (plus the shard's spans), so a
worker killed mid-job leaves a merge-ready trail behind. Because the
deterministic per-shard metrics (``jobs.shards_scanned``,
``jobs.items_scanned``, the ``jobs.shard_items`` histogram) move by
exactly the shard's item count, merging the per-shard deltas
(:meth:`CorpusJob.flight_totals`, via :func:`repro.obs.merge_records`)
reproduces the uninterrupted job's ``jobs.*`` totals bit-exactly however
the job was killed and resumed — the multi-host aggregation story,
executed locally first.

Layout::

    <workdir>/job.json               # version, digest, ids, n_shards
    <workdir>/shards/shard_00007.npz # hits: (P, shard_items) bool
    <workdir>/flight/flight.jsonl    # per-shard metric deltas + spans
"""

from __future__ import annotations

import hashlib
import json
import os
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from .. import obs
from ..construction import dfa_cache_key
from ..engine import ScanPlan, Scanner, ScanResult
from ..obs.aggregate import merge_records
from ..obs.flight import FlightRecorder, read_flight
from .corpus import CorpusManifest, scan_shard

JOB_VERSION = 1

#: ``jobs.shard_items`` bucket edges: shard sizes are item counts, not
#: seconds, so the default (time) edges don't apply. Powers of two up to
#: the largest shards a manifest realistically cuts.
SHARD_ITEM_EDGES = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512,
                    1024, 4096, 16384, 65536)


@dataclass(frozen=True)
class JobReport:
    """Outcome of one :meth:`CorpusJob.run` call."""

    n_shards: int
    done_before: int       # shards already checkpointed when run() started
    scanned: int           # shards scanned (and checkpointed) by this call
    complete: bool

    @property
    def done(self) -> int:
        return self.done_before + self.scanned


class CorpusJob:
    """One resumable scan of a sharded corpus. See module docstring."""

    def __init__(self, patterns, manifest: CorpusManifest, workdir,
                 plan: ScanPlan | None = None,
                 stream_threshold: int | None = None,
                 flight: bool = True,
                 flight_interval_s: float | None = None):
        self.manifest = manifest
        self.workdir = Path(workdir)
        self.stream_threshold = stream_threshold
        self._shard_dir = self.workdir / "shards"
        self._shard_dir.mkdir(parents=True, exist_ok=True)
        # The job owns one trace id for its whole lifetime: the compile here
        # and every shard span in run() carry it, so a resumed job's spans
        # correlate with the original compile in the event log.
        with obs.span("jobs.compile") as sp:
            self.trace_id = sp.trace_id if sp is not None else None
            # Compilation runs through the plan's cache tiers, so a resuming
            # process with a persistent store pays zero construction rounds.
            self.scanner = Scanner.compile(patterns, plan)
        self._check_or_write_meta()
        # Flight recorder: created *after* the compile so its delta base
        # excludes construction — shard records then carry exactly shard
        # work, the additivity the kill/resume merge acceptance relies on.
        # ``flight_interval_s`` additionally ticks a background record
        # during long shards (run() starts/stops the thread).
        self.flight = FlightRecorder(
            self.flight_path, interval_s=flight_interval_s, label="corpus_job"
        ) if flight else None

    # -- metadata ------------------------------------------------------------

    def digest(self) -> str:
        """Content hash of *what* this job computes: corpus + patterns.
        Plan knobs are excluded on purpose (see module docstring)."""
        h = hashlib.sha256()
        h.update(f"job-v{JOB_VERSION}|".encode())
        h.update(self.manifest.digest().encode())
        for d in self.scanner._dfas:
            h.update(b"|")
            h.update(dfa_cache_key(d).encode())
        return h.hexdigest()

    def _check_or_write_meta(self) -> None:
        meta_path = self.workdir / "job.json"
        digest = self.digest()
        if meta_path.exists():
            try:
                meta = json.loads(meta_path.read_text())
            except ValueError:
                meta = {}
            if meta.get("version") != JOB_VERSION or \
                    meta.get("digest") != digest:
                raise ValueError(
                    f"work directory {self.workdir} belongs to a different "
                    "job (corpus or pattern set changed); point the job at "
                    "a fresh directory or delete the old one"
                )
            return
        tmp = meta_path.with_suffix(f".tmp.{os.getpid()}")
        tmp.write_text(json.dumps({
            "version": JOB_VERSION,
            "digest": digest,
            "ids": list(self.scanner.ids),
            "kind": self.manifest.kind,
            "n_shards": self.manifest.n_shards,
            "n_items": self.manifest.n_items,
        }, indent=1))
        os.replace(tmp, meta_path)

    @property
    def flight_path(self) -> Path:
        return self.workdir / "flight" / "flight.jsonl"

    # -- shard bookkeeping ---------------------------------------------------

    def _shard_path(self, shard: int) -> Path:
        return self._shard_dir / f"shard_{shard:05d}.npz"

    def _load_shard(self, shard: int) -> np.ndarray | None:
        """A finished shard's hits, or None (missing / unreadable / wrong
        shape — unreadable checkpoints are re-scanned, never fatal)."""
        path = self._shard_path(shard)
        start, stop = self.manifest.shard_range(shard)
        try:
            with np.load(path) as z:
                hits = np.asarray(z["hits"], dtype=bool)
        except Exception:
            return None
        if hits.shape != (self.scanner.n_patterns, stop - start):
            return None
        return hits

    def _shard_ready(self, shard: int) -> bool:
        """Cheap completeness probe: the checkpoint's zip directory must be
        intact and name the hits array — no payload read (aggregate() does
        the full load + shape check once, at the end)."""
        try:
            with np.load(self._shard_path(shard)) as z:
                return "hits" in z.files
        except Exception:
            return False

    def pending(self) -> list:
        """Shard indices not yet validly checkpointed, in scan order."""
        return [s for s in range(self.manifest.n_shards)
                if not self._shard_ready(s)]

    @property
    def complete(self) -> bool:
        return not self.pending()

    # -- execution -----------------------------------------------------------

    def run(self, max_shards: int | None = None) -> JobReport:
        """Scan up to ``max_shards`` pending shards (all, by default),
        checkpointing each one atomically as it finishes. With the flight
        recorder on (default), every checkpoint also appends the shard's
        registry delta to the work directory's flight trail."""
        todo = self.pending()
        done_before = self.manifest.n_shards - len(todo)
        scanned = 0
        if self.flight is not None:
            # Flush anything that moved since the last record (other work
            # between construction and run) into a non-shard record, so
            # each shard record below is the shard's work alone.
            self.flight.record(label="jobs.pre_run", force=False)
            if self.flight.interval_s is not None:
                self.flight.start()
        try:
            for shard in todo:
                if max_shards is not None and scanned >= max_shards:
                    break
                start, stop = self.manifest.shard_range(shard)
                with obs.span("jobs.shard", trace_id=self.trace_id,
                              shard=shard):
                    hits = scan_shard(self.scanner, self.manifest, shard,
                                      stream_threshold=self.stream_threshold)
                    path = self._shard_path(shard)
                    tmp = path.with_suffix(f".tmp.{os.getpid()}")
                    with open(tmp, "wb") as f:
                        np.savez(f, hits=hits)
                    os.replace(tmp, path)   # commit point
                obs.counter("jobs.shards_scanned",
                            help="corpus shards scanned to completion").inc()
                # Deterministic per-shard quantities: these move by exactly
                # the shard's item count, so per-shard flight deltas merge
                # to the same totals however a job is killed and resumed.
                obs.counter("jobs.items_scanned",
                            help="corpus items (documents or windows) "
                                 "scanned").inc(stop - start)
                obs.histogram("jobs.shard_items", edges=SHARD_ITEM_EDGES,
                              help="items per scanned shard"
                              ).observe(stop - start)
                if self.flight is not None:
                    self.flight.record(shard=shard, items=stop - start)
                scanned += 1
        finally:
            if self.flight is not None:
                self.flight.stop()
        return JobReport(
            n_shards=self.manifest.n_shards,
            done_before=done_before,
            scanned=scanned,
            complete=done_before + scanned == self.manifest.n_shards,
        )

    # -- aggregation ---------------------------------------------------------

    def flight_records(self) -> list:
        """Every record on this job's flight trail (rotations included,
        oldest first) — shard deltas, span records, periodic ticks."""
        return read_flight(self.flight_path)

    def flight_totals(self, prefix: str | None = "jobs",
                      shards_only: bool = True) -> dict:
        """Merge the flight trail's shard deltas into one fleet record.

        The default view keeps only shard-stamped records and the
        deterministic ``jobs.*`` metrics, which is the exact-reproduction
        contract: however the job was killed and resumed (even across
        processes appending to the same trail), the merged counters and
        histograms equal the uninterrupted run's bit-for-bit. Pass
        ``prefix=None``/``shards_only=False`` for the kitchen-sink merge
        (wall-time histograms included — informative, not deterministic).
        """
        recs = [r for r in self.flight_records()
                if r.get("kind") == "flight"
                and (not shards_only or "shard" in r)]
        return merge_records(recs, prefix=prefix)

    def aggregate(self) -> ScanResult:
        """Concatenate every shard's hits -> ``(P, n_items)``
        :class:`~repro.engine.ScanResult` (``.counts`` is the census).
        Raises if any shard is still pending."""
        parts = []
        missing = []
        for shard in range(self.manifest.n_shards):
            hits = self._load_shard(shard)
            if hits is None:
                missing.append(shard)
            else:
                parts.append(hits)
        if missing:
            raise RuntimeError(
                f"job incomplete: shards {missing} pending — call run() first"
            )
        return ScanResult(hits=np.concatenate(parts, axis=1),
                          ids=self.scanner.ids)

    def census(self) -> np.ndarray:
        """Aggregated per-pattern hit counts over the whole corpus."""
        return self.aggregate().counts
