"""Pallas TPU kernel: enumeration-mode chunk matching with a VMEM-resident
transposed transition table.

Per chunk, the DFA runs from *all* n start states simultaneously (the SFA
idea applied at matching time). Each character step is two one-hot MXU
contractions instead of gathers:

    cols[b, :]  = onehot(sym[b]) @ table_T          # (k,) x (k, n) -> (n,)
    v'[b, q]    = Σ_j onehot(v)[b, q, j] · cols[b, j]

``table_T`` is the paper's transposed (symbol-major) table — here it is
pinned in VMEM for the whole chunk block, which is the TPU restatement of
the paper's L1-locality argument (§III-B3): one HBM read of the table serves
every character of every chunk in the block.

Both kernels process ``block_b`` chunks per grid cell with the time loop
inside (``fori_loop``), so the sequential dependency stays on-chip; chunk-
level parallelism comes from the grid, and the per-cell chunk block amortizes
the table fetch across ``block_b`` chunks (the same ``block_*`` tiling knob
the fingerprint/compose kernels expose).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chunk_block_body(table_t, syms, out_ref, out_prefix=()):
    """Run the all-states time loop for every chunk row of ``syms`` and write
    each result row into ``out_ref`` at ``out_prefix + (row,)``."""
    k, n = table_t.shape
    bb, L = syms.shape

    def one_chunk(b, _):
        def step(t, v):
            sym = syms[b, t]
            sym_onehot = (
                jax.lax.broadcasted_iota(jnp.int32, (1, k), 1) == sym
            ).astype(jnp.float32)                            # (1, k)
            cols = jax.lax.dot_general(                      # (1, n) = δ(., sym)
                sym_onehot, table_t, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            v_onehot = (
                v[:, None] == jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
            ).astype(jnp.float32)                            # (n, n)
            nxt = jax.lax.dot_general(                       # (n, 1)
                v_onehot, cols.T, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            return nxt[:, 0].astype(jnp.int32)

        v0 = jax.lax.iota(jnp.int32, n)
        out = jax.lax.fori_loop(0, L, step, v0)
        out_ref[out_prefix + (pl.dslice(b, 1), slice(None))] = out[None]
        return 0

    jax.lax.fori_loop(0, bb, one_chunk, 0)


def _match_kernel(table_t_ref, chunks_ref, out_ref):
    table_t = table_t_ref[...].astype(jnp.float32)       # (k, n)
    syms = chunks_ref[...]                               # (block_b, L) int32
    _chunk_block_body(table_t, syms, out_ref)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def match_chunks_pallas(
    table: jnp.ndarray,
    chunks: jnp.ndarray,
    *,
    block_b: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """table: (n, k) int32; chunks: (B, L) int32 -> (B, n) chunk mappings.

    ``block_b`` chunks share one grid cell (and one VMEM table residency);
    B pads up to a multiple of ``block_b`` and the padding is cropped.
    """
    n, k = table.shape
    B, L = chunks.shape
    block_b = min(block_b, B)
    pad = (-B) % block_b
    if pad:
        chunks = jnp.concatenate(
            [chunks, jnp.zeros((pad, L), dtype=chunks.dtype)], axis=0
        )
    table_t = table.T  # symbol-major (paper §III-B3)
    out = pl.pallas_call(
        _match_kernel,
        grid=((B + pad) // block_b,),
        in_specs=[
            pl.BlockSpec((k, n), lambda b: (0, 0)),
            pl.BlockSpec((block_b, L), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B + pad, n), jnp.int32),
        interpret=interpret,
    )(table_t, chunks)
    return out[:B]


def _match_bank_kernel(table_t_ref, chunks_ref, out_ref):
    """One (pattern, chunk-block) grid cell: the pattern's transposed table
    stays VMEM-resident across every chunk of the block."""
    table_t = table_t_ref[0].astype(jnp.float32)         # (k, n)
    syms = chunks_ref[...]                               # (block_b, L) int32
    _chunk_block_body(table_t, syms, out_ref, out_prefix=(0,))


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def match_bank_chunks_pallas(
    tables: jnp.ndarray,
    chunks: jnp.ndarray,
    *,
    block_b: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """Multi-automaton chunk matching: every (pattern, chunk) cell at once.

    ``tables``: (P, n, k) int32 padded bank stack; ``chunks``: (B, L) int32
    -> (P, B, n) chunk mappings. The grid is ``(pattern, chunk-block)`` with
    the chunk axis innermost, so the VMEM-resident transposed table block is
    swapped once per *pattern* and stays hot across all B chunks of that
    pattern — the §III-B3 table-locality argument applied to the bank axis.
    """
    Pn, n, k = tables.shape
    B, L = chunks.shape
    block_b = min(block_b, B)
    pad = (-B) % block_b
    if pad:
        chunks = jnp.concatenate(
            [chunks, jnp.zeros((pad, L), dtype=chunks.dtype)], axis=0
        )
    tables_t = jnp.swapaxes(tables, 1, 2)  # (P, k, n) symbol-major per pattern
    out = pl.pallas_call(
        _match_bank_kernel,
        grid=(Pn, (B + pad) // block_b),
        in_specs=[
            pl.BlockSpec((1, k, n), lambda p, b: (p, 0, 0)),
            pl.BlockSpec((block_b, L), lambda p, b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b, n), lambda p, b: (p, b, 0)),
        out_shape=jax.ShapeDtypeStruct((Pn, B + pad, n), jnp.int32),
        interpret=interpret,
    )(tables_t, chunks)
    return out[:, :B]
