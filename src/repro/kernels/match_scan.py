"""Pallas TPU kernel: enumeration-mode chunk matching with a VMEM-resident
transposed transition table.

Per chunk, the DFA runs from *all* n start states simultaneously (the SFA
idea applied at matching time). Each character step is two one-hot MXU
contractions instead of gathers:

    cols[b, :]  = onehot(sym[b]) @ table_T          # (k,) x (k, n) -> (n,)
    v'[b, q]    = Σ_j onehot(v)[b, q, j] · cols[b, j]

``table_T`` is the paper's transposed (symbol-major) table — here it is
pinned in VMEM for the whole chunk, which is the TPU restatement of the
paper's L1-locality argument (§III-B3): one HBM read of the table serves
every character of every chunk in the block.

The kernel processes one chunk per grid cell with the time loop inside
(``fori_loop``), so the sequential dependency stays on-chip; chunk-level
parallelism comes from the grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _match_kernel(table_t_ref, chunks_ref, out_ref):
    table_t = table_t_ref[...].astype(jnp.float32)       # (k, n)
    syms = chunks_ref[...]                               # (1, L) int32
    k, n = table_t.shape
    L = syms.shape[-1]

    def step(t, v):
        sym = syms[0, t]
        sym_onehot = (
            jax.lax.broadcasted_iota(jnp.int32, (1, k), 1) == sym
        ).astype(jnp.float32)                            # (1, k)
        cols = jax.lax.dot_general(                      # (1, n) = δ(., sym)
            sym_onehot, table_t, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        v_onehot = (
            v[:, None] == jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
        ).astype(jnp.float32)                            # (n, n)
        nxt = jax.lax.dot_general(                       # (n, 1)
            v_onehot, cols.T, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return nxt[:, 0].astype(jnp.int32)

    v0 = jax.lax.iota(jnp.int32, n)
    out_ref[...] = jax.lax.fori_loop(0, L, step, v0)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def match_chunks_pallas(
    table: jnp.ndarray,
    chunks: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """table: (n, k) int32; chunks: (B, L) int32 -> (B, n) chunk mappings."""
    n, k = table.shape
    B, L = chunks.shape
    table_t = table.T  # symbol-major (paper §III-B3)
    out = pl.pallas_call(
        _match_kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((k, n), lambda b: (0, 0)),
            pl.BlockSpec((1, L), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n), jnp.int32),
        interpret=interpret,
    )(table_t, chunks)
    return out


def _match_bank_kernel(table_t_ref, chunks_ref, out_ref):
    """One (pattern, chunk) grid cell: same time loop as ``_match_kernel``
    with the pattern's transposed table as the VMEM-resident block."""
    table_t = table_t_ref[0].astype(jnp.float32)         # (k, n)
    syms = chunks_ref[...]                               # (1, L) int32
    k, n = table_t.shape
    L = syms.shape[-1]

    def step(t, v):
        sym = syms[0, t]
        sym_onehot = (
            jax.lax.broadcasted_iota(jnp.int32, (1, k), 1) == sym
        ).astype(jnp.float32)                            # (1, k)
        cols = jax.lax.dot_general(                      # (1, n) = δ_p(., sym)
            sym_onehot, table_t, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        v_onehot = (
            v[:, None] == jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
        ).astype(jnp.float32)                            # (n, n)
        nxt = jax.lax.dot_general(                       # (n, 1)
            v_onehot, cols.T, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return nxt[:, 0].astype(jnp.int32)

    v0 = jax.lax.iota(jnp.int32, n)
    out_ref[...] = jax.lax.fori_loop(0, L, step, v0)[None, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def match_bank_chunks_pallas(
    tables: jnp.ndarray,
    chunks: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Multi-automaton chunk matching: every (pattern, chunk) cell at once.

    ``tables``: (P, n, k) int32 padded bank stack; ``chunks``: (B, L) int32
    -> (P, B, n) chunk mappings. The grid is ``(pattern, chunk)`` with the
    chunk axis innermost, so the VMEM-resident transposed table block is
    swapped once per *pattern* and stays hot across all B chunks of that
    pattern — the §III-B3 table-locality argument applied to the bank axis.
    """
    Pn, n, k = tables.shape
    B, L = chunks.shape
    tables_t = jnp.swapaxes(tables, 1, 2)  # (P, k, n) symbol-major per pattern
    out = pl.pallas_call(
        _match_bank_kernel,
        grid=(Pn, B),
        in_specs=[
            pl.BlockSpec((1, k, n), lambda p, b: (p, 0, 0)),
            pl.BlockSpec((1, L), lambda p, b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, n), lambda p, b: (p, b, 0)),
        out_shape=jax.ShapeDtypeStruct((Pn, B, n), jnp.int32),
        interpret=interpret,
    )(tables_t, chunks)
    return out
