"""Pallas TPU kernel: batched Rabin fingerprinting (clmul fold + Barrett).

The paper's hot loop is fingerprinting every candidate SFA state (frontier ×
alphabet of them per round). On x86 it leans on ``PCLMULQDQ``; the TPU has no
carry-less multiply, so the kernel bit-slices: a 32×32 clmul is 32 unrolled
mask/shift/XOR steps on the VPU, executed for a whole block of state vectors
at once — per-fingerprint cost is amortized across VPU lanes instead of
per-instruction silicon.

Layout (the paper's §III-B3 locality argument, restated for VMEM):
  - the packed word block ``(block_b, W)`` streams HBM→VMEM once per block;
  - the fold constants ``x^(32 i) mod P`` (W × 2 u32) and the Barrett
    constants are tiny and stay VMEM-resident across the whole grid;
  - each block writes a ``(block_b, 2)`` fingerprint tile.

Block size is chosen so ``block_b × W × 4`` bytes plus the 3 accumulator
copies fit comfortably in VMEM (≤ ~2 MB by default).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fingerprint import BarrettConstants


def _clmul32_block(a: jnp.ndarray, b: jnp.ndarray) -> tuple:
    """(…,) u32 × (…,) u32 -> 64-bit (hi, lo) pair; fully unrolled 32 steps."""
    hi = jnp.zeros_like(a)
    lo = jnp.zeros_like(a)
    one = jnp.uint32(1)
    for i in range(32):
        bit = (b >> jnp.uint32(i)) & one
        mask = jnp.uint32(0) - bit
        lo = lo ^ ((a << jnp.uint32(i)) & mask)
        hi = hi ^ (((a >> jnp.uint32(31 - i)) >> one) & mask)
    return hi, lo


def _fold_block(words, weights, c):
    """Fold + Barrett-reduce one block: (Bb, W) words with (W, 2) fold
    constants and (4,) Barrett limbs -> ((Bb,) hi, (Bb,) lo)."""
    w_hi = weights[..., 0][None]      # (1, W)
    w_lo = weights[..., 1][None]

    # Fold: 96-bit partial products, XOR-reduced over the word axis.
    p_lo_h, p_lo_l = _clmul32_block(words, jnp.broadcast_to(w_lo, words.shape))
    p_hi_h, p_hi_l = _clmul32_block(words, jnp.broadcast_to(w_hi, words.shape))

    def xred(x):
        return jax.lax.reduce(x, jnp.zeros((), x.dtype), jax.lax.bitwise_xor, (1,))

    l0 = xred(p_lo_l)                 # (Bb,)
    l1 = xred(p_lo_h ^ p_hi_l)
    l2 = xred(p_hi_h)

    # Barrett reduction with constants [p_hi, p_lo, mu_hi, mu_lo].
    p = (jnp.broadcast_to(c[0], l2.shape), jnp.broadcast_to(c[1], l2.shape))
    mu = (jnp.broadcast_to(c[2], l2.shape), jnp.broadcast_to(c[3], l2.shape))

    zeros = jnp.zeros_like(l2)
    t1pre = (zeros, l2)
    m3, m2 = _clmul64_hi(t1pre, mu)
    t2pre = (t1pre[0] ^ m3, t1pre[1] ^ m2)
    q1, q0 = _clmul64_lo(t2pre, p)
    return l1 ^ q1, l0 ^ q0


def _fingerprint_kernel(words_ref, weights_ref, consts_ref, out_ref):
    hi, lo = _fold_block(words_ref[...], weights_ref[...], consts_ref[...])
    out_ref[..., 0] = hi
    out_ref[..., 1] = lo


def _clmul64_hi(a: tuple, b: tuple) -> tuple:
    """High 64 bits (limbs 3, 2) of a 64×64 carry-less product."""
    ah, al = a
    bh, bl = b
    ll_h, _ = _clmul32_block(al, bl)
    lh_h, lh_l = _clmul32_block(al, bh)
    hl_h, hl_l = _clmul32_block(ah, bl)
    hh_h, hh_l = _clmul32_block(ah, bh)
    l2 = lh_h ^ hl_h ^ hh_l
    l3 = hh_h
    return l3, l2


def _clmul64_lo(a: tuple, b: tuple) -> tuple:
    """Low 64 bits (limbs 1, 0) of a 64×64 carry-less product."""
    ah, al = a
    bh, bl = b
    ll_h, ll_l = _clmul32_block(al, bl)
    _, lh_l = _clmul32_block(al, bh)
    _, hl_l = _clmul32_block(ah, bl)
    l0 = ll_l
    l1 = ll_h ^ lh_l ^ hl_l
    return l1, l0


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fingerprint_pallas(
    words: jnp.ndarray,
    weights: jnp.ndarray,
    consts_limbs: jnp.ndarray,
    *,
    block_b: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched fingerprints. words: (B, W) u32; weights: (W, 2) u32;
    consts_limbs: (4,) u32 [p_hi, p_lo, mu_hi, mu_lo] -> (B, 2) u32."""
    B, W = words.shape
    block_b = min(block_b, B)
    if B % block_b:
        pad = block_b - B % block_b
        words = jnp.pad(words, ((0, pad), (0, 0)))
    grid = (words.shape[0] // block_b,)
    out = pl.pallas_call(
        _fingerprint_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, W), lambda i: (i, 0)),
            pl.BlockSpec((W, 2), lambda i: (0, 0)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((words.shape[0], 2), jnp.uint32),
        interpret=interpret,
    )(words, weights, consts_limbs)
    return out[:B]


def consts_limbs_of(consts: BarrettConstants) -> jnp.ndarray:
    return jnp.asarray(
        [
            (consts.poly_low >> 32) & 0xFFFFFFFF,
            consts.poly_low & 0xFFFFFFFF,
            (consts.mu_low >> 32) & 0xFFFFFFFF,
            consts.mu_low & 0xFFFFFFFF,
        ],
        dtype=jnp.uint32,
    )


# --------------------------------------------------------------------------
# Bank variant: the fold batched over the pattern axis
# --------------------------------------------------------------------------
#
# Batched construction (repro.construction.batched) fingerprints every
# pattern's candidate tile each round, and each pattern carries its *own*
# fold/Barrett constants (per-pattern polynomial retry re-randomizes one
# pattern's P(t) without touching the others). The bank kernel adds the
# pattern axis to the grid: cell (p, i) folds block i of pattern p with
# pattern p's constants, which stay VMEM-resident across that pattern's
# whole block row — the same residency argument as the multi-automaton
# match kernel.


def _fingerprint_bank_kernel(words_ref, weights_ref, consts_ref, out_ref):
    hi, lo = _fold_block(words_ref[0], weights_ref[0], consts_ref[0])
    out_ref[0, :, 0] = hi
    out_ref[0, :, 1] = lo


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fingerprint_bank_pallas(
    words: jnp.ndarray,
    weights: jnp.ndarray,
    consts_limbs: jnp.ndarray,
    *,
    block_b: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Per-pattern batched fingerprints.

    words: (P, B, W) u32; weights: (P, W, 2) u32 per-pattern fold constants;
    consts_limbs: (P, 4) u32 per-pattern Barrett constants -> (P, B, 2) u32.
    Grid (pattern, block): pattern p's constants load once per row of blocks.
    """
    P, B, W = words.shape
    block_b = min(block_b, B)
    if B % block_b:
        pad = block_b - B % block_b
        words = jnp.pad(words, ((0, 0), (0, pad), (0, 0)))
    grid = (P, words.shape[1] // block_b)
    out = pl.pallas_call(
        _fingerprint_bank_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_b, W), lambda p, i: (p, i, 0)),
            pl.BlockSpec((1, W, 2), lambda p, i: (p, 0, 0)),
            pl.BlockSpec((1, 4), lambda p, i: (p, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b, 2), lambda p, i: (p, i, 0)),
        out_shape=jax.ShapeDtypeStruct((P, words.shape[1], 2), jnp.uint32),
        interpret=interpret,
    )(words, weights, consts_limbs)
    return out[:, :B]
