"""Pallas TPU kernel: function-composition combine as a one-hot MXU matmul.

The SFA monoid combine ``out[b, q] = g[b, f[b, q]]`` is a gather — latency
bound and VPU-serial on TPU. For the state-vector sizes the paper works with
(n ≤ a few thousand), re-expressing the gather as

    out[b, q] = Σ_j onehot(f)[b, q, j] · g[b, j]

turns it into an MXU contraction: n² MACs replace n dependent loads, and the
MXU's 128×128 systolic throughput makes that trade profitable for n ≥ ~128.
State ids are < 2^24, so f32 accumulation is exact and the kernel is
bit-exact against the gather oracle.

Grid: (batch, q-tiles). Per cell the kernel holds a ``(block_q, n)`` one-hot
tile and the full ``g`` row in VMEM — ≤ ~3 MB at n = 2930, block_q = 256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compose_kernel(f_ref, g_ref, out_ref):
    f = f_ref[...]                      # (1, block_q) int32
    g = g_ref[...]                      # (1, n) int32
    n = g.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (f.shape[-1], n), 1)
    onehot = (f[0][:, None] == iota).astype(jnp.float32)   # (block_q, n)
    vals = jax.lax.dot_general(
        onehot,
        g[0].astype(jnp.float32)[:, None],                 # (n, 1)
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                      # (block_q, 1)
    out_ref[...] = vals[:, 0].astype(jnp.int32)[None]


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def compose_pallas(
    f: jnp.ndarray,
    g: jnp.ndarray,
    *,
    block_q: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Composition combine. f, g: (B, n) int32 -> (B, n) int32 (f then g)."""
    B, n = f.shape
    block_q = min(block_q, n)
    padded_n = -(-n // block_q) * block_q
    if padded_n != n:
        f = jnp.pad(f, ((0, 0), (0, padded_n - n)))
    grid = (B, padded_n // block_q)
    out = pl.pallas_call(
        _compose_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, q: (b, q)),
            pl.BlockSpec((1, n), lambda b, q: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q), lambda b, q: (b, q)),
        out_shape=jax.ShapeDtypeStruct((B, padded_n), jnp.int32),
        interpret=interpret,
    )(f, g)
    return out[:, :n]
