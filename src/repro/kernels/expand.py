"""Pallas TPU kernel: frontier × alphabet expansion as a one-hot MXU matmul.

The batched construction round's expansion stage is the gather

    cand[t·k + a, q] = table[ft[t, q], a]

— every frontier state vector ``ft[t]`` advanced by every symbol ``a`` at
once. XLA lowers ``table[ft]`` to a dynamic-gather; on TPU that is latency
bound and VPU-serial, exactly like the composition combine in
``kernels/compose.py``. The same one-hot re-expression applies: per frontier
row ``t``,

    onehot(ft[t]) (n, n) @ table (n, k)  ->  (n, k)

turns the ``n·k`` dependent loads into one MXU contraction whose systolic
throughput wins for ``n ≥ ~128``. State ids are < 2^16 (the batched engine's
packing bound), far under f32's 2^24 exact-integer range, so the matmul is
bit-exact against the gather oracle — the property the construction tests
pin (``expand_backend="pallas"`` must be bit-identical to the XLA gather).

Grid: (pattern, frontier-tile blocks). Per cell the kernel holds a
``(block_t, n, n)`` one-hot stack and the pattern's full ``(n, k)`` table in
VMEM; ``block_t`` auto-shrinks with ``n`` to bound the one-hot residency.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: One-hot residency bound: block_t · n · n f32 elements per grid cell
#: (~2 MB at the cap). ``_auto_block_t`` shrinks block_t to honor it.
_ONEHOT_BUDGET = 1 << 19


def _auto_block_t(tile: int, n: int) -> int:
    """Largest divisor of ``tile`` whose one-hot stack fits the budget."""
    bt = max(1, min(tile, _ONEHOT_BUDGET // max(1, n * n)))
    while tile % bt:
        bt -= 1
    return bt


def _expand_kernel(ft_ref, table_ref, out_ref):
    ft = ft_ref[0]                                   # (bt, n) int32
    table = table_ref[0].astype(jnp.float32)         # (n, k)
    bt, n = ft.shape
    k = table.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (bt, n, n), 2)
    onehot = (ft[:, :, None] == iota).astype(jnp.float32)    # (bt, n, n)
    vals = jax.lax.dot_general(
        onehot, table, (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # (bt, n, k)
    # Row-major (frontier, symbol) candidate order — the layout the
    # sort-merge's delta scatter-back assumes.
    out_ref[0] = jnp.swapaxes(vals, 1, 2).reshape(bt * k, n).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def expand_bank_pallas(
    tables: jnp.ndarray,
    ft: jnp.ndarray,
    *,
    block_t: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Frontier expansion over a bank: (B, n, k) tables, (B, T, n) frontier
    tiles -> (B, T·k, n) candidates in row-major (frontier, symbol) order.
    ``block_t = 0`` picks the largest tile divisor fitting the VMEM budget.
    """
    B, T, n = ft.shape
    k = tables.shape[-1]
    if block_t <= 0:
        block_t = _auto_block_t(T, n)
    if T % block_t:
        raise ValueError(f"block_t ({block_t}) must divide the tile ({T})")
    grid = (B, T // block_t)
    return pl.pallas_call(
        _expand_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, n), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, n, k), lambda b, t: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_t * k, n), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T * k, n), jnp.int32),
        interpret=interpret,
    )(ft, tables)
