"""Jitted public wrappers around the Pallas kernels.

On this CPU container the kernels always run with ``interpret=True`` (the
kernel body executes step-by-step on CPU, validating semantics); on a real
TPU runtime ``interpret=False`` compiles them to Mosaic. The flag defaults
from the active backend so call-sites never branch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.fingerprint import BarrettConstants, fold_weights_u32

from .clmul import consts_limbs_of, fingerprint_bank_pallas, fingerprint_pallas
from .compose import compose_pallas
from .expand import expand_bank_pallas
from .match_scan import match_bank_chunks_pallas, match_chunks_pallas


@functools.lru_cache(maxsize=None)
def _default_interpret() -> bool:
    """Cached backend probe: the active platform cannot change mid-process,
    so ``jax.default_backend()`` (which can trigger backend init) runs once."""
    return jax.default_backend() != "tpu"


def _count(name: str) -> None:
    # ``kernels.<op>.calls`` counts *wrapper* invocations: eager dispatches,
    # or trace events when the wrapper is inlined into a jitted round — a
    # cheap "which kernels does this workload reach" signal, not a per-
    # execution count (XLA replays compiled programs without re-entering
    # Python).
    obs.counter(f"kernels.{name}.calls",
                help=f"dispatches of the {name} kernel wrapper").inc()


def fingerprint(
    words: jnp.ndarray,
    consts: BarrettConstants,
    *,
    block_b: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Batched Rabin fingerprints of packed (B, W) uint32 words -> (B, 2)."""
    if interpret is None:
        interpret = _default_interpret()
    _count("fingerprint")
    weights = fold_weights_u32(words.shape[-1], consts)
    return fingerprint_pallas(
        words, weights, consts_limbs_of(consts), block_b=block_b, interpret=interpret
    )


def fingerprint_bank(
    words: jnp.ndarray,
    consts_list,
    *,
    block_b: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Batched Rabin fingerprints over the pattern axis: (P, B, W) packed
    words with one :class:`BarrettConstants` per pattern -> (P, B, 2).

    This is the fold of the batched construction rounds
    (:mod:`repro.construction.batched`) as a standalone kernel: per-pattern
    constants (each pattern may sit on a different polynomial after a
    collision retry) ride the grid's pattern axis and stay VMEM-resident
    across that pattern's block row. On CPU the construction rounds keep
    their fused-XLA fold (interpret-mode Pallas would dominate); on a TPU
    runtime this kernel is the drop-in fold.
    """
    if interpret is None:
        interpret = _default_interpret()
    _count("fingerprint_bank")
    P, B, W = words.shape
    if len(consts_list) != P:
        raise ValueError(f"expected {P} per-pattern constants, got "
                         f"{len(consts_list)}")
    weights = jnp.stack(
        [fold_weights_u32(W, c) for c in consts_list])         # (P, W, 2)
    limbs = jnp.stack([consts_limbs_of(c) for c in consts_list])  # (P, 4)
    return fingerprint_bank_pallas(
        words, weights, limbs, block_b=block_b, interpret=interpret
    )


def fingerprint_bank_stacked(
    words: jnp.ndarray,
    weights: jnp.ndarray,
    limbs: jnp.ndarray,
    *,
    block_b: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """:func:`fingerprint_bank` with prestacked per-pattern constants:
    (P, B, W) packed words, (P, W, 2) fold weights, (P, 4) Barrett limbs ->
    (P, B, 2). Fully traceable (no host-side ``BarrettConstants`` objects),
    which is what lets ``repro.construction.batched`` select this kernel as
    the fingerprint stage *inside* its AOT-compiled round."""
    if interpret is None:
        interpret = _default_interpret()
    _count("fingerprint_bank_stacked")
    return fingerprint_bank_pallas(
        words, weights, limbs, block_b=block_b, interpret=interpret
    )


def expand_frontier_bank(
    tables: jnp.ndarray,
    ft: jnp.ndarray,
    *,
    block_t: int = 0,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Frontier × alphabet expansion over the pattern axis: (B, n, k)
    transition tables and (B, T, n) frontier state-vector tiles ->
    (B, T·k, n) candidate vectors, ``out[b, t·k + a, q] = tables[b,
    ft[b, t, q], a]`` — bit-identical to the XLA gather ``tables[b][ft[b]]``
    (the construction round's ``expand_backend="xla"`` stage). Formulated
    as a one-hot MXU contraction so each pattern's table stays VMEM-resident
    across its frontier blocks (see :mod:`repro.kernels.expand`).
    """
    if interpret is None:
        interpret = _default_interpret()
    _count("expand_frontier_bank")
    return expand_bank_pallas(tables, ft, block_t=block_t,
                              interpret=interpret)


def compose(
    f: jnp.ndarray,
    g: jnp.ndarray,
    *,
    block_q: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Function-composition combine (f then g): (B, n) x (B, n) -> (B, n)."""
    if interpret is None:
        interpret = _default_interpret()
    _count("compose")
    return compose_pallas(f, g, block_q=block_q, interpret=interpret)


def match_chunks(
    table: jnp.ndarray,
    chunks: jnp.ndarray,
    *,
    block_b: int = 8,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-chunk transition functions: (n, k), (B, L) -> (B, n).

    ``block_b`` chunks share one grid cell / VMEM table residency (the same
    block-tiling knob as ``fingerprint``/``compose``).
    """
    if interpret is None:
        interpret = _default_interpret()
    _count("match_chunks")
    return match_chunks_pallas(table, chunks, block_b=block_b,
                               interpret=interpret)


def match_bank_chunks(
    tables: jnp.ndarray,
    chunks: jnp.ndarray,
    *,
    block_b: int = 8,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Multi-automaton chunk functions: (P, n, k), (B, L) -> (P, B, n)."""
    if interpret is None:
        interpret = _default_interpret()
    _count("match_bank_chunks")
    return match_bank_chunks_pallas(tables, chunks, block_b=block_b,
                                    interpret=interpret)
