"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth its kernel twin must reproduce
bit-exactly (integer kernels) or to float tolerance (matmul-based kernels
compute exact small-integer arithmetic in f32, so they are bit-exact too).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.fingerprint import (
    BarrettConstants,
    fingerprint_u32,
    fold_weights_u32,
)


def fingerprint_ref(words: jnp.ndarray, consts: BarrettConstants) -> jnp.ndarray:
    """Rabin/Barrett fingerprint of packed word streams.

    words: (B, W) uint32 -> (B, 2) uint32 [hi, lo].
    """
    weights = fold_weights_u32(words.shape[-1], consts)
    hi, lo = fingerprint_u32(words, weights, consts)
    return jnp.stack([hi, lo], axis=-1)


def compose_ref(f: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Function-composition monoid combine: out[b, q] = g[b, f[b, q]].

    f, g: (B, n) int32 mapping vectors ("f then g").
    """
    return jnp.take_along_axis(g, f, axis=-1)


def match_chunks_ref(table: jnp.ndarray, chunks: jnp.ndarray) -> jnp.ndarray:
    """Enumeration-mode chunk matching: per chunk, run the DFA from every
    start state. table: (n, k) int32; chunks: (B, L) int32 -> (B, n) mappings.
    """
    n = table.shape[0]

    def one(chunk):
        def step(v, sym):
            return table[v, sym], None

        out, _ = jax.lax.scan(step, jnp.arange(n, dtype=jnp.int32), chunk)
        return out

    return jax.vmap(one)(chunks)
