"""Shared data types of the construction subsystem.

The :class:`SFA` dataclass and the two failure modes (``FingerprintCollision``
for a detected 64-bit fingerprint clash — exactness by detection + retry —
and ``StateBlowup`` for the O(n^n) wall) used to live in ``core/sfa.py``;
that module now re-exports them from here so existing imports keep working.
This module adds the bank-level results: :class:`BankStats` (the bulk-round
accounting the cache/retry tests assert on) and
:class:`BankConstructionResult` (per-pattern SFAs + blowup flags).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.dfa import DFA


class FingerprintCollision(RuntimeError):
    """Two distinct state vectors produced the same 64-bit fingerprint."""


class StateBlowup(RuntimeError):
    """SFA state count exceeded the configured cap (the O(n^n) problem)."""


@dataclass
class SFAStats:
    engine: str
    rounds: int = 0
    candidates: int = 0
    fp_compares: int = 0
    exact_compares: int = 0
    collisions_detected: int = 0
    wall_time_s: float = 0.0


@dataclass
class SFA:
    """The simultaneous automaton.

    ``mappings[i]`` is the state vector of SFA state ``i``; ``delta[i, a]`` is
    the SFA transition table; state 0 is the start (identity mapping).
    """

    mappings: np.ndarray      # (S, n) int32
    delta: np.ndarray         # (S, |Σ|) int32
    fingerprints: np.ndarray  # (S, 2) uint32 [hi, lo]
    dfa: DFA
    stats: SFAStats

    @property
    def n_states(self) -> int:
        return int(self.mappings.shape[0])

    @property
    def start(self) -> int:
        return 0

    def accepting_states(self) -> np.ndarray:
        """F_s = { f | f(q0) ∈ F } (paper line 11, with I = {q0})."""
        return self.dfa.accepting[self.mappings[:, self.dfa.start]]

    def run(self, symbols: np.ndarray, state: int | None = None) -> int:
        """Run the SFA like a plain DFA (one table lookup per character)."""
        s = 0 if state is None else state
        tbl = self.delta
        for x in np.asarray(symbols, dtype=np.int64):
            s = int(tbl[s, x])
        return s

    def mapping_of(self, symbols: np.ndarray) -> np.ndarray:
        """Transition function of the whole input string, as a vector."""
        return self.mappings[self.run(symbols)]

    def nbytes(self) -> int:
        """Array payload size (the cache's eviction currency)."""
        return int(
            self.mappings.nbytes + self.delta.nbytes + self.fingerprints.nbytes
        )


@dataclass(frozen=True)
class BucketStats:
    """Accounting of one size bucket of a bucketed bank construction.

    ``edge`` is the bucket's size-ladder edge (patterns with
    ``n_states <= edge``), ``n_max`` the bucket's true widest pattern —
    the row width every pattern in the bucket actually paid, versus the
    whole bank's ``n_max`` it would have paid unbucketed.
    """

    edge: int
    n_patterns: int
    n_max: int
    rounds: int
    blown: int
    wall_time_s: float

    def to_json(self) -> dict:
        return {
            "edge": self.edge, "n_patterns": self.n_patterns,
            "n_max": self.n_max, "rounds": self.rounds,
            "blown": self.blown, "wall_time_s": self.wall_time_s,
        }


@dataclass
class BankStats:
    """Accounting of one :func:`~repro.construction.construct_bank` call.

    ``rounds`` counts *bulk-synchronous device rounds* for the batched method
    (one jitted call advancing every active pattern's frontier by one tile) —
    for the loop method it is the sum of the per-pattern engines' rounds, so
    "a cached compile performed zero construction rounds" is meaningful for
    both. ``pattern_rounds[p]`` counts the rounds in which pattern ``p``
    actually had frontier states processed (a retried pattern's counter grows;
    a finished passenger's does not — the per-pattern retry test pins this).

    ``pattern_candidates[p]`` is pattern ``p``'s candidate-expansion count
    (the single source for both ``candidates == pattern_candidates.sum()``
    and each pattern's ``SFAStats.candidates``). ``wall_time_s`` is the
    whole-bank wall time; per-pattern ``SFAStats.wall_time_s`` is the
    rounds-weighted *share* of it — a bank's wall belongs to the bank, and a
    pattern that closed in 2 of 13 rounds must not report 13 rounds' worth.

    These per-call dataclasses are the *request-scoped* view of the same
    accounting the process-wide ``repro.obs`` registry aggregates:
    ``construct_bank`` publishes each result's totals into the
    ``construction.*`` counters/histograms at return, so registry values
    are running sums of the fields reported here (field meanings and
    values are unchanged whether observability is on or off).
    """

    method: str
    rounds: int = 0
    pattern_rounds: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    retries: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    pattern_candidates: np.ndarray = field(
        default_factory=lambda: np.zeros(0, np.int64)
    )
    candidates: int = 0
    wall_time_s: float = 0.0
    #: Per-size-bucket accounting (``BucketStats``) when the batched method
    #: ran bucketed; empty for unbucketed or loop constructions.
    buckets: list = field(default_factory=list)


@dataclass
class BankConstructionResult:
    """Per-pattern outcome of a bank construction.

    ``sfas[p]`` is the exact SFA of pattern ``p`` or ``None`` where
    ``blown[p]`` (state count exceeded ``max_states``).
    """

    sfas: list
    blown: np.ndarray            # (P,) bool
    stats: BankStats

    @property
    def n_patterns(self) -> int:
        return len(self.sfas)

    def require_all(self) -> "BankConstructionResult":
        """Raise :class:`StateBlowup` unless every pattern closed."""
        if bool(np.any(self.blown)):
            bad = [int(i) for i in np.flatnonzero(self.blown)]
            raise StateBlowup(f"patterns {bad} exceeded the state cap")
        return self
