"""Content-addressed SFA cache.

Construction is the expensive half of the paper's pipeline (minutes for large
PROSITE signatures, vs milliseconds to scan); it is also *pure*: every engine
produces the bit-identical exact SFA for a given DFA and base polynomial. So
SFAs are cached content-addressed — the key is a canonical byte serialization
of the DFA (transition table, start, accepting set, alphabet) plus the base
polynomial of the fingerprint retry sequence — and a hit is valid no matter
which engine or scanner produced it.

Entries are positive (the exact SFA) or negative (a *blowup marker*: the
construction exceeded some state budget). Negative entries record the budget
that failed, so a later request with a larger budget is a miss (the closure
might fit) while an equal-or-smaller budget is a hit (known blowup, skip the
work). A positive entry whose SFA is larger than the requested budget also
answers "blowup" without constructing anything — the cache knows the exact
state count.

Eviction is LRU over a byte budget (``max_bytes``) with an entry-count lid
(``max_entries``); blowup markers are near-free and only count against the
entry lid. ``repro.engine.Scanner`` consults the shared process-wide
instance (:func:`shared_cache`) by default, so recompiling the same patterns
performs zero construction rounds.

The cache optionally sits on a **backing store** — any object speaking the
protocol of :class:`repro.scanservice.ArtifactStore` (``get(key)`` ->
``("sfa", SFA) | ("blowup", budget) | None``, ``put_sfa``, ``put_blowup``,
``entries()``). Memory misses fall through to the backing tier (a hit
promotes into memory and counts in ``info.disk_hits``), and stores write
through, so the cache persists across processes: a *fresh* ``SFACache``
pointed at the same store directory answers previously-seen patterns with
zero construction rounds. :meth:`SFACache.preload` bulk-loads the backing
tier for warm starts.

This module also holds the cache for the *other* expensive artifact of
construction: :class:`RoundCompileCache` keeps the AOT-compiled batched
round closures (keyed by round shape), so repeat same-shape
``construct_bank`` calls perform zero new XLA compiles even when the SFA
cache itself missed (eviction, cache="off", a different budget).
"""

from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from dataclasses import dataclass, field

from .. import obs
from ..core.dfa import DFA
from ..core.fingerprint import DEFAULT_POLY_LOW
from .types import SFA

# /metrics HELP descriptions, registered once; hot paths increment by name.
obs.counter("cache.sfa.hits", help="SFA cache lookups answered in memory")
obs.counter("cache.sfa.misses", help="SFA cache lookups that missed")
obs.counter("cache.sfa.disk_hits",
            help="misses answered by the backing store (promoted to memory)")
obs.counter("cache.sfa.stores", help="SFA entries written to the cache")
obs.counter("cache.sfa.evictions", help="SFA entries evicted (LRU)")
obs.gauge("cache.sfa.bytes",
          help="resident SFA bytes in memory (fleet merges by sum)")
obs.counter("cache.rounds.hits",
            help="AOT round-compile cache hits (zero new XLA compiles)")
obs.counter("cache.rounds.lowerings",
            help="round closures lowered + AOT-compiled on miss")
obs.counter("cache.rounds.evictions",
            help="compiled round closures evicted (LRU)")


def dfa_cache_key(dfa: DFA, poly_low: int = DEFAULT_POLY_LOW) -> str:
    """Canonical content hash of a DFA + fingerprint base polynomial.

    Deliberately hashes the exact table layout (not an isomorphism-canonical
    form): SFA mappings are vectors *of these state ids*, so only an
    identically-numbered DFA may share the entry.
    """
    h = hashlib.sha256()
    h.update(b"sfa-v1|")
    h.update(str(dfa.n_states).encode())
    h.update(b"|")
    h.update(dfa.alphabet.encode())
    h.update(b"|")
    h.update(int(dfa.start).to_bytes(4, "little"))
    h.update(dfa.table.astype("<i4", copy=False).tobytes())
    h.update(dfa.accepting.astype("u1", copy=False).tobytes())
    h.update(poly_low.to_bytes(8, "little"))
    return h.hexdigest()


@dataclass
class CacheInfo:
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    stores: int = 0
    current_bytes: int = 0
    disk_hits: int = 0

    def snapshot(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "stores": self.stores,
            "current_bytes": self.current_bytes,
            "disk_hits": self.disk_hits,
        }


@dataclass
class _Blowup:
    """Negative entry: construction exceeded ``budget`` states."""

    budget: int
    nbytes: int = 0


class SFACache:
    """LRU content-addressed cache of constructed SFAs (+ blowup markers).

    ``backing``: optional persistent tier (see module docstring). Lookups
    fall through to it on a memory miss; stores write through to it.
    """

    def __init__(self, max_entries: int = 256,
                 max_bytes: int = 256 * 1024 * 1024,
                 backing=None):
        if max_entries < 1 or max_bytes < 1:
            raise ValueError("max_entries and max_bytes must be >= 1")
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.backing = backing
        self.info = CacheInfo()
        self._entries: OrderedDict = OrderedDict()
        # One coarse lock over lookup/store/preload: the scan service's
        # thread driver compiles through the same cache its callers use.
        self._lock = threading.RLock()

    def attach_backing(self, backing) -> None:
        """Attach/replace the persistent tier (plan plumbing entry point).

        A no-op when ``backing`` already is the attached store (object
        identity or store equality), so repeated compiles under one plan
        don't churn; otherwise the new store wins. NOTE: attaching to the
        process-wide :func:`shared_cache` is a process-wide decision —
        every later compile in the process reads/writes that store until
        another one is attached.
        """
        if backing is None or self.backing is backing or self.backing == backing:
            return
        with self._lock:
            self.backing = backing

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, dfa: DFA) -> bool:
        return dfa_cache_key(dfa) in self._entries

    # -- lookup / store -----------------------------------------------------

    def lookup(self, dfa: DFA, *, max_states: int,
               poly_low: int = DEFAULT_POLY_LOW) -> tuple:
        """-> ("sfa", SFA) | ("blowup", None) | (None, None).

        "blowup" means construction under ``max_states`` is *known* to fail:
        either a marker recorded at an equal-or-larger budget, or a cached
        SFA whose exact state count exceeds the budget.
        """
        key = dfa_cache_key(dfa, poly_low)
        with self._lock:
            ent = self._entries.get(key)
            if ent is None and self.backing is not None:
                ent = self._promote(key)
            if ent is None:
                self.info.misses += 1
                obs.counter("cache.sfa.misses").inc()
                return None, None
            if isinstance(ent, _Blowup):
                if ent.budget >= max_states:
                    self.info.hits += 1
                    obs.counter("cache.sfa.hits").inc()
                    self._entries.move_to_end(key)
                    return "blowup", None
                self.info.misses += 1  # bigger budget might close — rebuild
                obs.counter("cache.sfa.misses").inc()
                return None, None
            self.info.hits += 1
            obs.counter("cache.sfa.hits").inc()
            self._entries.move_to_end(key)
            if ent.n_states > max_states:
                return "blowup", None
            return "sfa", ent

    def store(self, dfa: DFA, sfa: SFA,
              poly_low: int = DEFAULT_POLY_LOW) -> None:
        """Insert/refresh the positive entry for ``dfa`` (write-through)."""
        key = dfa_cache_key(dfa, poly_low)
        with self._lock:
            self._put(key, sfa, sfa.nbytes())
            if self.backing is not None:
                self.backing.put_sfa(key, sfa)

    def store_blowup(self, dfa: DFA, budget: int,
                     poly_low: int = DEFAULT_POLY_LOW) -> None:
        """Record that construction under ``budget`` states blew up.

        Never downgrades: a positive entry (the exact SFA) stays, and a
        marker only grows its recorded budget.
        """
        key = dfa_cache_key(dfa, poly_low)
        with self._lock:
            ent = self._entries.get(key)
            if isinstance(ent, SFA):
                return
            if isinstance(ent, _Blowup):
                ent.budget = max(ent.budget, budget)
                self._entries.move_to_end(key)
            else:
                self._put(key, _Blowup(budget=budget), 0)
            if self.backing is not None:
                self.backing.put_blowup(key, budget)

    def preload(self, max_entries: int | None = None) -> int:
        """Warm start: bulk-promote the backing tier into memory.

        ``entries()`` yields in the store's LRU order (least-recently-used
        first), so insertion preserves recency in the memory LRU and any
        in-memory eviction drops the coldest artifacts. With ``max_entries``
        only the *most*-recently-used that many are promoted.
        -> number of entries promoted; 0 without a backing store.
        """
        if self.backing is None:
            return 0
        entries = self.backing.entries()
        if max_entries is not None:
            from collections import deque

            entries = deque(entries, maxlen=max_entries)  # keep the hottest
        n = 0
        with self._lock:
            for key, kind, payload in entries:
                if kind == "sfa":
                    self._put(key, payload, payload.nbytes())
                else:
                    self._put(key, _Blowup(budget=int(payload)), 0)
                self.info.disk_hits += 1
                n += 1
        obs.counter("cache.sfa.disk_hits").inc(n)
        return n

    def _promote(self, key: str):
        """Memory miss -> consult the backing tier; insert any hit into the
        memory LRU (without writing back) and return the new entry."""
        got = self.backing.get(key)
        if got is None:
            return None
        kind, payload = got
        if kind == "sfa":
            ent = payload
            self._put(key, ent, ent.nbytes())
        else:
            ent = _Blowup(budget=int(payload))
            self._put(key, ent, 0)
        self.info.disk_hits += 1
        obs.counter("cache.sfa.disk_hits").inc()
        return ent

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.info.current_bytes = 0

    # -- internals ----------------------------------------------------------

    @staticmethod
    def _size(ent) -> int:
        return ent.nbytes() if isinstance(ent, SFA) else ent.nbytes

    def _put(self, key: str, value, nbytes: int) -> None:
        old = self._entries.pop(key, None)
        if old is not None:
            self.info.current_bytes -= self._size(old)
        self._entries[key] = value
        self.info.stores += 1
        obs.counter("cache.sfa.stores").inc()
        self.info.current_bytes += nbytes
        while (len(self._entries) > self.max_entries
               or self.info.current_bytes > self.max_bytes):
            _, victim = self._entries.popitem(last=False)
            self.info.evictions += 1
            obs.counter("cache.sfa.evictions").inc()
            self.info.current_bytes -= self._size(victim)
        obs.gauge("cache.sfa.bytes").set(self.info.current_bytes)


_SHARED: SFACache | None = None


def shared_cache() -> SFACache:
    """The process-wide cache ``Scanner.compile`` consults by default."""
    global _SHARED
    if _SHARED is None:
        _SHARED = SFACache()
    return _SHARED


# --------------------------------------------------------------------------
# Compiled-round cache (the other half of "recompiling is free")
# --------------------------------------------------------------------------


@dataclass
class RoundCacheInfo:
    """Counters of :class:`RoundCompileCache`. ``lowerings`` is the number of
    trace+lower+compile passes actually performed — the compile-count
    regression tests assert its delta is zero across a repeat same-shape
    ``construct_bank``."""

    lowerings: int = 0
    hits: int = 0
    evictions: int = 0

    def snapshot(self) -> dict:
        return {
            "lowerings": self.lowerings,
            "hits": self.hits,
            "evictions": self.evictions,
        }


class RoundCompileCache:
    """Process-wide LRU of compiled bank-round closures.

    :class:`SFACache` makes re-*constructing* a seen pattern free; this cache
    makes re-*compiling* a seen round shape free. Batched construction visits
    a precomputed schedule of ``(capacity, bucket)`` shapes (see
    :func:`repro.construction.batched.round_schedule`); each visited shape's
    fused round step is AOT-lowered exactly once per process and keyed by the
    full shape tuple ``(tile, n, k, capacity, P, bucket, fingerprint backend,
    interpret, distribution)``. A hit replays the stored executable with zero
    new traces, so a second bank of the same shape — or the same bank after
    SFA-cache eviction — performs zero new XLA compiles.

    Entries are executables (``jax.jit(step).lower(...).compile()`` results
    for the local path; jitted shard_map wrappers for the distributed path,
    which keep per-bucket shapes in jit's own cache). Eviction is LRU over an
    entry count — executables hold device programs, not SFA payloads, so a
    count lid is the right currency.
    """

    def __init__(self, max_entries: int = 512):
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = max_entries
        self.info = RoundCacheInfo()
        self._entries: OrderedDict = OrderedDict()
        self._lock = threading.RLock()

    def get(self, key, build):
        """The executable for ``key``, building (and counting a lowering)
        on a miss. ``build`` runs outside the lock — compiles are slow and a
        racing duplicate build is benign (last writer wins)."""
        with self._lock:
            ent = self._entries.get(key)
            if ent is not None:
                self.info.hits += 1
                obs.counter("cache.rounds.hits").inc()
                self._entries.move_to_end(key)
                return ent
        ent = build()
        with self._lock:
            self._entries[key] = ent
            self._entries.move_to_end(key)
            self.info.lowerings += 1
            obs.counter("cache.rounds.lowerings").inc()
            while len(self._entries) > self.max_entries:
                self._entries.popitem(last=False)
                self.info.evictions += 1
                obs.counter("cache.rounds.evictions").inc()
        return ent

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()


_SHARED_ROUNDS: RoundCompileCache | None = None


def round_compile_cache() -> RoundCompileCache:
    """The process-wide compiled-round cache batched construction uses."""
    global _SHARED_ROUNDS
    if _SHARED_ROUNDS is None:
        _SHARED_ROUNDS = RoundCompileCache()
    return _SHARED_ROUNDS
