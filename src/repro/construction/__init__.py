"""SFA construction — the paper's core contribution, as one subsystem.

Construction used to live in three near-duplicate single-pattern engines
(``core/sfa.py`` sequential + vectorized, ``core/sfa_jax.py``); it is now
one worklist closure (:mod:`.worklist`, fixed FIFO-BFS discovery order)
over pluggable membership stores (:mod:`.stores` — the paper's §III-A
baseline / fingerprint / hash-table ablation, plus the TPU-idiomatic
fingerprint-sort bulk store), with three execution shapes:

* :func:`construct_sfa_sequential` / :func:`construct_sfa_vectorized` —
  scalar and bulk single-pattern closures (NumPy);
* :func:`construct_sfa_jax` — the jitted fixed-capacity engine, now the
  ``P = 1`` case of the batched rounds;
* :func:`construct_bank` — the bank-native path (:mod:`.batched`): all ``P``
  patterns' frontiers advance simultaneously in one jitted bulk-synchronous
  round over stacked ``(P, capacity, n_max)`` buffers, with per-pattern
  done/blowup/collision flags, per-pattern polynomial retry, host-side
  compaction of finished patterns, and ``distribution="shard_map"``
  sharding patterns across devices.

All engines produce bit-identical exact SFAs (equal fingerprints never merge
states silently), which is what makes the content-addressed :class:`SFACache`
(:mod:`.cache`) sound: ``repro.engine.Scanner`` consults the shared cache so
recompiling the same patterns performs zero construction rounds.

``core/sfa.py`` and ``core/sfa_jax.py`` remain as thin re-export shims.
"""

from .cache import (
    CacheInfo,
    RoundCacheInfo,
    RoundCompileCache,
    SFACache,
    dfa_cache_key,
    round_compile_cache,
    shared_cache,
)
from .batched import (
    BUCKETINGS,
    EXPAND_BACKENDS,
    FINGERPRINT_BACKENDS,
    RoundSchedule,
    construct_bank,
    construct_sfa_jax,
    round_schedule,
)
from .single import (
    construct_sfa,
    construct_sfa_sequential,
    construct_sfa_vectorized,
)
from .stores import (
    ExhaustiveStore,
    FingerprintScanStore,
    HashChainStore,
    SortedFingerprintStore,
)
from .types import (
    SFA,
    BankConstructionResult,
    BankStats,
    BucketStats,
    FingerprintCollision,
    SFAStats,
    StateBlowup,
)

__all__ = [
    "BUCKETINGS",
    "BankConstructionResult",
    "BankStats",
    "BucketStats",
    "CacheInfo",
    "EXPAND_BACKENDS",
    "FINGERPRINT_BACKENDS",
    "ExhaustiveStore",
    "FingerprintCollision",
    "FingerprintScanStore",
    "HashChainStore",
    "RoundCacheInfo",
    "RoundCompileCache",
    "RoundSchedule",
    "SFA",
    "SFACache",
    "SFAStats",
    "SortedFingerprintStore",
    "StateBlowup",
    "construct_bank",
    "construct_sfa",
    "construct_sfa_jax",
    "construct_sfa_sequential",
    "construct_sfa_vectorized",
    "dfa_cache_key",
    "round_compile_cache",
    "round_schedule",
    "shared_cache",
]
