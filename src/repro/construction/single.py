"""Single-pattern construction entry points over the shared worklist core.

These keep the exact public signatures that ``core/sfa.py`` has always
exported (that module now re-exports from here):

* :func:`construct_sfa_sequential` — paper Algorithm 1 with the §III-A
  optimizations as toggles; the toggles now literally select a
  :mod:`~repro.construction.stores` membership store (the Fig. 4 ablation
  is a store swap, not a separate engine).
* :func:`construct_sfa_vectorized` — the TPU-shaped bulk frontier closure on
  NumPy (fast CPU path).
* :func:`construct_sfa` — the exactness wrapper: on a detected fingerprint
  collision, retry with a fresh random irreducible polynomial. Retries route
  through the cached polynomial/Barrett-constant helpers
  (:func:`~repro.core.fingerprint.nth_poly_low` /
  :meth:`~repro.core.fingerprint.BarrettConstants.cached`), so a retry costs
  one closure re-run — not a fresh irreducibility search plus a
  t^128-division per attempt.  The ``engine="jax"`` path is the ``P = 1``
  case of :func:`~repro.construction.batched.construct_bank`.
"""

from __future__ import annotations

from ..core.dfa import DFA
from ..core.fingerprint import BarrettConstants, nth_poly_low
from .stores import (
    ExhaustiveStore,
    FingerprintScanStore,
    HashChainStore,
    SortedFingerprintStore,
)
from .types import SFA, FingerprintCollision, SFAStats
from .worklist import close_bulk, close_scalar


def _consts_for(poly_index: int) -> BarrettConstants:
    return BarrettConstants.cached(nth_poly_low(poly_index))


def construct_sfa_sequential(
    dfa: DFA,
    *,
    use_fingerprints: bool = True,
    use_hashing: bool = True,
    poly_index: int = 0,
    max_states: int = 1_000_000,
) -> SFA:
    """Algorithm 1 with the paper's §III-A optimizations as toggles.

    - fingerprints off: membership is the exhaustive vector comparison against
      every known state (the paper's baseline — O(|Q|·|Q_s|) per test).
    - fingerprints on, hashing off: linear scan compares 64-bit fingerprints,
      exact vector compare only on fingerprint equality.
    - hashing on (requires fingerprints): dict keyed by fingerprint with
      collision chains — the paper's hash table, O(1) expected.
    """
    if use_hashing and not use_fingerprints:
        raise ValueError("hashing requires fingerprints (paper §III-A)")
    stats = SFAStats(engine="sequential")
    if not use_fingerprints:
        store = ExhaustiveStore(stats)
    elif use_hashing:
        store = HashChainStore(stats, _consts_for(poly_index))
    else:
        store = FingerprintScanStore(stats, _consts_for(poly_index))
    return close_scalar(dfa, store, stats, max_states=max_states)


def construct_sfa_vectorized(
    dfa: DFA,
    *,
    poly_index: int = 0,
    max_states: int = 4_000_000,
    tile: int = 4096,
) -> SFA:
    """Bulk-synchronous frontier closure on NumPy (the fast CPU path)."""
    stats = SFAStats(engine="vectorized")
    store = SortedFingerprintStore(stats, _consts_for(poly_index), dfa.n_states)
    return close_bulk(dfa, store, stats, max_states=max_states, tile=tile)


def construct_sfa(
    dfa: DFA,
    *,
    engine: str = "vectorized",
    max_states: int = 4_000_000,
    max_retries: int = 4,
    poly_index: int = 0,
    cache=None,
    **kwargs,
) -> SFA:
    """Construct the exact SFA; on a detected fingerprint collision, retry
    with a fresh random irreducible polynomial (paper §II: P is random).
    ``poly_index`` is the base of the retry sequence (attempt ``a`` uses
    polynomial ``poly_index + a``), matching ``construct_bank``'s.

    ``cache`` optionally names a :class:`~repro.construction.cache.SFACache`
    consulted before (and populated after) construction; all engines are
    bit-identical, so a hit is valid regardless of which engine produced it.
    """
    from .types import StateBlowup

    base_poly = nth_poly_low(poly_index)
    if cache is not None:
        hit, sfa = cache.lookup(dfa, max_states=max_states,
                                poly_low=base_poly)
        if hit == "sfa":
            return sfa
        if hit == "blowup":  # known to exceed this budget: fail fast
            raise StateBlowup(
                f"SFA exceeds {max_states} states (cached blowup)"
            )
    last: Exception | None = None
    try:
        for attempt in range(max_retries):
            poly = poly_index + attempt
            try:
                if engine == "sequential":
                    sfa = construct_sfa_sequential(
                        dfa, poly_index=poly, max_states=max_states, **kwargs
                    )
                elif engine == "vectorized":
                    sfa = construct_sfa_vectorized(
                        dfa, poly_index=poly, max_states=max_states, **kwargs
                    )
                elif engine == "jax":
                    from .batched import construct_sfa_jax

                    sfa = construct_sfa_jax(
                        dfa, poly_index=poly, max_states=max_states, **kwargs
                    )
                else:
                    raise ValueError(f"unknown engine {engine!r}")
                if cache is not None:
                    cache.store(dfa, sfa, poly_low=base_poly)
                return sfa
            except FingerprintCollision as e:  # pragma: no cover (rare)
                last = e
    except StateBlowup:
        if cache is not None:
            cache.store_blowup(dfa, max_states, poly_low=base_poly)
        raise
    raise last  # pragma: no cover
