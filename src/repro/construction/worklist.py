"""The one worklist closure every construction engine shares (paper Alg. 1).

Given a DFA, the SFA is the closure of the identity mapping under
``f ↦ λq. δ(f[q], σ)`` for every symbol σ.  Discovery order is FIFO BFS with
symbols in order — fixed here, once, so *all* engines (scalar stores, the
bulk store, the jitted batched rounds in :mod:`.batched`) produce
bit-identical SFAs.  What varies is only the membership policy
(:mod:`.stores`) and the execution shape:

* :func:`close_scalar` — one candidate at a time through a scalar store
  (the faithful sequential engine, with the paper's §III-A ablation toggles
  expressed as store choice);
* :func:`close_bulk` — whole frontier × alphabet tiles through the
  :class:`~repro.construction.stores.SortedFingerprintStore` (the TPU-shaped
  algorithm on NumPy: fused gather on the transposed table, vectorized
  fingerprint fold, searchsorted membership).
"""

from __future__ import annotations

import time

import numpy as np

from ..core.dfa import DFA
from .stores import SortedFingerprintStore
from .types import SFA, SFAStats, StateBlowup


def close_scalar(dfa: DFA, store, stats: SFAStats, *,
                 max_states: int) -> SFA:
    """Algorithm 1 with membership delegated to a scalar store."""
    t0 = time.perf_counter()
    n, k = dfa.n_states, dfa.n_symbols
    table = dfa.table

    identity = np.arange(n, dtype=np.int32)
    store.lookup_or_add(identity)
    delta_rows: list = []
    head = 0

    while head < len(store):
        cur_vec = store.mappings[head]
        head += 1
        stats.rounds += 1
        row = np.empty(k, dtype=np.int32)
        for a in range(k):
            nxt = table[cur_vec, a]  # f_next(q) = δ(f(q), σ) (paper line 6)
            stats.candidates += 1
            idx, is_new = store.lookup_or_add(nxt)
            if is_new and idx >= max_states:
                raise StateBlowup(f"SFA exceeded {max_states} states")
            row[a] = idx
        delta_rows.append(row)

    stats.wall_time_s = time.perf_counter() - t0
    return SFA(
        mappings=np.stack(store.mappings).astype(np.int32),
        delta=np.stack(delta_rows).astype(np.int32),
        fingerprints=store.fingerprint_pairs(),
        dfa=dfa,
        stats=stats,
    )


def close_bulk(dfa: DFA, store: SortedFingerprintStore, stats: SFAStats, *,
               max_states: int, tile: int) -> SFA:
    """Bulk-synchronous frontier closure.

    Per round, the *whole frontier × alphabet* expands in one fused gather on
    the transposed transition table (paper §III-B3: symbol-major layout), all
    candidates are fingerprinted in one vectorized fold (paper §III-A), and
    membership is the store's fingerprint ``searchsorted``. Discovery order
    is row-major (frontier, symbol), identical to :func:`close_scalar`'s
    FIFO BFS, so the engines produce bit-identical SFAs.
    """
    t0 = time.perf_counter()
    n, k = dfa.n_states, dfa.n_symbols
    if n >= 1 << 16:
        raise ValueError("bulk engine packs 16-bit state ids (paper layout)")
    tableT = dfa.transposed()  # (k, n) symbol-major

    delta = np.zeros((0, k), dtype=np.int32)
    frontier_lo = 0            # store.mappings[frontier_lo:] unprocessed

    while frontier_lo < len(store):
        stats.rounds += 1
        frontier = store.mappings[frontier_lo:]
        new_rows = []
        for t in range(0, frontier.shape[0], tile):
            ft = frontier[t : t + tile]              # (m, n)
            m = ft.shape[0]
            # Fused expansion: next[f, σ, q] = δT[σ, f[q]]  — one gather.
            cand = tableT[:, ft]                     # (k, m, n)
            cand = np.ascontiguousarray(np.swapaxes(cand, 0, 1))  # (m, k, n)
            cand = cand.reshape(m * k, n)
            stats.candidates += m * k
            ids = store.assign(cand)
            if len(store) > max_states:
                raise StateBlowup(f"SFA exceeded {max_states} states")
            new_rows.append(ids.reshape(m, k))
        delta = np.concatenate([delta, *new_rows], axis=0)
        frontier_lo = delta.shape[0]

    stats.wall_time_s = time.perf_counter() - t0
    return SFA(
        mappings=store.mappings,
        delta=delta,
        fingerprints=store.fingerprint_pairs(),
        dfa=dfa,
        stats=stats,
    )
