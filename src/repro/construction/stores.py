"""Pluggable state stores: membership + id assignment for discovered states.

The worklist closure (paper Alg. 1) is identical across every engine; what
differs is how "have we seen this transition-function vector before?" is
answered. That policy lives here, behind two small interfaces:

* scalar stores (one candidate at a time — the faithful sequential engine):

  - :class:`ExhaustiveStore`   — the paper's baseline: exact vector compare
    against every known state, O(|Q|·|Q_s|) per test;
  - :class:`FingerprintScanStore` — linear scan over 64-bit fingerprints,
    exact compare only on fingerprint equality (paper §III-A, fp only);
  - :class:`HashChainStore`    — dict keyed by fingerprint with exact-compare
    collision chains: the paper's hash table, O(1) expected.

* a bulk store (whole frontier × alphabet at once — the TPU-shaped engines):

  - :class:`SortedFingerprintStore` — membership is fingerprint
    ``searchsorted`` against the sorted known set, the bulk equivalent of the
    hash table; fingerprint hits are confirmed with exact vector compares and
    any mismatch raises :class:`~repro.construction.FingerprintCollision`.

All stores share one exactness contract: equal fingerprints never merge
states silently, so the closure always yields the exact SFA (or raises).
"""

from __future__ import annotations

import numpy as np

from ..core.fingerprint import (
    BarrettConstants,
    fingerprint_int,
    fingerprint_words_np,
    pack_states_np,
)
from .types import FingerprintCollision, SFAStats


# --------------------------------------------------------------------------
# Scalar stores (sequential engine)
# --------------------------------------------------------------------------


class ExhaustiveStore:
    """Baseline membership: exact comparison against all known states."""

    def __init__(self, stats: SFAStats):
        self.stats = stats
        self.mappings: list = []

    def __len__(self) -> int:
        return len(self.mappings)

    def lookup_or_add(self, vec: np.ndarray) -> tuple:
        """-> (state id, is_new)."""
        for i, m in enumerate(self.mappings):
            self.stats.exact_compares += 1
            if np.array_equal(m, vec):
                return i, False
        return self._append(vec), True

    def _append(self, vec: np.ndarray) -> int:
        self.mappings.append(np.asarray(vec, dtype=np.int32))
        return len(self.mappings) - 1

    def fingerprint_pairs(self) -> np.ndarray:
        return np.zeros((len(self.mappings), 2), dtype=np.uint32)


class _FingerprintedStore(ExhaustiveStore):
    """Shared fingerprint bookkeeping for the fp-based scalar stores."""

    def __init__(self, stats: SFAStats, consts: BarrettConstants):
        super().__init__(stats)
        self.consts = consts
        self.fps: list = []

    def fp_of(self, vec: np.ndarray) -> int:
        return fingerprint_int(pack_states_np(vec), self.consts)

    def _append_fp(self, vec: np.ndarray, fp: int) -> int:
        idx = self._append(vec)
        self.fps.append(fp)
        return idx

    def fingerprint_pairs(self) -> np.ndarray:
        out = np.zeros((len(self.fps), 2), dtype=np.uint32)
        for i, f in enumerate(self.fps):
            out[i, 0] = (f >> 32) & 0xFFFFFFFF
            out[i, 1] = f & 0xFFFFFFFF
        return out


class FingerprintScanStore(_FingerprintedStore):
    """Fingerprints without hashing: linear 64-bit scan, exact confirm."""

    def lookup_or_add(self, vec: np.ndarray) -> tuple:
        f = self.fp_of(vec)
        for i, fi in enumerate(self.fps):
            self.stats.fp_compares += 1
            if fi == f:
                self.stats.exact_compares += 1
                if np.array_equal(self.mappings[i], vec):
                    return i, False
                self.stats.collisions_detected += 1
        return self._append_fp(vec, f), True


class HashChainStore(_FingerprintedStore):
    """The paper's hash table: dict keyed by fingerprint, exact-chain."""

    def __init__(self, stats: SFAStats, consts: BarrettConstants):
        super().__init__(stats, consts)
        self.table: dict = {}

    def lookup_or_add(self, vec: np.ndarray) -> tuple:
        f = self.fp_of(vec)
        chain = self.table.setdefault(f, [])
        self.stats.fp_compares += 1
        for i in chain:
            self.stats.exact_compares += 1
            if np.array_equal(self.mappings[i], vec):
                return i, False
            self.stats.collisions_detected += 1
        idx = self._append_fp(vec, f)
        chain.append(idx)
        return idx, True


# --------------------------------------------------------------------------
# Bulk store (vectorized frontier engine)
# --------------------------------------------------------------------------


class SortedFingerprintStore:
    """Bulk membership: fingerprint sort + ``searchsorted`` (paper's hash
    table, restated for data-parallel hardware). Holds the growing known set
    as dense arrays; candidates arrive whole-tile at a time.
    """

    def __init__(self, stats: SFAStats, consts: BarrettConstants, n: int):
        self.stats = stats
        self.consts = consts
        self._pack_scratch: np.ndarray | None = None  # reused across tiles
        identity = np.arange(n, dtype=np.int32)[None]
        self.mappings = identity.copy()              # (S, n)
        self.fps = self._fp64(identity)              # (S,) uint64
        self.order = np.argsort(self.fps, kind="stable")

    def __len__(self) -> int:
        return int(self.mappings.shape[0])

    def _fp64(self, states: np.ndarray) -> np.ndarray:
        # Reuse one packed-word scratch buffer across tiles and collision
        # retries: packing is polynomial-independent, only the fold changes.
        self._pack_scratch = pack_states_np(states, out=self._pack_scratch)
        pair = fingerprint_words_np(self._pack_scratch, self.consts)
        return (pair[..., 0].astype(np.uint64) << np.uint64(32)) | pair[
            ..., 1
        ].astype(np.uint64)

    def assign(self, cand: np.ndarray) -> np.ndarray:
        """Map candidate rows (m, n) to SFA ids, appending unseen states in
        first-occurrence order. Raises :class:`FingerprintCollision` on any
        fp-equal-but-vector-unequal pair (vs the known set or intra-tile)."""
        n_cand = cand.shape[0]
        cfps = self._fp64(cand)

        # --- membership test against the known set -------------------------
        sorted_fps = self.fps[self.order]
        pos = np.searchsorted(sorted_fps, cfps)
        pos_c = np.minimum(pos, len(sorted_fps) - 1)
        fp_hit = sorted_fps[pos_c] == cfps
        self.stats.fp_compares += n_cand
        known_idx = np.where(fp_hit, self.order[pos_c], -1)

        hit_rows = np.flatnonzero(fp_hit)
        if hit_rows.size:
            self.stats.exact_compares += int(hit_rows.size)
            exact = np.all(
                cand[hit_rows] == self.mappings[known_idx[hit_rows]], axis=1
            )
            if not np.all(exact):
                self.stats.collisions_detected += int(np.sum(~exact))
                raise FingerprintCollision(
                    f"{int(np.sum(~exact))} fingerprint collisions detected"
                )

        ids = known_idx.copy()

        # --- dedup + append the genuinely new candidates -------------------
        new_rows = np.flatnonzero(known_idx < 0)
        if new_rows.size:
            new_fps = cfps[new_rows]
            uniq_fp, first_pos, inverse = np.unique(
                new_fps, return_index=True, return_inverse=True
            )
            # Exactness within the tile: all rows in an fp-group must equal
            # the group representative.
            reps = cand[new_rows[first_pos]]          # (U, n)
            same = np.all(cand[new_rows] == reps[inverse], axis=1)
            if not np.all(same):
                self.stats.collisions_detected += int(np.sum(~same))
                raise FingerprintCollision("intra-round fingerprint collision")
            # Renumber unique states by first occurrence (BFS order).
            occ_order = np.argsort(first_pos, kind="stable")
            rank_of_uniq = np.empty_like(occ_order)
            rank_of_uniq[occ_order] = np.arange(occ_order.size)
            base = self.mappings.shape[0]
            ids[new_rows] = base + rank_of_uniq[inverse]

            self.mappings = np.concatenate(
                [self.mappings, reps[occ_order]], axis=0
            )
            self.fps = np.concatenate([self.fps, uniq_fp[occ_order]])
            self.order = np.argsort(self.fps, kind="stable")
        return ids.astype(np.int32)

    def fingerprint_pairs(self) -> np.ndarray:
        out = np.empty((self.fps.shape[0], 2), dtype=np.uint32)
        out[:, 0] = (self.fps >> np.uint64(32)).astype(np.uint32)
        out[:, 1] = (self.fps & np.uint64(0xFFFFFFFF)).astype(np.uint32)
        return out
