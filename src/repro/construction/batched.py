"""Batched SFA construction: every pattern's frontier advances at once.

The paper's headline result is *construction* speed through task-level
parallelism: hundreds of PROSITE signatures, each an independent worklist
closure. This module expresses that task parallelism as a batch dimension.
``construct_bank`` pads ``P`` DFAs to a common state count (the
``PatternBank`` self-loop/identity padding story) and advances **all P
frontiers simultaneously** in one jitted bulk-synchronous round over stacked
``(P, capacity, n_max)`` state buffers:

  1. each pattern slices a ``tile`` of unprocessed frontier states;
  2. frontier × alphabet expands in one fused gather per pattern (vmapped);
  3. candidates are fingerprinted with *per-pattern* fold constants — a
     per-pattern word mask zeroes the padding tail, so the fingerprints (and
     therefore the whole discovery sequence) are bit-identical to the
     unpadded per-pattern engines;
  4. membership is the sort-merge of (known ∪ candidates) fingerprints, per
     pattern, batched by ``vmap`` — one XLA program for the whole bank;
  5. per-pattern ``done`` / ``blowup`` / ``collision`` flags come back each
     round. A collided pattern restarts alone with the next irreducible
     polynomial (per-pattern retry: the other patterns keep their progress);
     finished or blown patterns are *compacted out* of later rounds on the
     host (padded to a few bucket sizes so XLA compiles O(log P) shapes, not
     one per active-set size) — the paper's nonblocking construction: no
     pattern waits on a straggler's barrier.

``distribution="shard_map"`` shards the pattern axis of every buffer across
the devices of a mesh, one bank shard per device, with the same host loop
driving all shards — the multicore experiment of the paper at pod scale.

The single-pattern jitted engine (``construct_sfa_jax``, formerly
``core/sfa_jax.py``) is the ``P = 1`` special case of the same round.
"""

from __future__ import annotations

import functools
import math
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PSpec

from ..compat import make_mesh, shard_map as compat_shard_map
from ..core.dfa import DFA
from ..core.fingerprint import (
    BarrettConstants,
    clmul32,
    clmul64,
    fingerprint_states_np,
    fold_weights_u32,
    nth_poly_low,
    pack_states_u32,
)
from ..core.multipattern import PatternBank
from .types import (
    BankConstructionResult,
    BankStats,
    SFA,
    SFAStats,
    FingerprintCollision,
    StateBlowup,
)

_U32MAX = jnp.uint32(0xFFFFFFFF)


# --------------------------------------------------------------------------
# The jitted round (one pattern; vmapped over the bank axis)
# --------------------------------------------------------------------------


def _masked_fingerprint(states, weights, word_mask, limbs):
    """Fingerprint padded state vectors with per-pattern constants.

    ``word_mask`` zeroes the packed words of the identity padding tail, so
    the result equals the fingerprint of the *unpadded* vector — the bit
    that keeps batched construction bit-identical to the per-pattern
    engines. ``limbs`` are the Barrett constants as traced u32 scalars
    [p_hi, p_lo, mu_hi, mu_lo].
    """
    words = pack_states_u32(states) & word_mask[None, :]
    wh = weights[: words.shape[-1], 0]
    wl = weights[: words.shape[-1], 1]
    p_lo_h, p_lo_l = clmul32(words, wl)
    p_hi_h, p_hi_l = clmul32(words, wh)

    def xred(x):
        return jax.lax.reduce(
            x, jnp.zeros((), x.dtype), jax.lax.bitwise_xor, (x.ndim - 1,)
        )

    l0 = xred(p_lo_l)
    l1 = xred(p_lo_h ^ p_hi_l)
    l2 = xred(p_hi_h)
    t1pre = (jnp.zeros_like(l2), l2)
    m3, m2, _, _ = clmul64(t1pre, (limbs[2], limbs[3]))  # × mu
    t2pre = (t1pre[0] ^ m3, t1pre[1] ^ m2)
    _, _, q1, q0 = clmul64(t2pre, (limbs[0], limbs[1]))  # × p
    return jnp.stack([l1 ^ q1, l0 ^ q0], axis=-1)


def _pattern_round(
    table,            # (n, k) int32 — padded transition table
    states_buf,       # (C, n) int32
    fp_hi, fp_lo,     # (C,) uint32
    delta_buf,        # (C, k) int32
    n_states,         # () int32
    frontier_lo,      # () int32
    active,           # () bool — this pattern still advancing
    weights,          # (W, 2) uint32 per-pattern fold constants
    limbs,            # (4,) uint32 per-pattern Barrett constants
    word_mask,        # (W,) uint32 padding mask
    *, tile: int, n: int, k: int, capacity: int,
):
    """One bulk-synchronous frontier round for one (padded) pattern."""
    # ---- 1/2: slice frontier tile, fused expansion -------------------------
    ft = jax.lax.dynamic_slice(states_buf, (frontier_lo, 0), (tile, n))
    row_ids = frontier_lo + jnp.arange(tile, dtype=jnp.int32)
    row_valid = (row_ids < n_states) & active            # (T,)
    # next[f, a, q] = δ(f[q], a): one gather, symbol axis materialized.
    cand = table[ft]                                     # (T, n, k)
    cand = jnp.swapaxes(cand, 1, 2).reshape(tile * k, n)  # row-major (f, a)
    cand_valid = jnp.repeat(row_valid, k)                # (T·k,)

    # ---- 3: fingerprint all candidates (per-pattern constants) --------------
    fp = _masked_fingerprint(cand, weights, word_mask, limbs)
    c_hi, c_lo = fp[:, 0], fp[:, 1]

    # ---- 4: sort-merge membership -------------------------------------------
    C = capacity
    total = C + tile * k
    known_valid = jnp.arange(C, dtype=jnp.int32) < n_states
    inval = jnp.concatenate([(~known_valid), (~cand_valid)]).astype(jnp.uint32)
    hi = jnp.concatenate([fp_hi, c_hi])
    lo = jnp.concatenate([fp_lo, c_lo])
    is_cand = jnp.concatenate(
        [jnp.zeros(C, jnp.uint32), jnp.ones(tile * k, jnp.uint32)]
    )
    payload = jnp.concatenate(
        [jnp.arange(C, dtype=jnp.int32), jnp.arange(tile * k, dtype=jnp.int32)]
    )
    # Sort by (validity, fp_hi, fp_lo, known<cand, original index).
    tie = payload.astype(jnp.uint32)
    s_inval, s_hi, s_lo, s_isc, s_tie, s_pay = jax.lax.sort(
        (inval, hi, lo, is_cand, tie, payload), num_keys=5
    )

    run_start = jnp.concatenate(
        [jnp.ones(1, bool),
         (s_hi[1:] != s_hi[:-1]) | (s_lo[1:] != s_lo[:-1])
         | (s_inval[1:] != s_inval[:-1])]
    )
    pos = jnp.arange(total, dtype=jnp.int32)
    head_pos = jax.lax.cummax(jnp.where(run_start, pos, -1), axis=0)
    head_pay = s_pay[head_pos]
    head_is_known = s_isc[head_pos] == 0

    # New-state heads: candidate-headed runs that are valid.
    s_valid = s_inval == 0
    is_new_head = run_start & (s_isc == 1) & s_valid
    # Rank new heads by original candidate index -> BFS discovery order.
    rank_key = jnp.where(is_new_head, s_pay, jnp.int32(2**31 - 1))
    order = jnp.argsort(rank_key)
    ranks = jnp.zeros(total, jnp.int32).at[order].set(
        jnp.arange(total, dtype=jnp.int32)
    )
    new_id_at_pos = n_states + ranks                     # valid where is_new_head

    # id of each sorted position = head's id.
    head_new_id = new_id_at_pos[head_pos]
    id_sorted = jnp.where(head_is_known, head_pay, head_new_id)

    # ---- 5: exactness check (candidates vs run-head vectors) ----------------
    cand_rows = s_isc == 1
    ref_known = states_buf[jnp.clip(head_pay, 0, C - 1)]
    ref_cand = cand[jnp.clip(head_pay, 0, tile * k - 1)]
    ref_vec = jnp.where(head_is_known[:, None], ref_known, ref_cand)
    own_vec = cand[jnp.clip(s_pay, 0, tile * k - 1)]
    mismatch = jnp.any(ref_vec != own_vec, axis=1) & cand_rows & s_valid
    collision = jnp.any(mismatch)

    # ---- append new states ---------------------------------------------------
    num_new = jnp.sum(is_new_head.astype(jnp.int32))
    tgt = jnp.where(is_new_head, new_id_at_pos, C)       # C = out-of-range drop
    src_vec = cand[jnp.clip(s_pay, 0, tile * k - 1)]
    states_buf = states_buf.at[tgt].set(src_vec, mode="drop")
    fp_hi = fp_hi.at[tgt].set(s_hi, mode="drop")
    fp_lo = fp_lo.at[tgt].set(s_lo, mode="drop")

    # ---- write δ_s rows for the tile -----------------------------------------
    # Candidate (f, a) order is row-major, so candidate ids scattered back to
    # original order reshape straight into delta rows.
    ids_orig = jnp.zeros(tile * k, jnp.int32).at[
        jnp.where(cand_rows, s_pay, tile * k)
    ].set(id_sorted, mode="drop")
    delta_rows = ids_orig.reshape(tile, k)
    delta_buf = jax.lax.dynamic_update_slice(
        delta_buf, delta_rows, (frontier_lo, 0)
    )

    processed = jnp.where(
        active, jnp.minimum(n_states - frontier_lo, tile), 0
    )
    return (
        states_buf, fp_hi, fp_lo, delta_buf,
        n_states + num_new, frontier_lo + processed, collision,
    )


@functools.partial(jax.jit, static_argnames=("tile", "n", "k", "capacity"))
def _bank_round(tables, states, fp_hi, fp_lo, delta, n_states, frontier,
                active, weights, limbs, word_mask,
                *, tile: int, n: int, k: int, capacity: int):
    """All patterns advance one tile: vmap of :func:`_pattern_round`."""
    step = functools.partial(
        _pattern_round, tile=tile, n=n, k=k, capacity=capacity
    )
    return jax.vmap(step)(tables, states, fp_hi, fp_lo, delta, n_states,
                          frontier, active, weights, limbs, word_mask)


@functools.lru_cache(maxsize=None)
def _sharded_bank_round(mesh, pattern_axis: str, tile: int, n: int, k: int,
                        capacity: int):
    """shard_map wrapper of the vmapped round: every buffer's pattern axis
    shards over ``pattern_axis``; each device closes its bank shard."""

    def local(*args):
        step = functools.partial(
            _pattern_round, tile=tile, n=n, k=k, capacity=capacity
        )
        return jax.vmap(step)(*args)

    @jax.jit
    def rounds(*args):
        fn = compat_shard_map(
            local,
            mesh=mesh,
            in_specs=tuple(PSpec(pattern_axis) for _ in range(11)),
            out_specs=tuple(PSpec(pattern_axis) for _ in range(7)),
            check_vma=False,
        )
        return fn(*args)

    return rounds


# --------------------------------------------------------------------------
# Host-side bank driver
# --------------------------------------------------------------------------


def _word_mask(n_true: int, n_pad: int) -> np.ndarray:
    """Packed-word mask selecting the unpadded prefix of a padded vector."""
    W = (n_pad + 1) // 2
    m = np.zeros(W, dtype=np.uint32)
    m[: n_true // 2] = np.uint32(0xFFFFFFFF)
    if n_true % 2:
        m[n_true // 2] = np.uint32(0x0000FFFF)
    return m


def _state_cap(n: int, max_states: int) -> int:
    """min(max_states, n^n): the SFA can never exceed n^n mappings, so small
    automata get small buffers even under a huge budget."""
    if n <= 1:
        return 1
    if n * math.log2(n) <= 40:
        return min(max_states, n ** n)
    return max_states


def _bucket_sizes(P: int, quantum: int) -> list:
    """Active-set padding buckets: halving from P, rounded up to multiples of
    ``quantum`` (the mesh's pattern-axis size) — O(log P) compiled shapes."""

    def up(x):
        return max(quantum, ((x + quantum - 1) // quantum) * quantum)

    sizes, b = [], up(P)
    while True:
        sizes.append(b)
        if b == up(1):
            break
        b = up((b + 1) // 2)
    return sorted(set(sizes))


def _default_weight_fn(pattern: int, attempt: int, n_words: int,
                       consts: BarrettConstants) -> np.ndarray:
    return np.asarray(fold_weights_u32(n_words, consts))


def _limbs_of(consts: BarrettConstants) -> np.ndarray:
    return np.asarray(
        [
            (consts.poly_low >> 32) & 0xFFFFFFFF,
            consts.poly_low & 0xFFFFFFFF,
            (consts.mu_low >> 32) & 0xFFFFFFFF,
            consts.mu_low & 0xFFFFFFFF,
        ],
        dtype=np.uint32,
    )


def construct_bank(
    dfas: Sequence[DFA] | PatternBank,
    *,
    max_states: int = 200_000,
    tile: int = 128,
    max_retries: int = 4,
    poly_index: int = 0,
    method: str = "batched",
    engine: str = "vectorized",
    distribution: str = "local",
    mesh=None,
    pattern_axis: str = "pattern",
    on_blowup: str = "skip",
    _weight_fn=None,
) -> BankConstructionResult:
    """Construct the exact SFA of every pattern in one batched closure.

    ``method="batched"`` runs the jitted bulk-synchronous bank rounds above;
    ``method="loop"`` is the sequential-loop baseline (per-pattern
    :func:`~repro.construction.construct_sfa` with ``engine=``), kept for
    benchmarking and as the cheap path when only one pattern misses the
    cache. Both return bit-identical SFAs.

    ``on_blowup``: ``"skip"`` marks patterns whose closure exceeds
    ``max_states`` in ``result.blown`` (their slot in ``sfas`` is ``None``);
    ``"raise"`` raises :class:`StateBlowup` instead.

    ``distribution="shard_map"`` (batched method only) shards the pattern
    axis of every buffer over ``mesh`` (default: a fresh 1-axis mesh over
    all devices named ``pattern_axis``).

    ``_weight_fn(pattern, attempt, n_words, consts)`` is a test seam: it
    supplies the fingerprint fold constants and lets tests force a
    fingerprint collision for one pattern's first attempt.
    """
    if isinstance(dfas, PatternBank):
        dfas = [dfas.dfa(p) for p in range(dfas.n_patterns)]
    dfas = list(dfas)
    if not dfas:
        raise ValueError("empty pattern bank")
    if method not in ("batched", "loop"):
        raise ValueError(f"method must be 'batched' or 'loop', got {method!r}")

    if method == "loop":
        result = _construct_loop(
            dfas, max_states=max_states, max_retries=max_retries,
            engine=engine, poly_index=poly_index,
        )
    else:
        result = _construct_batched(
            dfas, max_states=max_states, tile=tile, max_retries=max_retries,
            poly_index=poly_index, distribution=distribution, mesh=mesh,
            pattern_axis=pattern_axis,
            weight_fn=_weight_fn or _default_weight_fn,
        )
    if on_blowup == "raise":
        result.require_all()
    return result


def _construct_loop(dfas, *, max_states, max_retries, engine, poly_index=0):
    from .single import construct_sfa

    t0 = time.perf_counter()
    P = len(dfas)
    stats = BankStats(
        method="loop",
        pattern_rounds=np.zeros(P, np.int64),
        retries=np.zeros(P, np.int64),
    )
    sfas: list = [None] * P
    blown = np.zeros(P, dtype=bool)
    for p, d in enumerate(dfas):
        try:
            sfa = construct_sfa(
                d, engine=engine, max_states=max_states,
                max_retries=max_retries, poly_index=poly_index,
            )
        except StateBlowup:
            blown[p] = True
            continue
        sfas[p] = sfa
        stats.rounds += sfa.stats.rounds
        stats.pattern_rounds[p] = sfa.stats.rounds
        stats.candidates += sfa.stats.candidates
    stats.wall_time_s = time.perf_counter() - t0
    return BankConstructionResult(sfas=sfas, blown=blown, stats=stats)


def _construct_batched(dfas, *, max_states, tile, max_retries, poly_index,
                       distribution, mesh, pattern_axis, weight_fn):
    t0 = time.perf_counter()
    bank = PatternBank.from_dfas(dfas)  # validates the shared alphabet
    P, n, k = bank.n_patterns, bank.n_max, bank.n_symbols
    if n >= 1 << 16:
        raise ValueError("batched engine packs 16-bit state ids")
    W = (n + 1) // 2
    # Buffers grow geometrically toward the full cap rather than starting
    # there: a 200k-state budget must not mean 200k-row sorts for a bank
    # that closes in a few hundred states. The growth guard below keeps
    # ``capacity >= n_states + tile·k`` for every runnable pattern, so a
    # round can never drop an append of a pattern that still fits the cap.
    full_cap = _state_cap(n, max_states) + tile
    capacity = min(full_cap, max(1024, 2 * (tile * k + tile)))

    if distribution == "shard_map":
        if mesh is None:
            mesh = make_mesh((jax.device_count(),), (pattern_axis,))
        quantum = int(np.prod(list(mesh.shape.values())))
    elif distribution == "local":
        quantum = 1
    else:
        raise ValueError(
            f"distribution must be 'local' or 'shard_map', got {distribution!r}"
        )

    def make_round_fn():
        if distribution == "shard_map":
            return _sharded_bank_round(mesh, pattern_axis, tile, n, k, capacity)
        return functools.partial(
            _bank_round, tile=tile, n=n, k=k, capacity=capacity
        )

    round_fn = make_round_fn()
    buckets = _bucket_sizes(P, quantum)

    stats = BankStats(
        method="batched",
        pattern_rounds=np.zeros(P, np.int64),
        retries=np.zeros(P, np.int64),
    )

    # -- per-pattern fingerprint constants + initial buffers ------------------
    n_true = bank.n_states.astype(np.int64)
    attempts = np.zeros(P, dtype=np.int64)

    def consts_of(p):
        return BarrettConstants.cached(
            nth_poly_low(poly_index + int(attempts[p]))
        )

    weights_np = np.empty((P, W, 2), dtype=np.uint32)
    limbs_np = np.empty((P, 4), dtype=np.uint32)
    masks_np = np.empty((P, W), dtype=np.uint32)
    fp0_np = np.empty((P, 2), dtype=np.uint32)
    for p in range(P):
        c = consts_of(p)
        weights_np[p] = weight_fn(p, 0, W, c)
        limbs_np[p] = _limbs_of(c)
        masks_np[p] = _word_mask(int(n_true[p]), n)
        fp0_np[p] = fingerprint_states_np(
            np.arange(int(n_true[p]), dtype=np.int32)[None], c
        )[0]

    identity = np.arange(n, dtype=np.int32)
    states = jnp.zeros((P, capacity, n), jnp.int32).at[:, 0].set(identity)
    fp_hi = jnp.full((P, capacity), _U32MAX, jnp.uint32).at[:, 0].set(
        jnp.asarray(fp0_np[:, 0])
    )
    fp_lo = jnp.full((P, capacity), _U32MAX, jnp.uint32).at[:, 0].set(
        jnp.asarray(fp0_np[:, 1])
    )
    delta = jnp.zeros((P, capacity, k), jnp.int32)
    n_states = jnp.ones(P, jnp.int32)
    frontier = jnp.zeros(P, jnp.int32)
    weights = jnp.asarray(weights_np)
    limbs = jnp.asarray(limbs_np)
    masks = jnp.asarray(masks_np)
    tables = jnp.asarray(bank.tables)

    n_states_h = np.ones(P, dtype=np.int64)
    frontier_h = np.zeros(P, dtype=np.int64)
    blown = np.zeros(P, dtype=bool)
    cand_h = np.zeros(P, dtype=np.int64)

    # -- the nonblocking host loop -------------------------------------------
    while True:
        runnable = (~blown) & (frontier_h < n_states_h)
        act = np.flatnonzero(runnable)
        if act.size == 0:
            break
        worst = int(n_states_h[act].max()) + tile * k
        if worst > capacity and capacity < full_cap:
            grown = min(full_cap, max(capacity * 4, worst))
            pad = grown - capacity
            states = jnp.pad(states, ((0, 0), (0, pad), (0, 0)))
            fp_hi = jnp.pad(fp_hi, ((0, 0), (0, pad)),
                            constant_values=np.uint32(0xFFFFFFFF))
            fp_lo = jnp.pad(fp_lo, ((0, 0), (0, pad)),
                            constant_values=np.uint32(0xFFFFFFFF))
            delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
            capacity = grown
            round_fn = make_round_fn()
        bucket = next(b for b in buckets if b >= act.size)
        idx = np.concatenate(
            [act, np.full(bucket - act.size, act[0], dtype=act.dtype)]
        )
        act_mask = np.zeros(bucket, dtype=bool)
        act_mask[: act.size] = True
        jidx = jnp.asarray(idx)

        cand_h[act] += np.minimum(n_states_h[act] - frontier_h[act], tile) * k
        stats.candidates += int(
            np.sum(np.minimum(n_states_h[act] - frontier_h[act], tile)) * k
        )
        out = round_fn(
            tables[jidx], states[jidx], fp_hi[jidx], fp_lo[jidx],
            delta[jidx], n_states[jidx], frontier[jidx],
            jnp.asarray(act_mask), weights[jidx], limbs[jidx], masks[jidx],
        )
        o_states, o_fp_hi, o_fp_lo, o_delta, o_n, o_frontier, o_coll = out
        live = jnp.asarray(act)
        states = states.at[live].set(o_states[: act.size])
        fp_hi = fp_hi.at[live].set(o_fp_hi[: act.size])
        fp_lo = fp_lo.at[live].set(o_fp_lo[: act.size])
        delta = delta.at[live].set(o_delta[: act.size])
        n_states = n_states.at[live].set(o_n[: act.size])
        frontier = frontier.at[live].set(o_frontier[: act.size])

        stats.rounds += 1
        stats.pattern_rounds[act] += 1
        n_states_h[act] = np.asarray(o_n[: act.size], dtype=np.int64)
        frontier_h[act] = np.asarray(o_frontier[: act.size], dtype=np.int64)
        collided = act[np.asarray(o_coll[: act.size])]

        # Per-pattern polynomial retry: only the collided pattern restarts.
        for p in collided:
            attempts[p] += 1
            stats.retries[p] += 1
            if attempts[p] >= max_retries:
                raise FingerprintCollision(
                    f"pattern {p}: {max_retries} polynomials all collided"
                )
            c = consts_of(p)
            weights_np[p] = weight_fn(int(p), int(attempts[p]), W, c)
            limbs_np[p] = _limbs_of(c)
            fp0 = fingerprint_states_np(
                np.arange(int(n_true[p]), dtype=np.int32)[None], c
            )[0]
            weights = weights.at[p].set(jnp.asarray(weights_np[p]))
            limbs = limbs.at[p].set(jnp.asarray(limbs_np[p]))
            fp_hi = fp_hi.at[p, 0].set(jnp.uint32(fp0[0]))
            fp_lo = fp_lo.at[p, 0].set(jnp.uint32(fp0[1]))
            n_states = n_states.at[p].set(1)
            frontier = frontier.at[p].set(0)
            n_states_h[p] = 1
            frontier_h[p] = 0

        blown |= n_states_h > max_states

    # -- crop per-pattern results ---------------------------------------------
    stats.wall_time_s = time.perf_counter() - t0
    states_np = np.asarray(states)
    delta_np = np.asarray(delta)
    fp_hi_np = np.asarray(fp_hi)
    fp_lo_np = np.asarray(fp_lo)
    sfas: list = [None] * P
    for p in range(P):
        if blown[p]:
            continue
        S = int(n_states_h[p])
        pstats = SFAStats(
            engine="batched",
            rounds=int(stats.pattern_rounds[p]),
            candidates=int(cand_h[p]),
            wall_time_s=stats.wall_time_s,
        )
        fps = np.stack([fp_hi_np[p, :S], fp_lo_np[p, :S]], axis=1).astype(
            np.uint32
        )
        sfas[p] = SFA(
            mappings=np.ascontiguousarray(states_np[p, :S, : int(n_true[p])]),
            delta=np.ascontiguousarray(delta_np[p, :S]),
            fingerprints=fps,
            dfa=dfas[p],
            stats=pstats,
        )
    return BankConstructionResult(sfas=sfas, blown=blown, stats=stats)


# --------------------------------------------------------------------------
# The single-pattern jitted engine (P = 1 special case)
# --------------------------------------------------------------------------


def construct_sfa_jax(
    dfa: DFA,
    *,
    poly_index: int = 0,
    max_states: int = 200_000,
    tile: int = 256,
) -> SFA:
    """The jitted TPU-shaped engine — now literally the bank construction
    with one pattern. Raises :class:`FingerprintCollision` on a detected
    collision (the :func:`~repro.construction.construct_sfa` wrapper
    retries with the next polynomial)."""
    result = construct_bank(
        [dfa], max_states=max_states, tile=tile, poly_index=poly_index,
        max_retries=1, method="batched", on_blowup="raise",
    )
    sfa = result.sfas[0]
    sfa.stats.engine = "jax"
    return sfa
