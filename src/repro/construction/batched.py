"""Batched SFA construction: every pattern's frontier advances at once.

The paper's headline result is *construction* speed through task-level
parallelism: hundreds of PROSITE signatures, each an independent worklist
closure. This module expresses that task parallelism as a batch dimension.
``construct_bank`` pads ``P`` DFAs to a common state count (the
``PatternBank`` self-loop/identity padding story) and advances **all P
frontiers simultaneously** in one compiled bulk-synchronous round over
stacked ``(P, capacity, n_max)`` state buffers:

  1. each pattern slices a ``tile`` of unprocessed frontier states;
  2. frontier × alphabet expands in one fused gather per pattern (vmapped);
  3. candidates are fingerprinted with *per-pattern* fold constants — a
     per-pattern word mask zeroes the padding tail, so the fingerprints (and
     therefore the whole discovery sequence) are bit-identical to the
     unpadded per-pattern engines. The fingerprint stage is plan-selectable:
     the fused-XLA clmul fold, or the ``kernels.clmul`` Pallas bank kernel
     (bit-identical; the natural pick on a TPU runtime);
  4. membership is the sort-merge of (known ∪ candidates) fingerprints, per
     pattern, batched by ``vmap`` — one XLA program for the whole bank;
  5. per-pattern ``done`` / ``blowup`` / ``collision`` flags come back each
     round. Collided patterns restart alone with the next irreducible
     polynomial (per-pattern retry, applied as one batched scatter: the
     other patterns keep their progress); finished or blown patterns are
     *compacted out* of later rounds on the host — the paper's nonblocking
     construction: no pattern waits on a straggler's barrier.

**Every shape a construction can visit is known before the first round.**
:func:`round_schedule` precomputes the capacity tiers (geometric growth
toward the ``n^n``/budget cap) and active-set buckets (geometric shrink from
``P``) from ``(tile, n, k, max_states, P, quantum)`` alone; the host loop
only ever selects shapes from that schedule. Each selected shape's round —
the *whole* round: pattern gather, frontier expansion, fingerprints,
sort-merge, scatter-back — is one AOT-compiled executable cached in the
process-wide :func:`~repro.construction.cache.round_compile_cache`, so a
repeat ``construct_bank`` of a previously-seen shape performs **zero new
traces and zero new XLA compiles** (asserted by the compile-count
regression tests).

``distribution="shard_map"`` shards the pattern axis of every buffer across
the devices of a mesh, one bank shard per device, with the same host loop
driving all shards — the multicore experiment of the paper at pod scale.

The single-pattern jitted engine (``construct_sfa_jax``, formerly
``core/sfa_jax.py``) is the ``P = 1`` special case of the same round.
"""

from __future__ import annotations

import functools
import math
import time
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as PSpec

from .. import obs
from ..compat import make_mesh, shard_map as compat_shard_map
from ..core.dfa import DFA
from ..core.fingerprint import (
    BarrettConstants,
    clmul32,
    clmul64,
    fingerprint_states_np,
    fold_weights_u32,
    nth_poly_low,
    pack_states_u32,
)
from ..core.multipattern import PatternBank
from .cache import round_compile_cache
from .types import (
    BankConstructionResult,
    BankStats,
    BucketStats,
    SFA,
    SFAStats,
    FingerprintCollision,
    StateBlowup,
)

_U32MAX = jnp.uint32(0xFFFFFFFF)

# /metrics HELP descriptions, registered once; callsites publish by name.
obs.counter("construction.banks", help="construct_bank calls completed")
obs.counter("construction.patterns", help="patterns constructed in banks")
obs.counter("construction.rounds", help="batched construction rounds run")
obs.counter("construction.retries",
            help="per-pattern fingerprint-collision retries")
obs.counter("construction.blown",
            help="patterns abandoned to the state-budget blowup verdict")
obs.histogram("construction.bank_wall_s",
              help="construct_bank wall seconds per bank")
obs.histogram("construction.round_wall_s",
              help="wall seconds per batched construction round")

#: Fingerprint-stage backends of the batched round. ``"auto"`` resolves to
#: ``"pallas"`` on a real TPU runtime and ``"xla"`` elsewhere (interpret-mode
#: Pallas would dominate a CPU round).
FINGERPRINT_BACKENDS = ("auto", "xla", "pallas")

#: Expansion-stage backends of the batched round: ``"xla"`` is the fused
#: ``jnp.take`` gather, ``"pallas"`` the one-hot MXU gather kernel
#: (``kernels.ops.expand_frontier_bank``, bit-identical), ``"auto"`` picks
#: pallas on a TPU runtime and xla elsewhere.
EXPAND_BACKENDS = ("auto", "xla", "pallas")

#: Size-bucketing modes of ``construct_bank``. ``"size"`` always partitions
#: the bank by DFA state count, ``"off"`` never does, ``"auto"`` partitions
#: when the bank is big and skewed enough for bucketing to pay (at least
#: ``_BUCKET_AUTO_MIN_P`` patterns spreading over >= 2 merged buckets).
BUCKETINGS = ("auto", "size", "off")

#: Buckets smaller than this merge into a neighbor: a compiled round shape
#: has to amortize over enough patterns to beat riding along padded.
_BUCKET_MIN_PATTERNS = 4

#: ``bucketing="auto"`` leaves banks smaller than this unbucketed — the
#: round dispatch overhead of extra sub-banks outweighs padding savings.
_BUCKET_AUTO_MIN_P = 8

#: Capacity tiers grow by this factor between schedule entries. Fixed (not a
#: knob): fewer, coarser tiers mean fewer compiled shapes, and results are
#: capacity-invariant anyway (pinned by the capacity-growth bit-exactness
#: test).
CAPACITY_GROWTH = 4


# --------------------------------------------------------------------------
# The round, in stages (each stage batched over the pattern axis)
# --------------------------------------------------------------------------


def _fold_words(words, weights, limbs):
    """Fold + Barrett-reduce packed (B, W) words with one pattern's
    constants -> (B, 2) uint32 [hi, lo]. The reference fingerprint stage
    (fused XLA clmul); ``kernels.clmul.fingerprint_bank_pallas`` computes
    the identical function as a Pallas kernel."""
    wh = weights[: words.shape[-1], 0]
    wl = weights[: words.shape[-1], 1]
    p_lo_h, p_lo_l = clmul32(words, wl)
    p_hi_h, p_hi_l = clmul32(words, wh)

    def xred(x):
        return jax.lax.reduce(
            x, jnp.zeros((), x.dtype), jax.lax.bitwise_xor, (x.ndim - 1,)
        )

    l0 = xred(p_lo_l)
    l1 = xred(p_lo_h ^ p_hi_l)
    l2 = xred(p_hi_h)
    t1pre = (jnp.zeros_like(l2), l2)
    m3, m2, _, _ = clmul64(t1pre, (limbs[2], limbs[3]))  # × mu
    t2pre = (t1pre[0] ^ m3, t1pre[1] ^ m2)
    _, _, q1, q0 = clmul64(t2pre, (limbs[0], limbs[1]))  # × p
    return jnp.stack([l1 ^ q1, l0 ^ q0], axis=-1)


def _masked_fingerprint(states, weights, word_mask, limbs):
    """Fingerprint padded state vectors with per-pattern constants.

    ``word_mask`` zeroes the packed words of the identity padding tail, so
    the result equals the fingerprint of the *unpadded* vector — the bit
    that keeps batched construction bit-identical to the per-pattern
    engines. ``limbs`` are the Barrett constants as traced u32 scalars
    [p_hi, p_lo, mu_hi, mu_lo].
    """
    words = pack_states_u32(states) & word_mask[None, :]
    return _fold_words(words, weights, limbs)


def _frontier_tile(
    states_buf,       # (C, n) int32
    n_states,         # () int32
    frontier_lo,      # () int32
    active,           # () bool — this pattern still advancing
    *, tile: int, n: int,
):
    """Stage 1: slice the frontier tile and its row-validity mask.
    -> (ft (T, n), row_valid (T,))."""
    ft = jax.lax.dynamic_slice(states_buf, (frontier_lo, 0), (tile, n))
    row_ids = frontier_lo + jnp.arange(tile, dtype=jnp.int32)
    row_valid = (row_ids < n_states) & active            # (T,)
    return ft, row_valid


def _gather_expand(table, ft, *, tile: int, n: int, k: int):
    """Stage 2, XLA backend: expand frontier × alphabet in one fused gather.
    ``next[f, a, q] = δ(f[q], a)``, symbol axis materialized, row-major
    (frontier, symbol) candidate order. -> (T·k, n)."""
    cand = jnp.take(table, ft, axis=0)                   # (T, n, k)
    return jnp.swapaxes(cand, 1, 2).reshape(tile * k, n)


def _merge(
    states_buf,       # (C, n) int32
    fp_hi, fp_lo,     # (C,) uint32
    delta_buf,        # (C, k) int32
    n_states,         # () int32
    frontier_lo,      # () int32
    active,           # () bool
    cand,             # (T·k, n) int32
    cand_valid,       # (T·k,) bool
    c_hi, c_lo,       # (T·k,) uint32 candidate fingerprints
    *, tile: int, n: int, k: int, capacity: int,
):
    """Stages 4/5: sort-merge membership, exactness check, state append and
    δ_s rows — one pattern; vmapped over the bank axis."""
    C = capacity
    total = C + tile * k
    known_valid = jnp.arange(C, dtype=jnp.int32) < n_states
    inval = jnp.concatenate([(~known_valid), (~cand_valid)]).astype(jnp.uint32)
    hi = jnp.concatenate([fp_hi, c_hi])
    lo = jnp.concatenate([fp_lo, c_lo])
    is_cand = jnp.concatenate(
        [jnp.zeros(C, jnp.uint32), jnp.ones(tile * k, jnp.uint32)]
    )
    payload = jnp.concatenate(
        [jnp.arange(C, dtype=jnp.int32), jnp.arange(tile * k, dtype=jnp.int32)]
    )
    # Sort by (validity, fp_hi, fp_lo, known<cand, original index).
    tie = payload.astype(jnp.uint32)
    s_inval, s_hi, s_lo, s_isc, s_tie, s_pay = jax.lax.sort(
        (inval, hi, lo, is_cand, tie, payload), num_keys=5
    )

    run_start = jnp.concatenate(
        [jnp.ones(1, bool),
         (s_hi[1:] != s_hi[:-1]) | (s_lo[1:] != s_lo[:-1])
         | (s_inval[1:] != s_inval[:-1])]
    )
    pos = jnp.arange(total, dtype=jnp.int32)
    head_pos = jax.lax.cummax(jnp.where(run_start, pos, -1), axis=0)
    head_pay = s_pay[head_pos]
    head_is_known = s_isc[head_pos] == 0

    # New-state heads: candidate-headed runs that are valid.
    s_valid = s_inval == 0
    is_new_head = run_start & (s_isc == 1) & s_valid
    # Rank new heads by original candidate index -> BFS discovery order.
    rank_key = jnp.where(is_new_head, s_pay, jnp.int32(2**31 - 1))
    order = jnp.argsort(rank_key)
    ranks = jnp.zeros(total, jnp.int32).at[order].set(
        jnp.arange(total, dtype=jnp.int32)
    )
    new_id_at_pos = n_states + ranks                     # valid where is_new_head

    # id of each sorted position = head's id.
    head_new_id = new_id_at_pos[head_pos]
    id_sorted = jnp.where(head_is_known, head_pay, head_new_id)

    # Exactness check (candidates vs run-head vectors).
    cand_rows = s_isc == 1
    ref_known = states_buf[jnp.clip(head_pay, 0, C - 1)]
    ref_cand = cand[jnp.clip(head_pay, 0, tile * k - 1)]
    ref_vec = jnp.where(head_is_known[:, None], ref_known, ref_cand)
    own_vec = cand[jnp.clip(s_pay, 0, tile * k - 1)]
    mismatch = jnp.any(ref_vec != own_vec, axis=1) & cand_rows & s_valid
    collision = jnp.any(mismatch)

    # Append new states.
    num_new = jnp.sum(is_new_head.astype(jnp.int32))
    tgt = jnp.where(is_new_head, new_id_at_pos, C)       # C = out-of-range drop
    src_vec = cand[jnp.clip(s_pay, 0, tile * k - 1)]
    states_buf = states_buf.at[tgt].set(src_vec, mode="drop")
    fp_hi = fp_hi.at[tgt].set(s_hi, mode="drop")
    fp_lo = fp_lo.at[tgt].set(s_lo, mode="drop")

    # Write δ_s rows for the tile: candidate (f, a) order is row-major, so
    # candidate ids scattered back to original order reshape into delta rows.
    ids_orig = jnp.zeros(tile * k, jnp.int32).at[
        jnp.where(cand_rows, s_pay, tile * k)
    ].set(id_sorted, mode="drop")
    delta_rows = ids_orig.reshape(tile, k)
    delta_buf = jax.lax.dynamic_update_slice(
        delta_buf, delta_rows, (frontier_lo, 0)
    )

    processed = jnp.where(
        active, jnp.minimum(n_states - frontier_lo, tile), 0
    )
    return (
        states_buf, fp_hi, fp_lo, delta_buf,
        n_states + num_new, frontier_lo + processed, collision,
    )


def _bucket_round(tables, states, fp_hi, fp_lo, delta, n_states, frontier,
                  active, weights, limbs, word_masks,
                  *, tile: int, n: int, k: int, capacity: int,
                  fp_backend: str, expand_backend: str, interpret: bool):
    """One bulk-synchronous round over a bucket of patterns: expand
    (selected backend), then fingerprint (selected backend), then
    sort-merge — stages 1–5 above, batched over the bucket axis."""
    tiler = functools.partial(_frontier_tile, tile=tile, n=n)
    ft, row_valid = jax.vmap(tiler)(states, n_states, frontier, active)
    if expand_backend == "pallas":
        from ..kernels import ops as kernel_ops

        cand = kernel_ops.expand_frontier_bank(
            tables, ft, interpret=interpret
        )                                                    # (B, T·k, n)
    else:
        gather = functools.partial(_gather_expand, tile=tile, n=n, k=k)
        cand = jax.vmap(gather)(tables, ft)
    cand_valid = jnp.repeat(row_valid, k, axis=1)            # (B, T·k)
    words = pack_states_u32(cand) & word_masks[:, None, :]   # (B, T·k, W)
    if fp_backend == "pallas":
        from ..kernels import ops as kernel_ops

        fp = kernel_ops.fingerprint_bank_stacked(
            words, weights, limbs, interpret=interpret
        )
    else:
        fp = jax.vmap(_fold_words)(words, weights, limbs)
    merge = functools.partial(_merge, tile=tile, n=n, k=k, capacity=capacity)
    return jax.vmap(merge)(
        states, fp_hi, fp_lo, delta, n_states, frontier, active,
        cand, cand_valid, fp[..., 0], fp[..., 1],
    )


# --------------------------------------------------------------------------
# Compiled round steps (AOT, cached process-wide)
# --------------------------------------------------------------------------


def _make_local_step(*, tile, n, k, capacity, P, bucket, fp_backend,
                     expand_backend, interpret):
    """The whole local round as ONE function of the full-size bank buffers:
    gather the active bucket, run the round, scatter the bucket back. AOT
    compiling *this* (rather than only the vmapped round) keeps the host
    loop free of per-round eager gather/scatter dispatches — those small ops
    were half the cold-start compile wall."""

    def step(tables, states, fp_hi, fp_lo, delta, n_states, frontier,
             weights, limbs, word_masks, idx, act):
        def take(a):
            return jnp.take(a, idx, axis=0)

        o_states, o_fp_hi, o_fp_lo, o_delta, o_n, o_frontier, o_coll = (
            _bucket_round(
                take(tables), take(states), take(fp_hi), take(fp_lo),
                take(delta), take(n_states), take(frontier), act,
                take(weights), take(limbs), take(word_masks),
                tile=tile, n=n, k=k, capacity=capacity,
                fp_backend=fp_backend, expand_backend=expand_backend,
                interpret=interpret,
            )
        )
        # ``idx`` pads the bucket tail with duplicates of its first entry;
        # route inactive rows out of range so the scatter targets are unique
        # and padding never writes.
        sidx = jnp.where(act, idx, jnp.int32(P))
        return (
            states.at[sidx].set(o_states, mode="drop"),
            fp_hi.at[sidx].set(o_fp_hi, mode="drop"),
            fp_lo.at[sidx].set(o_fp_lo, mode="drop"),
            delta.at[sidx].set(o_delta, mode="drop"),
            n_states.at[sidx].set(o_n, mode="drop"),
            frontier.at[sidx].set(o_frontier, mode="drop"),
            o_coll & act,
        )

    return step


def _local_step_exe(*, tile, n, k, capacity, P, bucket, fp_backend,
                    expand_backend, interpret):
    """AOT executable of the fused local step for one schedule shape,
    through the process-wide :func:`round_compile_cache`."""
    key = ("local-step", tile, n, k, capacity, P, bucket, fp_backend,
           expand_backend, interpret)

    def build():
        step = _make_local_step(
            tile=tile, n=n, k=k, capacity=capacity, P=P, bucket=bucket,
            fp_backend=fp_backend, expand_backend=expand_backend,
            interpret=interpret,
        )
        W = (n + 1) // 2
        s = jax.ShapeDtypeStruct
        i32, u32 = jnp.int32, jnp.uint32
        avals = (
            s((P, n, k), i32),            # tables
            s((P, capacity, n), i32),     # states
            s((P, capacity), u32),        # fp_hi
            s((P, capacity), u32),        # fp_lo
            s((P, capacity, k), i32),     # delta
            s((P,), i32),                 # n_states
            s((P,), i32),                 # frontier
            s((P, W, 2), u32),            # weights
            s((P, 4), u32),               # limbs
            s((P, W), u32),               # word masks
            s((bucket,), i32),            # idx
            s((bucket,), jnp.bool_),      # act
        )
        return jax.jit(step).lower(*avals).compile()

    return round_compile_cache().get(key, build)


def _sharded_round_exe(mesh, pattern_axis: str, *, tile, n, k, capacity,
                       fp_backend, expand_backend, interpret):
    """shard_map wrapper of the bucket round: every buffer's pattern axis
    shards over ``pattern_axis``; each device closes its bank shard. Cached
    as a jitted callable (jit's own cache keys the per-bucket shapes), so
    repeat constructions reuse both the wrapper and its compiled shapes."""
    key = ("shard-round", mesh, pattern_axis, tile, n, k, capacity,
           fp_backend, expand_backend, interpret)

    def build():
        def local(*args):
            return _bucket_round(
                *args, tile=tile, n=n, k=k, capacity=capacity,
                fp_backend=fp_backend, expand_backend=expand_backend,
                interpret=interpret,
            )

        @jax.jit
        def rounds(*args):
            fn = compat_shard_map(
                local,
                mesh=mesh,
                in_specs=tuple(PSpec(pattern_axis) for _ in range(11)),
                out_specs=tuple(PSpec(pattern_axis) for _ in range(7)),
                check_vma=False,
            )
            return fn(*args)

        return rounds

    return round_compile_cache().get(key, build)


# --------------------------------------------------------------------------
# The fixed shape schedule
# --------------------------------------------------------------------------


def _state_cap(n: int, max_states: int) -> int:
    """min(max_states, n^n): the SFA can never exceed n^n mappings, so small
    automata get small buffers even under a huge budget."""
    if n <= 1:
        return 1
    if n * math.log2(n) <= 40:
        return min(max_states, n ** n)
    return max_states


def _bucket_sizes(P: int, quantum: int, growth: int = 4) -> list:
    """Active-set padding buckets: shrinking by ``growth`` from P, rounded up
    to multiples of ``quantum`` (the mesh's pattern-axis size) — O(log P)
    compiled shapes."""

    def up(x):
        return max(quantum, ((x + quantum - 1) // quantum) * quantum)

    sizes, b = [], up(P)
    while True:
        sizes.append(b)
        if b == up(1):
            break
        b = up((b + growth - 1) // growth)
    return sorted(set(sizes))


@dataclass(frozen=True)
class RoundSchedule:
    """Every (capacity, bucket) round shape one bank construction may visit,
    precomputed from static quantities — no runtime value can produce a
    shape outside this set, which is what makes the AOT compile cache's
    "zero new traces on repeat" guarantee possible.

    ``capacities`` are the buffer-row tiers (ascending, last = full cap);
    ``buckets`` the active-set padding sizes (ascending, ``quantum``-rounded
    for the mesh's pattern axis).
    """

    tile: int
    n: int
    k: int
    P: int
    quantum: int
    capacities: tuple
    buckets: tuple

    def capacity_for(self, worst: int) -> int:
        """Smallest tier holding ``worst`` rows (or the full cap)."""
        for c in self.capacities:
            if c >= worst:
                return c
        return self.capacities[-1]

    def bucket_for(self, n_active: int) -> int:
        """Smallest bucket holding ``n_active`` patterns."""
        for b in self.buckets:
            if b >= n_active:
                return b
        return self.buckets[-1]

    @property
    def shapes(self) -> tuple:
        """The full (capacity, bucket) cross product — the upper bound on
        compiled round programs for this bank."""
        return tuple((c, b) for c in self.capacities for b in self.buckets)


def round_schedule(*, tile: int, n: int, k: int, max_states: int, P: int,
                   quantum: int = 1,
                   bucket_growth: int = 4) -> RoundSchedule:
    """Precompute the capacity/bucket schedule of a bank construction.

    Capacity starts small (a 200k-state budget must not mean 200k-row sorts
    for a bank that closes in a few hundred states) and grows by
    ``CAPACITY_GROWTH`` toward ``n^n``/budget; buckets shrink by
    ``bucket_growth`` from ``P``. The host loop's growth guard keeps
    ``capacity >= n_states + tile·k`` for every runnable pattern, so a round
    can never drop an append of a pattern that still fits the cap.
    """
    if bucket_growth < 2:
        raise ValueError(f"bucket_growth must be >= 2, got {bucket_growth}")
    full_cap = _state_cap(n, max_states) + tile
    caps = [min(full_cap, max(1024, 2 * (tile * k + tile)))]
    while caps[-1] < full_cap:
        caps.append(min(full_cap, caps[-1] * CAPACITY_GROWTH))
    return RoundSchedule(
        tile=tile, n=n, k=k, P=P, quantum=quantum,
        capacities=tuple(caps),
        buckets=tuple(_bucket_sizes(P, quantum, bucket_growth)),
    )


def _resolve_fp_backend(backend: str) -> str:
    if backend not in FINGERPRINT_BACKENDS:
        raise ValueError(
            f"fingerprint_backend must be one of {FINGERPRINT_BACKENDS}, "
            f"got {backend!r}"
        )
    if backend == "auto":
        from ..kernels import ops as kernel_ops

        return "xla" if kernel_ops._default_interpret() else "pallas"
    return backend


def _resolve_expand_backend(backend: str) -> str:
    if backend not in EXPAND_BACKENDS:
        raise ValueError(
            f"expand_backend must be one of {EXPAND_BACKENDS}, "
            f"got {backend!r}"
        )
    if backend == "auto":
        from ..kernels import ops as kernel_ops

        return "xla" if kernel_ops._default_interpret() else "pallas"
    return backend


# --------------------------------------------------------------------------
# Host-side bank driver
# --------------------------------------------------------------------------


def _word_mask(n_true: int, n_pad: int) -> np.ndarray:
    """Packed-word mask selecting the unpadded prefix of a padded vector."""
    W = (n_pad + 1) // 2
    m = np.zeros(W, dtype=np.uint32)
    m[: n_true // 2] = np.uint32(0xFFFFFFFF)
    if n_true % 2:
        m[n_true // 2] = np.uint32(0x0000FFFF)
    return m


def _default_weight_fn(pattern: int, attempt: int, n_words: int,
                       consts: BarrettConstants) -> np.ndarray:
    return np.asarray(fold_weights_u32(n_words, consts))


@functools.lru_cache(maxsize=8192)
def _seed_fingerprint(n_true: int, poly_low: int) -> tuple:
    """(hi, lo) fingerprint of the identity mapping over ``n_true`` states —
    the bank's seed row. Pure function of (size, polynomial), so it is
    cached: warm re-constructions and same-size patterns inside one bank
    skip the host-side Barrett fold entirely."""
    c = BarrettConstants.cached(poly_low)
    fp = fingerprint_states_np(np.arange(n_true, dtype=np.int32)[None], c)[0]
    return int(fp[0]), int(fp[1])


def _limbs_of(consts: BarrettConstants) -> np.ndarray:
    return np.asarray(
        [
            (consts.poly_low >> 32) & 0xFFFFFFFF,
            consts.poly_low & 0xFFFFFFFF,
            (consts.mu_low >> 32) & 0xFFFFFFFF,
            consts.mu_low & 0xFFFFFFFF,
        ],
        dtype=np.uint32,
    )


def construct_bank(
    dfas: Sequence[DFA] | PatternBank,
    *,
    max_states: int = 200_000,
    tile: int = 128,
    max_retries: int = 4,
    poly_index: int = 0,
    method: str = "batched",
    engine: str = "vectorized",
    distribution: str = "local",
    mesh=None,
    pattern_axis: str = "pattern",
    on_blowup: str = "skip",
    fingerprint_backend: str = "auto",
    expand_backend: str = "auto",
    bucketing: str = "auto",
    bucket_growth: int = 4,
    _weight_fn=None,
) -> BankConstructionResult:
    """Construct the exact SFA of every pattern in one batched closure.

    ``method="batched"`` runs the compiled bulk-synchronous bank rounds
    above; ``method="loop"`` is the sequential-loop baseline (per-pattern
    :func:`~repro.construction.construct_sfa` with ``engine=``), kept for
    benchmarking and as the cheap path when only one pattern misses the
    cache. Both return bit-identical SFAs.

    ``on_blowup``: ``"skip"`` marks patterns whose closure exceeds
    ``max_states`` in ``result.blown`` (their slot in ``sfas`` is ``None``);
    ``"raise"`` raises :class:`StateBlowup` instead.

    ``distribution="shard_map"`` (batched method only) shards the pattern
    axis of every buffer over ``mesh`` (default: a fresh 1-axis mesh over
    all devices named ``pattern_axis``).

    ``fingerprint_backend`` picks the round's fingerprint stage: ``"xla"``
    (fused clmul fold), ``"pallas"`` (the ``kernels.ops.fingerprint_bank``
    Rabin kernel — bit-identical), or ``"auto"`` (pallas on a TPU runtime,
    xla elsewhere). ``expand_backend`` picks the frontier-expansion stage
    the same way (``"xla"`` = fused ``jnp.take`` gather, ``"pallas"`` = the
    ``kernels.ops.expand_frontier_bank`` one-hot MXU gather, bit-identical).
    ``bucket_growth`` sets the active-set bucket shrink factor of the shape
    schedule (see :func:`round_schedule`): larger means fewer compiled
    shapes, at the cost of more padding in mid-size rounds.

    ``bucketing`` controls *size*-bucketed construction: one padded bank
    charges every pattern ``n_max``-wide frontier rows, fingerprint words,
    and full-capacity sort-merges, so size-skewed banks (the P=64 regime)
    pay mostly for padding. ``"size"`` partitions the bank by DFA state
    count into O(log n_max) sub-banks (``core.bucketing`` geometric edges,
    small buckets merged away), each constructed with bucket-local
    ``n_max``/capacity tiers through the same AOT round cache; ``"off"``
    keeps one bank; ``"auto"`` buckets only when the bank is big and skewed
    enough to pay (>= 2 merged buckets over >= 8 patterns). Results are
    bit-identical across all three — per-pattern word masks already make
    fingerprints padding-invariant.

    ``_weight_fn(pattern, attempt, n_words, consts)`` is a test seam: it
    supplies the fingerprint fold constants and lets tests force a
    fingerprint collision for one pattern's first attempt.
    """
    if isinstance(dfas, PatternBank):
        dfas = [dfas.dfa(p) for p in range(dfas.n_patterns)]
    dfas = list(dfas)
    if not dfas:
        raise ValueError("empty pattern bank")
    if method not in ("batched", "loop"):
        raise ValueError(f"method must be 'batched' or 'loop', got {method!r}")
    fp_backend = _resolve_fp_backend(fingerprint_backend)
    exp_backend = _resolve_expand_backend(expand_backend)
    if bucketing not in BUCKETINGS:
        raise ValueError(
            f"bucketing must be one of {BUCKETINGS}, got {bucketing!r}"
        )
    if bucket_growth < 2:
        raise ValueError(f"bucket_growth must be >= 2, got {bucket_growth}")

    with obs.span("construct_bank", patterns=len(dfas), method=method,
                  bucketing=bucketing):
        if method == "loop":
            result = _construct_loop(
                dfas, max_states=max_states, max_retries=max_retries,
                engine=engine, poly_index=poly_index,
            )
        else:
            result = _construct_bucketed(
                dfas, max_states=max_states, tile=tile,
                max_retries=max_retries,
                poly_index=poly_index, distribution=distribution, mesh=mesh,
                pattern_axis=pattern_axis, fp_backend=fp_backend,
                expand_backend=exp_backend, bucketing=bucketing,
                bucket_growth=bucket_growth,
                weight_fn=_weight_fn or _default_weight_fn,
            )
    obs.counter("construction.banks").inc()
    obs.counter("construction.patterns").inc(len(dfas))
    obs.counter("construction.rounds").inc(result.stats.rounds)
    obs.counter("construction.retries").inc(int(result.stats.retries.sum()))
    obs.counter("construction.blown").inc(int(result.blown.sum()))
    obs.histogram("construction.bank_wall_s").observe(result.stats.wall_time_s)
    if on_blowup == "raise":
        result.require_all()
    return result


def _construction_partition(sizes, bucketing: str):
    """The size-bucket partition of one bank, or ``None`` to run unbucketed.
    -> ``[(edge, [pattern indices…]), …]`` via the shared
    :mod:`repro.core.bucketing` helpers (geometric edge ladder, undersized
    buckets merged into neighbors)."""
    from ..core.bucketing import (
        geometric_edges,
        merge_small_buckets,
        partition_by_size,
    )

    if bucketing == "off" or len(sizes) < 2:
        return None
    parts = merge_small_buckets(
        partition_by_size(sizes, geometric_edges(max(sizes))),
        _BUCKET_MIN_PATTERNS,
    )
    if len(parts) < 2:
        return None
    if bucketing == "auto" and len(sizes) < _BUCKET_AUTO_MIN_P:
        return None
    return parts


def _construct_bucketed(dfas, *, max_states, tile, max_retries, poly_index,
                        distribution, mesh, pattern_axis, fp_backend,
                        expand_backend, bucketing, bucket_growth, weight_fn):
    """The size-bucketed batched driver: partition the bank by DFA state
    count, close each sub-bank with bucket-local ``n_max``/capacity/round
    shapes, and scatter results back to the original pattern order.

    Wall-time attribution stays a *bank-global* rounds-weighted share: the
    merged stats recompute every pattern's ``SFAStats.wall_time_s`` against
    the whole call's wall and the total active-round count across buckets,
    so the attribution contract is bucketing-invariant.
    """
    t0 = time.perf_counter()
    parts = _construction_partition(
        [d.n_states for d in dfas], bucketing
    )
    if parts is None:
        return _construct_batched(
            dfas, max_states=max_states, tile=tile, max_retries=max_retries,
            poly_index=poly_index, distribution=distribution, mesh=mesh,
            pattern_axis=pattern_axis, fp_backend=fp_backend,
            expand_backend=expand_backend, bucket_growth=bucket_growth,
            weight_fn=weight_fn,
        )

    P = len(dfas)
    stats = BankStats(
        method="batched",
        pattern_rounds=np.zeros(P, np.int64),
        retries=np.zeros(P, np.int64),
        pattern_candidates=np.zeros(P, np.int64),
    )
    sfas: list = [None] * P
    blown = np.zeros(P, dtype=bool)
    for edge, idx in parts:
        sub_dfas = [dfas[i] for i in idx]

        def sub_weight_fn(p, attempt, n_words, consts, _idx=idx):
            # The seam keys on *bank-global* pattern position, so forced
            # collisions hit the same pattern bucketed or not. n_words is
            # bucket-local; weight fns must derive weights from it alone.
            return weight_fn(_idx[p], attempt, n_words, consts)

        with obs.span("construct_bank.bucket", edge=int(edge),
                      n_patterns=len(idx),
                      n_max=max(d.n_states for d in sub_dfas)):
            sub = _construct_batched(
                sub_dfas, max_states=max_states, tile=tile,
                max_retries=max_retries, poly_index=poly_index,
                distribution=distribution, mesh=mesh,
                pattern_axis=pattern_axis,
                fp_backend=fp_backend, expand_backend=expand_backend,
                bucket_growth=bucket_growth, weight_fn=sub_weight_fn,
            )
        ii = np.asarray(idx, dtype=np.int64)
        stats.pattern_rounds[ii] = sub.stats.pattern_rounds
        stats.retries[ii] = sub.stats.retries
        stats.pattern_candidates[ii] = sub.stats.pattern_candidates
        stats.rounds += sub.stats.rounds
        blown[ii] = sub.blown
        for j, i in enumerate(idx):
            sfas[i] = sub.sfas[j]
        stats.buckets.append(BucketStats(
            edge=int(edge),
            n_patterns=len(idx),
            n_max=max(d.n_states for d in sub_dfas),
            rounds=sub.stats.rounds,
            blown=int(sub.blown.sum()),
            wall_time_s=sub.stats.wall_time_s,
        ))
    stats.candidates = int(stats.pattern_candidates.sum())
    stats.wall_time_s = time.perf_counter() - t0
    total_rounds = int(stats.pattern_rounds.sum())
    for p in range(P):
        if sfas[p] is not None:
            sfas[p].stats.wall_time_s = (
                stats.wall_time_s * int(stats.pattern_rounds[p]) / total_rounds
                if total_rounds else 0.0
            )
    return BankConstructionResult(sfas=sfas, blown=blown, stats=stats)


def _construct_loop(dfas, *, max_states, max_retries, engine, poly_index=0):
    from .single import construct_sfa

    t0 = time.perf_counter()
    P = len(dfas)
    stats = BankStats(
        method="loop",
        pattern_rounds=np.zeros(P, np.int64),
        retries=np.zeros(P, np.int64),
        pattern_candidates=np.zeros(P, np.int64),
    )
    sfas: list = [None] * P
    blown = np.zeros(P, dtype=bool)
    for p, d in enumerate(dfas):
        try:
            sfa = construct_sfa(
                d, engine=engine, max_states=max_states,
                max_retries=max_retries, poly_index=poly_index,
            )
        except StateBlowup:
            blown[p] = True
            continue
        sfas[p] = sfa
        stats.rounds += sfa.stats.rounds
        stats.pattern_rounds[p] = sfa.stats.rounds
        stats.pattern_candidates[p] = sfa.stats.candidates
    stats.candidates = int(stats.pattern_candidates.sum())
    stats.wall_time_s = time.perf_counter() - t0
    return BankConstructionResult(sfas=sfas, blown=blown, stats=stats)


def _construct_batched(dfas, *, max_states, tile, max_retries, poly_index,
                       distribution, mesh, pattern_axis, fp_backend,
                       expand_backend, bucket_growth, weight_fn):
    t0 = time.perf_counter()
    bank = PatternBank.from_dfas(dfas)  # validates the shared alphabet
    P, n, k = bank.n_patterns, bank.n_max, bank.n_symbols
    if n >= 1 << 16:
        raise ValueError("batched engine packs 16-bit state ids")
    W = (n + 1) // 2

    if distribution == "shard_map":
        if mesh is None:
            mesh = make_mesh((jax.device_count(),), (pattern_axis,))
        quantum = int(np.prod(list(mesh.shape.values())))
    elif distribution == "local":
        quantum = 1
    else:
        raise ValueError(
            f"distribution must be 'local' or 'shard_map', got {distribution!r}"
        )

    # The interpret flag only shapes the pallas stages; pin it for all-xla
    # rounds so the compile-cache key does not split on an irrelevant axis.
    if "pallas" in (fp_backend, expand_backend):
        from ..kernels import ops as kernel_ops

        interpret = kernel_ops._default_interpret()
    else:
        interpret = False

    sched = round_schedule(
        tile=tile, n=n, k=k, max_states=max_states, P=P, quantum=quantum,
        bucket_growth=bucket_growth,
    )
    capacity = sched.capacities[0]

    stats = BankStats(
        method="batched",
        pattern_rounds=np.zeros(P, np.int64),
        retries=np.zeros(P, np.int64),
        pattern_candidates=np.zeros(P, np.int64),
    )

    # -- per-pattern fingerprint constants + initial buffers ------------------
    n_true = bank.n_states.astype(np.int64)
    attempts = np.zeros(P, dtype=np.int64)

    def consts_of(p):
        return BarrettConstants.cached(
            nth_poly_low(poly_index + int(attempts[p]))
        )

    weights_np = np.empty((P, W, 2), dtype=np.uint32)
    limbs_np = np.empty((P, 4), dtype=np.uint32)
    masks_np = np.empty((P, W), dtype=np.uint32)
    fp0_np = np.empty((P, 2), dtype=np.uint32)
    for p in range(P):
        c = consts_of(p)
        weights_np[p] = weight_fn(p, 0, W, c)
        limbs_np[p] = _limbs_of(c)
        masks_np[p] = _word_mask(int(n_true[p]), n)
        fp0_np[p] = _seed_fingerprint(int(n_true[p]), c.poly_low)

    identity = np.arange(n, dtype=np.int32)
    states = jnp.zeros((P, capacity, n), jnp.int32).at[:, 0].set(identity)
    fp_hi = jnp.full((P, capacity), _U32MAX, jnp.uint32).at[:, 0].set(
        jnp.asarray(fp0_np[:, 0])
    )
    fp_lo = jnp.full((P, capacity), _U32MAX, jnp.uint32).at[:, 0].set(
        jnp.asarray(fp0_np[:, 1])
    )
    delta = jnp.zeros((P, capacity, k), jnp.int32)
    n_states = jnp.ones(P, jnp.int32)
    frontier = jnp.zeros(P, jnp.int32)
    weights = jnp.asarray(weights_np)
    limbs = jnp.asarray(limbs_np)
    masks = jnp.asarray(masks_np)
    tables = jnp.asarray(bank.tables)

    n_states_h = np.ones(P, dtype=np.int64)
    frontier_h = np.zeros(P, dtype=np.int64)
    blown = np.zeros(P, dtype=bool)

    # -- the nonblocking host loop -------------------------------------------
    while True:
        runnable = (~blown) & (frontier_h < n_states_h)
        act = np.flatnonzero(runnable)
        if act.size == 0:
            break
        worst = int(n_states_h[act].max()) + tile * k
        if worst > capacity and capacity < sched.capacities[-1]:
            grown = sched.capacity_for(worst)
            pad = grown - capacity
            states = jnp.pad(states, ((0, 0), (0, pad), (0, 0)))
            fp_hi = jnp.pad(fp_hi, ((0, 0), (0, pad)),
                            constant_values=np.uint32(0xFFFFFFFF))
            fp_lo = jnp.pad(fp_lo, ((0, 0), (0, pad)),
                            constant_values=np.uint32(0xFFFFFFFF))
            delta = jnp.pad(delta, ((0, 0), (0, pad), (0, 0)))
            capacity = grown
        bucket = sched.bucket_for(act.size)
        idx_np = np.full(bucket, act[0], dtype=np.int32)
        idx_np[: act.size] = act
        act_np = np.zeros(bucket, dtype=bool)
        act_np[: act.size] = True
        jidx = jnp.asarray(idx_np)
        jact = jnp.asarray(act_np)

        stats.rounds += 1
        stats.pattern_rounds[act] += 1
        stats.pattern_candidates[act] += (
            np.minimum(n_states_h[act] - frontier_h[act], tile) * k
        )

        round_t0 = time.perf_counter()
        with obs.span("construction.round", round=stats.rounds,
                      bucket=bucket, capacity=capacity):
            if distribution == "shard_map":
                round_fn = _sharded_round_exe(
                    mesh, pattern_axis, tile=tile, n=n, k=k,
                    capacity=capacity,
                    fp_backend=fp_backend, expand_backend=expand_backend,
                    interpret=interpret,
                )
                out = round_fn(
                    tables[jidx], states[jidx], fp_hi[jidx], fp_lo[jidx],
                    delta[jidx], n_states[jidx], frontier[jidx],
                    jact, weights[jidx], limbs[jidx], masks[jidx],
                )
                (o_states, o_fp_hi, o_fp_lo, o_delta, o_n, o_frontier,
                 o_coll) = out
                live = jnp.asarray(act)
                states = states.at[live].set(o_states[: act.size])
                fp_hi = fp_hi.at[live].set(o_fp_hi[: act.size])
                fp_lo = fp_lo.at[live].set(o_fp_lo[: act.size])
                delta = delta.at[live].set(o_delta[: act.size])
                n_states = n_states.at[live].set(o_n[: act.size])
                frontier = frontier.at[live].set(o_frontier[: act.size])
                n_states_h[act] = np.asarray(o_n[: act.size], dtype=np.int64)
                frontier_h[act] = np.asarray(
                    o_frontier[: act.size], dtype=np.int64
                )
                coll_np = np.asarray(o_coll[: act.size])
            else:
                step = _local_step_exe(
                    tile=tile, n=n, k=k, capacity=capacity, P=P,
                    bucket=bucket,
                    fp_backend=fp_backend, expand_backend=expand_backend,
                    interpret=interpret,
                )
                states, fp_hi, fp_lo, delta, n_states, frontier, o_coll = \
                    step(
                        tables, states, fp_hi, fp_lo, delta, n_states,
                        frontier, weights, limbs, masks, jidx, jact,
                    )
                n_states_h = np.asarray(n_states).astype(np.int64)
                frontier_h = np.asarray(frontier).astype(np.int64)
                coll_np = np.asarray(o_coll)[: act.size]
        obs.histogram("construction.round_wall_s").observe(
            time.perf_counter() - round_t0
        )

        collided = act[coll_np]
        # Per-pattern polynomial retry, applied as ONE batched scatter per
        # buffer: only collided patterns restart; the others keep progress.
        if collided.size:
            new_w = np.empty((collided.size, W, 2), dtype=np.uint32)
            new_l = np.empty((collided.size, 4), dtype=np.uint32)
            new_fp = np.empty((collided.size, 2), dtype=np.uint32)
            for j, p in enumerate(collided):
                attempts[p] += 1
                stats.retries[p] += 1
                if attempts[p] >= max_retries:
                    raise FingerprintCollision(
                        f"pattern {p}: {max_retries} polynomials all collided"
                    )
                c = consts_of(p)
                new_w[j] = weight_fn(int(p), int(attempts[p]), W, c)
                new_l[j] = _limbs_of(c)
                new_fp[j] = _seed_fingerprint(int(n_true[p]), c.poly_low)
                weights_np[p] = new_w[j]
                limbs_np[p] = new_l[j]
            cidx = jnp.asarray(collided.astype(np.int32))
            weights = weights.at[cidx].set(jnp.asarray(new_w))
            limbs = limbs.at[cidx].set(jnp.asarray(new_l))
            fp_hi = fp_hi.at[cidx, 0].set(jnp.asarray(new_fp[:, 0]))
            fp_lo = fp_lo.at[cidx, 0].set(jnp.asarray(new_fp[:, 1]))
            n_states = n_states.at[cidx].set(jnp.int32(1))
            frontier = frontier.at[cidx].set(jnp.int32(0))
            n_states_h[collided] = 1
            frontier_h[collided] = 0

        blown |= n_states_h > max_states

    # -- crop per-pattern results ---------------------------------------------
    stats.wall_time_s = time.perf_counter() - t0
    stats.candidates = int(stats.pattern_candidates.sum())
    total_rounds = int(stats.pattern_rounds.sum())
    states_np = np.asarray(states)
    delta_np = np.asarray(delta)
    fp_hi_np = np.asarray(fp_hi)
    fp_lo_np = np.asarray(fp_lo)
    sfas: list = [None] * P
    for p in range(P):
        if blown[p]:
            continue
        S = int(n_states_h[p])
        # Rounds-weighted share: the bank's wall belongs to BankStats; a
        # pattern reports only the fraction of rounds it was active in.
        share = (
            stats.wall_time_s * int(stats.pattern_rounds[p]) / total_rounds
            if total_rounds else 0.0
        )
        pstats = SFAStats(
            engine="batched",
            rounds=int(stats.pattern_rounds[p]),
            candidates=int(stats.pattern_candidates[p]),
            wall_time_s=share,
        )
        fps = np.stack([fp_hi_np[p, :S], fp_lo_np[p, :S]], axis=1).astype(
            np.uint32
        )
        sfas[p] = SFA(
            mappings=np.ascontiguousarray(states_np[p, :S, : int(n_true[p])]),
            delta=np.ascontiguousarray(delta_np[p, :S]),
            fingerprints=fps,
            dfa=dfas[p],
            stats=pstats,
        )
    return BankConstructionResult(sfas=sfas, blown=blown, stats=stats)


# --------------------------------------------------------------------------
# The single-pattern jitted engine (P = 1 special case)
# --------------------------------------------------------------------------


def construct_sfa_jax(
    dfa: DFA,
    *,
    poly_index: int = 0,
    max_states: int = 200_000,
    tile: int = 256,
) -> SFA:
    """The jitted TPU-shaped engine — now literally the bank construction
    with one pattern. Raises :class:`FingerprintCollision` on a detected
    collision (the :func:`~repro.construction.construct_sfa` wrapper
    retries with the next polynomial)."""
    result = construct_bank(
        [dfa], max_states=max_states, tile=tile, poly_index=poly_index,
        max_retries=1, method="batched", on_blowup="raise",
    )
    sfa = result.sfas[0]
    sfa.stats.engine = "jax"
    return sfa
