"""Logical-axis sharding rules (MaxText-style).

Every tensor in the model is annotated with *logical* axis names; a rule set
maps logical names to mesh axes (or ``None`` = replicated). Swapping rule
sets re-shards the whole model without touching model code — this is the
lever the §Perf hillclimb turns.

Defaults encode the production layout on the (data=16, model=16) mesh
(+"pod" data-parallel axis when multi-pod):

  batch           -> ("pod", "data")   activations: DP/FSDP axis
  embed (weights) -> "data"            ZeRO-3/FSDP weight shard
  heads / mlp     -> "model"           Megatron tensor parallelism
  vocab           -> "model"           sharded embedding + logits
  cache_seq       -> "model"           flash-decode style KV-cache sequence
                                       sharding (softmax combine = the
                                       paper's chunk-combine monoid)
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
from jax.sharding import PartitionSpec as P

# Logical axis vocabulary. Weights and activations use disjoint names for the
# model dim so FSDP (weights) and activation layout can differ.
DEFAULT_MAPPING: dict = {
    # activations
    "batch": ("pod", "data"),
    "seq_act": None,          # "model" enables Megatron-style sequence parallelism
    "attn_seq": None,         # seq layout INSIDE attention (None = gathered);
                              # with seq_act="model" this realizes the
                              # all-gather-at-entry / reduce-scatter-at-exit SP
    "embed_act": None,
    "heads_act": "model",
    "cache_batch": ("pod", "data"),
    "cache_seq": "model",     # sequence-sharded KV cache for decode
    "cache_kv_heads": None,
    "cache_head_dim": None,
    # weights
    "layers": None,
    "embed": "data",          # FSDP shard of the d_model dim
    "vocab": "model",
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "mlp": "model",
    "experts": None,
    "rnn": "model",           # ssm/rglru inner channels
    "state": None,            # ssm state dim N
    "conv": None,
}


@dataclass(frozen=True)
class Rules:
    mapping: dict = field(default_factory=lambda: dict(DEFAULT_MAPPING))
    mesh_axes: tuple = ("data", "model")

    def with_overrides(self, overrides: dict) -> "Rules":
        m = dict(self.mapping)
        m.update(overrides)
        return replace(self, mapping=m)

    def resolve(self, logical: str | None):
        if logical is None:
            return None
        axes = self.mapping.get(logical, None)
        if axes is None:
            return None
        if isinstance(axes, str):
            axes = (axes,)
        # Drop axes absent from the active mesh (e.g. "pod" on single-pod).
        kept = tuple(a for a in axes if a in self.mesh_axes)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]

    def spec(self, *logical_axes) -> P:
        return P(*(self.resolve(a) for a in logical_axes))


DEFAULT_RULES = Rules()


@dataclass(frozen=True)
class Dist:
    """Distribution context threaded through model code: the sharding rules,
    the active mesh (None on single-device test paths — shard_map layers fall
    back to local computation), and the axis roles."""

    rules: Rules = DEFAULT_RULES
    mesh: object = None
    data_axes: tuple = ("pod", "data")
    model_axis: str = "model"

    @classmethod
    def for_mesh(cls, mesh, rules: Rules | None = None) -> "Dist":
        names = tuple(mesh.axis_names)
        rules = rules or Rules(mesh_axes=names)
        return cls(
            rules=replace(rules, mesh_axes=names),
            mesh=mesh,
            data_axes=tuple(a for a in names if a != "model"),
            model_axis="model" if "model" in names else None,
        )


def logical_spec(rules: Rules, *axes) -> P:
    return rules.spec(*axes)


def constrain(x, rules: Rules, *axes):
    """with_sharding_constraint against the ambient mesh; no-op shapes pass
    through untouched when tracing without a mesh (unit tests on CPU)."""
    try:
        return jax.lax.with_sharding_constraint(x, rules.spec(*axes))
    except (ValueError, RuntimeError):
        return x
