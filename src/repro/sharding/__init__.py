from .rules import Dist, Rules, DEFAULT_RULES, logical_spec, constrain

__all__ = ["Dist", "Rules", "DEFAULT_RULES", "logical_spec", "constrain"]
