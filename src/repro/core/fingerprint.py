"""Rabin fingerprints over GF(2^64) via Barrett reduction (paper §II, Eq. 4/5).

An SFA state (a vector of DFA state ids) is viewed as a bit-string, i.e. a
polynomial ``A(t)`` over Z_2; its fingerprint is ``A(t) mod P(t)`` for a fixed
irreducible degree-64 polynomial ``P``. Equal fingerprints are *necessary* for
equality of states, so almost all set-membership comparisons reduce to one
64-bit compare (the paper's key construction optimization).

Two implementations live here:

* A pure-Python big-int reference (``clmul_int``/``poly_mod_int``/
  ``fingerprint_int``) — the correctness oracle, also used by the faithful
  sequential constructor.
* A JAX implementation on **32-bit limbs** (``fingerprint_u32``). The paper
  leans on the x86 ``PCLMULQDQ`` instruction; TPUs have no carry-less multiply
  and no fast 64-bit integers, so we bit-slice: a 32x32 carry-less multiply is
  32 mask/shift/XOR lane-steps on the VPU, *batched over the whole frontier*,
  which amortizes the bit loop the way PCLMULQDQ amortizes it in silicon.
  The >64-bit "folding" method [Gopal et al. 2009] becomes a data-parallel
  weighted XOR-reduction with precomputed ``x^(64 i) mod P`` constants.

Everything below is non-probabilistic *in the paper's sense*: fingerprint
equality is always confirmed by an exact vector comparison before two states
are identified (see ``core.sfa``).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

MASK64 = (1 << 64) - 1

# Default degree-64 *irreducible* polynomial over Z_2:
# x^64 + x^4 + x^3 + x + 1 (verified by ``is_irreducible``). ``POLY_LOW`` are
# the low 64 coefficient bits; the x^64 coefficient is implicit. The paper's
# collision bound n^2 m / 2^k requires P irreducible.
DEFAULT_POLY_LOW = 0x000000000000001B


@functools.lru_cache(maxsize=None)
def nth_poly_low(i: int) -> int:
    """Deterministic sequence of irreducible degree-64 polys: index 0 is the
    default; higher indices draw random irreducibles (used to re-randomize on
    a detected fingerprint collision — exactness by detection + retry,
    see repro.construction). Cached: a collision retry in one pattern of a
    bank must not re-run the Rabin irreducibility search for every caller.
    """
    if i == 0:
        return DEFAULT_POLY_LOW
    return random_irreducible_poly64(seed=i) & MASK64


# --------------------------------------------------------------------------
# Pure-integer GF(2) reference
# --------------------------------------------------------------------------


def clmul_int(a: int, b: int) -> int:
    """Carry-less multiply of two GF(2) polynomials given as ints."""
    acc = 0
    while b:
        lsb = b & -b
        acc ^= a * lsb  # multiply by a power of two == shift, carry-free
        b ^= lsb
    return acc


def poly_degree(p: int) -> int:
    return p.bit_length() - 1


def poly_mod_int(a: int, p: int) -> int:
    """Naive polynomial remainder a(t) mod p(t)."""
    dp = poly_degree(p)
    while a.bit_length() - 1 >= dp and a:
        a ^= p << (a.bit_length() - 1 - dp)
    return a


def poly_div_int(a: int, p: int) -> int:
    """Polynomial quotient floor(a(t) / p(t))."""
    q = 0
    dp = poly_degree(p)
    while a.bit_length() - 1 >= dp and a:
        shift = a.bit_length() - 1 - dp
        q ^= 1 << shift
        a ^= p << shift
    return q


def is_irreducible(p: int) -> bool:
    """Rabin's irreducibility test for polynomials over GF(2)."""
    n = poly_degree(p)

    def powmod(base: int, e: int, mod: int) -> int:
        r = 1
        base = poly_mod_int(base, mod)
        while e:
            if e & 1:
                r = poly_mod_int(clmul_int(r, base), mod)
            base = poly_mod_int(clmul_int(base, base), mod)
            e >>= 1
        return r

    # x^(2^n) == x mod p
    h = 2  # the polynomial "x"
    for _ in range(n):
        h = poly_mod_int(clmul_int(h, h), p)
    if h != 2:
        return False
    # gcd(x^(2^(n/q)) - x, p) == 1 for prime divisors q of n
    def prime_divisors(n: int):
        d, out = 2, set()
        while d * d <= n:
            while n % d == 0:
                out.add(d)
                n //= d
            d += 1
        if n > 1:
            out.add(n)
        return out

    def gcd(a: int, b: int) -> int:
        while b:
            a, b = b, poly_mod_int(a, b)
        return a

    for q in prime_divisors(n):
        h = 2
        for _ in range(n // q):
            h = poly_mod_int(clmul_int(h, h), p)
        if gcd(h ^ 2, p) != 1:
            return False
    return True


def random_irreducible_poly64(seed: int) -> int:
    """Draw a random irreducible degree-64 polynomial (paper §II: P(t) is a
    *random* irreducible polynomial)."""
    rng = np.random.default_rng(seed)
    while True:
        low = int(rng.integers(0, 1 << 63, dtype=np.uint64)) << 1 | 1  # odd
        p = (1 << 64) | low
        if is_irreducible(p):
            return p


# --------------------------------------------------------------------------
# Barrett reduction (paper Eq. 4/5)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class BarrettConstants:
    """Precomputed constants for reduction mod P(t), degree-64.

    ``poly_low``: low 64 bits of P (x^64 coefficient implicit).
    ``mu_low``:   low 64 bits of M = floor(t^128 / P(t)) (x^64 implicit).
    """

    poly_low: int
    mu_low: int

    @classmethod
    def create(cls, poly_low: int = DEFAULT_POLY_LOW) -> "BarrettConstants":
        p = (1 << 64) | (poly_low & MASK64)
        mu = poly_div_int(1 << 128, p)
        assert mu >> 64 == 1, "M = t^128 / P must have degree exactly 64"
        return cls(poly_low=poly_low & MASK64, mu_low=mu & MASK64)

    @classmethod
    @functools.lru_cache(maxsize=None)
    def cached(cls, poly_low: int = DEFAULT_POLY_LOW) -> "BarrettConstants":
        """Memoized :meth:`create`: collision retries and per-pattern bank
        polynomials share one μ = t^128 / P division per polynomial."""
        return cls.create(poly_low)

    @property
    def poly(self) -> int:
        return (1 << 64) | self.poly_low


def barrett_reduce_int(a: int, consts: BarrettConstants) -> int:
    """A(t) mod P(t) via Barrett reduction; A of degree < 128 (Eq. 5)."""
    p = consts.poly
    mu = (1 << 64) | consts.mu_low
    t1pre = a >> 64                       # floor(A / t^64)
    t1 = clmul_int(t1pre, mu)             # T1pre • M
    t2pre = t1 >> 64                      # floor(T1 / t^64)
    t2 = clmul_int(t2pre, p)              # T2pre • P
    return (a ^ t2) & MASK64              # A ⊕ T2, degree < 64


def fingerprint_int(words: np.ndarray, consts: BarrettConstants) -> int:
    """Fingerprint of a uint32-word stream via the folding method.

    fp = XOR_i barrett(clmul(word_i, x^(32 i) mod P)) — linearity of the
    residue lets the per-word products be folded *before* a single reduction
    round, which is exactly what makes this data-parallel.
    """
    weights = fold_weights_int(len(words), consts)
    acc = 0
    for w, wt in zip(np.asarray(words, dtype=np.uint64).tolist(), weights):
        acc ^= clmul_int(int(w), wt)
    return barrett_reduce_int(acc, consts)


@functools.lru_cache(maxsize=64)
def _fold_weights_cached(n_words: int, poly_low: int) -> tuple:
    p = (1 << 64) | poly_low
    out = []
    w = 1  # x^0 mod P
    for _ in range(n_words):
        out.append(w)
        w = poly_mod_int(w << 32, p)  # advance by x^32
    return tuple(out)


def fold_weights_int(n_words: int, consts: BarrettConstants) -> tuple:
    return _fold_weights_cached(n_words, consts.poly_low)


# --------------------------------------------------------------------------
# JAX implementation on 32-bit limbs
# --------------------------------------------------------------------------
# 64-bit quantities are (hi, lo) uint32 pairs; 128-bit are (l3, l2, l1, l0)
# with l0 the least-significant limb.


def _u32(x) -> jnp.ndarray:
    return jnp.asarray(x, dtype=jnp.uint32)


def clmul32(a: jnp.ndarray, b: jnp.ndarray) -> tuple:
    """Carry-less 32x32 -> 64-bit multiply, bit-sliced over 32 steps.

    Branch-free: each step masks on bit i of ``b`` and XOR-accumulates
    ``a << i`` into a 64-bit (hi, lo) accumulator. Fully vectorized over the
    operands' leading batch dims.
    """
    a = _u32(a)
    b = _u32(b)
    zero = jnp.zeros_like(a)

    def body(i, carry):
        hi, lo = carry
        bit = (b >> i) & _u32(1)
        mask = (_u32(0) - bit)  # 0x0 or 0xFFFFFFFF
        lo = lo ^ ((a << i) & mask)
        # (a >> (32 - i)) without the undefined i==0 shift-by-32:
        hi = hi ^ (((a >> (_u32(31) - i)) >> 1) & mask)
        return hi, lo

    hi, lo = jax.lax.fori_loop(0, 32, body, (zero, zero), unroll=True)
    return hi, lo


def xor64(x: tuple, y: tuple) -> tuple:
    return x[0] ^ y[0], x[1] ^ y[1]


def clmul64(a: tuple, b: tuple) -> tuple:
    """Carry-less 64x64 -> 128-bit multiply from four 32-bit partials."""
    ah, al = a
    bh, bl = b
    ll_h, ll_l = clmul32(al, bl)   # -> limbs 1,0
    lh_h, lh_l = clmul32(al, bh)   # -> limbs 2,1
    hl_h, hl_l = clmul32(ah, bl)   # -> limbs 2,1
    hh_h, hh_l = clmul32(ah, bh)   # -> limbs 3,2
    l0 = ll_l
    l1 = ll_h ^ lh_l ^ hl_l
    l2 = lh_h ^ hl_h ^ hh_l
    l3 = hh_h
    return l3, l2, l1, l0


def barrett_reduce_u32(a128: tuple, consts: BarrettConstants) -> tuple:
    """Barrett reduction of a 128-bit polynomial to 64 bits, limb form."""
    l3, l2, l1, l0 = a128
    p = (_u32(consts.poly_low >> 32), _u32(consts.poly_low & 0xFFFFFFFF))
    mu = (_u32(consts.mu_low >> 32), _u32(consts.mu_low & 0xFFFFFFFF))

    t1pre = (l3, l2)  # floor(A / t^64)
    # T1 = clmul(T1pre, M) with M = t^64 + mu  ->  T1>>64 = T1pre ^ hi64(T1pre*mu)
    m3, m2, _, _ = clmul64(t1pre, mu)
    t2pre = xor64(t1pre, (m3, m2))
    # T2 = clmul(T2pre, P) with P = t^64 + p_low. The (T2pre << 64) part only
    # touches limbs 2..3, which cancel against A's by construction; the low 64
    # result bits come from A_low ^ low64(T2pre * p_low).
    _, _, q1, q0 = clmul64(t2pre, p)
    return l1 ^ q1, l0 ^ q0


def fold_weights_u32(n_words: int, consts: BarrettConstants) -> jnp.ndarray:
    """(n_words, 2) uint32 array of x^(32 i) mod P constants (hi, lo)."""
    ws = fold_weights_int(n_words, consts)
    arr = np.zeros((n_words, 2), dtype=np.uint32)
    for i, w in enumerate(ws):
        arr[i, 0] = (w >> 32) & 0xFFFFFFFF
        arr[i, 1] = w & 0xFFFFFFFF
    return jnp.asarray(arr)


def fingerprint_u32(words: jnp.ndarray, weights: jnp.ndarray,
                    consts: BarrettConstants) -> tuple:
    """Rabin fingerprint of ``words`` (..., W) uint32 -> ((...), (...)) u32 pair.

    The fold: fp = reduce( XOR_i clmul64((0, word_i), weight_i) ). Each word
    contributes a 96-bit product (32x64); the XOR-accumulated 128-bit value is
    Barrett-reduced once at the end.
    """
    words = _u32(words)
    wh = weights[..., 0]
    wl = weights[..., 1]

    # clmul64((0, w), (wh, wl)) = limbs from clmul32(w, wl) and clmul32(w, wh)
    p_lo_h, p_lo_l = clmul32(words, wl)   # limbs 1,0
    p_hi_h, p_hi_l = clmul32(words, wh)   # limbs 2,1
    l0 = p_lo_l
    l1 = p_lo_h ^ p_hi_l
    l2 = p_hi_h

    # XOR-reduce over the word axis (last axis).
    l0 = _xor_reduce(l0)
    l1 = _xor_reduce(l1)
    l2 = _xor_reduce(l2)
    l3 = jnp.zeros_like(l2)
    return barrett_reduce_u32((l3, l2, l1, l0), consts)


def _xor_reduce(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce(x, jnp.zeros((), x.dtype), jax.lax.bitwise_xor, (x.ndim - 1,))


def pack_states_u32(states: jnp.ndarray) -> jnp.ndarray:
    """Pack an int32 state-id vector (..., n) into uint32 words (..., ceil(n/2))
    with two 16-bit ids per word (the paper stores FA states as uint16)."""
    states = jnp.asarray(states, dtype=jnp.uint32)
    n = states.shape[-1]
    if n % 2:
        pad = [(0, 0)] * (states.ndim - 1) + [(0, 1)]
        states = jnp.pad(states, pad)
    lo = states[..., 0::2] & jnp.uint32(0xFFFF)
    hi = states[..., 1::2] & jnp.uint32(0xFFFF)
    return lo | (hi << 16)


def fingerprint_states(states: jnp.ndarray, consts: BarrettConstants) -> jnp.ndarray:
    """Fingerprint batched SFA state vectors: (..., n) int32 -> (..., 2) uint32.

    Output [..., 0] is the high 32 bits, [..., 1] the low 32 bits.
    """
    words = pack_states_u32(states)
    weights = fold_weights_u32(words.shape[-1], consts)
    hi, lo = fingerprint_u32(words, weights, consts)
    return jnp.stack([hi, lo], axis=-1)


def pack_states_np(states: np.ndarray, out: np.ndarray | None = None) -> np.ndarray:
    """NumPy twin of :func:`pack_states_u32`: (..., n) ids -> (..., ceil(n/2))
    uint32 words, two 16-bit ids per word. ``out`` lets callers reuse one
    scratch buffer across construction tiles and collision-retry attempts
    (packing is polynomial-independent, so the packed words survive a retry
    with a fresh P(t))."""
    states = np.asarray(states, dtype=np.uint32)
    n = states.shape[-1]
    n_words = (n + 1) // 2
    shape = states.shape[:-1] + (n_words,)
    if out is None or out.shape != shape:
        out = np.empty(shape, dtype=np.uint32)
    np.bitwise_and(states[..., 0::2], np.uint32(0xFFFF), out=out)
    out[..., : n // 2] |= (states[..., 1::2] & np.uint32(0xFFFF)) << np.uint32(16)
    return out


def fingerprint_words_np(words: np.ndarray, consts: BarrettConstants) -> np.ndarray:
    """Fold + Barrett-reduce pre-packed words: (..., W) u32 -> (..., 2) u32."""
    ws = fold_weights_int(words.shape[-1], consts)
    w_lo = np.asarray([w & 0xFFFFFFFF for w in ws], dtype=np.uint32)
    w_hi = np.asarray([(w >> 32) & 0xFFFFFFFF for w in ws], dtype=np.uint32)

    p_lo_h, p_lo_l = _clmul32_np(words, w_lo)
    p_hi_h, p_hi_l = _clmul32_np(words, w_hi)
    l0 = _xor_reduce_np(p_lo_l)
    l1 = _xor_reduce_np(p_lo_h ^ p_hi_l)
    l2 = _xor_reduce_np(p_hi_h)
    l3 = np.zeros_like(l2)
    hi, lo = _barrett_np((l3, l2, l1, l0), consts)
    return np.stack([hi, lo], axis=-1)


def fingerprint_states_np(states: np.ndarray, consts: BarrettConstants) -> np.ndarray:
    """NumPy twin of :func:`fingerprint_states` (vectorized, used by the fast
    CPU constructor). Works in 32-bit word space mirroring the JAX path
    exactly. Returns (..., 2) uint32 [hi, lo]."""
    return fingerprint_words_np(pack_states_np(states), consts)


def _clmul32_np(a: np.ndarray, b: np.ndarray) -> tuple:
    a = a.astype(np.uint32)
    b = np.broadcast_to(np.asarray(b, dtype=np.uint32), a.shape)
    hi = np.zeros_like(a)
    lo = np.zeros_like(a)
    for i in range(32):
        bit = (b >> np.uint32(i)) & np.uint32(1)
        mask = np.where(bit != 0, np.uint32(0xFFFFFFFF), np.uint32(0))
        lo ^= (a << np.uint32(i)) & mask
        hi ^= (((a >> np.uint32(31 - i)) >> np.uint32(1)) & mask)
    return hi, lo


def _xor_reduce_np(x: np.ndarray) -> np.ndarray:
    return np.bitwise_xor.reduce(x, axis=-1)


def _barrett_np(a128: tuple, consts: BarrettConstants) -> tuple:
    l3, l2, l1, l0 = a128
    p = (np.uint32(consts.poly_low >> 32), np.uint32(consts.poly_low & 0xFFFFFFFF))
    mu = (np.uint32(consts.mu_low >> 32), np.uint32(consts.mu_low & 0xFFFFFFFF))
    m3, m2, _, _ = _clmul64_np((l3, l2), mu)
    t2 = (l3 ^ m3, l2 ^ m2)
    _, _, q1, q0 = _clmul64_np(t2, p)
    return l1 ^ q1, l0 ^ q0


def _clmul64_np(a: tuple, b: tuple) -> tuple:
    ah, al = a
    bh, bl = np.asarray(b[0], dtype=np.uint32), np.asarray(b[1], dtype=np.uint32)
    ll_h, ll_l = _clmul32_np(al, bl)
    lh_h, lh_l = _clmul32_np(al, np.broadcast_to(bh, al.shape))
    hl_h, hl_l = _clmul32_np(ah, np.broadcast_to(bl, ah.shape))
    hh_h, hh_l = _clmul32_np(ah, np.broadcast_to(bh, ah.shape))
    return hh_h, lh_h ^ hl_h ^ hh_l, ll_h ^ lh_l ^ hl_l, ll_l
