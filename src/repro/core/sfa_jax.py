"""SFA construction as a jitted, fixed-capacity JAX program — the form of the
paper's algorithm that runs on a TPU.

The bulk-synchronous round is one jitted call with static shapes:

  1. slice a tile of ``T`` unprocessed frontier states from the state buffer;
  2. expand frontier × alphabet in one fused gather (paper's coarse+medium
     parallelism collapsed into a single data-parallel tensor op);
  3. fingerprint all ``T·|Σ|`` candidates with the bit-sliced Rabin/Barrett
     fold (``core.fingerprint``);
  4. set membership for all candidates at once: one multi-key ``lax.sort``
     over (known ∪ candidates) fingerprints groups equal fingerprints into
     runs; each run's head decides the id (known id, or a freshly assigned
     one in BFS first-occurrence order). This is the TPU-idiomatic
     replacement for the paper's hash table — no pointer chasing, O(log)
     depth, fully vectorized.
  5. exactness (paper §III-A, non-probabilistic): every candidate is
     vector-compared against its run head; any fingerprint-equal but
     vector-unequal pair sets a collision flag, and the host-side wrapper
     retries with a fresh irreducible polynomial.

Dynamic sizes (frontier length, number of new states) live in scalars; all
arrays are fixed capacity, so one XLA compilation serves the whole closure.
Discovery order is identical to the sequential/vectorized engines (FIFO BFS,
symbols in order), so all three engines produce bit-identical SFAs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .dfa import DFA
from .fingerprint import (
    BarrettConstants,
    fingerprint_states,
    nth_poly_low,
)
from .sfa import SFA, FingerprintCollision, SFAStats, StateBlowup

_U32MAX = jnp.uint32(0xFFFFFFFF)


@functools.partial(jax.jit, static_argnames=("tile", "n", "k", "capacity"))
def _round_step(
    table,            # (n, k) int32
    states_buf,       # (C, n) int32
    fp_hi, fp_lo,     # (C,) uint32
    delta_buf,        # (C, k) int32
    n_states,         # () int32
    frontier_lo,      # () int32
    weights,          # (W, 2) uint32 fingerprint fold constants
    poly_limbs,       # (4,) uint32 [p_hi, p_lo, mu_hi, mu_lo]
    *, tile: int, n: int, k: int, capacity: int,
):
    consts = _consts_from_limbs(poly_limbs)

    # ---- 1/2: slice frontier tile, fused expansion -------------------------
    ft = jax.lax.dynamic_slice(states_buf, (frontier_lo, 0), (tile, n))
    row_ids = frontier_lo + jnp.arange(tile, dtype=jnp.int32)
    row_valid = row_ids < n_states                          # (T,)
    # next[f, a, q] = δ(f[q], a): one gather, symbol axis materialized.
    cand = table[ft]                                        # (T, n, k)
    cand = jnp.swapaxes(cand, 1, 2).reshape(tile * k, n)    # (T·k, n) row-major (f, a)
    cand_valid = jnp.repeat(row_valid, k)                   # (T·k,)

    # ---- 3: fingerprint all candidates --------------------------------------
    fp = _fingerprint_with(cand, weights, consts)           # (T·k, 2) uint32
    c_hi, c_lo = fp[:, 0], fp[:, 1]

    # ---- 4: sort-merge membership -------------------------------------------
    C = capacity
    total = C + tile * k
    known_valid = jnp.arange(C, dtype=jnp.int32) < n_states
    inval = jnp.concatenate([(~known_valid), (~cand_valid)]).astype(jnp.uint32)
    hi = jnp.concatenate([fp_hi, c_hi])
    lo = jnp.concatenate([fp_lo, c_lo])
    is_cand = jnp.concatenate(
        [jnp.zeros(C, jnp.uint32), jnp.ones(tile * k, jnp.uint32)]
    )
    payload = jnp.concatenate(
        [jnp.arange(C, dtype=jnp.int32), jnp.arange(tile * k, dtype=jnp.int32)]
    )
    # Sort by (validity, fp_hi, fp_lo, known<cand, original index).
    tie = payload.astype(jnp.uint32)
    s_inval, s_hi, s_lo, s_isc, s_tie, s_pay = jax.lax.sort(
        (inval, hi, lo, is_cand, tie, payload), num_keys=5
    )

    run_start = jnp.concatenate(
        [jnp.ones(1, bool),
         (s_hi[1:] != s_hi[:-1]) | (s_lo[1:] != s_lo[:-1]) | (s_inval[1:] != s_inval[:-1])]
    )
    pos = jnp.arange(total, dtype=jnp.int32)
    head_pos = jax.lax.cummax(jnp.where(run_start, pos, -1), axis=0)
    head_pay = s_pay[head_pos]
    head_is_known = s_isc[head_pos] == 0

    # New-state heads: candidate-headed runs that are valid.
    s_valid = s_inval == 0
    is_new_head = run_start & (s_isc == 1) & s_valid
    # Rank new heads by original candidate index -> BFS discovery order.
    rank_key = jnp.where(is_new_head, s_pay, jnp.int32(2**31 - 1))
    order = jnp.argsort(rank_key)
    ranks = jnp.zeros(total, jnp.int32).at[order].set(jnp.arange(total, dtype=jnp.int32))
    new_id_at_pos = n_states + ranks                         # valid where is_new_head

    # id of each sorted position = head's id.
    head_new_id = new_id_at_pos[head_pos]
    id_sorted = jnp.where(head_is_known, head_pay, head_new_id)

    # ---- 5: exactness check (candidates vs run head vectors) ----------------
    cand_rows = s_isc == 1
    # Reference vector for every sorted position's run head:
    ref_known = states_buf[jnp.clip(head_pay, 0, C - 1)]
    ref_cand = cand[jnp.clip(head_pay, 0, tile * k - 1)]
    ref_vec = jnp.where(head_is_known[:, None], ref_known, ref_cand)
    own_vec = cand[jnp.clip(s_pay, 0, tile * k - 1)]
    mismatch = jnp.any(ref_vec != own_vec, axis=1) & cand_rows & s_valid
    collision = jnp.any(mismatch)

    # ---- append new states ----------------------------------------------------
    num_new = jnp.sum(is_new_head.astype(jnp.int32))
    # Scatter new states / fps into the buffers.
    tgt = jnp.where(is_new_head, new_id_at_pos, C)  # C = out-of-range drop
    src_vec = cand[jnp.clip(s_pay, 0, tile * k - 1)]
    states_buf = states_buf.at[tgt].set(src_vec, mode="drop")
    fp_hi = fp_hi.at[tgt].set(s_hi, mode="drop")
    fp_lo = fp_lo.at[tgt].set(s_lo, mode="drop")

    # ---- write δ_s rows for the tile -----------------------------------------
    # Candidate (f, a) order is row-major, so ids for candidates (scattered
    # back to original order) reshape straight into delta rows. Non-candidate
    # rows scatter out of range and drop.
    ids_orig = jnp.zeros(tile * k, jnp.int32).at[
        jnp.where(cand_rows, s_pay, tile * k)
    ].set(id_sorted, mode="drop")
    delta_rows = ids_orig.reshape(tile, k)
    delta_buf = jax.lax.dynamic_update_slice(delta_buf, delta_rows, (frontier_lo, 0))

    processed = jnp.minimum(n_states - frontier_lo, tile)
    return (
        states_buf, fp_hi, fp_lo, delta_buf,
        n_states + num_new, frontier_lo + processed, collision,
    )


def _consts_from_limbs(limbs):
    # Rebuild python-int constants is impossible inside jit; we only need the
    # limb values, so mirror BarrettConstants with traced uint32 scalars.
    class _C:
        pass

    c = _C()
    c.p_hi, c.p_lo, c.mu_hi, c.mu_lo = limbs[0], limbs[1], limbs[2], limbs[3]
    return c


def _fingerprint_with(states, weights, c):
    """fingerprint_states with traced Barrett constants (limb form)."""
    from .fingerprint import clmul32, clmul64, pack_states_u32

    words = pack_states_u32(states)
    wh = weights[: words.shape[-1], 0]
    wl = weights[: words.shape[-1], 1]
    p_lo_h, p_lo_l = clmul32(words, wl)
    p_hi_h, p_hi_l = clmul32(words, wh)

    def xred(x):
        return jax.lax.reduce(x, jnp.zeros((), x.dtype), jax.lax.bitwise_xor, (x.ndim - 1,))

    l0 = xred(p_lo_l)
    l1 = xred(p_lo_h ^ p_hi_l)
    l2 = xred(p_hi_h)
    # Barrett with traced limbs:
    t1pre = (jnp.zeros_like(l2), l2)
    m3, m2, _, _ = clmul64(t1pre, (c.mu_hi, c.mu_lo))
    t2pre = (t1pre[0] ^ m3, t1pre[1] ^ m2)
    _, _, q1, q0 = clmul64(t2pre, (c.p_hi, c.p_lo))
    return jnp.stack([l1 ^ q1, l0 ^ q0], axis=-1)


def construct_sfa_jax(
    dfa: DFA,
    *,
    poly_index: int = 0,
    max_states: int = 200_000,
    tile: int = 256,
) -> SFA:
    """Host loop driving the jitted round; returns the exact SFA."""
    import time

    t0 = time.perf_counter()
    stats = SFAStats(engine="jax")
    n, k = dfa.n_states, dfa.n_symbols
    if n >= 1 << 16:
        raise ValueError("jax engine packs 16-bit state ids")
    consts = BarrettConstants.create(nth_poly_low(poly_index))
    # Buffers are over-allocated by one tile so the frontier dynamic_slice
    # never clamps (XLA clamps out-of-range starts, which would silently
    # misalign the final tile).
    C = int(max_states) + tile

    from .fingerprint import fold_weights_u32

    n_words = (n + 1) // 2
    weights = fold_weights_u32(n_words, consts)
    poly_limbs = jnp.asarray(
        [
            (consts.poly_low >> 32) & 0xFFFFFFFF,
            consts.poly_low & 0xFFFFFFFF,
            (consts.mu_low >> 32) & 0xFFFFFFFF,
            consts.mu_low & 0xFFFFFFFF,
        ],
        dtype=jnp.uint32,
    )

    table = jnp.asarray(dfa.table)
    states_buf = jnp.zeros((C, n), jnp.int32)
    states_buf = states_buf.at[0].set(jnp.arange(n, dtype=jnp.int32))
    fp0 = np.asarray(
        fingerprint_states(jnp.arange(n, dtype=jnp.int32)[None], consts)
    )[0]
    fp_hi = jnp.full((C,), _U32MAX, jnp.uint32).at[0].set(jnp.uint32(fp0[0]))
    fp_lo = jnp.full((C,), _U32MAX, jnp.uint32).at[0].set(jnp.uint32(fp0[1]))
    delta_buf = jnp.zeros((C, k), jnp.int32)
    n_states = jnp.asarray(1, jnp.int32)
    frontier_lo = jnp.asarray(0, jnp.int32)

    while int(frontier_lo) < int(n_states):
        stats.rounds += 1
        stats.candidates += min(tile, int(n_states) - int(frontier_lo)) * k
        (states_buf, fp_hi, fp_lo, delta_buf, n_states, frontier_lo, collision) = (
            _round_step(
                table, states_buf, fp_hi, fp_lo, delta_buf, n_states, frontier_lo,
                weights, poly_limbs, tile=tile, n=n, k=k, capacity=C,
            )
        )
        if bool(collision):
            stats.collisions_detected += 1
            raise FingerprintCollision("jax engine detected a collision")
        if int(n_states) >= max_states:
            raise StateBlowup(f"SFA exceeded capacity {max_states}")

    S = int(n_states)
    stats.wall_time_s = time.perf_counter() - t0
    fps = np.stack(
        [np.asarray(fp_hi[:S]), np.asarray(fp_lo[:S])], axis=1
    ).astype(np.uint32)
    return SFA(
        mappings=np.asarray(states_buf[:S]),
        delta=np.asarray(delta_buf[:S]),
        fingerprints=fps,
        dfa=dfa,
        stats=stats,
    )
