"""Compatibility shim: the jitted engine moved to :mod:`repro.construction`.

``construct_sfa_jax`` is now the ``P = 1`` special case of
:func:`repro.construction.construct_bank` (the batched bank rounds); import
it from ``repro.construction`` in new code.
"""

from __future__ import annotations

from ..construction import (  # noqa: F401
    SFA,
    FingerprintCollision,
    SFAStats,
    StateBlowup,
    construct_sfa_jax,
)

__all__ = ["construct_sfa_jax"]
