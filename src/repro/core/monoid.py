"""The framework's unifying abstraction: parallelize a left fold by lifting
each step into a monoid of composable elements.

This is the paper's SFA idea stated generally. An SFA state *is* the lifted
element (the transition function of a string chunk); combining chunk results
by function composition is the monoid reduce. The exact same machinery
parallelizes the model zoo's recurrences:

* ``function_monoid``  — finite-function composition (SFA matching; paper §I).
* ``affine_monoid``    — diagonal affine maps ``h' = a·h + b`` (mamba2 SSD
  inter-chunk recurrence, RG-LRU).
* ``softmax_monoid``   — flash-attention partial-softmax combining
  ``(m, s, o)`` (chunk-parallel long-context decode).

All combines are associative, so they work under ``jax.lax.associative_scan``
(intra-device log-depth scan), plain ``reduce`` (sequential fold over few
chunks), and ``shard_reduce``/``shard_scan`` (cross-device combining inside
``shard_map`` — the pod-scale version of the paper's "combine the result
vectors by reduction").
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Monoid:
    """An associative combine with identity.

    ``combine(a, b)`` means "a happens first, then b" — order matters for the
    non-commutative instances (function composition).
    ``identity(like)`` builds the identity element shaped like one element.
    """

    combine: Callable[[Any, Any], Any]
    identity: Callable[[Any], Any]
    name: str = "monoid"


# --------------------------------------------------------------------------
# Instances
# --------------------------------------------------------------------------


def function_monoid() -> Monoid:
    """Elements: mapping vectors ``f`` with shape (..., n) int32;
    ``combine(f, g)[..., q] = g[..., f[..., q]]`` (apply f, then g)."""

    def combine(f, g):
        return jnp.take_along_axis(g, f, axis=-1)

    def identity(like):
        n = like.shape[-1]
        ident = jnp.arange(n, dtype=like.dtype)
        return jnp.broadcast_to(ident, like.shape)

    return Monoid(combine, identity, "function_composition")


def affine_monoid() -> Monoid:
    """Elements: pairs ``(a, b)`` representing ``h' = a * h + b`` elementwise.
    ``combine((a1,b1),(a2,b2)) = (a2*a1, a2*b1 + b2)``."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    def identity(like):
        a, b = like
        return jnp.ones_like(a), jnp.zeros_like(b)

    return Monoid(combine, identity, "affine")


def softmax_monoid() -> Monoid:
    """Elements: ``(m, s, o)`` — running max, unnormalized denominator, and
    unnormalized weighted sum from a chunk of attention scores. Final output
    is ``o / s``. Associative and commutative."""

    def combine(x, y):
        m1, s1, o1 = x
        m2, s2, o2 = y
        m = jnp.maximum(m1, m2)
        e1 = jnp.exp(m1 - m)
        e2 = jnp.exp(m2 - m)
        return m, s1 * e1 + s2 * e2, o1 * e1 + o2 * e2

    def identity(like):
        m, s, o = like
        neg_inf = jnp.full_like(m, -jnp.inf)
        return neg_inf, jnp.zeros_like(s), jnp.zeros_like(o)

    return Monoid(combine, identity, "softmax")


# --------------------------------------------------------------------------
# Execution strategies
# --------------------------------------------------------------------------


def reduce(monoid: Monoid, xs, axis: int = 0):
    """Sequential fold along ``axis`` (cheap when the chunk count is small)."""
    moved = jax.tree.map(lambda x: jnp.moveaxis(x, axis, 0), xs)
    first = jax.tree.map(lambda x: x[0], moved)
    rest = jax.tree.map(lambda x: x[1:], moved)
    n_rest = jax.tree.leaves(rest)[0].shape[0]
    if n_rest == 0:
        return first

    def body(carry, x):
        return monoid.combine(carry, x), None

    out, _ = jax.lax.scan(body, first, rest)
    return out


def scan(monoid: Monoid, xs, axis: int = 0, reverse: bool = False):
    """Inclusive prefix-combine along ``axis`` via ``associative_scan``
    (log-depth — the data-parallel execution the paper targets)."""
    return jax.lax.associative_scan(monoid.combine, xs, axis=axis, reverse=reverse)


def exclusive_scan(monoid: Monoid, xs, axis: int = 0):
    """Exclusive prefix: element i gets the combine of elements [0, i).

    Used to recover each chunk's *entry state* from per-chunk lifted elements
    (matching needs to know where the DFA was at every chunk boundary)."""
    inclusive = scan(monoid, xs, axis=axis)
    one = jax.tree.map(lambda x: jax.lax.slice_in_dim(x, 0, 1, axis=axis), xs)
    ident = monoid.identity(one)  # identity element, shaped like a length-1 slice
    return jax.tree.map(
        lambda inc, idn: jnp.concatenate(
            [idn, jax.lax.slice_in_dim(inc, 0, inc.shape[axis] - 1, axis=axis)],
            axis=axis,
        ),
        inclusive,
        ident,
    )


def shard_reduce(monoid: Monoid, x_local, axis_name: str):
    """Combine one element per device along a mesh axis, inside ``shard_map``.

    Strategy (paper §IV-C at pod scale): ``all_gather`` the lifted elements —
    tiny (an SFA mapping is n ints) — then fold locally. One collective of
    O(devices · element_size) beats log-depth permutes for small elements.
    Returns the total combine, replicated across the axis.
    """
    gathered = jax.tree.map(
        lambda x: jax.lax.all_gather(x, axis_name, axis=0), x_local
    )
    return reduce(monoid, gathered, axis=0)


def shard_exclusive_scan(monoid: Monoid, x_local, axis_name: str):
    """Exclusive prefix-combine across a mesh axis: device i receives the
    combine of devices [0, i)'s elements. Entry-state computation for
    distributed matching."""
    gathered = jax.tree.map(
        lambda x: jax.lax.all_gather(x, axis_name, axis=0), x_local
    )
    prefixes = exclusive_scan(monoid, gathered, axis=0)
    idx = jax.lax.axis_index(axis_name)
    return jax.tree.map(lambda x: jax.lax.dynamic_index_in_dim(x, idx, 0, keepdims=False), prefixes)
