"""PROSITE protein-pattern compiler.

PROSITE patterns (https://prosite.expasy.org, the paper's benchmark source)
use a syntax of ``-``-separated elements:

  ``A``        a literal amino acid
  ``x``        any amino acid
  ``[ALT]``    any of the listed residues
  ``{AM}``     any residue *except* those listed
  ``e(n)``     element repeated exactly ``n`` times
  ``e(n,m)``   element repeated ``n``..``m`` times
  ``<``        pattern anchored at the N-terminus (string start)
  ``>``        pattern anchored at the C-terminus (string end)

We translate to the framework regex syntax (``core.regex``) and compile to a
minimal, complete DFA with *search* semantics unless ``<`` anchors the start
(matching ScanProsite behaviour).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .dfa import DFA, _make_accepting_absorbing, minimize, subset_construct
from .regex import AMINO_ACIDS, compile_nfa


class PrositeSyntaxError(ValueError):
    pass


@dataclass
class PrositePattern:
    raw: str
    regex: str
    anchored_start: bool
    anchored_end: bool


def translate(pattern: str) -> PrositePattern:
    """Translate PROSITE syntax to framework regex syntax."""
    raw = pattern.strip().rstrip(".")
    body = raw
    anchored_start = body.startswith("<")
    if anchored_start:
        body = body[1:]
    anchored_end = body.endswith(">")
    if anchored_end:
        body = body[:-1]
    if not body:
        raise PrositeSyntaxError(f"empty pattern {pattern!r}")

    out = []
    for elem in body.split("-"):
        elem = elem.strip()
        if not elem:
            raise PrositeSyntaxError(f"empty element in {pattern!r}")
        base, rep = _split_repeat(elem)
        out.append(_translate_element(base, pattern) + rep)
    return PrositePattern(
        raw=raw,
        regex="".join(out),
        anchored_start=anchored_start,
        anchored_end=anchored_end,
    )


def _split_repeat(elem: str) -> tuple:
    if elem.endswith(")"):
        open_idx = elem.rfind("(")
        if open_idx < 0:
            raise PrositeSyntaxError(f"unbalanced repeat in {elem!r}")
        inner = elem[open_idx + 1 : -1]
        parts = inner.split(",")
        if not all(p.strip().isdigit() for p in parts) or len(parts) > 2:
            raise PrositeSyntaxError(f"bad repeat spec {elem!r}")
        if len(parts) == 1:
            return elem[:open_idx], "{%d}" % int(parts[0])
        return elem[:open_idx], "{%d,%d}" % (int(parts[0]), int(parts[1]))
    return elem, ""


def _translate_element(base: str, pattern: str) -> str:
    if base == "x":
        return "."
    if base.startswith("[") and base.endswith("]"):
        members = base[1:-1]
        _check_members(members, pattern)
        return f"[{members}]"
    if base.startswith("{") and base.endswith("}"):
        members = base[1:-1]
        _check_members(members, pattern)
        return f"[^{members}]"
    if len(base) == 1 and base in AMINO_ACIDS:
        return base
    raise PrositeSyntaxError(f"bad element {base!r} in {pattern!r}")


def _check_members(members: str, pattern: str) -> None:
    if not members:
        raise PrositeSyntaxError(f"empty class in {pattern!r}")
    for c in members:
        if c not in AMINO_ACIDS:
            raise PrositeSyntaxError(f"residue {c!r} not an amino acid in {pattern!r}")


def compile_prosite(pattern: str, *, minimize_dfa: bool = True) -> DFA:
    """Compile a PROSITE pattern to a minimal complete search DFA."""
    tr = translate(pattern)
    regex = tr.regex
    if not tr.anchored_start:
        regex = "(.*)(" + regex + ")"
    if tr.anchored_end:
        # End-anchored: accepting only at string end — no absorbing accept.
        dfa = subset_construct(compile_nfa(regex, AMINO_ACIDS))
    else:
        dfa = _make_accepting_absorbing(subset_construct(compile_nfa(regex, AMINO_ACIDS)))
    return minimize(dfa) if minimize_dfa else dfa


# --------------------------------------------------------------------------
# A bundled selection of real PROSITE signatures (from the public database),
# spanning small to large DFA sizes — the benchmark suite's pattern corpus.
# --------------------------------------------------------------------------

PROSITE_SAMPLES = {
    # id: pattern                                            (documented family)
    "PS00001": "N-{P}-[ST]-{P}",                             # N-glycosylation
    "PS00004": "[RK](2)-x-[ST]",                             # cAMP phospho site
    "PS00005": "[ST]-x-[RK]",                                # PKC phospho site
    "PS00006": "[ST]-x(2)-[DE]",                             # CK2 phospho site
    "PS00007": "[RK]-x(2)-[DE]-x(3)-Y",                      # Tyr kinase phospho
    "PS00008": "G-{EDRKHPFYW}-x(2)-[STAGCN]-{P}",            # N-myristoylation
    "PS00009": "x-G-[RK]-[RK]",                              # amidation
    "PS00016": "R-G-D",                                      # RGD cell attachment
    "PS00017": "[AG]-x(4)-G-K-[ST]",                         # ATP/GTP P-loop
}

# A second tranche of small real signatures plus size-graded synthetic
# signatures in PROSITE syntax — enough patterns for bank-sized workloads
# (the multipattern engine wants >= 16 tables in one stack; the public
# database has thousands, we bundle a representative spread).
PROSITE_EXTRA = {
    "PS00002": "S-G-x-G",                                # glycosaminoglycan
    "PS00010": "C-x-[DN]-x(4)-[FY]-x-C-x-C",             # ASX hydroxylation
    "PS00014": "[KRHQSA]-[DENQ]-E-L>",                   # ER targeting (KDEL)
    "PS00342": "[STAGCN]-[RKH]-[LIVMAFY]>",              # peroxisome targeting
    "SYN00001": "C-x(2)-C",                              # cys pair, tiny
    "SYN00002": "H-x(3)-H",                              # his spacer
    "SYN00003": "L-x(2)-L-x(3)-L",                       # mini zipper
    "SYN00004": "[LIVM]-G-x-G-[ST]",                     # glycine-rich walker
    "SYN00005": "<M-x(2)-[KR]",                          # N-terminal anchored
    "SYN00006": "[FYW](2)-x-[DE]",                       # aromatic pair + acid
    "SYN00007": "P-x-P-x-P",                             # polyproline comb
    "SYN00008": "[RK](3)",                               # basic cluster
    "SYN00009": "G-[AG]-G-x-G",                          # nucleotide fold frag
    "SYN00010": "[ST]-P-x-[RK]",                         # proline-directed
}


def load_bank(ids=None, *, include_extra: bool = True):
    """Compile bundled signatures into one :class:`~.multipattern.PatternBank`.

    ``ids``: optional explicit signature ids (from ``PROSITE_SAMPLES`` /
    ``PROSITE_EXTRA``); default is every bundled tractable signature. The
    documented-intractable ``PROSITE_HARD`` set is never included — its
    members exceed subset construction long before banking matters.
    """
    from .multipattern import PatternBank

    pool = dict(PROSITE_SAMPLES)
    if include_extra:
        pool.update(PROSITE_EXTRA)
    if ids is None:
        ids = list(pool.keys())
    missing = [i for i in ids if i not in pool]
    if missing:
        raise KeyError(f"unknown PROSITE ids {missing}")
    return PatternBank.from_patterns({i: pool[i] for i in ids})


# Patterns whose *search DFA* already explodes during subset construction
# (wide wildcard windows -> exponentially many active-position subsets), let
# alone the SFA. The paper reports the same wall: "a large part of the
# sequence patterns from PROSITE exceeded the computational power of a
# contemporary 4-CPU multicore server with 128 GB of main memory" (§I).
# Kept out of the default benchmark/test loops; the census reports them as
# documented-intractable.
PROSITE_HARD = {
    "PS00018": "D-x-[DNS]-{ILVFYW}-[DENSTG]-[DNQGHRK]-{GP}-[LIVMC]-[DENQSTAGC]-x(2)-[DE]-[LIVMFYW]",  # EF-hand
    "PS00028": "C-x(2,4)-C-x(3)-[LIVMFYWC]-x(8)-H-x(3,5)-H",  # zinc finger C2H2
    "PS00029": "L-x(6)-L-x(6)-L-x(6)-L",                     # leucine zipper
    "PS00027": "[RK]-x(1,3)-[RKSAQ]-N-x(2)-[SAQ](2)-x-[RKTAENQ]-x-R-x-[RK]",  # homeobox-ish
    "PS00038": "[STAGC]-G-[PAV]-[LIVMFYWA]-[LIVM]-[STAGC]-x(2)-[LIVMFYWT]-[LIVMFYWGS]-x-[NQEH]",
}


def synthetic_protein(length: int, seed: int = 0) -> str:
    """Random amino-acid string for matching benchmarks."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(AMINO_ACIDS), size=length)
    return "".join(AMINO_ACIDS[i] for i in idx)
