"""Core library: the paper's contribution (SFA construction + parallel
matching with Rabin fingerprints) and the monoid machinery it generalizes to.

Construction lives in :mod:`repro.construction` (re-exported here, lazily,
through the long-standing ``core.sfa`` names); parallel matching lives in
:mod:`repro.engine` behind the ``Scanner`` facade. The pre-engine free
functions (``match_parallel_enumeration``, ``match_bank_parallel``,
``census_bank``, ...) were removed after the PR-2 deprecation window —
import from ``repro.engine.executors`` instead.
"""

from .dfa import DFA, compile_dfa, example_fa, minimize, random_dfa, subset_construct
from .fingerprint import (
    BarrettConstants,
    DEFAULT_POLY_LOW,
    barrett_reduce_int,
    clmul_int,
    fingerprint_int,
    fingerprint_states,
    fingerprint_states_np,
    is_irreducible,
    nth_poly_low,
    poly_mod_int,
    random_irreducible_poly64,
)
from .matching import (
    chunk_accept_trace,
    chunk_mapping_enumeration,
    chunk_state_sfa,
    match_ends_sequential,
    match_sequential,
)
from .multipattern import PatternBank, bucket_by_size, census_sequential
from .monoid import (
    Monoid,
    affine_monoid,
    exclusive_scan,
    function_monoid,
    reduce,
    scan,
    shard_exclusive_scan,
    shard_reduce,
    softmax_monoid,
)
from .prosite import (
    PROSITE_EXTRA,
    PROSITE_SAMPLES,
    compile_prosite,
    load_bank,
    synthetic_protein,
    translate,
)
from .regex import AMINO_ACIDS, compile_nfa, parse

# Construction names resolve lazily through core.sfa / core.sfa_jax (PEP 562):
# repro.construction imports core submodules while it initializes, so an eager
# import here would be circular when repro.construction is imported first.
_CONSTRUCTION_NAMES = (
    "SFA",
    "FingerprintCollision",
    "SFAStats",
    "StateBlowup",
    "construct_sfa",
    "construct_sfa_sequential",
    "construct_sfa_vectorized",
)


def __getattr__(name: str):
    if name in _CONSTRUCTION_NAMES:
        from .. import construction

        return getattr(construction, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = sorted(
    [k for k in dir() if not k.startswith("_")] + list(_CONSTRUCTION_NAMES)
)
