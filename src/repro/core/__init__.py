"""Core library: the paper's contribution (SFA construction + parallel
matching with Rabin fingerprints) and the monoid machinery it generalizes to.
"""

from .dfa import DFA, compile_dfa, example_fa, minimize, random_dfa, subset_construct
from .fingerprint import (
    BarrettConstants,
    DEFAULT_POLY_LOW,
    barrett_reduce_int,
    clmul_int,
    fingerprint_int,
    fingerprint_states,
    fingerprint_states_np,
    is_irreducible,
    nth_poly_low,
    poly_mod_int,
    random_irreducible_poly64,
)
from .matching import (
    accepts_parallel,
    distributed_match_fn,
    find_matches_parallel,
    match_parallel_enumeration,
    match_parallel_sfa,
    throughput_matcher,
)
from .multipattern import (
    PatternBank,
    bank_hits,
    census_bank,
    census_sequential,
    distributed_bank_matcher,
    distributed_census_fn,
    match_bank_parallel,
)
from .monoid import (
    Monoid,
    affine_monoid,
    exclusive_scan,
    function_monoid,
    reduce,
    scan,
    shard_exclusive_scan,
    shard_reduce,
    softmax_monoid,
)
from .prosite import (
    PROSITE_EXTRA,
    PROSITE_SAMPLES,
    compile_prosite,
    load_bank,
    synthetic_protein,
    translate,
)
from .regex import AMINO_ACIDS, compile_nfa, parse
from .sfa import (
    SFA,
    FingerprintCollision,
    SFAStats,
    StateBlowup,
    construct_sfa,
    construct_sfa_sequential,
    construct_sfa_vectorized,
)

__all__ = [k for k in dir() if not k.startswith("_")]
