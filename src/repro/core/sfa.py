"""Compatibility shim: SFA construction moved to :mod:`repro.construction`.

The three engines that used to live here (sequential, vectorized, and the
jitted jax engine in ``sfa_jax.py``) were consolidated behind one worklist
core with pluggable membership stores, plus the bank-native
``construct_bank`` batched path and the content-addressed ``SFACache`` —
see :mod:`repro.construction`. This module re-exports the long-standing
public names so existing imports keep working; new code should import from
``repro.construction`` directly.
"""

from __future__ import annotations

from ..construction import (  # noqa: F401
    SFA,
    FingerprintCollision,
    SFAStats,
    StateBlowup,
    construct_sfa,
    construct_sfa_sequential,
    construct_sfa_vectorized,
)

__all__ = [
    "SFA",
    "FingerprintCollision",
    "SFAStats",
    "StateBlowup",
    "construct_sfa",
    "construct_sfa_sequential",
    "construct_sfa_vectorized",
]
