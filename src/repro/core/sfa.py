"""Simultaneous DFA (SFA) construction — the paper's core contribution.

Given a DFA ``A`` with ``n`` states, the SFA ``S(A)`` has one state per
*reachable transition function*: an SFA state is a vector ``f`` of ``n`` DFA
states (``f[q]`` = where ``A`` lands starting from ``q``), the start state is
the identity mapping, and ``δ_s(f, σ)[q] = δ(f[q], σ)``. Matching a string
chunk through the SFA yields the transition function of the whole chunk, so
chunks can be matched in parallel and combined by function composition
(see ``core.matching``).

Construction is a worklist closure (paper Alg. 1) that can blow up to
``O(n^n)`` states; the paper's optimizations — Rabin fingerprints, fingerprint
hashing, parallel expansion over frontier states × symbols, transposed
transition tables — are all reproduced here in three engines:

* ``engine="sequential"``: the faithful Algorithm 1 with independent toggles
  for fingerprints and hashing (reproduces the paper's Fig. 4 ablation).
* ``engine="vectorized"``: the TPU-shaped algorithm run on NumPy — the whole
  frontier × alphabet expands in one fused gather on the *transposed*
  transition table; membership is fingerprint sort + searchsorted (the
  TPU-idiomatic equivalent of the paper's hash table). This is the fast CPU
  path used by benchmarks.
* ``engine="jax"``: the same bulk-synchronous frontier algorithm expressed in
  jitted JAX with fixed-capacity buffers — the path that runs on TPU and that
  ``shard_map`` distributes (see ``core/matching.py`` and benchmarks).

Exactness: like the paper, equal fingerprints never merge states silently.
The sequential engine chains and exact-compares; the bulk engines detect
fp-equal-but-vector-unequal events and raise ``FingerprintCollision``; the
``construct_sfa`` wrapper retries with a fresh random irreducible polynomial,
so the returned SFA is always the exact SFA.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from .dfa import DFA
from .fingerprint import (
    BarrettConstants,
    fingerprint_int,
    fingerprint_states_np,
    nth_poly_low,
)


class FingerprintCollision(RuntimeError):
    """Two distinct state vectors produced the same 64-bit fingerprint."""


class StateBlowup(RuntimeError):
    """SFA state count exceeded the configured cap (the O(n^n) problem)."""


@dataclass
class SFAStats:
    engine: str
    rounds: int = 0
    candidates: int = 0
    fp_compares: int = 0
    exact_compares: int = 0
    collisions_detected: int = 0
    wall_time_s: float = 0.0


@dataclass
class SFA:
    """The simultaneous automaton.

    ``mappings[i]`` is the state vector of SFA state ``i``; ``delta[i, a]`` is
    the SFA transition table; state 0 is the start (identity mapping).
    """

    mappings: np.ndarray      # (S, n) int32
    delta: np.ndarray         # (S, |Σ|) int32
    fingerprints: np.ndarray  # (S, 2) uint32 [hi, lo]
    dfa: DFA
    stats: SFAStats

    @property
    def n_states(self) -> int:
        return int(self.mappings.shape[0])

    @property
    def start(self) -> int:
        return 0

    def accepting_states(self) -> np.ndarray:
        """F_s = { f | f(q0) ∈ F } (paper line 11, with I = {q0})."""
        return self.dfa.accepting[self.mappings[:, self.dfa.start]]

    def run(self, symbols: np.ndarray, state: int | None = None) -> int:
        """Run the SFA like a plain DFA (one table lookup per character)."""
        s = 0 if state is None else state
        tbl = self.delta
        for x in np.asarray(symbols, dtype=np.int64):
            s = int(tbl[s, x])
        return s

    def mapping_of(self, symbols: np.ndarray) -> np.ndarray:
        """Transition function of the whole input string, as a vector."""
        return self.mappings[self.run(symbols)]


# ==========================================================================
# Faithful sequential construction (paper Algorithm 1, with §III-A toggles)
# ==========================================================================


def construct_sfa_sequential(
    dfa: DFA,
    *,
    use_fingerprints: bool = True,
    use_hashing: bool = True,
    poly_index: int = 0,
    max_states: int = 1_000_000,
) -> SFA:
    """Algorithm 1 with the paper's §III-A optimizations as toggles.

    - fingerprints off: membership is the exhaustive vector comparison against
      every known state (the paper's baseline — O(|Q|·|Q_s|) per test).
    - fingerprints on, hashing off: linear scan compares 64-bit fingerprints,
      exact vector compare only on fingerprint equality.
    - hashing on (requires fingerprints): dict keyed by fingerprint with
      collision chains — the paper's hash table, O(1) expected.
    """
    if use_hashing and not use_fingerprints:
        raise ValueError("hashing requires fingerprints (paper §III-A)")
    t0 = time.perf_counter()
    stats = SFAStats(engine="sequential")
    consts = BarrettConstants.create(nth_poly_low(poly_index))
    n, k = dfa.n_states, dfa.n_symbols
    table = dfa.table

    def fp_of(vec: np.ndarray) -> int:
        packed = _pack16(vec)
        return fingerprint_int(packed, consts)

    identity = np.arange(n, dtype=np.int32)
    mappings: list = [identity]
    fps: list = [fp_of(identity) if use_fingerprints else 0]
    hash_table: dict = {fps[0]: [0]} if use_hashing else {}
    delta_rows: list = []
    worklist = [0]  # FIFO -> BFS discovery order (shared by all engines)
    head = 0

    while head < len(worklist):
        cur = worklist[head]
        head += 1
        stats.rounds += 1
        row = np.empty(k, dtype=np.int32)
        cur_vec = mappings[cur]
        for a in range(k):
            nxt = table[cur_vec, a]  # f_next(q) = δ(f(q), σ) (paper line 6)
            stats.candidates += 1
            idx = _lookup_sequential(
                nxt, mappings, fps, hash_table, stats,
                use_fingerprints, use_hashing, fp_of,
            )
            if idx is None:
                idx = len(mappings)
                if idx >= max_states:
                    raise StateBlowup(f"SFA exceeded {max_states} states")
                mappings.append(np.asarray(nxt, dtype=np.int32))
                f = fp_of(nxt) if use_fingerprints else 0
                fps.append(f)
                if use_hashing:
                    hash_table.setdefault(f, []).append(idx)
                worklist.append(idx)
            row[a] = idx
        delta_rows.append(row)

    stats.wall_time_s = time.perf_counter() - t0
    mapped = np.stack(mappings).astype(np.int32)
    return SFA(
        mappings=mapped,
        delta=np.stack(delta_rows).astype(np.int32),
        fingerprints=_fps_to_u32_pairs(fps),
        dfa=dfa,
        stats=stats,
    )


def _lookup_sequential(nxt, mappings, fps, hash_table, stats,
                       use_fingerprints, use_hashing, fp_of):
    if not use_fingerprints:
        # Paper baseline: exhaustive comparison against all known states.
        for i, m in enumerate(mappings):
            stats.exact_compares += 1
            if np.array_equal(m, nxt):
                return i
        return None
    f = fp_of(nxt)
    if use_hashing:
        chain = hash_table.get(f, ())
        stats.fp_compares += 1
        for i in chain:
            stats.exact_compares += 1
            if np.array_equal(mappings[i], nxt):
                return i
            stats.collisions_detected += 1
        return None
    # fingerprints without hashing: linear fingerprint scan.
    for i, fi in enumerate(fps):
        stats.fp_compares += 1
        if fi == f:
            stats.exact_compares += 1
            if np.array_equal(mappings[i], nxt):
                return i
            stats.collisions_detected += 1
    return None


def _pack16(vec: np.ndarray) -> np.ndarray:
    v = np.asarray(vec, dtype=np.uint32)
    if v.shape[0] % 2:
        v = np.pad(v, (0, 1))
    return (v[0::2] & 0xFFFF) | ((v[1::2] & 0xFFFF) << 16)


def _fps_to_u32_pairs(fps: list) -> np.ndarray:
    arr = np.zeros((len(fps), 2), dtype=np.uint32)
    for i, f in enumerate(fps):
        arr[i, 0] = (f >> 32) & 0xFFFFFFFF
        arr[i, 1] = f & 0xFFFFFFFF
    return arr


# ==========================================================================
# Vectorized frontier construction (the TPU-shaped algorithm, on NumPy)
# ==========================================================================


def construct_sfa_vectorized(
    dfa: DFA,
    *,
    poly_index: int = 0,
    max_states: int = 4_000_000,
    tile: int = 4096,
) -> SFA:
    """Bulk-synchronous frontier closure.

    Per round, the *whole frontier × alphabet* expands in one fused gather on
    the transposed transition table (paper §III-B3: symbol-major layout), all
    candidates are fingerprinted in one vectorized fold (paper §III-A), and
    set membership is fingerprint ``searchsorted`` against the sorted known
    set — the bulk equivalent of the paper's hash table. Discovery order is
    row-major (frontier, symbol), identical to the sequential engine's FIFO
    BFS, so the two engines produce bit-identical SFAs.
    """
    t0 = time.perf_counter()
    stats = SFAStats(engine="vectorized")
    consts = BarrettConstants.create(nth_poly_low(poly_index))
    n, k = dfa.n_states, dfa.n_symbols
    if n >= 1 << 16:
        raise ValueError("vectorized engine packs 16-bit state ids (paper layout)")
    tableT = dfa.transposed()  # (k, n) symbol-major

    identity = np.arange(n, dtype=np.int32)[None]
    mappings = identity.copy()                       # (S, n)
    fps = _fp64_np(identity, consts)                 # (S,) uint64
    order = np.argsort(fps, kind="stable")           # sorted view indices
    delta = np.zeros((0, k), dtype=np.int32)
    frontier_lo = 0                                  # mappings[frontier_lo:] unprocessed

    while frontier_lo < mappings.shape[0]:
        stats.rounds += 1
        frontier = mappings[frontier_lo:]
        new_rows = []
        for t in range(0, frontier.shape[0], tile):
            ft = frontier[t : t + tile]              # (m, n)
            m = ft.shape[0]
            # Fused expansion: next[f, σ, q] = δT[σ, f[q]]  — one gather.
            cand = tableT[:, ft]                     # (k, m, n)
            cand = np.ascontiguousarray(np.swapaxes(cand, 0, 1))  # (m, k, n)
            cand = cand.reshape(m * k, n)
            stats.candidates += m * k
            cfps = _fp64_np(cand, consts)            # (m*k,)

            ids, mappings, fps, order, n_new = _assign_ids_bulk(
                cand, cfps, mappings, fps, order, stats, max_states
            )
            new_rows.append(ids.reshape(m, k))
        delta = np.concatenate([delta, *new_rows], axis=0)
        frontier_lo = delta.shape[0]

    stats.wall_time_s = time.perf_counter() - t0
    return SFA(
        mappings=mappings,
        delta=delta,
        fingerprints=_u64_to_pairs(fps),
        dfa=dfa,
        stats=stats,
    )


def _fp64_np(states: np.ndarray, consts: BarrettConstants) -> np.ndarray:
    pair = fingerprint_states_np(states, consts)
    return (pair[..., 0].astype(np.uint64) << np.uint64(32)) | pair[..., 1].astype(
        np.uint64
    )


def _u64_to_pairs(fps: np.ndarray) -> np.ndarray:
    out = np.empty((fps.shape[0], 2), dtype=np.uint32)
    out[:, 0] = (fps >> np.uint64(32)).astype(np.uint32)
    out[:, 1] = (fps & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return out


def _assign_ids_bulk(cand, cfps, mappings, fps, order, stats, max_states):
    """Map each candidate row to its SFA id, appending unseen states.

    Candidates are deduplicated *in first-occurrence order* and checked
    against the known set via fingerprint searchsorted; fingerprint hits are
    confirmed with an exact vector compare (collision -> raise).
    """
    n_cand = cand.shape[0]

    # --- membership test against the known set -----------------------------
    sorted_fps = fps[order]
    pos = np.searchsorted(sorted_fps, cfps)
    pos_c = np.minimum(pos, len(sorted_fps) - 1)
    fp_hit = sorted_fps[pos_c] == cfps
    stats.fp_compares += n_cand
    known_idx = np.where(fp_hit, order[pos_c], -1)

    hit_rows = np.flatnonzero(fp_hit)
    if hit_rows.size:
        stats.exact_compares += int(hit_rows.size)
        exact = np.all(cand[hit_rows] == mappings[known_idx[hit_rows]], axis=1)
        if not np.all(exact):
            stats.collisions_detected += int(np.sum(~exact))
            raise FingerprintCollision(
                f"{int(np.sum(~exact))} fingerprint collisions detected"
            )

    ids = known_idx.copy()

    # --- dedup + append the genuinely new candidates ------------------------
    new_rows = np.flatnonzero(known_idx < 0)
    if new_rows.size:
        new_fps = cfps[new_rows]
        uniq_fp, first_pos, inverse = np.unique(
            new_fps, return_index=True, return_inverse=True
        )
        # Exactness within the round: all rows in an fp-group must be equal
        # to the group representative.
        reps = cand[new_rows[first_pos]]          # (U, n)
        same = np.all(cand[new_rows] == reps[inverse], axis=1)
        if not np.all(same):
            stats.collisions_detected += int(np.sum(~same))
            raise FingerprintCollision("intra-round fingerprint collision")
        # Renumber unique states by first occurrence (BFS order).
        occ_order = np.argsort(first_pos, kind="stable")
        rank_of_uniq = np.empty_like(occ_order)
        rank_of_uniq[occ_order] = np.arange(occ_order.size)
        base = mappings.shape[0]
        if base + occ_order.size > max_states:
            raise StateBlowup(f"SFA exceeded {max_states} states")
        ids[new_rows] = base + rank_of_uniq[inverse]

        append_states = reps[occ_order]
        append_fps = uniq_fp[occ_order]
        mappings = np.concatenate([mappings, append_states], axis=0)
        fps = np.concatenate([fps, append_fps])
        order = np.argsort(fps, kind="stable")  # re-sort the known set
    return ids.astype(np.int32), mappings, fps, order, int(new_rows.size)


# ==========================================================================
# Public wrapper: exactness via collision retry
# ==========================================================================


def construct_sfa(
    dfa: DFA,
    *,
    engine: str = "vectorized",
    max_states: int = 4_000_000,
    max_retries: int = 4,
    **kwargs,
) -> SFA:
    """Construct the exact SFA; on a detected fingerprint collision, retry
    with a fresh random irreducible polynomial (paper §II: P is random)."""
    last: Exception | None = None
    for attempt in range(max_retries):
        try:
            if engine == "sequential":
                return construct_sfa_sequential(
                    dfa, poly_index=attempt, max_states=max_states, **kwargs
                )
            if engine == "vectorized":
                return construct_sfa_vectorized(
                    dfa, poly_index=attempt, max_states=max_states, **kwargs
                )
            if engine == "jax":
                from . import sfa_jax

                return sfa_jax.construct_sfa_jax(
                    dfa, poly_index=attempt, max_states=max_states, **kwargs
                )
            raise ValueError(f"unknown engine {engine!r}")
        except FingerprintCollision as e:  # pragma: no cover (astronomically rare)
            last = e
    raise last  # pragma: no cover
