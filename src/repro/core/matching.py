"""Chunk-level matching primitives (paper §I, §IV-C) + legacy shims.

The dependency chain ``state ← δ(state, Str[i])`` makes plain DFA matching
sequential. The SFA breaks it: split the input into chunks, compute each
chunk's *transition function* independently, and combine the functions
associatively (``core.monoid.function_monoid``). Two ways to get a chunk's
function:

* **SFA mode** (the paper): run the SFA like a DFA — one ``δ_s`` lookup per
  character — and read the mapping off the final SFA state. Requires the
  constructed SFA; per-char cost identical to plain DFA matching.
* **Enumeration mode** (Mytkowicz et al., the paper's related work): run all
  ``n`` DFA instances per chunk as a vectorized gather — no SFA needed, per
  char cost is an ``n``-wide gather (cheap on a VPU, and how we match when
  the SFA would blow up).

This module now holds only the *per-chunk* primitives and the sequential
references; the parallel entry points that used to live here moved to
``repro.engine.executors`` behind the :class:`repro.engine.Scanner` facade.
(The deprecation shims that bridged the move were removed after two further
PRs touched every call site, per the PR-2 policy — import from
``repro.engine.executors`` or use the ``Scanner``.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .dfa import DFA


# --------------------------------------------------------------------------
# Reference: sequential matching (paper Fig. 1c)
# --------------------------------------------------------------------------


def match_sequential(dfa: DFA, symbols: np.ndarray) -> int:
    return dfa.run(symbols)


def match_ends_sequential(dfa: DFA, symbols: np.ndarray) -> np.ndarray:
    """Accepting-state flag after every position (for match localization)."""
    out = np.zeros(len(symbols), dtype=bool)
    s = dfa.start
    for i, x in enumerate(np.asarray(symbols, dtype=np.int64)):
        s = int(dfa.table[s, x])
        out[i] = bool(dfa.accepting[s])
    return out


# --------------------------------------------------------------------------
# Chunk matchers (jit-safe primitives; the engine vmaps these over the
# chunk, doc, and pattern axes)
# --------------------------------------------------------------------------


def chunk_mapping_enumeration(table: jnp.ndarray, chunk: jnp.ndarray) -> jnp.ndarray:
    """Transition function of one chunk by running all n states at once.

    ``table``: (n, k) int32; ``chunk``: (L,) int32 -> mapping (n,) int32.
    The per-step ``table[state_vec, sym]`` gather is the transposed-table
    access pattern of paper §III-B3 — symbol-major row stays hot.
    """
    n = table.shape[0]

    def step(state_vec, sym):
        return table[state_vec, sym], None

    out, _ = jax.lax.scan(step, jnp.arange(n, dtype=jnp.int32), chunk)
    return out


def chunk_state_sfa(delta_s: jnp.ndarray, chunk: jnp.ndarray,
                    start: jnp.ndarray | int = 0) -> jnp.ndarray:
    """Final SFA state of one chunk (single lookup per character)."""

    def step(s, sym):
        return delta_s[s, sym], None

    out, _ = jax.lax.scan(step, jnp.asarray(start, dtype=jnp.int32), chunk)
    return out


def chunk_accept_trace(table: jnp.ndarray, accepting: jnp.ndarray,
                       chunk: jnp.ndarray, entry_state: jnp.ndarray) -> jnp.ndarray:
    """Accept flags per position for one chunk given its entry state."""

    def step(s, sym):
        nxt = table[s, sym]
        return nxt, accepting[nxt]

    _, flags = jax.lax.scan(step, entry_state.astype(jnp.int32), chunk)
    return flags


