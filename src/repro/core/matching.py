"""Parallel FA matching with SFAs (paper §I, §IV-C).

The dependency chain ``state ← δ(state, Str[i])`` makes plain DFA matching
sequential. The SFA breaks it: split the input into chunks, compute each
chunk's *transition function* independently, and combine the functions
associatively (``core.monoid.function_monoid``). Two ways to get a chunk's
function:

* **SFA mode** (the paper): run the SFA like a DFA — one ``δ_s`` lookup per
  character — and read the mapping off the final SFA state. Requires the
  constructed SFA; per-char cost identical to plain DFA matching.
* **Enumeration mode** (Mytkowicz et al., the paper's related work): run all
  ``n`` DFA instances per chunk as a vectorized gather — no SFA needed, per
  char cost is an ``n``-wide gather (cheap on a VPU, and how we match when
  the SFA would blow up).

Distribution: chunks shard across devices (``shard_map`` over the ``data``
axis); each device matches its chunks locally and the per-device functions
are combined with ``monoid.shard_reduce`` — an ``all_gather`` of n-int
vectors, the pod-scale version of the paper's result-vector reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..compat import shard_map as compat_shard_map
from . import monoid as M
from .dfa import DFA
from .sfa import SFA

FN = M.function_monoid()


# --------------------------------------------------------------------------
# Reference: sequential matching (paper Fig. 1c)
# --------------------------------------------------------------------------


def match_sequential(dfa: DFA, symbols: np.ndarray) -> int:
    return dfa.run(symbols)


def match_ends_sequential(dfa: DFA, symbols: np.ndarray) -> np.ndarray:
    """Accepting-state flag after every position (for match localization)."""
    out = np.zeros(len(symbols), dtype=bool)
    s = dfa.start
    for i, x in enumerate(np.asarray(symbols, dtype=np.int64)):
        s = int(dfa.table[s, x])
        out[i] = bool(dfa.accepting[s])
    return out


# --------------------------------------------------------------------------
# Chunk matchers (jitted)
# --------------------------------------------------------------------------


def chunk_mapping_enumeration(table: jnp.ndarray, chunk: jnp.ndarray) -> jnp.ndarray:
    """Transition function of one chunk by running all n states at once.

    ``table``: (n, k) int32; ``chunk``: (L,) int32 -> mapping (n,) int32.
    The per-step ``table[state_vec, sym]`` gather is the transposed-table
    access pattern of paper §III-B3 — symbol-major row stays hot.
    """
    n = table.shape[0]

    def step(state_vec, sym):
        return table[state_vec, sym], None

    out, _ = jax.lax.scan(step, jnp.arange(n, dtype=jnp.int32), chunk)
    return out


def chunk_state_sfa(delta_s: jnp.ndarray, chunk: jnp.ndarray,
                    start: jnp.ndarray | int = 0) -> jnp.ndarray:
    """Final SFA state of one chunk (single lookup per character)."""

    def step(s, sym):
        return delta_s[s, sym], None

    out, _ = jax.lax.scan(step, jnp.asarray(start, dtype=jnp.int32), chunk)
    return out


def chunk_accept_trace(table: jnp.ndarray, accepting: jnp.ndarray,
                       chunk: jnp.ndarray, entry_state: jnp.ndarray) -> jnp.ndarray:
    """Accept flags per position for one chunk given its entry state."""

    def step(s, sym):
        nxt = table[s, sym]
        return nxt, accepting[nxt]

    _, flags = jax.lax.scan(step, entry_state.astype(jnp.int32), chunk)
    return flags


# --------------------------------------------------------------------------
# Single-host parallel matching
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def match_parallel_enumeration(table: jnp.ndarray, symbols: jnp.ndarray,
                               n_chunks: int = 8) -> jnp.ndarray:
    """Parallel match via enumeration; returns the mapping of the whole input.

    The input length must be divisible by ``n_chunks`` (callers pad; padding
    symbols would corrupt the composed function otherwise).
    """
    L = symbols.shape[0]
    assert L % n_chunks == 0, "pad input to a multiple of n_chunks"
    chunks = symbols.reshape(n_chunks, L // n_chunks)
    mappings = jax.vmap(lambda c: chunk_mapping_enumeration(table, c))(chunks)
    return M.reduce(FN, mappings, axis=0)


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def match_parallel_sfa(delta_s: jnp.ndarray, sfa_mappings: jnp.ndarray,
                       symbols: jnp.ndarray, n_chunks: int = 8) -> jnp.ndarray:
    """Parallel match via the SFA (paper's method); returns the input mapping."""
    L = symbols.shape[0]
    assert L % n_chunks == 0
    chunks = symbols.reshape(n_chunks, L // n_chunks)
    final_states = jax.vmap(lambda c: chunk_state_sfa(delta_s, c))(chunks)
    mappings = sfa_mappings[final_states]  # (n_chunks, n)
    return M.reduce(FN, mappings, axis=0)


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def find_matches_parallel(table: jnp.ndarray, accepting: jnp.ndarray,
                          symbols: jnp.ndarray, start: int,
                          n_chunks: int = 8) -> jnp.ndarray:
    """Per-position accept flags, computed in two parallel passes:
    (1) chunk functions + exclusive scan -> entry state per chunk;
    (2) per-chunk accept traces from the entry states."""
    L = symbols.shape[0]
    assert L % n_chunks == 0
    chunks = symbols.reshape(n_chunks, L // n_chunks)
    mappings = jax.vmap(lambda c: chunk_mapping_enumeration(table, c))(chunks)
    prefix = M.exclusive_scan(FN, mappings, axis=0)      # (n_chunks, n)
    entry = prefix[:, start]                              # (n_chunks,)
    flags = jax.vmap(lambda c, e: chunk_accept_trace(table, accepting, c, e))(
        chunks, entry
    )
    return flags.reshape(L)


def accepts_parallel(dfa: DFA, text: str, n_chunks: int = 8,
                     sfa: SFA | None = None) -> bool:
    """End-to-end helper: does ``text`` match? (pads to chunk multiple)."""
    symbols = jnp.asarray(dfa.encode(text))
    L = symbols.shape[0]
    chunk_len = -(-L // n_chunks)
    pad = chunk_len * n_chunks - L
    if pad:
        # Pad the *front* with a harmless loop at the start state: we instead
        # simply process the unpadded tail sequentially — cheap (< chunk_len).
        head_len = L - (L % n_chunks) if L % n_chunks else L
        head = symbols[:head_len]
        tail = symbols[head_len:]
    else:
        head, tail = symbols, symbols[:0]
    if head.shape[0]:
        if sfa is not None:
            mapping = match_parallel_sfa(
                jnp.asarray(sfa.delta), jnp.asarray(sfa.mappings), head, n_chunks
            )
        else:
            mapping = match_parallel_enumeration(jnp.asarray(dfa.table), head, n_chunks)
        state = int(mapping[dfa.start])
    else:
        state = dfa.start
    state = dfa.run(np.asarray(tail), state=state)
    return bool(dfa.accepting[state])


# --------------------------------------------------------------------------
# Distributed matching (shard_map over the data axis)
# --------------------------------------------------------------------------


def distributed_match_fn(mesh: Mesh, table_shape: tuple, axis_name: str = "data"):
    """Build a pjit-able distributed matcher for a given mesh.

    Input ``symbols`` (L,) is sharded over ``axis_name``; each device runs
    enumeration matching on its shard (vectorized over sub-chunks for VPU
    utilization), then per-device functions combine via ``shard_reduce``
    (one all_gather of n-int vectors — the paper's result reduction).
    Returns ``mapping`` (n,) replicated.
    """
    n, _ = table_shape
    n_dev = mesh.shape[axis_name]

    def local_match(table, sym_shard, sub_chunks: int):
        L = sym_shard.shape[0]
        chunks = sym_shard.reshape(sub_chunks, L // sub_chunks)
        mappings = jax.vmap(lambda c: chunk_mapping_enumeration(table, c))(chunks)
        local = M.reduce(FN, mappings, axis=0)
        return M.shard_reduce(FN, local[None], axis_name)[0]

    @functools.partial(jax.jit, static_argnames=("sub_chunks",))
    def matcher(table, symbols, sub_chunks: int = 8):
        fn = compat_shard_map(
            functools.partial(local_match, sub_chunks=sub_chunks),
            mesh=mesh,
            in_specs=(P(), P(axis_name)),
            out_specs=P(),
            check_vma=False,
        )
        return fn(table, symbols)

    return matcher


def throughput_matcher(mesh: Mesh, start: int = 0, axis_name: str = "data"):
    """Batched many-strings matcher: (B, L) inputs sharded over ``axis_name``
    on the batch axis, each row matched independently (the network-security
    style throughput workload from the related work, for completeness)."""

    def local(table, accepting, batch):
        def per_row(row):
            mapping = chunk_mapping_enumeration(table, row)
            return accepting[mapping[start]]

        return jax.vmap(per_row)(batch)

    @jax.jit
    def matcher(table, accepting, batch):
        fn = compat_shard_map(
            local,
            mesh=mesh,
            in_specs=(P(), P(), P(axis_name)),
            out_specs=P(axis_name),
            check_vma=False,
        )
        return fn(table, accepting, batch)

    return matcher
