"""Deterministic finite automata: subset construction, Hopcroft minimization,
and the dense transition-table representation used throughout the framework.

The DFA here is always *complete* (every (state, symbol) has a target), so the
transition table is a dense ``(n_states, n_symbols)`` int32 array — the layout
the paper's SFA construction, the transposed-table locality optimization, and
our TPU kernels all assume.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

from .regex import AMINO_ACIDS, NFA, compile_nfa


@dataclass
class DFA:
    table: np.ndarray  # (n_states, n_symbols) int32
    start: int
    accepting: np.ndarray  # (n_states,) bool
    alphabet: str

    @property
    def n_states(self) -> int:
        return int(self.table.shape[0])

    @property
    def n_symbols(self) -> int:
        return int(self.table.shape[1])

    # -- execution ---------------------------------------------------------
    def encode(self, text: str) -> np.ndarray:
        sym = {c: i for i, c in enumerate(self.alphabet)}
        return np.asarray([sym[c] for c in text], dtype=np.int32)

    def run(self, symbols: np.ndarray, state: int | None = None) -> int:
        """Sequential matching routine (paper Fig. 1c)."""
        s = self.start if state is None else state
        tbl = self.table
        for x in np.asarray(symbols, dtype=np.int64):
            s = int(tbl[s, x])
        return s

    def accepts(self, text: str) -> bool:
        return bool(self.accepting[self.run(self.encode(text))])

    def transposed(self) -> np.ndarray:
        """Symbol-major transition table (paper §III-B3)."""
        return np.ascontiguousarray(self.table.T)


# --------------------------------------------------------------------------
# Subset construction (NFA -> DFA)
# --------------------------------------------------------------------------


def subset_construct(nfa: NFA) -> DFA:
    start_set = nfa.eps_closure([nfa.start])
    index: dict = {start_set: 0}
    worklist = [start_set]
    rows: list = []
    accepting: list = []
    while worklist:
        cur = worklist.pop()
        # Rows may be discovered out of order; fill placeholders first.
        while len(rows) <= index[cur]:
            rows.append(None)
            accepting.append(False)
        row = np.zeros(nfa.n_symbols, dtype=np.int32)
        for sym in range(nfa.n_symbols):
            nxt = nfa.step(cur, sym)
            if nxt not in index:
                index[nxt] = len(index)
                worklist.append(nxt)
            row[sym] = index[nxt]
        rows[index[cur]] = row
        accepting[index[cur]] = nfa.accept in cur
    table = np.stack(rows).astype(np.int32)
    return DFA(
        table=table,
        start=0,
        accepting=np.asarray(accepting, dtype=bool),
        alphabet=nfa.alphabet,
    )


# --------------------------------------------------------------------------
# Hopcroft minimization
# --------------------------------------------------------------------------


def minimize(dfa: DFA) -> DFA:
    n, k = dfa.n_states, dfa.n_symbols
    # Pre-compute inverse transitions: inv[sym][target] = list of sources.
    inv: list = [[[] for _ in range(n)] for _ in range(k)]
    for s in range(n):
        for a in range(k):
            inv[a][int(dfa.table[s, a])].append(s)

    accepting = set(np.flatnonzero(dfa.accepting).tolist())
    rejecting = set(range(n)) - accepting
    partitions: list = [p for p in (accepting, rejecting) if p]
    work = [p.copy() for p in partitions]

    while work:
        splitter = work.pop()
        for a in range(k):
            pre = set()
            for t in splitter:
                pre.update(inv[a][t])
            new_parts = []
            for p in partitions:
                inter = p & pre
                diff = p - pre
                if inter and diff:
                    new_parts.append(inter)
                    new_parts.append(diff)
                    if p in work:
                        work.remove(p)
                        work.append(inter)
                        work.append(diff)
                    else:
                        work.append(inter if len(inter) <= len(diff) else diff)
                else:
                    new_parts.append(p)
            partitions = new_parts

    # Renumber blocks; keep the start state's block as state 0.
    block_of = np.zeros(n, dtype=np.int64)
    for bi, p in enumerate(partitions):
        for s in p:
            block_of[s] = bi
    order = [int(block_of[dfa.start])]
    order += [b for b in range(len(partitions)) if b != order[0]]
    renum = {b: i for i, b in enumerate(order)}

    m = len(partitions)
    table = np.zeros((m, k), dtype=np.int32)
    accepting_out = np.zeros(m, dtype=bool)
    for bi, p in enumerate(partitions):
        rep = next(iter(p))
        for a in range(k):
            table[renum[bi], a] = renum[int(block_of[int(dfa.table[rep, a])])]
        accepting_out[renum[bi]] = bool(dfa.accepting[rep])
    return DFA(table=table, start=0, accepting=accepting_out, alphabet=dfa.alphabet)


# --------------------------------------------------------------------------
# High-level compilers
# --------------------------------------------------------------------------


def compile_dfa(
    pattern: str,
    alphabet: str = AMINO_ACIDS,
    *,
    search: bool = True,
    minimize_dfa: bool = True,
) -> DFA:
    """Compile a regex to a minimal complete DFA.

    With ``search=True`` the DFA accepts any string *containing* a match
    (``Σ* pattern Σ*`` semantics — the paper's Fig. 1 "contains RG" example):
    we prepend ``.*`` and make accepting states absorbing.
    """
    pat = f"(.*)({pattern})" if search else pattern
    dfa = subset_construct(compile_nfa(pat, alphabet))
    if search:
        dfa = _make_accepting_absorbing(dfa)
    if minimize_dfa:
        dfa = minimize(dfa)
    return dfa


def _make_accepting_absorbing(dfa: DFA) -> DFA:
    table = dfa.table.copy()
    for s in np.flatnonzero(dfa.accepting):
        table[s, :] = s
    return replace(dfa, table=table)


def example_fa() -> DFA:
    """The paper's running example (Fig. 1): accepts strings containing "RG"."""
    return compile_dfa("RG", AMINO_ACIDS, search=True)


def random_dfa(
    n_states: int,
    n_symbols: int,
    *,
    seed: int = 0,
    n_accepting: int = 1,
) -> DFA:
    """Random complete DFA — used by property tests and synthetic benchmarks."""
    rng = np.random.default_rng(seed)
    table = rng.integers(0, n_states, size=(n_states, n_symbols), dtype=np.int32)
    accepting = np.zeros(n_states, dtype=bool)
    accepting[rng.choice(n_states, size=min(n_accepting, n_states), replace=False)] = True
    alphabet = AMINO_ACIDS[:n_symbols] if n_symbols <= len(AMINO_ACIDS) else "".join(
        chr(ord("a") + i) for i in range(n_symbols)
    )
    return DFA(table=table, start=0, accepting=accepting, alphabet=alphabet)
