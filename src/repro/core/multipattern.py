"""Multi-pattern SFA matching: batched PROSITE scans (paper §IV, one level up).

The paper's evaluation workload is the PROSITE protein database — *hundreds*
of signatures scanned over the same corpus. ``core.matching`` parallelizes a
single DFA over input chunks (the fine-grained axis); this module adds the
coarse-grained axis the paper's §IV task parallelism exploits: run **P
automata at once** by stacking their transition tables into one padded
``(P, n_max, k)`` array and vmapping the chunk matchers over the pattern
axis as well as the chunk axis.

Padding story
-------------
Patterns compile to DFAs of very different sizes, so tables are padded to
the bank's ``n_max`` with **self-loop rows** (state ``j >= n_i`` maps every
symbol back to ``j``). Self-loops keep every table entry a valid state id
(gathers never go out of range under vmap) and make the padded states inert:
they are unreachable from real states, and under function composition a
padded entry ``f[q] = q`` stays the identity. Per-pattern true sizes ride
along in ``PatternBank.n_states`` so results can be cropped when needed.

The batched, distributed, and Pallas matchers that used to live here moved
to ``repro.engine.executors`` behind the :class:`repro.engine.Scanner`
facade (which also adds the stacked-SFA bank mode this module's enumeration
matchers lacked). This module keeps the data structures — ``PatternBank``,
``bucket_by_size``, and the ``census_sequential`` oracle. (The deprecation
shims that bridged the move were removed after two further PRs touched every
call site, per the PR-2 policy.)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Mapping, Sequence

import jax.numpy as jnp
import numpy as np

from .dfa import DFA


# --------------------------------------------------------------------------
# The bank: P automata as one padded table stack
# --------------------------------------------------------------------------


@dataclass
class PatternBank:
    """``P`` complete DFAs over one alphabet, padded to a common state count.

    ``tables[p]`` is pattern ``p``'s transition table, rows ``>= n_states[p]``
    are self-loops; ``accepting[p]``/``starts[p]`` follow the same layout.
    """

    tables: np.ndarray     # (P, n_max, k) int32
    accepting: np.ndarray  # (P, n_max) bool
    starts: np.ndarray     # (P,) int32
    n_states: np.ndarray   # (P,) int32 — true (unpadded) state counts
    ids: tuple
    alphabet: str

    @property
    def n_patterns(self) -> int:
        return int(self.tables.shape[0])

    @property
    def n_max(self) -> int:
        return int(self.tables.shape[1])

    @property
    def n_symbols(self) -> int:
        return int(self.tables.shape[2])

    def encode(self, text: str) -> np.ndarray:
        sym = {c: i for i, c in enumerate(self.alphabet)}
        return np.asarray([sym[c] for c in text], dtype=np.int32)

    def dfa(self, p: int) -> DFA:
        """Crop pattern ``p`` back out of the bank as a standalone DFA."""
        n = int(self.n_states[p])
        return DFA(
            table=np.ascontiguousarray(self.tables[p, :n, :]),
            start=int(self.starts[p]),
            accepting=np.ascontiguousarray(self.accepting[p, :n]),
            alphabet=self.alphabet,
        )

    @classmethod
    def from_dfas(cls, dfas: Sequence[DFA], ids: Iterable[str] | None = None
                  ) -> "PatternBank":
        if not dfas:
            raise ValueError("empty pattern bank")
        alphabet = dfas[0].alphabet
        k = dfas[0].n_symbols
        for d in dfas:
            if d.alphabet != alphabet or d.n_symbols != k:
                raise ValueError("bank patterns must share one alphabet")
        n_max = max(d.n_states for d in dfas)
        p_count = len(dfas)
        tables = np.empty((p_count, n_max, k), dtype=np.int32)
        accepting = np.zeros((p_count, n_max), dtype=bool)
        # Self-loop padding: row j -> j for every symbol (see module docstring).
        pad_rows = np.repeat(np.arange(n_max, dtype=np.int32)[:, None], k, axis=1)
        for p, d in enumerate(dfas):
            tables[p] = pad_rows
            tables[p, : d.n_states] = d.table
            accepting[p, : d.n_states] = d.accepting
        return cls(
            tables=tables,
            accepting=accepting,
            starts=np.asarray([d.start for d in dfas], dtype=np.int32),
            n_states=np.asarray([d.n_states for d in dfas], dtype=np.int32),
            ids=tuple(ids) if ids is not None else tuple(
                f"pattern_{p}" for p in range(p_count)
            ),
            alphabet=alphabet,
        )

    @classmethod
    def from_patterns(cls, patterns: Mapping[str, str] | Sequence[str]
                      ) -> "PatternBank":
        """Compile PROSITE signatures (id -> pattern mapping, or a list)."""
        from .prosite import compile_prosite

        if isinstance(patterns, Mapping):
            ids = tuple(patterns.keys())
            dfas = [compile_prosite(patterns[i]) for i in ids]
        else:
            ids = tuple(f"pattern_{p}" for p in range(len(patterns)))
            dfas = [compile_prosite(p) for p in patterns]
        return cls.from_dfas(dfas, ids)

    def device_arrays(self):
        """(tables, accepting, starts) as jnp arrays, ready for the matchers."""
        return (
            jnp.asarray(self.tables),
            jnp.asarray(self.accepting),
            jnp.asarray(self.starts),
        )


def bucket_by_size(dfas: Sequence[DFA], ids: Iterable[str] | None = None,
                   edges: Sequence[int] = (8, 16, 32, 64, 128, 256, 1024),
                   ) -> list:
    """Split patterns into size-bucketed banks to bound padding waste.

    One padded stack charges every pattern ``n_max``-wide gathers; real
    signature sets span two orders of magnitude in DFA size, so a single
    bank makes the small patterns pay for the largest one. Bucketing by
    state count (bucket ``i`` holds patterns with ``n <= edges[i]``) keeps
    per-bucket padding below ~2x while preserving the batched execution
    within each bucket. Returns the non-empty banks, smallest bucket first.

    The partition itself is :func:`repro.core.bucketing.partition_by_size`
    — the same helper batched construction buckets with.
    """
    from .bucketing import partition_by_size

    ids = list(ids) if ids is not None else [f"pattern_{p}" for p in range(len(dfas))]
    try:
        parts = partition_by_size([d.n_states for d in dfas], edges)
    except ValueError as e:
        raise ValueError(str(e).replace("item", "pattern", 1)) from None
    return [
        PatternBank.from_dfas([dfas[i] for i in idx], [ids[i] for i in idx])
        for _, idx in parts
    ]


def census_sequential(bank: PatternBank, corpus: np.ndarray) -> np.ndarray:
    """Reference census: plain per-pattern, per-sequence DFA loop (paper
    Fig. 1c applied P × D times). The differential-test oracle."""
    counts = np.zeros(bank.n_patterns, dtype=np.int32)
    for p in range(bank.n_patterns):
        d = bank.dfa(p)
        for row in np.asarray(corpus):
            counts[p] += bool(d.accepting[d.run(row)])
    return counts


