"""Multi-pattern SFA matching: batched PROSITE scans (paper §IV, one level up).

The paper's evaluation workload is the PROSITE protein database — *hundreds*
of signatures scanned over the same corpus. ``core.matching`` parallelizes a
single DFA over input chunks (the fine-grained axis); this module adds the
coarse-grained axis the paper's §IV task parallelism exploits: run **P
automata at once** by stacking their transition tables into one padded
``(P, n_max, k)`` array and vmapping the chunk matchers over the pattern
axis as well as the chunk axis.

Padding story
-------------
Patterns compile to DFAs of very different sizes, so tables are padded to
the bank's ``n_max`` with **self-loop rows** (state ``j >= n_i`` maps every
symbol back to ``j``). Self-loops keep every table entry a valid state id
(gathers never go out of range under vmap) and make the padded states inert:
they are unreachable from real states, and under function composition a
padded entry ``f[q] = q`` stays the identity. Per-pattern true sizes ride
along in ``PatternBank.n_states`` so results can be cropped when needed.

Sharding story (patterns × chunks over the mesh)
------------------------------------------------
``distributed_bank_matcher`` lays the bank out over a 2-D mesh: the pattern
axis shards over ``model`` (each device holds ``P/|model|`` tables — the
paper's "each core takes a subset of the patterns" task parallelism) and the
input shards over ``data`` exactly as single-pattern matching does. Each
device matches its pattern shard against its chunk shard locally, then one
fused monoid reduction (``monoid.shard_reduce`` vectorized over the local
pattern axis — a single ``all_gather`` of ``(P_local, n)`` int vectors)
composes the per-device chunk functions along ``data``. The result is the
final mapping of the *whole* input for every pattern, P-sharded over
``model`` — no pattern ever crosses a device boundary, so adding patterns
scales out with zero extra communication volume per pattern beyond its own
n-int mapping vector.

The Pallas twin lives in ``kernels.match_scan.match_bank_chunks_pallas``:
its grid iterates ``(pattern, chunk)`` with the chunk axis innermost, so the
VMEM-resident transposed table is swapped once per *pattern block* and stays
hot across every chunk of that pattern — the §III-B3 locality argument
applied to the bank axis.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from ..compat import shard_map as compat_shard_map
from . import monoid as M
from .dfa import DFA
from .matching import chunk_mapping_enumeration

FN = M.function_monoid()


# --------------------------------------------------------------------------
# The bank: P automata as one padded table stack
# --------------------------------------------------------------------------


@dataclass
class PatternBank:
    """``P`` complete DFAs over one alphabet, padded to a common state count.

    ``tables[p]`` is pattern ``p``'s transition table, rows ``>= n_states[p]``
    are self-loops; ``accepting[p]``/``starts[p]`` follow the same layout.
    """

    tables: np.ndarray     # (P, n_max, k) int32
    accepting: np.ndarray  # (P, n_max) bool
    starts: np.ndarray     # (P,) int32
    n_states: np.ndarray   # (P,) int32 — true (unpadded) state counts
    ids: tuple
    alphabet: str

    @property
    def n_patterns(self) -> int:
        return int(self.tables.shape[0])

    @property
    def n_max(self) -> int:
        return int(self.tables.shape[1])

    @property
    def n_symbols(self) -> int:
        return int(self.tables.shape[2])

    def encode(self, text: str) -> np.ndarray:
        sym = {c: i for i, c in enumerate(self.alphabet)}
        return np.asarray([sym[c] for c in text], dtype=np.int32)

    def dfa(self, p: int) -> DFA:
        """Crop pattern ``p`` back out of the bank as a standalone DFA."""
        n = int(self.n_states[p])
        return DFA(
            table=np.ascontiguousarray(self.tables[p, :n, :]),
            start=int(self.starts[p]),
            accepting=np.ascontiguousarray(self.accepting[p, :n]),
            alphabet=self.alphabet,
        )

    @classmethod
    def from_dfas(cls, dfas: Sequence[DFA], ids: Iterable[str] | None = None
                  ) -> "PatternBank":
        if not dfas:
            raise ValueError("empty pattern bank")
        alphabet = dfas[0].alphabet
        k = dfas[0].n_symbols
        for d in dfas:
            if d.alphabet != alphabet or d.n_symbols != k:
                raise ValueError("bank patterns must share one alphabet")
        n_max = max(d.n_states for d in dfas)
        p_count = len(dfas)
        tables = np.empty((p_count, n_max, k), dtype=np.int32)
        accepting = np.zeros((p_count, n_max), dtype=bool)
        # Self-loop padding: row j -> j for every symbol (see module docstring).
        pad_rows = np.repeat(np.arange(n_max, dtype=np.int32)[:, None], k, axis=1)
        for p, d in enumerate(dfas):
            tables[p] = pad_rows
            tables[p, : d.n_states] = d.table
            accepting[p, : d.n_states] = d.accepting
        return cls(
            tables=tables,
            accepting=accepting,
            starts=np.asarray([d.start for d in dfas], dtype=np.int32),
            n_states=np.asarray([d.n_states for d in dfas], dtype=np.int32),
            ids=tuple(ids) if ids is not None else tuple(
                f"pattern_{p}" for p in range(p_count)
            ),
            alphabet=alphabet,
        )

    @classmethod
    def from_patterns(cls, patterns: Mapping[str, str] | Sequence[str]
                      ) -> "PatternBank":
        """Compile PROSITE signatures (id -> pattern mapping, or a list)."""
        from .prosite import compile_prosite

        if isinstance(patterns, Mapping):
            ids = tuple(patterns.keys())
            dfas = [compile_prosite(patterns[i]) for i in ids]
        else:
            ids = tuple(f"pattern_{p}" for p in range(len(patterns)))
            dfas = [compile_prosite(p) for p in patterns]
        return cls.from_dfas(dfas, ids)

    def device_arrays(self):
        """(tables, accepting, starts) as jnp arrays, ready for the matchers."""
        return (
            jnp.asarray(self.tables),
            jnp.asarray(self.accepting),
            jnp.asarray(self.starts),
        )


def bucket_by_size(dfas: Sequence[DFA], ids: Iterable[str] | None = None,
                   edges: Sequence[int] = (8, 16, 32, 64, 128, 256, 1024),
                   ) -> list:
    """Split patterns into size-bucketed banks to bound padding waste.

    One padded stack charges every pattern ``n_max``-wide gathers; real
    signature sets span two orders of magnitude in DFA size, so a single
    bank makes the small patterns pay for the largest one. Bucketing by
    state count (bucket ``i`` holds patterns with ``n <= edges[i]``) keeps
    per-bucket padding below ~2x while preserving the batched execution
    within each bucket. Returns the non-empty banks, smallest bucket first.
    """
    ids = list(ids) if ids is not None else [f"pattern_{p}" for p in range(len(dfas))]
    buckets: dict = {}
    for d, i in zip(dfas, ids):
        for e in sorted(edges):
            if d.n_states <= e:
                buckets.setdefault(e, ([], []))
                buckets[e][0].append(d)
                buckets[e][1].append(i)
                break
        else:
            raise ValueError(
                f"pattern {i} has {d.n_states} states > max edge {max(edges)}"
            )
    return [
        PatternBank.from_dfas(ds, bids)
        for _, (ds, bids) in sorted(buckets.items())
    ]


# --------------------------------------------------------------------------
# Batched matchers (single host): vmap over the pattern axis
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def match_bank_parallel(tables: jnp.ndarray, symbols: jnp.ndarray,
                        n_chunks: int = 8) -> jnp.ndarray:
    """Final mappings of one input under every pattern.

    ``tables``: (P, n, k) int32; ``symbols``: (L,) with L divisible by
    ``n_chunks`` -> (P, n) int32: row ``p`` is the transition function of the
    whole input under pattern ``p`` (apply to ``starts[p]`` for the final
    state). Chunk functions for all (pattern, chunk) cells compute in one
    doubly-vmapped batch; composition is one monoid reduce over the chunk
    axis, batched over patterns.
    """
    L = symbols.shape[0]
    assert L % n_chunks == 0, "pad input to a multiple of n_chunks"
    chunks = symbols.reshape(n_chunks, L // n_chunks)
    mappings = jax.vmap(
        lambda t: jax.vmap(lambda c: chunk_mapping_enumeration(t, c))(chunks)
    )(tables)                                  # (P, n_chunks, n)
    return M.reduce(FN, mappings, axis=1)      # (P, n)


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def bank_hits(tables: jnp.ndarray, accepting: jnp.ndarray, starts: jnp.ndarray,
              corpus: jnp.ndarray, n_chunks: int = 8) -> jnp.ndarray:
    """Hit matrix of a corpus against the bank.

    ``corpus``: (D, L) int32 (equal-length encoded sequences; pad/crop the
    raw strings first) -> (P, D) bool: ``[p, d]`` iff sequence ``d`` is
    accepted by pattern ``p``.
    """
    D, L = corpus.shape
    assert L % n_chunks == 0, "pad sequences to a multiple of n_chunks"
    chunks = corpus.reshape(D, n_chunks, L // n_chunks)

    def per_pattern(table, acc, start):
        def per_doc(doc_chunks):
            mappings = jax.vmap(lambda c: chunk_mapping_enumeration(table, c))(
                doc_chunks
            )
            mapping = M.reduce(FN, mappings, axis=0)
            return acc[mapping[start]]

        return jax.vmap(per_doc)(chunks)

    return jax.vmap(per_pattern)(tables, accepting, starts)


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def census_bank(tables: jnp.ndarray, accepting: jnp.ndarray, starts: jnp.ndarray,
                corpus: jnp.ndarray, n_chunks: int = 8) -> jnp.ndarray:
    """Per-pattern hit counts over a corpus: (P,) int32 — the ScanProsite
    census (how many database sequences carry each signature)."""
    hits = bank_hits(tables, accepting, starts, corpus, n_chunks)
    return jnp.sum(hits, axis=1, dtype=jnp.int32)


def census_sequential(bank: PatternBank, corpus: np.ndarray) -> np.ndarray:
    """Reference census: plain per-pattern, per-sequence DFA loop (paper
    Fig. 1c applied P × D times). The differential-test oracle."""
    counts = np.zeros(bank.n_patterns, dtype=np.int32)
    for p in range(bank.n_patterns):
        d = bank.dfa(p)
        for row in np.asarray(corpus):
            counts[p] += bool(d.accepting[d.run(row)])
    return counts


# --------------------------------------------------------------------------
# Distributed: patterns × chunks over the mesh
# --------------------------------------------------------------------------


def distributed_bank_matcher(mesh: Mesh, pattern_axis: str = "model",
                             data_axis: str = "data"):
    """Build a jitted matcher distributing patterns × chunks over ``mesh``.

    ``tables`` (P, n, k) shards over ``pattern_axis``; ``symbols`` (L,)
    shards over ``data_axis``. Each device computes the chunk functions of
    its pattern shard on its data shard, then a single fused monoid
    reduction — ``shard_reduce`` batched over the local pattern axis, i.e.
    ONE all_gather of (P_local, n) int vectors along ``data_axis`` — yields
    the whole-input mapping per pattern. Output: (P, n), P-sharded over
    ``pattern_axis`` and replicated along ``data_axis``.

    P must divide the ``pattern_axis`` size and L the total chunk count
    ``|data_axis| * sub_chunks``.
    """

    def local_match(tables, sym_shard, sub_chunks: int):
        Lc = sym_shard.shape[0]
        chunks = sym_shard.reshape(sub_chunks, Lc // sub_chunks)
        mappings = jax.vmap(
            lambda t: jax.vmap(lambda c: chunk_mapping_enumeration(t, c))(chunks)
        )(tables)                                    # (P_local, sub_chunks, n)
        local = M.reduce(FN, mappings, axis=1)       # (P_local, n)
        return M.shard_reduce(FN, local, data_axis)  # fused over data axis

    @functools.partial(jax.jit, static_argnames=("sub_chunks",))
    def matcher(tables, symbols, sub_chunks: int = 8):
        fn = compat_shard_map(
            functools.partial(local_match, sub_chunks=sub_chunks),
            mesh=mesh,
            in_specs=(P(pattern_axis), P(data_axis)),
            out_specs=P(pattern_axis),
            check_vma=False,
        )
        return fn(tables, symbols)

    return matcher


def distributed_census_fn(mesh: Mesh, pattern_axis: str = "model",
                          data_axis: str = "data", n_chunks: int = 8):
    """Distributed census: corpus rows shard over ``data_axis``, patterns
    over ``pattern_axis``; per-device partial counts combine with one psum."""

    def local(tables, accepting, starts, corpus_shard):
        hits = bank_hits(tables, accepting, starts, corpus_shard, n_chunks)
        counts = jnp.sum(hits, axis=1, dtype=jnp.int32)
        return jax.lax.psum(counts, data_axis)

    @jax.jit
    def census(tables, accepting, starts, corpus):
        fn = compat_shard_map(
            local,
            mesh=mesh,
            in_specs=(P(pattern_axis), P(pattern_axis), P(pattern_axis),
                      P(data_axis)),
            out_specs=P(pattern_axis),
            check_vma=False,
        )
        return fn(tables, accepting, starts, corpus)

    return census
