"""Size-bucketing: one shared partition helper for matchers and construction.

Padded bank execution charges every pattern the widest pattern's row cost —
``n_max``-wide gathers at match time, ``n_max``-wide frontier rows and
fingerprint words at construction time. Real signature sets span two orders
of magnitude in DFA size, so both subsystems split a bank into size buckets
before padding. The partition logic lives here, once:

* :func:`partition_by_size` — group item indices by the smallest edge that
  holds them (the matcher's ``bucket_by_size`` and the Scanner's group
  partition are both thin wrappers over it);
* :func:`geometric_edges` — the default construction edge ladder (powers of
  ``growth`` from ``start``), giving O(log n_max) buckets;
* :func:`merge_small_buckets` — collapse undersized buckets into their
  neighbors so a batched closure never pays a compiled round shape for a
  near-empty bucket (padding waste is bounded by the edge ladder; dispatch
  waste is bounded by the merge floor).

Buckets come back smallest edge first, preserving input order within each
bucket — the stable layout every caller relies on to scatter per-item
results back to the original order.
"""

from __future__ import annotations

from typing import Sequence

#: Overflow policies of :func:`partition_by_size`.
OVERFLOWS = ("raise", "extend")


def geometric_edges(max_size: int, *, start: int = 8,
                    growth: int = 2) -> tuple:
    """The default size-edge ladder: ``start, start·growth, …`` up to the
    first edge holding ``max_size`` — O(log(max_size)) buckets.

    ``start`` keeps tiny sizes together (a 3-state and a 7-state pattern
    share a bucket; splitting them buys nothing but round dispatches).
    """
    if max_size < 1:
        raise ValueError(f"max_size must be >= 1, got {max_size}")
    if start < 1 or growth < 2:
        raise ValueError(
            f"start must be >= 1 and growth >= 2, got start={start}, "
            f"growth={growth}"
        )
    edges = [start]
    while edges[-1] < max_size:
        edges.append(edges[-1] * growth)
    return tuple(edges)


def partition_by_size(sizes: Sequence[int], edges: Sequence[int], *,
                      overflow: str = "raise") -> list:
    """Group item indices by the smallest edge that holds their size.

    -> ``[(edge, [indices…]), …]``, smallest edge first, only non-empty
    buckets, input order preserved within each bucket. An item larger than
    every edge either raises (``overflow="raise"``, the matcher contract) or
    lands in a final ``float("inf")`` bucket (``overflow="extend"``, the
    Scanner/overflow contract).
    """
    if not edges:
        raise ValueError("partition_by_size needs at least one edge")
    if overflow not in OVERFLOWS:
        raise ValueError(
            f"overflow must be one of {OVERFLOWS}, got {overflow!r}"
        )
    sorted_edges = sorted(edges)
    buckets: dict = {}
    for i, sz in enumerate(sizes):
        for e in sorted_edges:
            if sz <= e:
                buckets.setdefault(e, []).append(i)
                break
        else:
            if overflow == "raise":
                raise ValueError(
                    f"item {i} has size {sz} > max edge {sorted_edges[-1]}"
                )
            buckets.setdefault(float("inf"), []).append(i)
    return sorted(buckets.items(), key=lambda kv: kv[0])


def merge_small_buckets(parts: list, min_count: int) -> list:
    """Collapse buckets holding fewer than ``min_count`` items.

    An undersized bucket merges into its next-*larger* neighbor (its items
    were already paying at most that padding before bucketing existed);
    an undersized largest bucket merges downward instead, which widens the
    receiving bucket's edge to its own. Repeats until every bucket holds
    ``min_count`` items — or only one bucket remains (the unbucketed bank).
    Input and output have the :func:`partition_by_size` shape; item order
    within merged buckets stays size-ladder order (smaller bucket's items
    keep preceding larger ones only when merging upward — downward merges
    append the big items after, preserving each side's internal order).
    """
    if min_count < 1:
        raise ValueError(f"min_count must be >= 1, got {min_count}")
    parts = [(e, list(idx)) for e, idx in parts if idx]
    while len(parts) > 1:
        victim = next(
            (j for j, (_, idx) in enumerate(parts) if len(idx) < min_count),
            None,
        )
        if victim is None:
            break
        if victim + 1 < len(parts):      # merge upward into the wider bucket
            edge, items = parts[victim + 1]
            merged = (edge, parts[victim][1] + items)
            parts[victim:victim + 2] = [merged]
        else:                            # largest bucket: widen the one below
            edge = parts[victim][0]
            merged = (edge, parts[victim - 1][1] + parts[victim][1])
            parts[victim - 1:victim + 1] = [merged]
    return parts
