"""Regular-expression compiler: pattern -> Thompson NFA.

Supports the subset of regex syntax needed for PROSITE protein patterns and
the paper's benchmarks: literals, ``.``, character classes ``[...]`` /
``[^...]`` (with ranges), grouping ``(...)``, alternation ``|``, and the
postfix operators ``*``, ``+``, ``?``, ``{m}``, ``{m,n}``, ``{m,}``.

The automaton is built over an *explicit finite alphabet* (a list of single
characters); ``.`` and negated classes are expanded against that alphabet so
the resulting DFA transition table is dense and complete — the layout the
paper's construction and matching algorithms (and our TPU kernels) require.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable


# Default alphabet: one-letter amino-acid codes, as in the paper's PROSITE
# evaluation (Section I, Fig. 1).
AMINO_ACIDS = "ACDEFGHIKLMNPQRSTVWY"


class RegexSyntaxError(ValueError):
    pass


# --------------------------------------------------------------------------
# AST
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Node:
    pass


@dataclass(frozen=True)
class Epsilon(Node):
    pass


@dataclass(frozen=True)
class CharClass(Node):
    """A set of symbol ids (already resolved against the alphabet)."""

    symbols: frozenset


@dataclass(frozen=True)
class Concat(Node):
    parts: tuple


@dataclass(frozen=True)
class Alternate(Node):
    options: tuple


@dataclass(frozen=True)
class Repeat(Node):
    child: Node
    lo: int
    hi: int | None  # None == unbounded


# --------------------------------------------------------------------------
# Parser
# --------------------------------------------------------------------------


class _Parser:
    def __init__(self, pattern: str, alphabet: str):
        self.pat = pattern
        self.pos = 0
        self.alphabet = alphabet
        self.sym_id = {c: i for i, c in enumerate(alphabet)}

    # -- helpers ----------------------------------------------------------
    def _peek(self) -> str | None:
        return self.pat[self.pos] if self.pos < len(self.pat) else None

    def _next(self) -> str:
        c = self._peek()
        if c is None:
            raise RegexSyntaxError(f"unexpected end of pattern: {self.pat!r}")
        self.pos += 1
        return c

    def _expect(self, c: str) -> None:
        got = self._next()
        if got != c:
            raise RegexSyntaxError(
                f"expected {c!r} at position {self.pos - 1} in {self.pat!r}, got {got!r}"
            )

    def _symbols_of(self, c: str) -> frozenset:
        if c not in self.sym_id:
            raise RegexSyntaxError(f"character {c!r} not in alphabet {self.alphabet!r}")
        return frozenset((self.sym_id[c],))

    # -- grammar ----------------------------------------------------------
    def parse(self) -> Node:
        node = self._alternation()
        if self.pos != len(self.pat):
            raise RegexSyntaxError(
                f"trailing input at position {self.pos} in {self.pat!r}"
            )
        return node

    def _alternation(self) -> Node:
        options = [self._concat()]
        while self._peek() == "|":
            self._next()
            options.append(self._concat())
        if len(options) == 1:
            return options[0]
        return Alternate(tuple(options))

    def _concat(self) -> Node:
        parts = []
        while True:
            c = self._peek()
            if c is None or c in "|)":
                break
            parts.append(self._postfix())
        if not parts:
            return Epsilon()
        if len(parts) == 1:
            return parts[0]
        return Concat(tuple(parts))

    def _postfix(self) -> Node:
        node = self._atom()
        while True:
            c = self._peek()
            if c == "*":
                self._next()
                node = Repeat(node, 0, None)
            elif c == "+":
                self._next()
                node = Repeat(node, 1, None)
            elif c == "?":
                self._next()
                node = Repeat(node, 0, 1)
            elif c == "{":
                node = self._bounded_repeat(node)
            else:
                return node

    def _bounded_repeat(self, node: Node) -> Node:
        self._expect("{")
        lo = self._number()
        hi: int | None = lo
        if self._peek() == ",":
            self._next()
            hi = None if self._peek() == "}" else self._number()
        self._expect("}")
        if hi is not None and hi < lo:
            raise RegexSyntaxError(f"bad repeat bounds {{{lo},{hi}}}")
        return Repeat(node, lo, hi)

    def _number(self) -> int:
        digits = ""
        while (c := self._peek()) is not None and c.isdigit():
            digits += self._next()
        if not digits:
            raise RegexSyntaxError(f"expected number at position {self.pos}")
        return int(digits)

    def _atom(self) -> Node:
        c = self._next()
        if c == "(":
            node = self._alternation()
            self._expect(")")
            return node
        if c == "[":
            return self._char_class()
        if c == ".":
            return CharClass(frozenset(range(len(self.alphabet))))
        if c == "\\":
            return CharClass(self._symbols_of(self._next()))
        if c in "*+?{":
            raise RegexSyntaxError(f"dangling operator {c!r} at {self.pos - 1}")
        return CharClass(self._symbols_of(c))

    def _char_class(self) -> Node:
        negate = False
        if self._peek() == "^":
            self._next()
            negate = True
        members: set = set()
        while (c := self._peek()) != "]":
            if c is None:
                raise RegexSyntaxError(f"unterminated class in {self.pat!r}")
            c = self._next()
            if c == "\\":
                c = self._next()
            if self._peek() == "-" and self.pos + 1 < len(self.pat) and self.pat[self.pos + 1] != "]":
                self._next()  # consume '-'
                end = self._next()
                for code in range(ord(c), ord(end) + 1):
                    ch = chr(code)
                    if ch in self.sym_id:
                        members.add(self.sym_id[ch])
            else:
                members |= self._symbols_of(c)
        self._expect("]")
        if negate:
            members = set(range(len(self.alphabet))) - members
        if not members:
            raise RegexSyntaxError(f"empty character class in {self.pat!r}")
        return CharClass(frozenset(members))


def parse(pattern: str, alphabet: str = AMINO_ACIDS) -> Node:
    return _Parser(pattern, alphabet).parse()


# --------------------------------------------------------------------------
# Thompson construction: AST -> NFA
# --------------------------------------------------------------------------


@dataclass
class NFA:
    """Thompson NFA with a single start and single accept state.

    ``transitions[s]`` is a list of ``(symbol_id | None, target)`` edges;
    ``None`` marks an epsilon edge.
    """

    n_states: int
    transitions: list
    start: int
    accept: int
    n_symbols: int
    alphabet: str

    def eps_closure(self, states: Iterable[int]) -> frozenset:
        stack = list(states)
        seen = set(stack)
        while stack:
            s = stack.pop()
            for sym, t in self.transitions[s]:
                if sym is None and t not in seen:
                    seen.add(t)
                    stack.append(t)
        return frozenset(seen)

    def step(self, states: Iterable[int], symbol: int) -> frozenset:
        out = set()
        for s in states:
            for sym, t in self.transitions[s]:
                if sym == symbol:
                    out.add(t)
        return self.eps_closure(out)


class _NFABuilder:
    def __init__(self, n_symbols: int):
        self.transitions: list = []
        self.n_symbols = n_symbols

    def new_state(self) -> int:
        self.transitions.append([])
        return len(self.transitions) - 1

    def add_edge(self, src: int, sym: int | None, dst: int) -> None:
        self.transitions[src].append((sym, dst))

    def build(self, node: Node) -> tuple:
        """Return (start, accept) fragment for ``node``."""
        if isinstance(node, Epsilon):
            s, a = self.new_state(), self.new_state()
            self.add_edge(s, None, a)
            return s, a
        if isinstance(node, CharClass):
            s, a = self.new_state(), self.new_state()
            for sym in sorted(node.symbols):
                self.add_edge(s, sym, a)
            return s, a
        if isinstance(node, Concat):
            first_s, prev_a = self.build(node.parts[0])
            for part in node.parts[1:]:
                s, a = self.build(part)
                self.add_edge(prev_a, None, s)
                prev_a = a
            return first_s, prev_a
        if isinstance(node, Alternate):
            s, a = self.new_state(), self.new_state()
            for opt in node.options:
                os, oa = self.build(opt)
                self.add_edge(s, None, os)
                self.add_edge(oa, None, a)
            return s, a
        if isinstance(node, Repeat):
            return self._build_repeat(node)
        raise TypeError(f"unknown node {node!r}")

    def _build_repeat(self, node: Repeat) -> tuple:
        lo, hi = node.lo, node.hi
        if (lo, hi) == (0, None):  # star
            s, a = self.new_state(), self.new_state()
            cs, ca = self.build(node.child)
            self.add_edge(s, None, cs)
            self.add_edge(s, None, a)
            self.add_edge(ca, None, cs)
            self.add_edge(ca, None, a)
            return s, a
        if (lo, hi) == (1, None):  # plus = child child*
            return self.build(Concat((node.child, Repeat(node.child, 0, None))))
        if (lo, hi) == (0, 1):  # optional
            s, a = self.new_state(), self.new_state()
            cs, ca = self.build(node.child)
            self.add_edge(s, None, cs)
            self.add_edge(s, None, a)
            self.add_edge(ca, None, a)
            return s, a
        # bounded {m} / {m,n} / {m,}: expand.
        parts: list = [node.child] * lo
        if hi is None:
            parts.append(Repeat(node.child, 0, None))
        else:
            parts.extend([Repeat(node.child, 0, 1)] * (hi - lo))
        if not parts:
            return self.build(Epsilon())
        return self.build(Concat(tuple(parts)) if len(parts) > 1 else parts[0])


def to_nfa(node: Node, alphabet: str = AMINO_ACIDS) -> NFA:
    b = _NFABuilder(len(alphabet))
    start, accept = b.build(node)
    return NFA(
        n_states=len(b.transitions),
        transitions=b.transitions,
        start=start,
        accept=accept,
        n_symbols=len(alphabet),
        alphabet=alphabet,
    )


def compile_nfa(pattern: str, alphabet: str = AMINO_ACIDS) -> NFA:
    return to_nfa(parse(pattern, alphabet), alphabet)
