"""Exporters: Prometheus-style text exposition and JSONL event logs.

Two formats, two audiences:

* :func:`render_prometheus` — the text scrape format the
  :class:`repro.scanservice.TelemetryServer` ``/metrics`` endpoint serves.
  Dots in metric names become underscores; histograms emit cumulative
  ``_bucket{le=...}`` series plus ``_sum``/``_count``; registered ``help``
  descriptions emit as ``# HELP`` lines. :func:`parse_prometheus` inverts
  it (used by round-trip tests, the bench-smoke scrape gate, and tooling
  that diffs scrapes; HELP lines are ignored on the way back).
* :func:`write_jsonl` / :func:`read_jsonl` — append-only event logs for
  offline analysis: ``benchmarks/run.py`` appends one snapshot record per
  benchmark module, and span dumps ride the same format.
"""

from __future__ import annotations

import json
import os
import socket
import time


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def _escape_help(text: str) -> str:
    # Prometheus exposition-format escaping for HELP lines.
    return text.replace("\\", "\\\\").replace("\n", "\\n")


def _fmt(v: float) -> str:
    # ints render bare; floats use repr (shortest round-trippable form)
    if isinstance(v, int) or (isinstance(v, float) and v.is_integer()):
        return str(int(v))
    return repr(float(v))


def render_prometheus(snapshot: dict, help_texts: dict | None = None) -> str:
    """Render a :meth:`MetricsRegistry.snapshot` dict as Prometheus text.

    Counters (ints) and gauges (floats) are told apart by Python type —
    the snapshot preserves it. Histogram buckets are cumulated here; the
    snapshot stores per-bucket counts. ``help_texts`` maps metric names
    (dotted, as in the snapshot) to ``# HELP`` descriptions — pass
    ``MetricsRegistry.help_texts()`` (the ``repro.obs`` module-level
    wrapper does) to emit what ``counter/gauge/histogram(name, help=...)``
    registered.
    """
    help_texts = help_texts or {}
    lines = []
    for name in sorted(snapshot):
        v = snapshot[name]
        pname = _prom_name(name)
        if name in help_texts:
            lines.append(f"# HELP {pname} {_escape_help(help_texts[name])}")
        if isinstance(v, dict):  # histogram
            lines.append(f"# TYPE {pname} histogram")
            cum = 0
            for edge, c in zip(v["edges"], v["counts"]):
                cum += c
                lines.append(f'{pname}_bucket{{le="{_fmt(edge)}"}} {cum}')
            cum += v["counts"][len(v["edges"])]
            lines.append(f'{pname}_bucket{{le="+Inf"}} {cum}')
            lines.append(f"{pname}_sum {_fmt(v['sum'])}")
            lines.append(f"{pname}_count {v['count']}")
        elif isinstance(v, bool):
            raise TypeError(f"metric {name!r} has bool value")
        elif isinstance(v, int):
            lines.append(f"# TYPE {pname} counter")
            lines.append(f"{pname} {v}")
        else:
            lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname} {_fmt(v)}")
    return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus(text: str) -> dict:
    """Invert :func:`render_prometheus` (for its output only — not a general
    Prometheus parser). Returns a snapshot-shaped dict keyed by the
    underscored names; histogram counts are de-cumulated back to per-bucket.
    """
    types: dict = {}
    samples: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                types[parts[2]] = parts[3]
            continue
        key, val = line.rsplit(" ", 1)
        samples[key] = val

    out: dict = {}
    for name, kind in types.items():
        if kind == "counter":
            out[name] = int(samples[name])
        elif kind == "gauge":
            out[name] = float(samples[name])
        else:  # histogram
            edges, cums = [], []
            prefix = f'{name}_bucket{{le="'
            for key, val in samples.items():
                if key.startswith(prefix):
                    edge = key[len(prefix):-2]  # strip trailing "}
                    if edge != "+Inf":
                        edges.append(float(edge))
                    cums.append((float("inf") if edge == "+Inf"
                                 else float(edge), int(val)))
            cums.sort()
            edges.sort()
            counts, prev = [], 0
            for _, c in cums:
                counts.append(c - prev)
                prev = c
            out[name] = {
                "edges": edges,
                "counts": counts,
                "sum": float(samples[f"{name}_sum"]),
                "count": int(samples[f"{name}_count"]),
            }
    return out


def write_jsonl(path, records, mode: str = "a") -> None:
    """Append records (dicts) to a JSONL file, one per line."""
    with open(path, mode) as f:
        for rec in records:
            f.write(json.dumps(rec, sort_keys=True) + "\n")


def read_jsonl(path) -> list:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def snapshot_record(snapshot: dict, *, label: str | None = None,
                    kind: str = "metrics") -> dict:
    """Wrap a snapshot as one JSONL event record with a wall-clock stamp
    and the writing process's ``host``/``pid`` — the attribution a merged
    fleet view (:mod:`repro.obs.aggregate`) preserves per source."""
    rec = {"kind": kind, "ts": time.time(), "host": socket.gethostname(),
           "pid": os.getpid(), "metrics": snapshot}
    if label is not None:
        rec["label"] = label
    return rec


def span_records(spans) -> list:
    """Render Span objects (or their to_json dicts) as JSONL event records."""
    out = []
    for s in spans:
        d = s if isinstance(s, dict) else s.to_json()
        out.append({"kind": "span", **d})
    return out
