"""Cross-process snapshot aggregation: N worker snapshots -> one fleet view.

The metrics registry is per-process, but the serving story is N hosts
draining one corpus: each worker's :meth:`MetricsRegistry.snapshot` (or
:func:`snapshot_delta`) is one shard of the fleet's telemetry, and this
module is the merge operation that makes them one view:

* **counters** sum — work done anywhere is work done;
* **histograms** add bucket-wise (``sum``/``count`` too). Merging only
  makes sense over identical bucket layouts, so an edge mismatch *raises* —
  two processes that registered different edges under one name are
  publishing incompatible schemas, and silently aligning them would corrupt
  every percentile read off the result;
* **gauges** are levels, not totals, so the merge policy is per metric:
  ``last`` (default — latest writer wins, e.g. a hit rate), ``max`` (e.g.
  ``scheduler.max_coalesced``, a running max already), or ``sum`` (e.g.
  ``cache.sfa.bytes`` — per-process residency adds up to fleet residency).
  :data:`DEFAULT_GAUGE_POLICIES` carries the known non-``last`` metrics;
  callers override per name via ``gauge_policies``.

:func:`merge_records` lifts the merge from bare snapshots to the JSONL
records :func:`repro.obs.snapshot_record` emits (and the flight recorder
appends), preserving per-``host``/``pid`` attribution in a ``sources``
table — the merged view still answers "which worker did what".

The module doubles as a CLI::

    python -m repro.obs.aggregate worker0.jsonl worker1.jsonl ... \
        [--format json|prom] [--prefix jobs] [-o fleet.json]

merging every metrics/flight record from the given JSONL files (span
records are passed over) into one fleet snapshot, rendered as a fleet
JSON record or as Prometheus text. Torn trailing lines — a killed worker's
last write — are skipped, not fatal: aggregation is exactly the tool you
reach for after a crash.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .export import render_prometheus

GAUGE_POLICIES = ("last", "max", "sum")

#: Gauge metrics whose fleet merge is not last-write-wins. Extend via the
#: ``gauge_policies`` argument rather than editing in place.
DEFAULT_GAUGE_POLICIES = {
    "scheduler.max_coalesced": "max",
    "cache.sfa.bytes": "sum",
}


def _is_counter(v) -> bool:
    return isinstance(v, int) and not isinstance(v, bool)


def _kind_name(v) -> str:
    if isinstance(v, dict):
        return "histogram"
    return "counter" if _is_counter(v) else "gauge"


def merge_snapshots(snapshots, *, gauge_policy: str = "last",
                    gauge_policies: dict | None = None) -> dict:
    """Merge snapshot dicts into one (see module docstring for semantics).

    ``snapshots`` merge in order — under the ``last`` gauge policy the
    final occurrence of a name wins, so pass workers' snapshots oldest
    first when order matters. A name carrying different metric kinds
    across snapshots raises ``TypeError``; histograms with different
    bucket edges raise ``ValueError``.
    """
    if gauge_policy not in GAUGE_POLICIES:
        raise ValueError(
            f"gauge_policy must be one of {GAUGE_POLICIES}, "
            f"got {gauge_policy!r}"
        )
    policies = dict(DEFAULT_GAUGE_POLICIES)
    if gauge_policies:
        for name, pol in gauge_policies.items():
            if pol not in GAUGE_POLICIES:
                raise ValueError(
                    f"gauge policy for {name!r} must be one of "
                    f"{GAUGE_POLICIES}, got {pol!r}"
                )
            policies[name] = pol

    out: dict = {}
    for snap in snapshots:
        for name, v in snap.items():
            if isinstance(v, bool):
                raise TypeError(f"metric {name!r} has bool value")
            cur = out.get(name)
            if cur is not None and _kind_name(cur) != _kind_name(v):
                raise TypeError(
                    f"metric {name!r} is a {_kind_name(cur)} in one snapshot "
                    f"and a {_kind_name(v)} in another; refusing to merge"
                )
            if isinstance(v, dict):  # histogram
                edges = [float(e) for e in v["edges"]]
                counts = list(v["counts"])
                if len(counts) != len(edges) + 1:
                    raise ValueError(
                        f"histogram {name!r} has {len(counts)} counts for "
                        f"{len(edges)} edges (want edges+1)"
                    )
                if cur is None:
                    out[name] = {"edges": edges, "counts": counts,
                                 "sum": float(v["sum"]),
                                 "count": int(v["count"])}
                else:
                    if cur["edges"] != edges:
                        raise ValueError(
                            f"histogram {name!r} bucket edges differ across "
                            f"snapshots ({cur['edges']} vs {edges}); merging "
                            "mismatched layouts would corrupt percentiles"
                        )
                    cur["counts"] = [a + b
                                     for a, b in zip(cur["counts"], counts)]
                    cur["sum"] += float(v["sum"])
                    cur["count"] += int(v["count"])
            elif _is_counter(v):
                out[name] = v if cur is None else cur + v
            else:  # gauge
                v = float(v)
                if cur is None:
                    out[name] = v
                else:
                    pol = policies.get(name, gauge_policy)
                    out[name] = {"last": v, "max": max(cur, v),
                                 "sum": cur + v}[pol]
    return out


def merge_records(records, *, gauge_policy: str = "last",
                  gauge_policies: dict | None = None,
                  prefix: str | None = None) -> dict:
    """Merge :func:`snapshot_record`-shaped records into one fleet record.

    Only records carrying a ``metrics`` dict participate (span records pass
    through untouched, i.e. are ignored); they are ordered by ``ts`` before
    merging so the ``last`` gauge policy means "latest wall clock", not
    "last file on the command line". The result keeps per-process
    attribution: ``sources`` lists each distinct (host, pid) with its
    record count and the labels it reported under.

    ``prefix`` restricts the merged metrics to one namespace
    (``prefix`` itself or ``prefix.*``) — e.g. ``"jobs"`` for the
    deterministic per-shard corpus-job counters.
    """
    metric_recs = sorted(
        (r for r in records if isinstance(r, dict)
         and isinstance(r.get("metrics"), dict)),
        key=lambda r: r.get("ts", 0.0),
    )
    snaps = []
    for r in metric_recs:
        snap = r["metrics"]
        if prefix:
            snap = {k: v for k, v in snap.items()
                    if k == prefix or k.startswith(prefix + ".")}
        snaps.append(snap)
    merged = merge_snapshots(snaps, gauge_policy=gauge_policy,
                             gauge_policies=gauge_policies)
    sources: dict = {}
    for r in metric_recs:
        key = (r.get("host"), r.get("pid"))
        src = sources.setdefault(key, {
            "host": r.get("host"), "pid": r.get("pid"),
            "records": 0, "labels": [],
        })
        src["records"] += 1
        label = r.get("label")
        if label is not None and label not in src["labels"]:
            src["labels"].append(label)
    return {
        "kind": "fleet",
        "ts": max((r.get("ts", 0.0) for r in metric_recs), default=0.0),
        "n_records": len(metric_recs),
        "sources": list(sources.values()),
        "metrics": merged,
    }


def read_records(path) -> list:
    """All parseable JSONL records in ``path``, skipping torn lines (a
    killed writer's final append) instead of failing the whole merge."""
    out = []
    try:
        text = Path(path).read_text()
    except OSError:
        return out
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            continue
        if isinstance(rec, dict):
            out.append(rec)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.aggregate",
        description="Merge JSONL metric snapshots from N worker processes "
                    "into one fleet snapshot.",
    )
    ap.add_argument("paths", nargs="+", metavar="FILE.jsonl",
                    help="snapshot/flight JSONL files (span records ignored)")
    ap.add_argument("--format", choices=("json", "prom"), default="json",
                    help="fleet record JSON (default) or Prometheus text")
    ap.add_argument("--gauge-policy", choices=GAUGE_POLICIES, default="last",
                    help="default merge policy for gauges (per-metric "
                         "defaults in DEFAULT_GAUGE_POLICIES still apply)")
    ap.add_argument("--prefix", default=None,
                    help="restrict to one metric namespace (e.g. 'jobs')")
    ap.add_argument("-o", "--out", default=None,
                    help="output path (default: stdout)")
    args = ap.parse_args(argv)

    records = []
    for path in args.paths:
        if not Path(path).exists():
            print(f"aggregate: no such file: {path}", file=sys.stderr)
            return 1
        records.extend(read_records(path))
    try:
        fleet = merge_records(records, gauge_policy=args.gauge_policy,
                              prefix=args.prefix)
    except (TypeError, ValueError) as e:
        print(f"aggregate: {e}", file=sys.stderr)
        return 1
    if not fleet["n_records"]:
        print("aggregate: no metric records found in "
              f"{len(args.paths)} file(s)", file=sys.stderr)
        return 2

    if args.format == "prom":
        text = render_prometheus(fleet["metrics"])
    else:
        text = json.dumps(fleet, indent=1, sort_keys=True) + "\n"
    if args.out is None:
        sys.stdout.write(text)
    else:
        Path(args.out).write_text(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
