"""Unified observability: process-wide metrics + span tracing.

One registry and one tracer per process, addressed through module-level
helpers so instrumentation sites stay one-liners::

    from repro import obs

    obs.counter("cache.sfa.hits").inc()
    with obs.span("construct_bank", patterns=P):
        ...
    print(obs.render_prometheus(obs.snapshot()))

Observability is **enabled by default** (overhead is a handful of counter
increments and perf_counter reads per request — measured <2% on warm scans
by ``benchmarks/bench_obs.py``). ``obs.disable()`` turns every mutator into
a single attribute-check early return and ``obs.span`` into a shared no-op
context manager; scan/construct results are bit-identical either way
(asserted in ``tests/test_obs.py``).

``obs.configure(xla_annotations=True)`` additionally bridges each span into
``jax.profiler.TraceAnnotation`` so spans appear on the host timeline of
XLA profiler traces (``benchmarks/run.py --profile`` turns this on).

Metric namespace (see README "Observability" for the full table):

==============================  ============================================
prefix                          owner
==============================  ============================================
``engine.*``                    ``repro.engine.scanner`` compile/scan path
``construction.*``              ``repro.construction.batched`` round loop
``cache.sfa.*``                 ``repro.construction.cache.SFACache``
``cache.rounds.*``              round-executable compile cache
``store.artifact.*``            ``repro.scanservice.store.ArtifactStore``
``scheduler.*``                 ``repro.scanservice.scheduler``
``speculative.*``               speculative validate/repair executor
``jobs.*``                      ``repro.scanservice.jobs.CorpusJob``
``kernels.*``                   ``repro.kernels.ops`` dispatch wrappers
==============================  ============================================
"""

from __future__ import annotations

from .export import (  # noqa: F401
    parse_prometheus,
    read_jsonl,
    snapshot_record,
    span_records,
    write_jsonl,
)
from .export import render_prometheus as _render_prometheus
from .registry import (  # noqa: F401
    DEFAULT_EDGES,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    ObsState,
    snapshot_delta,
)
from .tracing import Span, Tracer  # noqa: F401

#: Shared on/off state — the registry and tracer check the same flag.
_state = ObsState()
registry = MetricsRegistry(_state)
tracer = Tracer(_state)

# Fleet-layer helpers build on the globals above, so they import after.
from .flight import FlightRecorder, read_flight  # noqa: E402,F401

#: Lazily re-exported from :mod:`repro.obs.aggregate` (PEP 562): eager
#: package import would trip runpy's double-import warning every time the
#: aggregation CLI runs as ``python -m repro.obs.aggregate``.
_AGGREGATE_NAMES = ("DEFAULT_GAUGE_POLICIES", "merge_records",
                    "merge_snapshots")


def __getattr__(name: str):
    if name in _AGGREGATE_NAMES:
        from . import aggregate
        return getattr(aggregate, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def enable() -> None:
    _state.enabled = True


def disable() -> None:
    _state.enabled = False


def enabled() -> bool:
    return _state.enabled


def configure(*, enabled: bool | None = None,
              xla_annotations: bool | None = None) -> None:
    if enabled is not None:
        _state.enabled = enabled
    if xla_annotations is not None:
        _state.xla_annotations = xla_annotations


def counter(name: str, help: str | None = None) -> Counter:
    return registry.counter(name, help=help)


def gauge(name: str, help: str | None = None) -> Gauge:
    return registry.gauge(name, help=help)


def histogram(name: str, edges=None, help: str | None = None) -> Histogram:
    return registry.histogram(name, edges, help=help)


def render_prometheus(snapshot: dict, help_texts: dict | None = None) -> str:
    """Prometheus text for ``snapshot``; ``# HELP`` lines default to the
    live registry's registered descriptions (pass ``help_texts={}`` to
    suppress, or an explicit mapping to override)."""
    if help_texts is None:
        help_texts = registry.help_texts()
    return _render_prometheus(snapshot, help_texts)


def span(name: str, trace_id: str | None = None, **attrs):
    return tracer.span(name, trace_id=trace_id, **attrs)


def current_trace_id() -> str | None:
    return tracer.current_trace_id()


def snapshot(prefix: str | None = None) -> dict:
    return registry.snapshot(prefix)


def trace_summary(trace_id: str | None = None) -> dict:
    return tracer.trace_summary(trace_id)


def recent_spans(limit: int = 100) -> list:
    return tracer.recent_spans(limit)


def reset() -> None:
    """Zero all metrics and drop retained spans (enabled flag unchanged)."""
    registry.reset()
    tracer.reset()
