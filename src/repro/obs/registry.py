"""The process-wide metrics registry: counters, gauges, histograms.

Every subsystem reports through one flat, hierarchically-*named* namespace
(``construction.rounds``, ``cache.sfa.hits``, ``scheduler.coalesced_requests``,
``speculative.hit_chunks`` …) so a single :meth:`MetricsRegistry.snapshot`
answers "what has this process done" across engine, construction, and the
scan service at once — the substrate :meth:`repro.scanservice.ScanService.metrics`
reads its correlated report from.

Design constraints, in order:

* **Exactness of the scan engine is untouchable.** Metrics only ever
  *observe* host-side quantities (counts, walls); nothing here feeds back
  into any computation, so results are bit-identical with observability on
  or off (pinned by tests).
* **Disabled means free.** Every mutator starts with one attribute read of
  the module-wide :class:`ObsState`; when disabled it returns immediately —
  no allocation, no lock, no dict lookup. A service that turns observability
  off pays a single predicted branch per call site.
* **Thread-safe increments.** The scan service's thread driver increments
  the same counters as caller threads; each metric carries its own lock
  (increments are ns-scale, contention is per-metric, and a snapshot takes
  the registry lock plus each metric's lock briefly).

Metric kinds:

* :class:`Counter` — monotonically increasing integer (``inc``).
* :class:`Gauge` — last-write-wins float (``set``), for levels and rates.
* :class:`Histogram` — fixed bucket edges chosen **at creation** (changing
  edges mid-flight would corrupt aggregation); ``observe`` bisects into the
  first bucket whose edge is >= the value, with an implicit +Inf bucket.
  Exported cumulatively (Prometheus ``le`` convention) by
  :mod:`repro.obs.export`.
"""

from __future__ import annotations

import threading
from bisect import bisect_left
from dataclasses import dataclass

#: Default histogram bucket edges (seconds): spans walls from microsecond
#: kernel dispatches to minute-scale cold constructions. Callers measuring
#: non-time quantities should pass explicit edges.
DEFAULT_EDGES = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)


@dataclass
class ObsState:
    """The one flag every hot-path mutator checks first."""

    enabled: bool = True
    #: bridge spans into ``jax.profiler.TraceAnnotation`` (XLA traces)
    xla_annotations: bool = False


class Counter:
    """Monotonic integer counter."""

    __slots__ = ("name", "_value", "_lock", "_state")

    def __init__(self, name: str, state: ObsState):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()
        self._state = state

    def inc(self, n: int = 1) -> None:
        if not self._state.enabled:
            return
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = 0


class Gauge:
    """Last-write-wins float gauge."""

    __slots__ = ("name", "_value", "_lock", "_state")

    def __init__(self, name: str, state: ObsState):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()
        self._state = state

    def set(self, v: float) -> None:
        if not self._state.enabled:
            return
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _reset(self) -> None:
        with self._lock:
            self._value = 0.0


class Histogram:
    """Fixed-edge histogram with an implicit +Inf overflow bucket.

    ``edges`` must be strictly increasing; ``observe(v)`` lands ``v`` in the
    first bucket whose edge is >= v (Prometheus ``le`` semantics).
    ``counts`` are per-bucket (*not* cumulative) with ``counts[-1]`` the
    +Inf bucket; the exporters cumulate.
    """

    __slots__ = ("name", "edges", "_counts", "_sum", "_count", "_lock",
                 "_state")

    def __init__(self, name: str, state: ObsState, edges=DEFAULT_EDGES):
        edges = tuple(float(e) for e in edges)
        if not edges:
            raise ValueError("histogram needs at least one bucket edge")
        if any(b <= a for a, b in zip(edges, edges[1:])):
            raise ValueError(f"histogram edges must be strictly increasing, "
                             f"got {edges}")
        self.name = name
        self.edges = edges
        self._counts = [0] * (len(edges) + 1)
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()
        self._state = state

    def observe(self, v: float) -> None:
        if not self._state.enabled:
            return
        v = float(v)
        i = bisect_left(self.edges, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def counts(self) -> tuple:
        with self._lock:
            return tuple(self._counts)

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def _reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.edges) + 1)
            self._sum = 0.0
            self._count = 0


class MetricsRegistry:
    """One process's metric namespace. See module docstring.

    ``counter``/``gauge``/``histogram`` are get-or-create: the first call
    for a name fixes its kind (and a histogram's edges); later calls return
    the same object, and a kind mismatch raises — two subsystems silently
    aggregating into one name with different semantics is the bug this
    guards against.
    """

    def __init__(self, state: ObsState | None = None):
        self.state = state or ObsState()
        self._metrics: dict = {}
        self._help: dict = {}
        self._lock = threading.Lock()

    # -- get-or-create --------------------------------------------------------

    def _get(self, name: str, kind, help=None, **kwargs):
        with self._lock:
            if help is not None:
                # First description wins; later sites may omit it freely.
                self._help.setdefault(name, str(help))
            m = self._metrics.get(name)
            if m is None:
                m = kind(name, self.state, **kwargs)
                self._metrics[name] = m
                return m
        if not isinstance(m, kind):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, requested {kind.__name__}"
            )
        if kwargs.get("edges") is not None and \
                tuple(float(e) for e in kwargs["edges"]) != m.edges:
            raise ValueError(
                f"histogram {name!r} already registered with edges "
                f"{m.edges}; edges are fixed at creation"
            )
        return m

    def counter(self, name: str, help: str | None = None) -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str | None = None) -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, edges=None,
                  help: str | None = None) -> Histogram:
        if edges is None:
            with self._lock:
                if help is not None:
                    self._help.setdefault(name, str(help))
                m = self._metrics.get(name)
            if isinstance(m, Histogram):
                return m
            edges = DEFAULT_EDGES
            help = None   # already registered above
        return self._get(name, Histogram, help=help, edges=edges)

    def help_texts(self) -> dict:
        """Registered metric descriptions (name -> ``# HELP`` text)."""
        with self._lock:
            return dict(self._help)

    # -- reading --------------------------------------------------------------

    def snapshot(self, prefix: str | None = None) -> dict:
        """Point-in-time copy of every metric (optionally under ``prefix.``),
        as plain JSON-serializable values:

        * counter -> int
        * gauge -> float
        * histogram -> {"edges": [...], "counts": [...], "sum": s, "count": n}
        """
        with self._lock:
            items = sorted(self._metrics.items())
        out = {}
        for name, m in items:
            if prefix and not (name == prefix or name.startswith(prefix + ".")):
                continue
            if isinstance(m, Counter):
                out[name] = m.value
            elif isinstance(m, Gauge):
                out[name] = m.value
            else:
                out[name] = {
                    "edges": list(m.edges),
                    "counts": list(m.counts),
                    "sum": m.sum,
                    "count": m.count,
                }
        return out

    def names(self) -> list:
        with self._lock:
            return sorted(self._metrics)

    def reset(self) -> None:
        """Zero every metric (names, kinds, and histogram edges survive —
        a reset is a new measurement window, not a new schema)."""
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m._reset()


def snapshot_delta(before: dict, after: dict) -> dict:
    """What moved between two :meth:`MetricsRegistry.snapshot` calls.

    Counters and gauges subtract; histograms subtract counts/sum per bucket.
    Names only in ``after`` pass through; names whose values did not change
    are dropped — the "what did this benchmark module actually touch" view
    :mod:`benchmarks.run` records per module.
    """
    out = {}
    for name, a in after.items():
        b = before.get(name)
        if isinstance(a, dict):  # histogram
            if b is None:
                d = dict(a)
            else:
                d = {
                    "edges": a["edges"],
                    "counts": [x - y for x, y in zip(a["counts"], b["counts"])],
                    "sum": a["sum"] - b["sum"],
                    "count": a["count"] - b["count"],
                }
            if d["count"]:
                out[name] = d
        else:
            d = a if b is None else a - b
            if d:
                out[name] = d
    return out
