"""Flight recorder: a crash-surviving telemetry trail on disk.

A process that dies takes its metrics registry with it — the fleet view
loses exactly the worker whose last minutes mattered most. The
:class:`FlightRecorder` fixes that the way aircraft do: a background thread
(or explicit :meth:`FlightRecorder.record` calls at checkpoints) appends
the registry's :func:`snapshot_delta` since the previous record, plus every
span finished since then, to a JSONL file on disk. The trail is

* **size-bounded**: when the active file exceeds ``max_bytes`` it rotates
  (``flight.jsonl`` -> ``flight.jsonl.1`` -> ... up to ``max_files`` files,
  oldest dropped) — a long-lived service records forever in constant disk;
* **delta-structured**: each record is what moved since the last one, so
  the records are *additive* — :func:`repro.obs.aggregate.merge_records`
  over any contiguous stretch reproduces the registry delta across that
  stretch exactly (counters and histograms bit-exact), which is what lets
  per-shard corpus-job records merge into the whole-job view, and a killed
  worker's partial trail merge with its successor's;
* **attributed**: every record carries ``host``/``pid`` (via
  :func:`snapshot_record`), so merged fleet views keep per-process origin.

:func:`read_flight` reads the whole ring back oldest-first, skipping the
torn final line a killed writer may leave.

``repro.scanservice.CorpusJob`` wires one recorder into its work directory
and records at every shard checkpoint; services with long quiet periods use
``interval_s`` + :meth:`start` for the periodic background mode (idle ticks
with nothing new are skipped, not written).
"""

from __future__ import annotations

import json
import threading
from pathlib import Path

from .export import snapshot_record, span_records, write_jsonl


def _live_obs():
    # Lazy: repro.obs imports this module while initializing, so the
    # package-level registry/tracer are fetched at call time, not import.
    from repro import obs
    return obs


class FlightRecorder:
    """Appends periodic/explicit telemetry deltas to a rotated JSONL ring.

    ``interval_s=None`` (default) is manual mode: records happen only via
    :meth:`record` — the corpus-job per-shard wiring. With ``interval_s``
    set, :meth:`start` launches a daemon thread recording every interval
    (skipping empty ticks); :meth:`stop` / :meth:`close` ends it.
    """

    def __init__(self, path, *, interval_s: float | None = None,
                 max_bytes: int = 1 << 20, max_files: int = 4,
                 label: str | None = None):
        if interval_s is not None and interval_s <= 0:
            raise ValueError("interval_s must be positive (or None)")
        if max_bytes < 1:
            raise ValueError("max_bytes must be >= 1")
        if max_files < 1:
            raise ValueError("max_files must be >= 1")
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.interval_s = interval_s
        self.max_bytes = max_bytes
        self.max_files = max_files
        self.label = label
        self._lock = threading.Lock()
        obs = _live_obs()
        # Delta base: everything before the recorder existed is not its
        # story. Same for spans — by max id, not ring position: the ring
        # appends in *finish* order, so a parent finishing last sits at the
        # tail with a lower id than its already-finished children.
        self._last_snap = obs.snapshot()
        self._last_span_id = max(
            (s.span_id for s in obs.recent_spans(1 << 30)), default=0)
        self._stop_event = threading.Event()
        self._thread: threading.Thread | None = None

    # -- recording -----------------------------------------------------------

    def record(self, *, label: str | None = None, force: bool = True,
               **extra) -> dict | None:
        """Append one delta record (+ the spans finished since the last
        record). ``extra`` keys land on the record top-level (the corpus job
        stamps ``shard=``). ``force=False`` skips the write when nothing
        moved (the periodic tick's idle case). -> the metrics record, or
        None if skipped."""
        obs = _live_obs()
        with self._lock:
            cur = obs.snapshot()
            delta = obs.snapshot_delta(self._last_snap, cur)
            self._last_snap = cur
            spans = [s for s in obs.recent_spans(1 << 30)
                     if s.span_id > self._last_span_id]
            if spans:
                self._last_span_id = max(s.span_id for s in spans)
            if not force and not delta and not spans:
                return None
            rec = snapshot_record(delta, label=label if label is not None
                                  else self.label, kind="flight")
            rec.update(extra)
            self._rotate_if_needed()
            write_jsonl(self.path, [rec] + span_records(spans))
            return rec

    def _rotate_if_needed(self) -> None:
        try:
            size = self.path.stat().st_size
        except OSError:
            return
        if size < self.max_bytes:
            return
        # logrotate-style shift: .{n-1} dropped, .k -> .k+1, active -> .1
        oldest = self._rotated(self.max_files - 1)
        oldest.unlink(missing_ok=True)
        for i in range(self.max_files - 2, 0, -1):
            src = self._rotated(i)
            if src.exists():
                src.replace(self._rotated(i + 1))
        if self.max_files > 1:
            self.path.replace(self._rotated(1))
        else:
            self.path.unlink(missing_ok=True)

    def _rotated(self, i: int) -> Path:
        return self.path.with_name(f"{self.path.name}.{i}")

    # -- the periodic background mode ----------------------------------------

    def start(self) -> "FlightRecorder":
        """Launch the periodic daemon thread (requires ``interval_s``)."""
        if self.interval_s is None:
            raise ValueError("start() needs interval_s; use record() for "
                             "explicit checkpoints")
        if self._thread is None or not self._thread.is_alive():
            self._stop_event.clear()
            self._thread = threading.Thread(
                target=self._loop, name="flight-recorder", daemon=True
            )
            self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop_event.wait(self.interval_s):
            self.record(force=False)

    def stop(self) -> None:
        if self._thread is not None:
            self._stop_event.set()
            self._thread.join()
            self._thread = None

    def close(self) -> None:
        """Stop the thread (if any) and flush a final tail delta."""
        self.stop()
        self.record(force=False)

    def __enter__(self) -> "FlightRecorder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_flight(path) -> list:
    """The whole ring's records, oldest first (rotations before the active
    file). Unparseable lines — a killed writer's torn tail — are skipped."""
    path = Path(path)
    suffix_of: dict = {}
    for p in path.parent.glob(f"{path.name}.*"):
        tail = p.name[len(path.name) + 1:]
        if tail.isdigit():
            suffix_of[int(tail)] = p
    files = [suffix_of[i] for i in sorted(suffix_of, reverse=True)]
    if path.exists():
        files.append(path)
    out = []
    for p in files:
        try:
            text = p.read_text()
        except OSError:
            continue
        for line in text.splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                continue
            if isinstance(rec, dict):
                out.append(rec)
    return out
