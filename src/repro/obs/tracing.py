"""Span tracing: nested wall-time spans with per-request trace IDs.

A *span* is one timed region (``scanner.compile``, ``construct_bank.bucket``,
``store.artifact.get`` …) with free-form attributes; spans nest through a
``contextvars`` stack, so a span opened inside another records its parent and
inherits its **trace id** — the correlation key that lets
:meth:`repro.scanservice.ScanService.metrics` reassemble one request's path
through scheduler → scanner → construction → store from the flat ring buffer.

Trace-id propagation is *explicit across threads*: ``contextvars`` don't
cross the scan service's worker thread, so :meth:`BatchScheduler.submit`
captures ``current_trace_id()`` at submit time and ``_run_batch`` re-roots
its spans with ``span(..., trace_id=captured)``. Anything running on the
caller's thread inherits implicitly.

Finished spans land in a bounded ring buffer (default 4096) — enough to
reconstruct recent requests without ever growing unbounded in a long-lived
service. :func:`trace_summary` filters and orders it by trace id.

When disabled, :func:`span` returns a shared no-op context manager: no
object allocation, no clock reads, no contextvar writes.
"""

from __future__ import annotations

import contextvars
import itertools
import os
import threading
import time
from collections import deque
from dataclasses import dataclass, field

from .registry import ObsState

#: Current open span, per task/thread (None at top level).
_current_span: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_current_span", default=None
)

_trace_counter = itertools.count(1)


def _mint_trace_id() -> str:
    # pid disambiguates multi-process benchmark runs writing one JSONL.
    return f"t{os.getpid():x}-{next(_trace_counter):06x}"


@dataclass
class Span:
    """One finished (or open) timed region."""

    name: str
    trace_id: str
    span_id: int
    parent_id: int | None
    attrs: dict = field(default_factory=dict)
    t_start: float = 0.0
    t_end: float = 0.0

    @property
    def wall_s(self) -> float:
        return self.t_end - self.t_start

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "attrs": dict(self.attrs),
            "t_start": self.t_start,
            "wall_s": self.wall_s,
        }


class _NoopSpan:
    """Shared do-nothing context manager for the disabled path."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False

    def set_attr(self, **attrs):  # parity with _LiveSpan's handle
        pass


_NOOP_SPAN = _NoopSpan()


class _LiveSpan:
    """Context manager that times one region and records it on exit."""

    __slots__ = ("tracer", "span", "_token", "_annotation")

    def __init__(self, tracer: "Tracer", span: Span):
        self.tracer = tracer
        self.span = span
        self._token = None
        self._annotation = None

    def __enter__(self) -> Span:
        self._token = _current_span.set(self.span)
        if self.tracer.state.xla_annotations:
            self._annotation = self.tracer._enter_annotation(self.span.name)
        self.span.t_start = time.perf_counter()
        return self.span

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.span.t_end = time.perf_counter()
        if exc_type is not None:
            self.span.attrs.setdefault("error", exc_type.__name__)
        if self._annotation is not None:
            self._annotation.__exit__(exc_type, exc, tb)
        _current_span.reset(self._token)
        self.tracer._record(self.span)
        return False


class Tracer:
    """Owns the finished-span ring buffer; usually one per process."""

    def __init__(self, state: ObsState | None = None, max_spans: int = 4096):
        self.state = state or ObsState()
        self._spans: deque = deque(maxlen=max_spans)
        self._span_counter = itertools.count(1)
        self._lock = threading.Lock()

    def span(self, name: str, trace_id: str | None = None, **attrs):
        """Open a span. ``trace_id=None`` inherits from the enclosing span
        (minting a fresh id at top level); pass it explicitly to re-root a
        trace on another thread."""
        if not self.state.enabled:
            return _NOOP_SPAN
        parent = _current_span.get()
        if trace_id is None:
            trace_id = parent.trace_id if parent is not None \
                else _mint_trace_id()
        s = Span(
            name=name,
            trace_id=trace_id,
            span_id=next(self._span_counter),
            parent_id=parent.span_id if parent is not None else None,
            attrs=attrs,
        )
        return _LiveSpan(self, s)

    def current_trace_id(self) -> str | None:
        s = _current_span.get()
        return s.trace_id if s is not None else None

    def _record(self, span: Span) -> None:
        with self._lock:
            self._spans.append(span)

    def _enter_annotation(self, name: str):
        try:
            from jax.profiler import TraceAnnotation
        except Exception:  # pragma: no cover - jax always present here
            return None
        a = TraceAnnotation(name)
        a.__enter__()
        return a

    # -- reading ---------------------------------------------------------------

    def recent_spans(self, limit: int = 100) -> list:
        """Most recent finished spans, newest last."""
        with self._lock:
            spans = list(self._spans)
        return spans[-limit:]

    def trace_summary(self, trace_id: str | None = None) -> dict:
        """All retained spans for one trace, in start order.

        ``trace_id=None`` summarizes the most recently finished trace.
        Wall attribution: ``wall_s`` is the duration of the trace's earliest
        root span-start to its latest span-end (spans on other threads count).
        """
        with self._lock:
            spans = list(self._spans)
        if trace_id is None:
            if not spans:
                return {"trace_id": None, "spans": [], "wall_s": 0.0}
            trace_id = spans[-1].trace_id
        mine = sorted((s for s in spans if s.trace_id == trace_id),
                      key=lambda s: s.t_start)
        wall = (max(s.t_end for s in mine) - min(s.t_start for s in mine)) \
            if mine else 0.0
        return {
            "trace_id": trace_id,
            "spans": [s.to_json() for s in mine],
            "wall_s": wall,
        }

    def reset(self) -> None:
        with self._lock:
            self._spans.clear()
