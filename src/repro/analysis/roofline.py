"""Three-term roofline model for dry-run cells (TPU v5e targets).

  compute    = FLOPs / (chips × peak)          peak = 197 TFLOP/s bf16 / chip
  memory     = bytes / (chips × HBM bw)        819 GB/s / chip
  collective = coll_bytes / (chips × link bw)  ~50 GB/s / link

All inputs come from the trip-corrected HLO analysis (per-device numbers, so
the ``chips`` division is already implicit — see ``roofline_terms``). The
dominant term is the bottleneck the §Perf loop iterates on. ``MODEL_FLOPS``
(6·N·D train / 2·N·D forward per token) gives the useful-compute ratio that
catches remat/dispatch waste.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.config import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    link_bw: float = 50e9            # bytes/s per ICI link


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful FLOPs per step: 6·N_active·tokens (train), 2·N_active·tokens
    (forward-only prefill/decode)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n_active * tokens


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_per_device: float
    useful_ratio: float

    def to_json(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_per_device": self.hlo_flops_per_device,
            "useful_ratio": self.useful_ratio,
        }


def roofline_terms(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    per_device_flops: float,
    per_device_bytes: float,
    per_device_coll_bytes: float,
    n_chips: int,
    hw: HW = HW(),
) -> Roofline:
    """All three inputs are per-device (post-SPMD HLO shapes are shards), so
    each term is simply per-device quantity / per-chip bandwidth — identical
    to the spec's global/(chips × bw) formulation."""
    compute = per_device_flops / hw.peak_flops
    memory = per_device_bytes / hw.hbm_bw
    coll = per_device_coll_bytes / hw.link_bw
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = per_device_flops * n_chips
    return Roofline(
        compute_s=compute,
        memory_s=memory,
        collective_s=coll,
        dominant=dominant,
        model_flops=mf,
        hlo_flops_per_device=per_device_flops,
        useful_ratio=(mf / hlo_global) if hlo_global else 0.0,
    )
