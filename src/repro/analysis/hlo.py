"""Post-SPMD HLO module analysis: trip-count-corrected FLOPs, memory traffic
and collective bytes.

Why not just ``compiled.cost_analysis()``? Two reasons:
  1. it has no collective accounting at all;
  2. it counts ``while`` bodies ONCE — scan-over-layers models (all of ours)
     would be undercounted by the layer count (verified: a scanned 8-step
     matmul reports 1/8 the flops of its unrolled twin).

So we parse the compiled module text:
  * split into computations; per computation resolve every instruction's
    output shape, count dot/conv FLOPs (2 · prod(out) · prod(contracted)),
    approximate memory traffic (operands + outputs of non-trivial ops), and
    collect collectives with their replica-group sizes;
  * build the call graph (fusion ``calls=``, ``to_apply=``, while
    ``body=/condition=``, conditional branches) and walk it from ENTRY with
    multiplicative trip counts (while trip = the comparison constant in its
    condition computation — exact for ``lax.scan``);
  * totals = Σ per-computation stats × multiplicity.

Shapes in post-SPMD HLO are per-device shards, so every number reported here
is per-device. Collective wire bytes use ring estimates:

  all-gather: (N-1)/N·out   reduce-scatter: (N-1)/N·in   all-reduce: 2(N-1)/N·out
  all-to-all: (N-1)/N·out   collective-permute: out
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<shape>\([^()]*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*(?P<op>[\w\-]+)\((?P<args>.*?)\)(?P<rest>.*)$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_CALL_EDGE_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|true_computation=|false_computation=)%?([\w.\-]+)"
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy-start", "copy-done", "after-all", "partition-id", "replica-id",
    "while", "conditional", "call",
}


def _dims(shape_str: str) -> list:
    """All typed arrays in a shape string -> [(dtype, [dims...]), ...]."""
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
        out.append((dt, dims))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    args: list       # operand names
    arg_texts: list  # full operand texts (inline shape + name)
    rest: str


@dataclass
class CompStats:
    dot_flops: int = 0
    traffic_bytes: int = 0
    coll_operand: int = 0
    coll_wire: int = 0
    coll_per_op: dict = field(default_factory=lambda: defaultdict(
        lambda: {"count": 0, "operand_bytes": 0, "wire_bytes": 0}))
    while_edges: list = field(default_factory=list)   # (body, cond, trip|None)
    ctrl_edges: list = field(default_factory=list)    # conditional branches etc.
    fused_edges: list = field(default_factory=list)   # fusion calls / to_apply
    max_const: int = 0                                # for trip inference


def _split_computations(text: str) -> dict:
    """Computation headers sit at column 0, end with '{', and contain '->';
    bodies are indented; the closing '}' is at column 0."""
    comps: dict = {}
    cur_name, cur_lines = None, []
    entry = None
    for line in text.splitlines():
        if cur_name is None:
            if (line and not line[0].isspace() and line.rstrip().endswith("{")
                    and "->" in line):
                head = line.split("(", 1)[0].strip()
                is_entry = head.startswith("ENTRY")
                head = head.replace("ENTRY", "").strip()
                cur_name = head.lstrip("%").strip()
                if is_entry:
                    entry = cur_name
                cur_lines = []
        else:
            if line.startswith("}"):
                comps[cur_name] = cur_lines
                cur_name = None
            else:
                cur_lines.append(line)
    return comps if entry is None else {**comps, "__entry__": entry}


def _split_top_level(s: str) -> list:
    """Split on commas outside any ()/[]/{} nesting — HLO operand lists embed
    commas inside shapes (``f32[64,64]{1,0}``), so a plain split mangles them."""
    parts, cur, depth = [], [], 0
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return [p.strip() for p in parts if p.strip()]


_ARG_NAME_RE = re.compile(r"%?([\w.\-]+)\s*$")


def _parse_instrs(lines: list) -> list:
    out = []
    for line in lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        arg_texts = _split_top_level(m.group("args"))
        args = []
        for t in arg_texts:
            mn = _ARG_NAME_RE.search(t)
            args.append(mn.group(1) if mn else t)
        out.append(Instr(m.group("name"), m.group("shape"), m.group("op"),
                         args, arg_texts, m.group("rest")))
    return out


def _operand_shape(instr: Instr, i: int, shapes: dict) -> str:
    """Shape text of operand ``i``: inline in post-optimization HLO, else
    resolved through the computation's name -> shape map."""
    if i < len(instr.arg_texts) and _SHAPE_RE.search(instr.arg_texts[i]):
        return instr.arg_texts[i]
    return shapes.get(instr.args[i], "") if i < len(instr.args) else ""


def _dot_flops(instr: Instr, shapes: dict) -> int:
    out_elems = 1
    for _, dims in _dims(instr.shape):
        for d in dims:
            out_elems *= d
    # contracted dims from lhs
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    contracted = 1
    if mc and instr.args:
        lhs_shape = _operand_shape(instr, 0, shapes)
        if lhs_shape:
            arrs = _dims(lhs_shape)
            if arrs:
                dims = arrs[0][1]
                for idx in (int(i) for i in mc.group(1).split(",") if i):
                    if idx < len(dims):
                        contracted *= dims[idx]
    return 2 * out_elems * contracted


def _conv_flops(instr: Instr, shapes: dict) -> int:
    # rough: 2 * out_elems * (kernel spatial * in_features)
    out_elems = 1
    for _, dims in _dims(instr.shape):
        for d in dims:
            out_elems *= d
    if len(instr.args) >= 2:
        k = _operand_shape(instr, 1, shapes)
        if k:
            arrs = _dims(k)
            if arrs:
                kelems = 1
                for d in arrs[0][1]:
                    kelems *= d
                # divide by output features (last dim conventionally)
                of = arrs[0][1][-1] if arrs[0][1] else 1
                return 2 * out_elems * max(kelems // max(of, 1), 1)
    return 2 * out_elems


def _analyze_computation(lines: list, n_devices: int) -> CompStats:
    instrs = _parse_instrs(lines)
    shapes = {i.name: i.shape for i in instrs}
    st = CompStats()
    for i in instrs:
        out_b = _shape_bytes(i.shape)
        if i.op == "dot":
            st.dot_flops += _dot_flops(i, shapes)
        elif i.op == "convolution":
            st.dot_flops += _conv_flops(i, shapes)
        if i.op not in _SKIP_BYTES_OPS and not i.op.startswith("constant"):
            operand_b = sum(
                _shape_bytes(_operand_shape(i, j, shapes)) for j in range(len(i.args))
            )
            st.traffic_bytes += out_b + operand_b

        base_op = i.op[:-6] if i.op.endswith("-start") else i.op
        if base_op in _COLLECTIVES and not i.op.endswith("-done"):
            n = _group_size(i.rest, n_devices)
            operand, wire = _coll_bytes(base_op, out_b, n)
            st.coll_operand += operand
            st.coll_wire += wire
            agg = st.coll_per_op[base_op]
            agg["count"] += 1
            agg["operand_bytes"] += operand
            agg["wire_bytes"] += wire

        if i.op == "while":
            mb = re.search(r"body=%?([\w.\-]+)", i.rest)
            mc = re.search(r"condition=%?([\w.\-]+)", i.rest)
            mt = _TRIP_RE.search(i.rest)
            if mb and mc:
                st.while_edges.append(
                    (mb.group(1), mc.group(1), int(mt.group(1)) if mt else None)
                )
        elif i.op == "conditional":
            for edge in _CALL_EDGE_RE.findall(i.rest):
                st.ctrl_edges.append(edge)
            mbr = _BRANCHES_RE.search(i.rest)
            if mbr:
                st.ctrl_edges.extend(
                    e.strip().lstrip("%") for e in mbr.group(1).split(",") if e.strip()
                )
        else:
            # fusion calls / reduce to_apply: flops & collectives inside are
            # real, but the internal instructions do NOT touch HBM — traffic
            # is the fusion's own operands/outputs (counted at this level).
            for edge in _CALL_EDGE_RE.findall(i.rest):
                st.fused_edges.append(edge)
    # trip inference support: scalar int constants in this computation
    for line in lines:
        m = re.search(r"s32\[\]\s*constant\((\d+)\)", line)
        if m:
            st.max_const = max(st.max_const, int(m.group(1)))
    return st


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def _coll_bytes(op: str, out_b: int, n: int) -> tuple:
    n = max(n, 1)
    if op == "all-gather":
        return out_b // n, (n - 1) * out_b // n
    if op == "reduce-scatter":
        return out_b * n, (n - 1) * out_b
    if op == "all-reduce":
        return out_b, 2 * (n - 1) * out_b // n
    if op == "all-to-all":
        return out_b, (n - 1) * out_b // n
    return out_b, out_b  # collective-permute


@dataclass
class ModuleStats:
    flops: int = 0
    traffic_bytes: int = 0
    coll_operand_bytes: int = 0
    coll_wire_bytes: int = 0
    coll_count: int = 0
    per_op: dict = field(default_factory=dict)

    def to_json(self) -> dict:
        return {
            "flops": self.flops,
            "traffic_bytes": self.traffic_bytes,
            "coll_operand_bytes": self.coll_operand_bytes,
            "coll_wire_bytes": self.coll_wire_bytes,
            "coll_count": self.coll_count,
            "per_op": self.per_op,
        }


def analyze_module(text: str, n_devices: int) -> ModuleStats:
    comps = _split_computations(text)
    entry = comps.pop("__entry__", None)
    stats = {name: _analyze_computation(lines, n_devices) for name, lines in comps.items()}
    if entry is None:
        entry = next(iter(stats)) if stats else None

    mult: dict = defaultdict(int)          # flops / collective multiplicity
    mult_traffic: dict = defaultdict(int)  # HBM-traffic multiplicity

    def walk(name: str, m: int, traffic: bool, depth: int = 0):
        if name not in stats or depth > 64:
            return
        mult[name] += m
        if traffic:
            mult_traffic[name] += m
        st = stats[name]
        for body, cond, trip in st.while_edges:
            if trip is None:  # fall back: comparison constant in the condition
                trip = stats[cond].max_const if cond in stats else 1
            trip = max(trip, 1)
            walk(cond, m * (trip + 1), traffic, depth + 1)
            walk(body, m * trip, traffic, depth + 1)
        for callee in st.ctrl_edges:
            walk(callee, m, traffic, depth + 1)
        for callee in st.fused_edges:
            walk(callee, m, False, depth + 1)

    if entry:
        walk(entry, 1, True)

    out = ModuleStats()
    per_op: dict = defaultdict(lambda: {"count": 0, "operand_bytes": 0, "wire_bytes": 0})
    for name, m in mult.items():
        st = stats[name]
        out.flops += st.dot_flops * m
        out.traffic_bytes += st.traffic_bytes * mult_traffic.get(name, 0)
        out.coll_operand_bytes += st.coll_operand * m
        out.coll_wire_bytes += st.coll_wire * m
        for op, agg in st.coll_per_op.items():
            per_op[op]["count"] += agg["count"] * m
            per_op[op]["operand_bytes"] += agg["operand_bytes"] * m
            per_op[op]["wire_bytes"] += agg["wire_bytes"] * m
            out.coll_count += agg["count"] * m
    out.per_op = dict(per_op)
    return out


# Back-compat helpers used by tests/benchmarks
def parse_collectives(text: str, n_devices: int):
    comps = _split_computations(text)
    comps.pop("__entry__", None)
    colls = []
    for lines in comps.values():
        st = _analyze_computation(lines, n_devices)
        colls.append(st)
    return colls


def collective_summary(text: str, n_devices: int) -> ModuleStats:
    return analyze_module(text, n_devices)
