from .hlo import collective_summary, parse_collectives
from .roofline import HW, roofline_terms

__all__ = ["collective_summary", "parse_collectives", "HW", "roofline_terms"]
