"""Re-analyze saved dry-run HLO and generate EXPERIMENTS.md tables.

Every dry-run cell saves its post-SPMD HLO (gzipped); this tool re-runs the
trip-corrected analysis + roofline over those artifacts — so parser/model
improvements never require recompiling 60+ cells — and renders the §Dry-run
and §Roofline markdown tables.

Usage:
  python -m repro.analysis.report --reanalyze   # refresh JSONs from HLO
  python -m repro.analysis.report --tables      # print markdown tables
"""

from __future__ import annotations

import argparse
import gzip
import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def reanalyze(results_dir: Path = RESULTS) -> None:
    from repro.analysis.hlo import analyze_module
    from repro.analysis.roofline import roofline_terms
    from repro.config import SHAPES
    from repro.configs import get_config

    for jf in sorted(results_dir.glob("*.json")):
        data = json.loads(jf.read_text())
        if data.get("status") != "ok":
            continue
        hf = results_dir / "hlo" / (jf.stem + ".hlo.gz")
        if not hf.exists():
            continue
        with gzip.open(hf, "rt") as f:
            hlo = f.read()
        n_dev = data["n_devices"]
        stats = analyze_module(hlo, n_dev)
        cfg = get_config(data["arch"])
        shape = SHAPES[data["shape"]]
        roof = roofline_terms(
            cfg, shape,
            per_device_flops=stats.flops,
            per_device_bytes=stats.traffic_bytes,
            per_device_coll_bytes=stats.coll_operand_bytes,
            n_chips=n_dev,
        )
        data["hlo_stats"] = stats.to_json()
        data["roofline"] = roof.to_json()
        jf.write_text(json.dumps(data, indent=2))
        r = data["roofline"]
        print(f"{jf.stem:55s} dom={r['dominant']:10s} "
              f"c={r['compute_s']:.3e} m={r['memory_s']:.3e} x={r['collective_s']:.3e} "
              f"useful={r['useful_ratio']:.2f}")


def _fmt(x: float) -> str:
    return f"{x:.3e}"


def tables(results_dir: Path = RESULTS) -> str:
    rows = []
    for jf in sorted(results_dir.glob("*__pod.json")):
        d = json.loads(jf.read_text())
        rows.append(d)
    lines = [
        "| arch | shape | status | args GB | temp GB | fits 16GB | compute s | memory s | collective s | dominant | useful FLOPs ratio |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in rows:
        if d.get("status") == "skipped":
            lines.append(
                f"| {d['arch']} | {d['shape']} | skipped | — | — | — | — | — | — | — | — |"
            )
            continue
        if d.get("status") != "ok":
            lines.append(f"| {d['arch']} | {d['shape']} | FAILED | | | | | | | | |")
            continue
        m, r = d["memory"], d["roofline"]
        lines.append(
            f"| {d['arch']} | {d['shape']} | ok | {m['argument_gb']:.2f} | {m['temp_gb']:.2f} "
            f"| {'yes' if m['fits_16gb'] else 'NO'} | {_fmt(r['compute_s'])} | {_fmt(r['memory_s'])} "
            f"| {_fmt(r['collective_s'])} | {r['dominant']} | {r['useful_ratio']:.2f} |"
        )
    return "\n".join(lines)


def multipod_table(results_dir: Path = RESULTS) -> str:
    lines = [
        "| arch | shape | status | args GB | temp GB | collectives (count) |",
        "|---|---|---|---|---|---|",
    ]
    for jf in sorted(results_dir.glob("*__multipod.json")):
        d = json.loads(jf.read_text())
        if d.get("status") == "skipped":
            lines.append(f"| {d['arch']} | {d['shape']} | skipped | — | — | — |")
            continue
        if d.get("status") != "ok":
            lines.append(f"| {d['arch']} | {d['shape']} | FAILED | | | |")
            continue
        m = d["memory"]
        per_op = d["hlo_stats"]["per_op"]
        ops = ", ".join(f"{k}×{v['count']}" for k, v in sorted(per_op.items()))
        lines.append(
            f"| {d['arch']} | {d['shape']} | ok | {m['argument_gb']:.2f} "
            f"| {m['temp_gb']:.2f} | {ops} |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reanalyze", action="store_true")
    ap.add_argument("--tables", action="store_true")
    ap.add_argument("--dir", default=str(RESULTS))
    args = ap.parse_args()
    d = Path(args.dir)
    if args.reanalyze:
        reanalyze(d)
    if args.tables:
        print(tables(d))
        print()
        print(multipod_table(d))


if __name__ == "__main__":
    main()
