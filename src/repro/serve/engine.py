"""Batched serving engine with continuous batching.

Slot model: a fixed decode batch of ``n_slots`` sequences. Incoming requests
queue; whenever a slot finishes (EOS / max tokens), the next request is
prefilled into that slot — prefill computes a batch-1 cache that is
scattered into the slot's row of the shared decode cache (paged-lite: one
contiguous region per slot, batch-dim scatter). Decode advances all live
slots one token per step, so chip utilization is independent of individual
request lengths — the standard continuous-batching serving pattern.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import RunConfig
from repro.models.model import Model
from repro.sharding.rules import Dist

from .steps import make_decode_step, make_prefill_step, temperature_sample


@dataclass
class Request:
    prompt: np.ndarray                 # (L,) int32
    max_new_tokens: int = 32
    rid: int = 0
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, run: RunConfig, dist: Dist, params,
                 *, n_slots: int = 4, max_len: int = 256, eos_id: int = -1,
                 temperature: float = 0.0):
        self.model = model
        self.run = run
        self.dist = dist
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature

        self.cache = model.init_cache(n_slots, max_len)
        self.prefill_one = jax.jit(make_prefill_step(model, run, dist))
        self.decode = jax.jit(make_decode_step(model, run, dist))
        self.slot_req: list = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, dtype=np.int64)   # next position
        self.slot_last = np.zeros(n_slots, dtype=np.int32)  # last sampled token
        self.queue: deque = deque()
        self._rng = jax.random.PRNGKey(0)
        self.completed: list = []
        self._single_cache = model.init_cache(1, max_len)

    # -- admission ---------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.n_slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.popleft()
                self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request):
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        single = jax.tree.map(jnp.zeros_like, self._single_cache)
        logits, cache1 = self.prefill_one(self.params, single, {"tokens": toks})
        # scatter the batch-1 cache into this slot's row
        def put(big, small):
            return jax.lax.dynamic_update_slice_in_dim(big, small.astype(big.dtype), slot, _batch_axis(big, small))
        self.cache = jax.tree.map(put, self.cache, cache1)
        tok = self._sample(logits)[0]
        self.slot_req[slot] = req
        self.slot_pos[slot] = len(req.prompt)
        self.slot_last[slot] = int(tok)
        req.out_tokens.append(int(tok))

    # -- decode loop ---------------------------------------------------------------
    def _sample(self, logits):
        self._rng, k = jax.random.split(self._rng)
        return np.asarray(temperature_sample(logits, k, self.temperature))

    def step(self):
        """One decode step over all live slots."""
        live = [s for s in range(self.n_slots) if self.slot_req[s] is not None]
        if not live:
            self._admit()
            live = [s for s in range(self.n_slots) if self.slot_req[s] is not None]
            if not live:
                return False
        tokens = jnp.asarray(self.slot_last, jnp.int32)[:, None]
        # per-slot positions: each row writes its own cache slot and masks
        # its own context length (true continuous batching)
        pos = jnp.asarray(self.slot_pos, jnp.int32)
        logits, self.cache = self.decode(self.params, self.cache, tokens, pos)
        next_tok = self._sample(logits)
        for s in live:
            req = self.slot_req[s]
            self.slot_pos[s] += 1
            t = int(next_tok[s])
            req.out_tokens.append(t)
            self.slot_last[s] = t
            if (t == self.eos_id or len(req.out_tokens) >= req.max_new_tokens
                    or self.slot_pos[s] >= self.max_len - 1):
                req.done = True
                self.completed.append(req)
                self.slot_req[s] = None
        self._admit()
        return True

    def run_until_done(self, max_steps: int = 10_000):
        self._admit()
        steps = 0
        while steps < max_steps and (self.queue or any(r is not None for r in self.slot_req)):
            if not self.step():
                break
            steps += 1
        return self.completed


def _batch_axis(big, small) -> int:
    """Axis where the slot (batch) dim lives — first axis whose size differs."""
    for i, (b, s) in enumerate(zip(big.shape, small.shape)):
        if b != s:
            return i
    return 0
