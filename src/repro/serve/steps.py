"""Serving step functions (prefill / decode) — what the inference dry-run
cells lower, and what the batched serving engine drives."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import RunConfig
from repro.models.model import Model
from repro.sharding.rules import Dist


def make_prefill_step(model: Model, run: RunConfig, dist: Dist):
    def prefill_step(params, cache, batch):
        kw = {}
        if "frames" in batch:
            kw["frames"] = batch["frames"]
        if "prefix_embeds" in batch:
            kw["prefix_embeds"] = batch["prefix_embeds"]
        logits, new_cache, _ = model.forward(
            params, batch["tokens"], dist, mode="prefill", cache=cache, **kw
        )
        return logits[:, -1], new_cache

    return prefill_step


def make_decode_step(model: Model, run: RunConfig, dist: Dist):
    def decode_step(params, cache, tokens, cache_pos):
        logits, new_cache, _ = model.forward(
            params, tokens, dist, mode="decode", cache=cache, cache_pos=cache_pos
        )
        return logits[:, 0], new_cache

    return decode_step


def greedy_sample(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(logits: jnp.ndarray, rng: jax.Array,
                       temperature: float = 1.0, top_k: int = 0) -> jnp.ndarray:
    if temperature <= 0:
        return greedy_sample(logits)
    logits = logits / temperature
    if top_k:
        top_vals, _ = jax.lax.top_k(logits, top_k)
        logits = jnp.where(logits < top_vals[..., -1:], -jnp.inf, logits)
    return jax.random.categorical(rng, logits).astype(jnp.int32)
