"""Configuration system: model / shape / mesh / run configs.

Every assigned architecture is a ``ModelConfig`` in ``repro.configs.<id>``;
shapes are the four assigned input-shape cells; a ``RunConfig`` bundles
model + shape + mesh + optimizer + sharding-rule overrides and is what the
launchers consume (``--arch`` / ``--shape`` CLI flags resolve to one).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # attention options
    qk_norm: bool = False            # qwen3
    qkv_bias: bool = False           # qwen1.5
    sliding_window: int = 0          # 0 = global attention (h2o-danube: 4096)
    rope_theta: float = 10_000.0
    rope_scaling: float = 1.0        # phi-3 longrope approximated as linear
    attn_logit_softcap: float = 0.0  # grok-style soft-capping
    mlp_variant: str = "swiglu"      # swiglu | gelu (whisper)

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # SSM (mamba2 SSD)
    ssm_state: int = 0               # N
    ssm_heads: int = 0               # H
    ssm_head_dim: int = 0            # P
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # hybrid / layer pattern: cycled over the depth. entries:
    #   "attn" | "swa" | "rglru" | "mamba2"
    layer_pattern: tuple = ("attn",)
    rglru_width: int = 0             # 0 -> d_model
    local_attn_window: int = 2048    # recurrentgemma local attention

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper 30 s of audio frames (stubbed)

    # modality stubs (vlm / audio): prefix embeddings provided by input_specs
    num_prefix_embeds: int = 0       # phi-3-vision: image patch embeddings

    # numerics / memory
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"              # none | full | dots
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False

    # per-arch sharding rule overrides (logical axis -> mesh axes)
    sharding_overrides: dict = field(default_factory=dict)
    # optional serving-specific overrides: training and serving want
    # different layouts (e.g. yi-34b trains FSDP+SP but serves head_dim-TP);
    # applied instead of sharding_overrides for prefill/decode shapes
    serving_overrides: dict = field(default_factory=dict)

    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (reported in configs/docs)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim()
        per_layer = 0
        pattern = self.layer_pattern
        for i in range(self.n_layers):
            kind = pattern[i % len(pattern)]
            if kind in ("attn", "swa", "lattn"):
                per_layer += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            elif kind == "rglru":
                w = self.rglru_width or d
                per_layer += 2 * d * w + w * d + 2 * w * w + w * self.ssm_conv_width + 5 * w
            elif kind == "mamba2":
                din = self.ssm_expand * d
                per_layer += d * (2 * din + 2 * self.ssm_state + self.ssm_heads) + din * d
            if self.d_ff > 0:
                if self.n_experts:
                    per_layer += self.n_experts * 3 * d * f + d * self.n_experts
                else:
                    n_mats = 3 if self.mlp_variant == "swiglu" else 2
                    per_layer += n_mats * d * f
            per_layer += 2 * d  # norms
        total = per_layer + v * d * (1 if self.tie_embeddings else 2)
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp; decoder already counted above
            enc = self.n_encoder_layers * (
                d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
                + (3 if self.mlp_variant == "swiglu" else 2) * d * f + 2 * d
            )
            # decoder cross-attention
            total += enc + self.n_layers * (
                d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d + d
            )
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k of the experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense = self.param_count() - self.n_layers * self.n_experts * 3 * d * f
        return int(dense + self.n_layers * self.experts_per_token * 3 * d * f)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class MeshConfig:
    shape: tuple = (16, 16)
    axes: tuple = ("data", "model")
    multi_pod: bool = False

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> tuple:
        """Mesh axes that shard the batch (everything except "model")."""
        return tuple(a for a in self.axes if a != "model")


SINGLE_POD = MeshConfig((16, 16), ("data", "model"), multi_pod=False)
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"), multi_pod=True)
HOST_MESH = MeshConfig((1, 1), ("data", "model"), multi_pod=False)


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"              # adamw | adamw8bit | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    schedule: str = "cosine"         # cosine | linear | constant


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = SINGLE_POD
    optimizer: OptimizerConfig = OptimizerConfig()
    micro_batches: int = 1
    seed: int = 0
    # ZeRO-1 style: all-gather a bf16 compute copy of the FSDP-sharded f32
    # params ONCE per step (outside the microbatch loop) instead of per
    # microbatch per layer. Trades +params_bf16/TP HBM for a micro_batches×
    # reduction in weight-gather traffic. (§Perf iteration on yi_34b.)
    gather_params_once: bool = False
    # dtype of the microbatch gradient-accumulation buffer. bf16 halves the
    # largest train-step temporary on very large models (314B: 4.9 -> 2.45 GB
    # per device) at the cost of ~8-bit accumulation mantissa over
    # micro_batches partial sums.
    grad_accum_dtype: str = "float32"
    # serving
    max_cache_len: int = 0           # 0 -> shape.seq_len
    # checkpointing / fault tolerance
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 200
    keep_checkpoints: int = 3
    async_checkpoint: bool = True

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def reduced(model: ModelConfig, **overrides: Any) -> ModelConfig:
    """A smoke-test-sized model of the same family (per-arch tests use this)."""
    base = dict(
        n_layers=min(model.n_layers, 2 * len(model.layer_pattern)),
        d_model=min(model.d_model, 64),
        n_heads=min(model.n_heads, 4),
        n_kv_heads=min(model.n_kv_heads, 2),
        d_ff=min(model.d_ff, 128) if model.d_ff else 0,
        vocab_size=min(model.vocab_size, 256),
        head_dim=16,
        n_experts=min(model.n_experts, 4),
        experts_per_token=min(model.experts_per_token, 2),
        ssm_state=min(model.ssm_state, 16),
        ssm_heads=min(model.ssm_heads, 4) if model.ssm_heads else 0,
        ssm_head_dim=min(model.ssm_head_dim, 8) if model.ssm_head_dim else 0,
        ssm_chunk=8,
        rglru_width=min(model.rglru_width, 64) if model.rglru_width else 0,
        local_attn_window=32,
        sliding_window=min(model.sliding_window, 32) if model.sliding_window else 0,
        n_encoder_layers=min(model.n_encoder_layers, 2),
        encoder_seq=32,
        num_prefix_embeds=min(model.num_prefix_embeds, 8),
        sharding_overrides={},
        remat="none",
    )
    base.update(overrides)
    return dataclasses.replace(model, **base)
