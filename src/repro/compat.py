"""Version-compatibility shims for the jax API surface we depend on.

The framework targets current jax, but the pinned CI container carries
jax 0.4.x, where ``jax.sharding.AxisType`` (and the matching ``axis_types=``
kwarg of ``jax.make_mesh``) does not exist yet. Every mesh construction in
src/, tests/ and benchmarks/ goes through :func:`make_mesh` so call-sites
never branch on the jax version.
"""

from __future__ import annotations

from typing import Sequence

import jax


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """``jax.make_mesh`` with explicit-Auto axis types where supported.

    On jax >= 0.5 meshes default to manual axis types under some configs, so
    we pin ``AxisType.Auto`` explicitly; on older jax the kwarg (and the enum)
    don't exist and plain ``make_mesh`` already behaves as Auto.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names),
            axis_types=(axis_type.Auto,) * len(tuple(axis_names)),
        )
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` across versions.

    jax 0.4.x ships it as ``jax.experimental.shard_map.shard_map`` with the
    replication check named ``check_rep``; newer jax promotes it to
    ``jax.shard_map`` and renames the flag ``check_vma``.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as legacy

    return legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a flat dict on every jax version
    (jax 0.4.x returns a one-element list of dicts, newer jax a dict)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost
