"""Mamba-2 (SSD — state-space duality) layer.

The SSD recurrence ``h_t = a_t · h_{t-1} + dt_t · B_t ⊗ x_t`` is exactly the
framework's affine monoid: the paper's "lift the sequential step into a
composable element, combine associatively" applied to a continuous state.
The chunked algorithm (Dao & Gu 2024), restated in monoid terms:

  * intra-chunk: a small attention-like quadratic form per chunk (MXU work);
  * inter-chunk: one affine-monoid *exclusive scan* over per-chunk lifted
    elements ``(Π a, Σ decay·dt·B⊗x)`` — ``core.monoid.exclusive_scan``,
    literally the same code path the SFA matcher uses for chunk entry states.

Decode carries ``(conv_state, ssm_state)`` — O(1) in context length, which is
why the mamba2 ``long_500k`` cell is runnable where full attention is not.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import monoid as M
from repro.sharding.rules import Rules, constrain

from .base import ParamSpec
from .layers import rmsnorm

AFF = M.affine_monoid()


def mamba2_dims(cfg: ModelConfig) -> tuple:
    d_in = cfg.ssm_expand * cfg.d_model
    H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    assert H * Pd == d_in, (H, Pd, d_in)
    return d_in, H, Pd, N


def mamba2_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, H, Pd, N = mamba2_dims(cfg)
    conv_dim = d_in + 2 * N
    pd = cfg.param_dtype
    return {
        # order: [z (d_in), x (d_in), B (N), C (N), dt (H)]
        "in_proj": ParamSpec((d, 2 * d_in + 2 * N + H), ("embed", "rnn"), pd, "uniform_scaled"),
        "conv_w": ParamSpec((cfg.ssm_conv_width, conv_dim), ("conv", "rnn"), pd, "uniform_scaled"),
        "conv_b": ParamSpec((conv_dim,), ("rnn",), pd, "zeros"),
        "A_log": ParamSpec((H,), (None,), pd, "normal", 0.5),
        "D": ParamSpec((H,), (None,), pd, "ones"),
        "dt_bias": ParamSpec((H,), (None,), pd, "zeros"),
        "norm": ParamSpec((d_in,), ("rnn",), pd, "ones"),
        "out_proj": ParamSpec((d_in, d), ("rnn", "embed"), pd, "uniform_scaled"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None) -> tuple:
    """Depthwise causal conv over seq. x: (B, S, C); w: (W, C).

    Returns (out (B, S, C), new_state (B, W-1, C)) — state carries the last
    W-1 inputs for decode continuation."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # (B, S+W-1, C)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W)
    ) + b.astype(x.dtype)
    new_state = xp[:, -(W - 1):] if W > 1 else None
    return jax.nn.silu(out), new_state


def _split_proj(zxbcdt: jnp.ndarray, cfg: ModelConfig) -> tuple:
    d_in, H, Pd, N = mamba2_dims(cfg)
    z = zxbcdt[..., :d_in]
    xin = zxbcdt[..., d_in : 2 * d_in]
    Bm = zxbcdt[..., 2 * d_in : 2 * d_in + N]
    Cm = zxbcdt[..., 2 * d_in + N : 2 * d_in + 2 * N]
    dt = zxbcdt[..., 2 * d_in + 2 * N :]
    return z, xin, Bm, Cm, dt


def mamba2_layer(
    params: dict,
    x: jnp.ndarray,                # (B, S, d)
    cfg: ModelConfig,
    rules: Rules,
    *,
    mode: str = "train",
    cache: dict | None = None,
) -> tuple:
    """Returns (out (B, S, d), new_cache)."""
    if mode == "decode":
        return _mamba2_decode(params, x, cfg, rules, cache)

    B, S, d = x.shape
    d_in, H, Pd, N = mamba2_dims(cfg)
    Q = min(cfg.ssm_chunk, S)
    S_orig = S
    if S % Q:
        # Right-pad to a chunk multiple; padding sits after every real token,
        # so causal results for real positions are unaffected. Prefill needs
        # the exact final state, so it requires divisibility (shape cells do).
        assert mode != "prefill", "prefill seq must be a multiple of ssm_chunk"
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    dtype = x.dtype

    zxbcdt = x @ params["in_proj"].astype(dtype)
    z, xin, Bm, Cm, dt = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    xin = conv_out[..., :d_in]
    Bm = conv_out[..., d_in : d_in + N]
    Cm = conv_out[..., d_in + N :]
    xin = constrain(xin, rules, "batch", "seq_act", "rnn")

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))          # (H,) negative
    loga = dt * A                                              # (B, S, H) ≤ 0

    xh = xin.reshape(B, nc, Q, H, Pd).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, Q, N).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Q, H)
    cum = jnp.cumsum(loga.reshape(B, nc, Q, H), axis=2)        # inclusive

    # --- intra-chunk (quadratic, attention-like) ------------------------------
    # decay(i, j) = exp(cum_i - cum_j) for j <= i. The (B,nc,Q,Q,H) tensors
    # dominate this layer's HBM traffic; the decay/score products are
    # computed in f32 for range but the big contraction runs on bf16
    # operands with f32 accumulation (§Perf mamba2 iteration A: exact to
    # ~3 decimal digits, halves score-tensor bytes).
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])   # (B,nc,Q_i,Q_j,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    scores = jnp.einsum("bnqk,bnjk->bnqj", Cc.astype(jnp.bfloat16),
                        Bc.astype(jnp.bfloat16),
                        preferred_element_type=jnp.float32)          # C_i · B_j
    scores = scores[..., None] * decay * dtc[:, :, None, :, :]       # (B,nc,Qi,Qj,H)
    scores = jnp.where(mask[None, None, :, :, None], scores, 0.0)
    y_intra = jnp.einsum("bnqjh,bnjhp->bnqhp", scores.astype(jnp.bfloat16),
                         xh.astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)

    # --- chunk lifted elements + inter-chunk monoid scan -----------------------
    last = cum[:, :, -1:, :]                                          # (B,nc,1,H)
    decay_to_end = jnp.exp(last - cum)                                # (B,nc,Q,H)
    S_c = jnp.einsum("bnqh,bnqk,bnqhp->bnhkp", decay_to_end * dtc, Bc, xh)
    a_c = jnp.exp(last[:, :, 0, :])[..., None, None]                  # (B,nc,H,1,1)
    state_in = M.exclusive_scan(AFF, (a_c, S_c), axis=1)[1]           # (B,nc,H,N,P)

    y_inter = jnp.einsum(
        "bnqh,bnqk,bnhkp->bnqhp", jnp.exp(cum), Cc, state_in
    )
    y = (y_intra + y_inter + params["D"].astype(jnp.float32)[None, None, None, :, None] * xh)
    y = y.reshape(B, S, d_in).astype(dtype)

    # gated norm + output
    if S != S_orig:
        y = y[:, :S_orig]
        z = z[:, :S_orig]
    y = rmsnorm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(dtype)
    out = constrain(out, rules, "batch", "seq_act", "embed_act")

    new_cache = None
    if mode == "prefill":
        final_state = (a_c[:, -1, ..., 0, 0][:, :, None, None] * state_in[:, -1]
                       + S_c[:, -1])                                  # (B,H,N,P)
        new_cache = {"conv": conv_state, "ssm": final_state.astype(jnp.float32)}
    return out, new_cache


def _mamba2_decode(params, x, cfg, rules, cache):
    """Single-token step. x: (B, 1, d); cache: conv (B, W-1, C), ssm (B,H,N,P)."""
    B = x.shape[0]
    d_in, H, Pd, N = mamba2_dims(cfg)
    dtype = x.dtype

    zxbcdt = x @ params["in_proj"].astype(dtype)
    z, xin, Bm, Cm, dt = _split_proj(zxbcdt, cfg)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)                 # (B,1,C)
    W = cfg.ssm_conv_width
    hist = jnp.concatenate([cache["conv"].astype(dtype), conv_in], axis=1)  # (B,W,C)
    conv_out = sum(hist[:, i] * params["conv_w"][i].astype(dtype) for i in range(W))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(dtype))  # (B,C)
    new_conv = hist[:, 1:]

    xin = conv_out[:, :d_in].reshape(B, H, Pd).astype(jnp.float32)
    Bv = conv_out[:, d_in : d_in + N].astype(jnp.float32)             # (B,N)
    Cv = conv_out[:, d_in + N :].astype(jnp.float32)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)                                               # (B,H)

    state = cache["ssm"]                                              # (B,H,N,P)
    state = (a[..., None, None] * state
             + jnp.einsum("bh,bk,bhp->bhkp", dt, Bv, xin))
    y = jnp.einsum("bk,bhkp->bhp", Cv, state)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xin
    y = y.reshape(B, 1, d_in).astype(dtype)

    y = rmsnorm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = y @ params["out_proj"].astype(dtype)
    return out, {"conv": new_conv, "ssm": state}


def mamba2_cache_shapes(cfg: ModelConfig, batch: int) -> dict:
    d_in, H, Pd, N = mamba2_dims(cfg)
    conv_dim = d_in + 2 * N
    return {
        "conv": (batch, cfg.ssm_conv_width - 1, conv_dim),
        "ssm": (batch, H, N, Pd),
    }
