"""Model facade: one API over decoder-only LMs and the enc-dec backbone.

``input_specs`` builds ShapeDtypeStruct stand-ins (weak-type-correct,
sharding-annotated, zero allocation) for every model input of a given
(arch × shape) cell — the multi-pod dry-run lowers against exactly these.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.config import ModelConfig, ShapeConfig
from repro.sharding.rules import Dist, Rules

from . import base
from .transformer import lm_cache_specs, lm_forward, lm_specs
from .whisper import whisper_cache_specs, whisper_forward, whisper_specs


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- parameters ----------------------------------------------------------
    def param_specs(self) -> dict:
        if self.cfg.is_encoder_decoder:
            return whisper_specs(self.cfg)
        return lm_specs(self.cfg)

    def init(self, rng: jax.Array) -> dict:
        return base.init_params(self.param_specs(), rng)

    def param_pspecs(self, rules: Rules):
        return base.pspec_tree(self.param_specs(), rules)

    def param_structs(self, rules: Rules, mesh):
        return base.shape_structs(self.param_specs(), rules, mesh)

    def n_params(self) -> int:
        return base.param_count(self.param_specs())

    # -- cache ---------------------------------------------------------------
    def cache_specs(self, batch: int, max_len: int) -> dict:
        if self.cfg.is_encoder_decoder:
            return whisper_cache_specs(self.cfg, batch, max_len)
        return lm_cache_specs(self.cfg, batch, max_len)

    def init_cache(self, batch: int, max_len: int, rng=None) -> dict:
        return base.init_params(
            self.cache_specs(batch, max_len), rng or jax.random.PRNGKey(0)
        )

    def cache_structs(self, batch: int, max_len: int, rules: Rules, mesh):
        return base.shape_structs(self.cache_specs(batch, max_len), rules, mesh)

    # -- forward ---------------------------------------------------------------
    def forward(self, params, tokens, dist: Dist, *, mode="train", cache=None,
                cache_pos=None, frames=None, prefix_embeds=None):
        if self.cfg.is_encoder_decoder:
            return whisper_forward(
                params, tokens, self.cfg, dist,
                frames=frames, mode=mode, cache=cache, cache_pos=cache_pos,
            )
        return lm_forward(
            params, tokens, self.cfg, dist,
            mode=mode, cache=cache, cache_pos=cache_pos, prefix_embeds=prefix_embeds,
        )


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


# --------------------------------------------------------------------------
# Dry-run input stand-ins
# --------------------------------------------------------------------------


def _struct(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(
        shape, jnp.dtype(dtype), sharding=NamedSharding(mesh, spec)
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules: Rules) -> dict:
    """ShapeDtypeStructs for the step function of an (arch × shape) cell.

    train:   {tokens, labels [, frames | prefix_embeds]}
    prefill: {tokens [, frames | prefix_embeds]}
    decode:  {tokens (B,1), cache_pos ()} — the cache is built separately via
             Model.cache_structs (it is an *input-output* of serve_step).
    """
    B, S = shape.global_batch, shape.seq_len
    tok_spec = rules.spec("batch", None)
    out: dict = {}

    if shape.kind == "train":
        out["tokens"] = _struct((B, S), jnp.int32, mesh, tok_spec)
        out["labels"] = _struct((B, S), jnp.int32, mesh, tok_spec)
    elif shape.kind == "prefill":
        out["tokens"] = _struct((B, S), jnp.int32, mesh, tok_spec)
    else:  # decode
        out["tokens"] = _struct((B, 1), jnp.int32, mesh, tok_spec)
        out["cache_pos"] = _struct((), jnp.int32, mesh, jax.sharding.PartitionSpec())

    if cfg.is_encoder_decoder and shape.kind != "decode":
        out["frames"] = _struct(
            (B, cfg.encoder_seq, cfg.d_model), cfg.dtype, mesh,
            rules.spec("batch", None, "embed_act"),
        )
    if cfg.num_prefix_embeds and shape.kind != "decode":
        out["prefix_embeds"] = _struct(
            (B, cfg.num_prefix_embeds, cfg.d_model), cfg.dtype, mesh,
            rules.spec("batch", None, "embed_act"),
        )
    return out
