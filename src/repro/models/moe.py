"""Mixture-of-Experts layer: sort-based capacity dispatch under shard_map.

Token routing is the same idiom as the paper's fingerprint dedup: *sort by
key, then operate on contiguous runs*. Tokens sort by expert id, take their
rank-within-expert as a capacity slot, and scatter into dense per-expert
buffers — no (T, E, C) one-hot dispatch tensor (whose einsum FLOPs would be
quadratic in tokens) and no pointer-chasing.

Distribution: the layer runs inside ``shard_map`` so the sort/scatter are
*per-device local* (a global jnp.argsort over a sharded axis would force a
cross-device sort). Expert weights enter with their ``mlp`` dim sharded over
``model`` (tensor parallelism inside each expert) and are all-gathered over
the FSDP (``data``) axis at entry — ZeRO-3 semantics, overlappable by the
scheduler because the layer sits inside scan-over-layers. The down-projection
partial sums ``psum`` over ``model``. Tokens never cross devices: each device
computes exactly its own tokens' top-k experts (compute-optimal; the traffic
trade — weights move, tokens don't — is analyzed in DESIGN.md).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map as compat_shard_map
from repro.config import ModelConfig
from repro.sharding.rules import Rules, constrain

from .base import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    pd = cfg.param_dtype
    return {
        "router": ParamSpec((d, E), ("embed", None), pd, "normal", 0.02),
        "w_gate": ParamSpec((E, d, f), ("experts", "embed", "mlp"), pd, "uniform_scaled"),
        "w_up": ParamSpec((E, d, f), ("experts", "embed", "mlp"), pd, "uniform_scaled"),
        "w_down": ParamSpec((E, f, d), ("experts", "mlp", "embed"), pd, "uniform_scaled"),
    }


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    return max(
        1,
        math.ceil(cfg.moe_capacity_factor * n_tokens * cfg.experts_per_token / cfg.n_experts),
    )


def _moe_local(router, w_gate, w_up, w_down, x, cfg: ModelConfig,
               model_axis: str | None):
    """Per-device MoE: x (T, d) local tokens -> (T, d)."""
    T, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    C = _capacity(T, cfg)
    dtype = x.dtype

    # --- routing ------------------------------------------------------------
    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))      # (T, E)
    top_logits, top_ids = jax.lax.top_k(logits, k)                     # (T, k)
    weights = jax.nn.softmax(top_logits, axis=-1)                      # renormalized

    # --- sort-based dispatch (the fingerprint-dedup idiom) -------------------
    flat_ids = top_ids.reshape(T * k)
    order = jnp.argsort(flat_ids, stable=True)                         # tokens grouped by expert
    sorted_ids = flat_ids[order]
    counts = jnp.bincount(flat_ids, length=E)                          # (E,)
    starts = jnp.cumsum(counts) - counts                               # exclusive
    pos_in_expert = jnp.arange(T * k) - starts[sorted_ids]
    keep = pos_in_expert < C
    buf_idx = jnp.where(keep, sorted_ids * C + pos_in_expert, E * C)   # E*C = drop

    token_of = order // k                                              # source token
    gathered = x[token_of]                                             # (T·k, d)
    buf = jnp.zeros((E * C, d), dtype).at[buf_idx].set(gathered, mode="drop")
    buf = buf.reshape(E, C, d)

    # --- expert FFNs (TP over the mlp dim) ------------------------------------
    gate = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(dtype))
    up = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(dtype))
    h = jax.nn.silu(gate) * up
    out_partial = jnp.einsum("ecf,efd->ecd", h, w_down.astype(dtype))
    if model_axis is not None:
        out_partial = jax.lax.psum(out_partial, model_axis)

    # --- combine back ----------------------------------------------------------
    y_sorted = out_partial.reshape(E * C, d)[jnp.minimum(buf_idx, E * C - 1)]
    y_sorted = jnp.where(keep[:, None], y_sorted, 0)
    w_sorted = weights.reshape(T * k)[order].astype(dtype)
    y = jnp.zeros((T, d), dtype).at[token_of].add(y_sorted * w_sorted[:, None])

    # auxiliary load-balance loss (Switch-style), returned for the trainer
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)             # (E,)
    ce = counts.astype(jnp.float32) / jnp.maximum(T * k, 1)
    aux = E * jnp.sum(me * ce)
    return y, aux


def moe_layer(
    params: dict,
    x: jnp.ndarray,              # (B, S, d)
    cfg: ModelConfig,
    rules: Rules,
    mesh=None,
    data_axes: tuple = ("data",),
    model_axis: str | None = "model",
) -> tuple:
    """Returns (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)

    if mesh is None:
        y, aux = _moe_local(
            params["router"], params["w_gate"], params["w_up"], params["w_down"],
            xt, cfg, model_axis=None,
        )
        return y.reshape(B, S, d), aux

    data_axes = tuple(a for a in data_axes if a in mesh.axis_names)
    model_in = model_axis if model_axis in mesh.axis_names else None

    fn = compat_shard_map(
        functools.partial(
            _local_wrapper, cfg=cfg, model_axis=model_in, all_axes=mesh.axis_names
        ),
        mesh=mesh,
        in_specs=(
            P(),                        # router (replicated)
            P(None, None, model_in),    # w_gate: FSDP-gathered, TP on f
            P(None, None, model_in),    # w_up
            P(None, model_in, None),    # w_down
            P(data_axes, None),         # tokens
        ),
        out_specs=(P(data_axes, None), P()),
        check_vma=False,
    )
    y, aux = fn(params["router"], params["w_gate"], params["w_up"],
                params["w_down"], xt)
    y = constrain(y.reshape(B, S, d), rules, "batch", "seq_act", "embed_act")
    return y, aux


def _local_wrapper(router, w_gate, w_up, w_down, xt, *, cfg, model_axis, all_axes):
    y, aux = _moe_local(router, w_gate, w_up, w_down, xt, cfg, model_axis)
    # out_spec P() requires a replicated value: average the load-balance loss
    # over every mesh axis.
    aux = jax.lax.pmean(aux, tuple(all_axes))
    return y, aux
