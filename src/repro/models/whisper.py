"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment spec, the conv frontend is a stub: ``input_specs`` provides
precomputed frame embeddings ``(B, S_enc, d)``. The encoder is bidirectional
self-attention over frames with sinusoidal positions; the decoder is causal
self-attention + cross-attention to encoder states. Positions are sinusoidal
(simplification of Whisper's learned embeddings — noted in DESIGN.md).

Decode shapes exercise the *decoder*: self-attn KV cache of the assigned
sequence length plus cross-attention K/V computed once at prefill.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.sharding.rules import Dist

from .attention import (
    attention_layer,
    attention_specs,
    cross_attention_layer,
    encode_kv,
    init_cache_shape,
)
from .base import ParamSpec, stack_tree
from .layers import mlp, mlp_specs, rmsnorm, rmsnorm_spec, unembed


def sinusoidal(positions: jnp.ndarray, d: int, dtype) -> jnp.ndarray:
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _enc_block_specs(cfg: ModelConfig) -> dict:
    return {
        "pre_norm": rmsnorm_spec(cfg.d_model),
        "attn": attention_specs(cfg),
        "post_norm": rmsnorm_spec(cfg.d_model),
        "ffn": mlp_specs(cfg),
    }


def _dec_block_specs(cfg: ModelConfig) -> dict:
    return {
        "pre_norm": rmsnorm_spec(cfg.d_model),
        "self_attn": attention_specs(cfg),
        "cross_norm": rmsnorm_spec(cfg.d_model),
        "cross_attn": attention_specs(cfg, cross=True),
        "post_norm": rmsnorm_spec(cfg.d_model),
        "ffn": mlp_specs(cfg),
    }


def whisper_specs(cfg: ModelConfig) -> dict:
    return {
        "embed": ParamSpec(
            (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), cfg.param_dtype, "normal"
        ),
        "enc_blocks": stack_tree(_enc_block_specs(cfg), cfg.n_encoder_layers),
        "enc_norm": rmsnorm_spec(cfg.d_model),
        "dec_blocks": stack_tree(_dec_block_specs(cfg), cfg.n_layers),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }


def whisper_cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Decoder self-attn cache + cross-attn K/V (computed at prefill)."""
    kv = init_cache_shape(cfg, batch, max_len, 0)
    dh = cfg.resolved_head_dim()
    cross_shape = (cfg.n_layers, batch, cfg.encoder_seq, cfg.n_kv_heads, dh)
    log = ("layers", "cache_batch", None, "cache_kv_heads", "cache_head_dim")
    return {
        "self": {
            "k": ParamSpec((cfg.n_layers, *kv["k"]),
                           ("layers", "cache_batch", "cache_seq", "cache_kv_heads", "cache_head_dim"),
                           cfg.dtype, "zeros"),
            "v": ParamSpec((cfg.n_layers, *kv["v"]),
                           ("layers", "cache_batch", "cache_seq", "cache_kv_heads", "cache_head_dim"),
                           cfg.dtype, "zeros"),
        },
        "cross_k": ParamSpec(cross_shape, log, cfg.dtype, "zeros"),
        "cross_v": ParamSpec(cross_shape, log, cfg.dtype, "zeros"),
    }


def encode(params, frames: jnp.ndarray, cfg: ModelConfig, dist: Dist) -> jnp.ndarray:
    """frames: (B, S_enc, d) precomputed embeddings -> encoder states."""
    B, S, d = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + sinusoidal(jnp.arange(S), d, x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(carry, bparams):
        xc = carry
        h = rmsnorm(xc, bparams["pre_norm"], cfg.norm_eps)
        out, _ = attention_layer(
            bparams["attn"], h, cfg, dist.rules,
            mode="train", positions=positions, use_rope=False, causal=False,
        )
        xc = xc + out
        h2 = rmsnorm(xc, bparams["post_norm"], cfg.norm_eps)
        xc = xc + mlp(bparams["ffn"], h2, cfg, dist.rules)
        return xc, None

    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def whisper_forward(
    params: dict,
    tokens: jnp.ndarray,            # (B, S_dec)
    cfg: ModelConfig,
    dist: Dist,
    *,
    frames: jnp.ndarray | None = None,   # (B, S_enc, d) — train/prefill
    mode: str = "train",
    cache: dict | None = None,
    cache_pos: jnp.ndarray | None = None,
) -> tuple:
    """Returns (logits, new_cache | None, aux=0)."""
    B, S = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    if mode == "decode":
        cp = cache_pos[:, None] if jnp.ndim(cache_pos) else cache_pos
        positions = jnp.broadcast_to(cp, (B, S)).astype(jnp.int32)
        x = x + sinusoidal(positions, cfg.d_model, dtype)
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = x + sinusoidal(positions, cfg.d_model, dtype)[0][None]

    # encoder states (loop-invariant: closed over by the scan body)
    enc = None
    if mode in ("train", "prefill"):
        assert frames is not None
        enc = encode(params, frames, cfg, dist)

    new_cache: dict = {}
    use_cache = cache is not None

    def body(carry, xs):
        xc = carry
        bparams, c_self_k, c_self_v, cross_k, cross_v = xs
        h = rmsnorm(xc, bparams["pre_norm"], cfg.norm_eps)
        blk_cache = {"k": c_self_k, "v": c_self_v} if use_cache else None
        out, ncache = attention_layer(
            bparams["self_attn"], h, cfg, dist.rules,
            mode=mode, positions=positions, cache=blk_cache,
            cache_pos=cache_pos, use_rope=False,
        )
        xc = xc + out
        h2 = rmsnorm(xc, bparams["cross_norm"], cfg.norm_eps)
        if mode == "decode":
            ck, cv = cross_k, cross_v
        else:
            ck, cv = encode_kv(bparams["cross_attn"], enc, cfg)
        xc = xc + cross_attention_layer(bparams["cross_attn"], h2, (ck, cv), cfg, dist.rules)
        h3 = rmsnorm(xc, bparams["post_norm"], cfg.norm_eps)
        xc = xc + mlp(bparams["ffn"], h3, cfg, dist.rules)
        outs = (
            (ncache["k"], ncache["v"], ck, cv) if use_cache else 0
        )
        return xc, outs

    L = cfg.n_layers
    if use_cache:
        xs = (
            params["dec_blocks"], cache["self"]["k"], cache["self"]["v"],
            cache["cross_k"], cache["cross_v"],
        )
    else:
        zeros = jnp.zeros((L, 1))
        xs = (params["dec_blocks"], zeros, zeros, zeros, zeros)
    body_fn = jax.checkpoint(body) if cfg.remat == "full" else body
    x, ys = jax.lax.scan(body_fn, x, xs)

    if use_cache:
        nk, nv, ck, cv = ys
        new_cache = {"self": {"k": nk, "v": nv}, "cross_k": ck, "cross_v": cv}

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x, dist.rules, transpose=True)
    return logits, (new_cache if use_cache else None), jnp.zeros((), jnp.float32)
