"""Decoder-only LM assembly: pattern-based blocks + scan-over-layers.

Layer kinds (cycled from ``cfg.layer_pattern``):
  "attn"   — global causal attention
  "swa"    — sliding-window attention (window = cfg.sliding_window)
  "lattn"  — local attention (window = cfg.local_attn_window; recurrentgemma)
  "rglru"  — RG-LRU recurrence
  "mamba2" — mamba-2 SSD

Each block is (norm → temporal-mixing → residual) and, when ``d_ff > 0``,
(norm → MLP/MoE → residual). Homogeneous *groups* (one full cycle of the
pattern) are stacked and driven by ``lax.scan`` — HLO size and SPMD
partitioning time stay O(1) in depth, which is what makes the 64-layer 314B
config compile on this host. A remainder (depth % pattern) runs as unstacked
tail blocks (recurrentgemma's 38 = 12×(2 rglru + 1 lattn) + 2 rglru).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.sharding.rules import Dist, constrain

from .attention import attention_layer, attention_specs, init_cache_shape
from .base import ParamSpec, stack_tree
from .layers import embed, embedding_spec, mlp, mlp_specs, rmsnorm, rmsnorm_spec, unembed
from .moe import moe_layer, moe_specs
from .rglru import rglru_cache_shapes, rglru_layer, rglru_specs
from .ssm import mamba2_cache_shapes, mamba2_layer, mamba2_specs


# --------------------------------------------------------------------------
# Parameter tree
# --------------------------------------------------------------------------


def _block_specs(cfg: ModelConfig, kind: str) -> dict:
    specs: dict = {"pre_norm": rmsnorm_spec(cfg.d_model)}
    if kind in ("attn", "swa", "lattn"):
        specs["attn"] = attention_specs(cfg)
    elif kind == "rglru":
        specs["rglru"] = rglru_specs(cfg)
    elif kind == "mamba2":
        specs["mamba2"] = mamba2_specs(cfg)
    else:
        raise ValueError(f"unknown layer kind {kind}")
    if cfg.d_ff > 0:
        specs["post_norm"] = rmsnorm_spec(cfg.d_model)
        specs["ffn"] = moe_specs(cfg) if cfg.n_experts else mlp_specs(cfg)
    return specs


def pattern_of(cfg: ModelConfig) -> tuple:
    return tuple(cfg.layer_pattern)


def lm_specs(cfg: ModelConfig) -> dict:
    p = pattern_of(cfg)
    n_groups, rem = divmod(cfg.n_layers, len(p))
    group = {f"{i}_{kind}": _block_specs(cfg, kind) for i, kind in enumerate(p)}
    specs: dict = {
        "embed": embedding_spec(cfg),
        "final_norm": rmsnorm_spec(cfg.d_model),
        "blocks": stack_tree(group, n_groups) if n_groups else {},
    }
    if rem:
        specs["tail"] = {
            f"{i}_{kind}": _block_specs(cfg, kind) for i, kind in enumerate(p[:rem])
        }
    if not cfg.tie_embeddings:
        specs["head"] = ParamSpec(
            (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), cfg.param_dtype, "normal"
        )
    return specs


# --------------------------------------------------------------------------
# Caches
# --------------------------------------------------------------------------


def _block_cache_shapes(cfg: ModelConfig, kind: str, batch: int, max_len: int) -> dict:
    if kind in ("attn", "swa", "lattn"):
        window = _window_of(cfg, kind)
        return init_cache_shape(cfg, batch, max_len, window)
    if kind == "rglru":
        return rglru_cache_shapes(cfg, batch)
    if kind == "mamba2":
        return mamba2_cache_shapes(cfg, batch)
    raise ValueError(kind)


def _cache_logical(kind: str, name: str, ndim: int) -> tuple:
    if kind in ("attn", "swa", "lattn"):
        return ("cache_batch", "cache_seq", "cache_kv_heads", "cache_head_dim")
    # recurrence caches: small, batch-sharded only
    return ("cache_batch",) + (None,) * (ndim - 1)


def lm_cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """ParamSpec tree for the KV/state cache (bf16 KV, f32 recurrent state)."""
    p = pattern_of(cfg)
    n_groups, rem = divmod(cfg.n_layers, len(p))

    def block(kind: str) -> dict:
        shapes = _block_cache_shapes(cfg, kind, batch, max_len)
        out = {}
        for name, shp in shapes.items():
            dtype = "float32" if kind in ("rglru", "mamba2") and name in ("h", "ssm") else cfg.dtype
            out[name] = ParamSpec(shp, _cache_logical(kind, name, len(shp)), dtype, "zeros")
        return out

    group = {f"{i}_{kind}": block(kind) for i, kind in enumerate(p)}
    specs: dict = {"blocks": stack_tree(group, n_groups) if n_groups else {}}
    if rem:
        specs["tail"] = {f"{i}_{kind}": block(kind) for i, kind in enumerate(p[:rem])}
    return specs


def _window_of(cfg: ModelConfig, kind: str) -> int:
    if kind == "swa":
        return cfg.sliding_window
    if kind == "lattn":
        return cfg.local_attn_window
    return 0


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def _block_forward(bparams, x, cfg, dist: Dist, kind: str, *, mode, positions,
                   cache, cache_pos):
    h = rmsnorm(x, bparams["pre_norm"], cfg.norm_eps)
    new_cache = None
    if kind in ("attn", "swa", "lattn"):
        out, new_cache = attention_layer(
            bparams["attn"], h, cfg, dist.rules,
            mode=mode, positions=positions, window=_window_of(cfg, kind),
            cache=cache, cache_pos=cache_pos,
        )
    elif kind == "rglru":
        out, new_cache = rglru_layer(
            bparams["rglru"], h, cfg, dist.rules, mode=mode, cache=cache
        )
    elif kind == "mamba2":
        out, new_cache = mamba2_layer(
            bparams["mamba2"], h, cfg, dist.rules, mode=mode, cache=cache
        )
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff > 0:
        h2 = rmsnorm(x, bparams["post_norm"], cfg.norm_eps)
        if cfg.n_experts:
            f_out, aux = moe_layer(
                bparams["ffn"], h2, cfg, dist.rules,
                mesh=dist.mesh, data_axes=dist.data_axes, model_axis=dist.model_axis,
            )
        else:
            f_out = mlp(bparams["ffn"], h2, cfg, dist.rules)
        x = x + f_out
    return x, new_cache, aux


def _group_forward(gparams, x, cfg, dist, *, mode, positions, cache, cache_pos,
                   kinds):
    new_caches = {}
    aux_total = jnp.zeros((), jnp.float32)
    for key in sorted(gparams.keys(), key=lambda s: int(s.split("_")[0])):
        kind = key.split("_", 1)[1]
        bc = cache.get(key) if cache else None
        x, nc, aux = _block_forward(
            gparams[key], x, cfg, dist, kind,
            mode=mode, positions=positions, cache=bc, cache_pos=cache_pos,
        )
        aux_total = aux_total + aux
        if nc is not None:
            new_caches[key] = nc
    return x, new_caches, aux_total


def lm_forward(
    params: dict,
    tokens: jnp.ndarray,            # (B, S) int32
    cfg: ModelConfig,
    dist: Dist,
    *,
    mode: str = "train",            # train | prefill | decode
    cache: dict | None = None,
    cache_pos: jnp.ndarray | None = None,
    prefix_embeds: jnp.ndarray | None = None,
) -> tuple:
    """Returns (logits (B, S, V) f32, new_cache | None, aux_loss)."""
    B, S = tokens.shape
    x = embed(params["embed"], tokens, cfg, dist.rules)
    if prefix_embeds is not None:
        n_pref = prefix_embeds.shape[1]
        x = jax.lax.dynamic_update_slice_in_dim(
            x, prefix_embeds.astype(x.dtype), 0, 1
        ) if n_pref == x.shape[1] else x.at[:, :n_pref].set(prefix_embeds.astype(x.dtype))

    if mode == "decode":
        assert cache_pos is not None
        if jnp.ndim(cache_pos) == 0:
            positions = jnp.broadcast_to(cache_pos, (B, S)).astype(jnp.int32)
        else:  # per-slot positions (continuous batching)
            positions = jnp.broadcast_to(cache_pos[:, None], (B, S)).astype(jnp.int32)
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    p = pattern_of(cfg)
    n_groups = cfg.n_layers // len(p)
    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict = {}

    if n_groups:
        gp = params["blocks"]
        gcache = cache["blocks"] if cache is not None else None
        use_cache = gcache is not None

        def body(carry, xs):
            xc, aux_c = carry
            if use_cache:
                gparams_i, gcache_i = xs
            else:
                gparams_i, gcache_i = xs, None
            xc, ncache, aux = _group_forward(
                gparams_i, xc, cfg, dist,
                mode=mode, positions=positions, cache=gcache_i,
                cache_pos=cache_pos, kinds=p,
            )
            return (xc, aux_c + aux), (ncache if use_cache else 0)

        scan_body = body
        if cfg.remat == "full":
            scan_body = jax.checkpoint(body)
        elif cfg.remat == "dots":
            scan_body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            )
        xs = (gp, gcache) if use_cache else gp
        (x, aux_total), ys = jax.lax.scan(scan_body, (x, aux_total), xs)
        if use_cache:
            new_cache["blocks"] = ys

    if "tail" in params:
        tcache = cache.get("tail") if cache else None
        x, ncache, aux = _group_forward(
            params["tail"], x, cfg, dist,
            mode=mode, positions=positions, cache=tcache, cache_pos=cache_pos, kinds=p,
        )
        aux_total = aux_total + aux
        if cache is not None:
            new_cache["tail"] = ncache

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = unembed(params["embed"], x, dist.rules, transpose=True)
    else:
        logits = unembed(params["head"], x, dist.rules, transpose=False)
    return logits, (new_cache if cache is not None else None), aux_total
