"""RG-LRU recurrence (RecurrentGemma / Griffin temporal-mixing block).

The gated diagonal recurrence

    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

is another instance of the affine monoid — the same associative-scan core as
SFA matching and mamba2. Decode state is one (B, width) vector: O(1) in
context, so recurrentgemma runs the ``long_500k`` cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import monoid as M
from repro.sharding.rules import Rules, constrain

from .base import ParamSpec
from .ssm import _causal_conv

AFF = M.affine_monoid()

_C = 8.0  # Griffin's fixed recurrence sharpness constant


def rglru_width(cfg: ModelConfig) -> int:
    return cfg.rglru_width or cfg.d_model


def rglru_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = rglru_width(cfg)
    pd = cfg.param_dtype
    return {
        "w_gate_in": ParamSpec((d, w), ("embed", "rnn"), pd, "uniform_scaled"),
        "w_rec_in": ParamSpec((d, w), ("embed", "rnn"), pd, "uniform_scaled"),
        "conv_w": ParamSpec((cfg.ssm_conv_width, w), ("conv", "rnn"), pd, "uniform_scaled"),
        "conv_b": ParamSpec((w,), ("rnn",), pd, "zeros"),
        "w_input_gate": ParamSpec((w, w), ("rnn", None), pd, "uniform_scaled"),
        "b_input_gate": ParamSpec((w,), ("rnn",), pd, "zeros"),
        "w_a_gate": ParamSpec((w, w), ("rnn", None), pd, "uniform_scaled"),
        "b_a_gate": ParamSpec((w,), ("rnn",), pd, "zeros"),
        "lam": ParamSpec((w,), ("rnn",), pd, "normal", 1.0),
        "w_out": ParamSpec((w, d), ("rnn", "embed"), pd, "uniform_scaled"),
    }


def _gates(params, xr):
    """Recurrence coefficients: returns (a, beta_x) in f32; xr (…, w)."""
    x32 = xr.astype(jnp.float32)
    i_gate = jax.nn.sigmoid(
        x32 @ params["w_input_gate"].astype(jnp.float32) + params["b_input_gate"].astype(jnp.float32)
    )
    r_gate = jax.nn.sigmoid(
        x32 @ params["w_a_gate"].astype(jnp.float32) + params["b_a_gate"].astype(jnp.float32)
    )
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r_gate
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i_gate * x32


def rglru_layer(
    params: dict,
    x: jnp.ndarray,               # (B, S, d)
    cfg: ModelConfig,
    rules: Rules,
    *,
    mode: str = "train",
    cache: dict | None = None,
) -> tuple:
    """Returns (out (B, S, d), new_cache)."""
    dtype = x.dtype
    gate = jax.nn.gelu(x @ params["w_gate_in"].astype(dtype))
    xr = x @ params["w_rec_in"].astype(dtype)

    if mode == "decode":
        W = cfg.ssm_conv_width
        hist = jnp.concatenate([cache["conv"].astype(dtype), xr], axis=1)  # (B,W,w)
        conv = sum(hist[:, i] * params["conv_w"][i].astype(dtype) for i in range(W))
        xr1 = jax.nn.silu(conv + params["conv_b"].astype(dtype))[:, None]  # (B,1,w)
        new_conv = hist[:, 1:]
        a, bx = _gates(params, xr1)
        h = a[:, 0] * cache["h"] + bx[:, 0]                                # (B,w)
        y = h[:, None].astype(dtype)
        new_cache = {"conv": new_conv, "h": h}
    else:
        xr, conv_state = _causal_conv(xr, params["conv_w"], params["conv_b"],
                                      cache["conv"].astype(dtype) if cache else None)
        a, bx = _gates(params, xr)
        if cache is not None and "h" in cache:
            bx = bx.at[:, 0].add(a[:, 0] * cache["h"])
        h = M.scan(AFF, (a, bx), axis=1)[1]                                # (B,S,w)
        y = h.astype(dtype)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": conv_state, "h": h[:, -1].astype(jnp.float32)}

    y = constrain(y * gate, rules, "batch", "seq_act", "rnn")
    out = y @ params["w_out"].astype(dtype)
    return constrain(out, rules, "batch", "seq_act", "embed_act"), new_cache


def rglru_cache_shapes(cfg: ModelConfig, batch: int) -> dict:
    w = rglru_width(cfg)
    return {"conv": (batch, cfg.ssm_conv_width - 1, w), "h": (batch, w)}
