"""Attention: GQA / MQA / sliding-window / qk-norm / bias variants.

Training and prefill use a blockwise (flash-style) formulation in pure JAX:
an outer scan over query chunks and an inner scan over KV chunks carrying the
``(m, s, o)`` partial-softmax accumulators — the ``core.monoid.softmax_monoid``
element. This keeps peak memory at one (Cq × Ckv) score block per head
regardless of sequence length, which is what lets the 32k prefill cells lower
without materializing S² scores.

Decode attends one query against the whole cache. Windowed layers (SWA /
recurrentgemma local attention) use a **ring cache** of window size, so the
``long_500k`` cells hold O(window), not O(S), state. With the default rules
the cache's sequence axis shards over the ``model`` mesh axis and XLA's
reductions implement the cross-shard softmax combine — the paper's
chunk-parallel match + associative combine, applied to attention.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.sharding.rules import Rules, constrain

from .base import ParamSpec
from .layers import rmsnorm, rope

NEG_INF = -1e30


def _snap_divisor(n: int, chunk: int) -> int:
    """Largest divisor of n that is <= chunk (chunked attention needs exact
    tiling; e.g. whisper's 1500-frame encoder snaps 512 -> 375)."""
    chunk = min(chunk, n)
    while n % chunk:
        chunk -= 1
    return max(chunk, 1)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, H, KV = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    dh = cfg.resolved_head_dim()
    pd = cfg.param_dtype
    specs = {
        "wq": ParamSpec((d, H, dh), ("embed", "heads", "head_dim"), pd, "uniform_scaled"),
        "wk": ParamSpec((d, KV, dh), ("embed", "kv_heads", "head_dim"), pd, "uniform_scaled"),
        "wv": ParamSpec((d, KV, dh), ("embed", "kv_heads", "head_dim"), pd, "uniform_scaled"),
        "wo": ParamSpec((H, dh, d), ("heads", "head_dim", "embed"), pd, "uniform_scaled"),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec((H, dh), ("heads", "head_dim"), pd, "zeros")
        specs["bk"] = ParamSpec((KV, dh), ("kv_heads", "head_dim"), pd, "zeros")
        specs["bv"] = ParamSpec((KV, dh), ("kv_heads", "head_dim"), pd, "zeros")
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((dh,), ("head_dim",), pd, "ones")
        specs["k_norm"] = ParamSpec((dh,), ("head_dim",), pd, "ones")
    if cross:
        specs.pop("q_norm", None)
        specs.pop("k_norm", None)
    return specs


def _project_qkv(params, x, cfg: ModelConfig, rules: Rules, positions,
                 apply_rope: bool = True):
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dtype)
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if apply_rope:
        q = rope(q, positions, cfg.rope_theta, cfg.rope_scaling)
        k = rope(k, positions, cfg.rope_theta, cfg.rope_scaling)
    # Attention contracts over the sequence; under sequence parallelism the
    # q/k/v enter gathered ("attn_seq", default replicated) and the output
    # re-scatters to "seq_act" — one all-gather + one reduce-scatter per
    # layer instead of GSPMD rescattering every chunk of the flash scan.
    q = constrain(q, rules, "batch", "attn_seq", "heads_act", None)
    k = constrain(k, rules, "batch", "attn_seq", None, None)
    v = constrain(v, rules, "batch", "attn_seq", None, None)
    return q, k, v


# --------------------------------------------------------------------------
# Blockwise (flash-style) attention — train / prefill
# --------------------------------------------------------------------------


def blockwise_attention(
    q: jnp.ndarray,              # (B, Sq, H, dh)
    k: jnp.ndarray,              # (B, Skv, KV, dh)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,             # 0 = unbounded
    q_offset: int = 0,           # absolute position of q[0]
    softcap: float = 0.0,
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jnp.ndarray:
    B, Sq, H, dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = _snap_divisor(Sq, q_chunk)
    kv_chunk = _snap_divisor(Skv, kv_chunk)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = dh ** -0.5

    qc = q.reshape(B, nq, q_chunk, KV, G, dh).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nk, kv_chunk, KV, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, kv_chunk, KV, dh).transpose(1, 0, 2, 3, 4)

    # Flash-style memory discipline: the inner kv scan's score blocks are
    # REMATERIALIZED in the backward pass (jax.checkpoint on the per-q-chunk
    # body). Without this, autodiff saves a (B,KV,G,Cq,Ckv) f32 tensor per
    # (q,kv) block pair — 155 GB/device on the whisper train cell; with it,
    # only the (m, s, o) accumulators survive the forward pass.
    @jax.checkpoint
    def q_body_inner(qi, q_blk):
        qpos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_body(carry, ki_and_blk):
            m, s, o = carry
            ki, k_blk, v_blk = ki_and_blk
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            scores = jnp.einsum(
                "bqhgd,bkhd->bhgqk", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            if softcap:
                scores = softcap * jnp.tanh(scores / softcap)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window:
                mask &= qpos[:, None] - kpos[None, :] < window
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
            m_blk = jnp.max(scores, axis=-1)                       # (B,KV,G,Cq)
            m_new = jnp.maximum(m, m_blk)
            p = jnp.exp(scores - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            s_new = s * alpha + jnp.sum(p, axis=-1)
            o_new = o * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, s_new, o_new), None

        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32)
        s0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, KV, G, q_chunk, dh), jnp.float32)
        (m, s, o), _ = jax.lax.scan(
            kv_body, (m0, s0, o0),
            (jnp.arange(nk), kc, vc),
        )
        out = o / jnp.maximum(s, 1e-30)[..., None]                  # (B,KV,G,Cq,dh)
        return out.transpose(0, 3, 1, 2, 4)                         # (B,Cq,KV,G,dh)

    def q_body(_, qi_and_chunk):
        qi, q_blk = qi_and_chunk
        return None, q_body_inner(qi, q_blk)

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq), qc))       # (nq,B,Cq,KV,G,dh)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, dh)
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# Decode attention (one query vs cache)
# --------------------------------------------------------------------------


def decode_attention(
    q: jnp.ndarray,              # (B, 1, H, dh)
    cache_k: jnp.ndarray,        # (B, S, KV, dh)
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,            # () or (B,) int32 — current absolute position
    *,
    window: int = 0,             # ring cache when > 0 (S == window)
    softcap: float = 0.0,
) -> jnp.ndarray:
    B, _, H, dh = q.shape
    S, KV = cache_k.shape[1], cache_k.shape[2]
    G = H // KV
    scale = dh ** -0.5
    qv = q.reshape(B, KV, G, dh)
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", qv, cache_k, preferred_element_type=jnp.float32
    ) * scale
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    idx = jnp.arange(S)[None]                     # (1, S)
    posb = jnp.broadcast_to(jnp.atleast_1d(pos), (B,))[:, None]  # (B, 1)
    if window:
        # Ring cache: slot s holds token t = pos - ((pos - s) mod S), valid if
        # 0 <= t and t > pos - S.
        t = posb - jnp.mod(posb - idx, S)
        valid = (t >= 0) & (t <= posb)            # (B, S)
    else:
        valid = idx <= posb
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhgs,bshd->bhgd", p.astype(cache_v.dtype), cache_v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# --------------------------------------------------------------------------
# Full layer: projections + attention + cache handling + output proj
# --------------------------------------------------------------------------


def init_cache_shape(cfg: ModelConfig, batch: int, max_len: int, window: int) -> dict:
    S = min(window, max_len) if window else max_len
    KV, dh = cfg.n_kv_heads, cfg.resolved_head_dim()
    return {"k": (batch, S, KV, dh), "v": (batch, S, KV, dh)}


def attention_layer(
    params: dict,
    x: jnp.ndarray,              # (B, S, d)
    cfg: ModelConfig,
    rules: Rules,
    *,
    mode: str,                   # train | prefill | decode
    positions: jnp.ndarray,      # (B, S) absolute positions
    window: int = 0,
    cache: dict | None = None,
    cache_pos: jnp.ndarray | None = None,
    use_rope: bool = True,
    causal: bool = True,
) -> tuple:
    """Returns (out (B, S, d), new_cache)."""
    dtype = x.dtype
    q, k, v = _project_qkv(params, x, cfg, rules, positions, apply_rope=use_rope)
    new_cache = None

    if mode == "decode":
        assert cache is not None and cache_pos is not None
        S_cache = cache["k"].shape[1]
        slot = jnp.mod(cache_pos, S_cache) if window else cache_pos
        if jnp.ndim(slot) == 0:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, 1)
        else:  # per-slot positions (continuous batching)
            rows = jnp.arange(k.shape[0])
            ck = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
        ck = constrain(ck, rules, "cache_batch", "cache_seq", "cache_kv_heads", "cache_head_dim")
        cv = constrain(cv, rules, "cache_batch", "cache_seq", "cache_kv_heads", "cache_head_dim")
        out = decode_attention(
            q, ck, cv, cache_pos, window=window, softcap=cfg.attn_logit_softcap
        )
        new_cache = {"k": ck, "v": cv}
    else:
        out = blockwise_attention(
            q, k, v,
            causal=causal,
            window=window,
            softcap=cfg.attn_logit_softcap,
        )
        if mode == "prefill":
            assert cache is not None
            S_cache = cache["k"].shape[1]
            S = k.shape[1]
            if window and S > S_cache:
                # Keep only the last ``window`` keys, placed at their ring slots.
                k_tail = k[:, S - S_cache:]
                v_tail = v[:, S - S_cache:]
                tail_pos = jnp.arange(S - S_cache, S)
                slots = jnp.mod(tail_pos, S_cache)
                ck = cache["k"].at[:, slots].set(k_tail.astype(cache["k"].dtype))
                cv = cache["v"].at[:, slots].set(v_tail.astype(cache["v"].dtype))
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), 0, 1
                )
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), 0, 1
                )
            ck = constrain(ck, rules, "cache_batch", "cache_seq", "cache_kv_heads", "cache_head_dim")
            cv = constrain(cv, rules, "cache_batch", "cache_seq", "cache_kv_heads", "cache_head_dim")
            new_cache = {"k": ck, "v": cv}

    out = constrain(out, rules, "batch", "attn_seq", "heads_act", None)
    proj = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return constrain(proj, rules, "batch", "seq_act", "embed_act"), new_cache


def cross_attention_layer(
    params: dict,
    x: jnp.ndarray,              # (B, S, d) decoder states
    enc_kv: tuple,               # precomputed (k, v): (B, S_enc, KV, dh)
    cfg: ModelConfig,
    rules: Rules,
) -> jnp.ndarray:
    dtype = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dtype)
    k, v = enc_kv
    out = blockwise_attention(q, k, v, causal=False, softcap=0.0)
    proj = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return constrain(proj, rules, "batch", "seq_act", "embed_act")


def encode_kv(params: dict, enc_states: jnp.ndarray, cfg: ModelConfig) -> tuple:
    """Project encoder output to cross-attention K/V once (cached)."""
    dtype = enc_states.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_states, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_states, params["wv"].astype(dtype))
    if cfg.qkv_bias:
        k = k + params["bk"].astype(dtype)
        v = v + params["bv"].astype(dtype)
    return k, v
