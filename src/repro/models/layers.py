"""Shared layers: norms, MLPs, embeddings, rotary positions.

All matmuls run in the config compute dtype (bf16 by default) with f32
accumulation where it matters (norm statistics, softmax, loss); parameters
are stored in ``param_dtype`` (f32) and cast at use — standard mixed
precision. Activation sharding constraints are applied at layer boundaries
so GSPMD propagates the intended layout (DP/FSDP × TP).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.sharding.rules import Rules, constrain

from .base import ParamSpec


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# MLP (swiglu / gelu)
# --------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    pd = cfg.param_dtype
    if cfg.mlp_variant == "swiglu":
        return {
            "w_gate": ParamSpec((d, f), ("embed", "mlp"), pd, "uniform_scaled"),
            "w_up": ParamSpec((d, f), ("embed", "mlp"), pd, "uniform_scaled"),
            "w_down": ParamSpec((f, d), ("mlp", "embed"), pd, "uniform_scaled"),
        }
    return {
        "w_up": ParamSpec((d, f), ("embed", "mlp"), pd, "uniform_scaled"),
        "b_up": ParamSpec((f,), ("mlp",), pd, "zeros"),
        "w_down": ParamSpec((f, d), ("mlp", "embed"), pd, "uniform_scaled"),
        "b_down": ParamSpec((d,), ("embed",), pd, "zeros"),
    }


def mlp(params: dict, x: jnp.ndarray, cfg: ModelConfig, rules: Rules) -> jnp.ndarray:
    dtype = x.dtype
    if cfg.mlp_variant == "swiglu":
        gate = x @ params["w_gate"].astype(dtype)
        up = x @ params["w_up"].astype(dtype)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(x @ params["w_up"].astype(dtype) + params["b_up"].astype(dtype))
    # hidden uses the inner-seq layout ("attn_seq"): under sequence
    # parallelism the residual stream is seq-sharded but the TP'd hidden is
    # seq-gathered (Megatron SP: gather at entry, reduce-scatter at exit)
    h = constrain(h, rules, "batch", "attn_seq", "mlp")
    out = h @ params["w_down"].astype(dtype)
    if cfg.mlp_variant != "swiglu":
        out = out + params["b_down"].astype(dtype)
    return constrain(out, rules, "batch", "seq_act", "embed_act")


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------


def embedding_spec(cfg: ModelConfig) -> ParamSpec:
    return ParamSpec(
        (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), cfg.param_dtype, "normal"
    )


def embed(table: jnp.ndarray, tokens: jnp.ndarray, cfg: ModelConfig,
          rules: Rules) -> jnp.ndarray:
    x = jnp.take(table, tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    return constrain(x, rules, "batch", "seq_act", "embed_act")


def unembed(table_or_head: jnp.ndarray, x: jnp.ndarray, rules: Rules,
            transpose: bool) -> jnp.ndarray:
    w = table_or_head.astype(x.dtype)
    logits = x @ (w.T if transpose else w)
    # logits shard over vocab; seq uses the inner (gathered) layout so vocab
    # TP and sequence parallelism never claim the same mesh axis
    logits = constrain(logits, rules, "batch", "attn_seq", "vocab")
    return logits.astype(jnp.float32)


# --------------------------------------------------------------------------
# Rotary positions
# --------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
         scaling: float = 1.0) -> jnp.ndarray:
    """x: (..., S, H, dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = (positions.astype(jnp.float32) / scaling)[..., None] * freqs  # (..., S, half)
    angles = angles[..., None, :]                                          # (..., S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 1e-4) -> jnp.ndarray:
    """Mean token cross-entropy in f32, with optional z-loss regularizer."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return jnp.mean(loss)
