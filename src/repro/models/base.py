"""Declarative parameter trees.

Models declare their parameters as a tree of ``ParamSpec`` (shape + logical
sharding axes + init), which supports three consumers without duplication:

* ``init_params``      — materialize real arrays (examples, tests, training);
* ``pspec_tree``       — ``PartitionSpec`` tree for pjit in/out shardings;
* ``shape_structs``    — ``jax.ShapeDtypeStruct`` stand-ins **with shardings**
                         for the multi-pod dry-run: a 314B-parameter model
                         lowers and compiles without a single byte allocated.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.sharding.rules import Rules


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    logical: tuple                  # logical axis name per dim (or None)
    dtype: str = "float32"
    init: str = "normal"            # normal | zeros | ones | uniform_scaled
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _map_specs(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def init_params(tree, rng: jax.Array, dtype_override: str | None = None):
    """Materialize a ParamSpec tree into arrays, rng folded per-leaf path."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for spec, key in zip(leaves, keys):
        dtype = jnp.dtype(dtype_override or spec.dtype)
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        elif spec.init == "normal":
            out.append(
                (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(dtype)
            )
        elif spec.init == "uniform_scaled":  # fan-in scaled
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            bound = float(np.sqrt(1.0 / max(fan_in, 1)))
            out.append(
                jax.random.uniform(key, spec.shape, jnp.float32, -bound, bound).astype(dtype)
            )
        else:
            raise ValueError(f"unknown init {spec.init}")
    return jax.tree.unflatten(treedef, out)


def pspec_tree(tree, rules: Rules):
    return _map_specs(lambda s: rules.spec(*s.logical), tree)


def shape_structs(tree, rules: Rules, mesh):
    """ShapeDtypeStructs with NamedShardings — dry-run stand-ins."""

    def one(s: ParamSpec):
        return jax.ShapeDtypeStruct(
            s.shape, jnp.dtype(s.dtype), sharding=NamedSharding(mesh, rules.spec(*s.logical))
        )

    return _map_specs(one, tree)


def param_bytes(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in leaves)


def param_count(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)


def stack_specs(spec: ParamSpec, n: int, axis_name: str = "layers") -> ParamSpec:
    """Prepend a stacked-layer axis (scan-over-layers layout)."""
    return ParamSpec(
        shape=(n, *spec.shape),
        logical=(axis_name, *spec.logical),
        dtype=spec.dtype,
        init=spec.init,
        scale=spec.scale,
    )


def stack_tree(tree, n: int):
    return _map_specs(lambda s: stack_specs(s, n), tree)
