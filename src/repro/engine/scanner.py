"""The ``Scanner`` facade: one entry point for every matching configuration.

``Scanner.compile(patterns, plan)`` accepts one pattern or a bank — a string
(PROSITE id, PROSITE signature, or framework regex), a compiled
:class:`~repro.core.dfa.DFA`, a :class:`~repro.core.multipattern.PatternBank`,
or a sequence/mapping of those — and a :class:`~repro.engine.plan.ScanPlan`
saying how to run. Compilation resolves each pattern's matching mode
(``auto`` attempts SFA construction under the plan's state budget — through
the content-addressed cache and the batched bank closure of
:mod:`repro.construction` — falling back to enumeration on
:class:`~repro.construction.StateBlowup`), stacks the
per-pattern tables into padded device arrays (stacked SFA deltas + mapping
lookups for SFA-mode patterns — the bank-axis version of the paper's
single-lookup inner loop), and returns a scanner exposing:

* ``scan(docs)``   — hit matrix of a document corpus against the bank;
* ``census(docs)`` — per-pattern hit counts (the ScanProsite census);
* ``stream(blocks)`` — corpora far larger than memory, fed as chunk blocks
  through the backend inner loop while the running function-monoid prefix
  carries across calls (see :mod:`repro.engine.streaming`);
* ``mapping(doc)`` / ``accepts(doc)`` / ``locate(doc, pattern)`` helpers.

Every backend (``reference`` / ``xla`` / ``pallas``) and every mode computes
the same exact integer automaton semantics, so results are bit-identical
across all plans — the differential property the test suite pins down.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Mapping, Sequence

import jax.numpy as jnp
import numpy as np

from .. import obs
from ..compat import make_mesh
from ..construction import SFA, StateBlowup, construct_bank
from ..core.bucketing import partition_by_size
from ..core.dfa import DFA
from ..core.multipattern import PatternBank
from ..speculative import (
    HotStateProfile,
    SpeculationStats,
    distributed_speculative_finals_fn,
    profile_hot_states,
    speculative_bank_finals,
    stack_profile_states,
)
from . import executors as X
from .plan import ChunkPolicy, ScanPlan
from .streaming import StreamResult, StreamSession

# /metrics HELP descriptions, registered once; hot paths increment by name.
obs.counter("engine.compiles", help="Scanner.compile calls")
obs.counter("engine.scans", help="Scanner scan/census calls")
obs.counter("engine.docs_scanned", help="documents scanned")
obs.counter("speculative.total_chunks",
            help="chunks executed speculatively")
obs.counter("speculative.hit_chunks",
            help="speculative chunks whose entry state was predicted")
obs.counter("speculative.repaired_chunks",
            help="misspeculated chunks re-scanned in the repair loop")
obs.counter("speculative.repair_rounds", help="repair rounds executed")
obs.counter("speculative.fallback_lanes",
            help="lanes handed to the exact enumeration fallback")
obs.gauge("speculative.hit_rate",
          help="speculation hit rate of the last scan")


# --------------------------------------------------------------------------
# Pattern normalization
# --------------------------------------------------------------------------


def _compile_one(spec: Any) -> DFA:
    """One pattern spec -> DFA. Strings resolve as: bundled PROSITE id,
    then PROSITE signature syntax, then framework regex."""
    from ..core.dfa import compile_dfa
    from ..core.prosite import (
        PROSITE_EXTRA,
        PROSITE_SAMPLES,
        PrositeSyntaxError,
        compile_prosite,
    )

    if isinstance(spec, DFA):
        return spec
    if isinstance(spec, str):
        pool = {**PROSITE_SAMPLES, **PROSITE_EXTRA}
        if spec in pool:
            return compile_prosite(pool[spec])
        try:
            return compile_prosite(spec)
        except PrositeSyntaxError:
            return compile_dfa(spec)
    raise TypeError(
        f"cannot compile pattern spec of type {type(spec).__name__}; "
        "expected str, DFA, PatternBank, or a sequence/mapping of those"
    )


def _normalize(patterns: Any) -> tuple:
    """-> (ids, dfas, single) where ``single`` marks a one-pattern input."""
    if isinstance(patterns, PatternBank):
        return (tuple(patterns.ids),
                [patterns.dfa(p) for p in range(patterns.n_patterns)], False)
    if isinstance(patterns, (str, DFA)):
        dfa = _compile_one(patterns)
        pid = patterns if isinstance(patterns, str) else "pattern_0"
        return (pid,), [dfa], True
    if isinstance(patterns, Mapping):
        ids = tuple(patterns.keys())
        return ids, [_compile_one(patterns[i]) for i in ids], False
    if isinstance(patterns, Sequence):
        dfas = [_compile_one(p) for p in patterns]
        ids = tuple(
            p if isinstance(p, str) else f"pattern_{i}"
            for i, p in enumerate(patterns)
        )
        return ids, dfas, False
    raise TypeError(f"cannot build a Scanner from {type(patterns).__name__}")


# --------------------------------------------------------------------------
# Compiled pattern groups
# --------------------------------------------------------------------------


@dataclass
class PatternGroup:
    """One homogeneous slice of the compiled bank: same mode, one padded
    table stack (and, for SFA mode, one stacked delta + mapping pair)."""

    indices: np.ndarray          # positions in the scanner's pattern order
    bank: PatternBank            # sub-bank (enumeration tables, padded)
    mode: str                    # "sfa" | "enumeration" | "speculative"
    tables: Any = None           # (Pg, n, k) jnp — enumeration tables
    deltas: Any = None           # (Pg, S, k) jnp — stacked SFA tables
    sfa_maps: Any = None         # (Pg, S, n) jnp — SFA state -> mapping
    sfa_states: np.ndarray | None = None  # (Pg,) true SFA state counts
    _dist_fn: Any = field(default=None, repr=False)
    _spec_dist_fn: Any = field(default=None, repr=False)
    _spec_profile: Any = field(default=None, repr=False)  # memoized (Pg, m)

    @property
    def n(self) -> int:
        return self.bank.n_max


def _stack_sfas(sfas: Sequence[SFA], n_max: int) -> tuple:
    """Stack per-pattern SFAs into padded (P, S_max, k) + (P, S_max, n_max).

    The padding story mirrors ``PatternBank``: delta rows ``s >= S_i`` are
    self-loops (inert, gathers stay in range) and mapping rows/columns pad
    with the identity, so an SFA-mode chunk function equals the enumeration
    chunk function on the padded layout entry for entry.
    """
    S_max = max(s.n_states for s in sfas)
    k = sfas[0].delta.shape[1]
    Pg = len(sfas)
    deltas = np.empty((Pg, S_max, k), dtype=np.int32)
    maps = np.empty((Pg, S_max, n_max), dtype=np.int32)
    pad_rows = np.repeat(np.arange(S_max, dtype=np.int32)[:, None], k, axis=1)
    ident = np.arange(n_max, dtype=np.int32)
    for p, s in enumerate(sfas):
        S_i = s.n_states
        n_i = s.mappings.shape[1]
        deltas[p] = pad_rows
        deltas[p, :S_i] = s.delta
        maps[p] = ident
        maps[p, :S_i, :n_i] = s.mappings
        maps[p, :S_i, n_i:] = ident[n_i:]
    return deltas, maps, np.asarray([s.n_states for s in sfas], dtype=np.int32)


def _size_partition(sizes: Sequence[int], edges: Sequence[int]):
    """Partition indices by size buckets (bucket i holds sizes <= edges[i]);
    oversized items land in one overflow bucket rather than erroring."""
    return [
        idx for _, idx in partition_by_size(sizes, edges, overflow="extend")
    ]


# --------------------------------------------------------------------------
# Construction resolution (cache + bank rounds)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ConstructionReport:
    """What ``Scanner.compile`` did to obtain its SFAs.

    ``rounds`` is zero when every pattern was answered by the cache — the
    "recompiling the same patterns performs zero construction rounds"
    contract the cache tests assert.
    """

    rounds: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    constructed: int = 0
    blown: int = 0
    method: str = "none"
    retries: int = 0


def _resolve_sfas(ids, dfas, plan: ScanPlan):
    """Per-pattern mode resolution: cache lookups first, then one bank
    construction for the misses. -> (modes, {index: SFA}, report)."""
    P = len(dfas)
    if plan.mode == "enumeration":
        return ["enumeration"] * P, {}, ConstructionReport()
    if plan.mode == "speculative":
        # Forced speculation needs no SFA construction at all — the whole
        # point of the mode is serving patterns the n^n bound locks out.
        return ["speculative"] * P, {}, ConstructionReport()

    policy = plan.construction
    budget = plan.sfa_state_budget
    cache = policy.resolve_cache()

    def fallback(i):
        if plan.mode == "sfa":
            raise StateBlowup(
                f"pattern {ids[i]!r}: SFA exceeds the "
                f"{budget}-state budget and "
                "mode='sfa' forbids the enumeration fallback"
            ) from None
        # auto's blowup tier: large automata go speculative (their n-wide
        # enumeration gathers are what speculation exists to avoid); small
        # blowup patterns keep the enumeration fallback.
        if dfas[i].n_states >= plan.speculation.auto_states:
            return "speculative"
        return "enumeration"

    modes: list = [None] * P
    sfas: dict = {}
    hits = misses = 0
    need = []
    for i, d in enumerate(dfas):
        kind, sfa = (None, None) if cache is None else cache.lookup(
            d, max_states=budget
        )
        if kind == "sfa":
            hits += 1
            sfas[i], modes[i] = sfa, "sfa"
        elif kind == "blowup":
            hits += 1
            modes[i] = fallback(i)
        else:
            misses += 1
            need.append(i)

    rounds = retries = blown_count = 0
    method = "none"
    if need:
        method = policy.method
        if method == "auto":
            # A bank round only pays once the missing set amortizes its XLA
            # compilation; small miss sets close faster on the NumPy loop.
            method = "batched" if len(need) >= 4 else "loop"
        result = construct_bank(
            [dfas[i] for i in need],
            max_states=budget,
            tile=policy.tile,
            max_retries=policy.max_retries,
            method=method,
            engine=policy.engine,
            distribution=policy.distribution,
            mesh=policy.mesh,
            pattern_axis=policy.pattern_axis,
            fingerprint_backend=policy.fingerprint_backend,
            expand_backend=policy.expand_backend,
            bucketing=policy.bucketing,
            bucket_growth=policy.bucket_growth,
        )
        rounds = result.stats.rounds
        retries = int(np.sum(result.stats.retries))
        for j, i in enumerate(need):
            if result.blown[j]:
                blown_count += 1
                if cache is not None:
                    cache.store_blowup(dfas[i], budget)
                modes[i] = fallback(i)
            else:
                sfas[i] = result.sfas[j]
                modes[i] = "sfa"
                if cache is not None:
                    cache.store(dfas[i], result.sfas[j])
    report = ConstructionReport(
        rounds=rounds, cache_hits=hits, cache_misses=misses,
        constructed=len(need) - blown_count, blown=blown_count,
        method=method, retries=retries,
    )
    return modes, sfas, report


# --------------------------------------------------------------------------
# Scan results
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ScanResult:
    """Hit matrix of a scan: ``hits[p, d]`` iff doc ``d`` matches pattern ``p``.

    ``speculation`` carries the scan's aggregated
    :class:`~repro.speculative.SpeculationStats` when any pattern group ran
    speculatively (None otherwise) — the per-scan hit-rate/repair report.
    """

    hits: np.ndarray      # (P, D) bool
    ids: tuple
    speculation: Any = None

    @property
    def counts(self) -> np.ndarray:
        """Per-pattern hit counts (the census row), (P,) int32."""
        return np.sum(self.hits, axis=1, dtype=np.int32)

    def by_id(self) -> dict:
        return {pid: self.hits[p] for p, pid in enumerate(self.ids)}


# --------------------------------------------------------------------------
# The facade
# --------------------------------------------------------------------------


class Scanner:
    """A compiled multi-pattern scan engine. Build with :meth:`compile`."""

    def __init__(self, ids, dfas, groups, plan, single, mesh,
                 construction_report: ConstructionReport | None = None):
        self.ids = ids
        self.plan = plan
        self.groups = groups
        self.single = single
        self.mesh = mesh
        self.construction_report = construction_report or ConstructionReport()
        self.alphabet = dfas[0].alphabet
        self.n_patterns = len(dfas)
        self.n_max = max(d.n_states for d in dfas)
        self.starts = np.asarray([d.start for d in dfas], dtype=np.int32)
        self._dfas = dfas
        self.last_speculation: SpeculationStats | None = None
        #: trace id of the last traced compile/scan through this scanner —
        #: the key ``obs.trace_summary`` (and ``describe``) correlates on.
        self.last_trace_id: str | None = None
        self.pattern_modes = {}
        for g in groups:
            for i in g.indices:
                self.pattern_modes[ids[i]] = g.mode

    # -- compilation --------------------------------------------------------

    @classmethod
    def compile(cls, patterns: Any, plan: ScanPlan | None = None,
                **overrides) -> "Scanner":
        """Compile patterns under a plan (``overrides`` patch plan fields,
        so ``Scanner.compile(bank, mode="sfa")`` works without a ScanPlan)."""
        plan = (plan or ScanPlan()).with_(**overrides) if overrides else \
            (plan or ScanPlan()).validate()
        ids, dfas, single = _normalize(patterns)
        if not dfas:
            raise ValueError("empty pattern set")
        alphabet = dfas[0].alphabet
        for d in dfas:
            if d.alphabet != alphabet:
                raise ValueError("all patterns must share one alphabet")

        trace_id = None
        with obs.span("scanner.compile", patterns=len(dfas),
                      mode=plan.mode, backend=plan.backend):
            trace_id = obs.current_trace_id()
            # Resolve per-pattern mode. ``auto`` = the paper's criterion:
            # use the SFA when construction closes under the budget,
            # enumeration when it blows up (Mytkowicz-style fallback).
            # Construction goes through the content-addressed cache + the
            # batched bank closure (see repro.construction): recompiling
            # the same patterns is free.
            modes, sfas, report = _resolve_sfas(ids, dfas, plan)

            mesh = None
            if plan.distribution == "shard_map":
                mesh = plan.mesh if plan.mesh is not None else make_mesh(
                    (1,), (plan.data_axis,)
                )

            groups = []
            for mode in ("sfa", "enumeration", "speculative"):
                member = [i for i, m in enumerate(modes) if m == mode]
                if not member:
                    continue
                if plan.chunking.bucket:
                    sizes = [
                        sfas[i].n_states if mode == "sfa"
                        else dfas[i].n_states
                        for i in member
                    ]
                    parts = _size_partition(sizes, plan.chunking.bucket_edges)
                    parts = [[member[j] for j in p] for p in parts]
                else:
                    parts = [member]
                for part in parts:
                    groups.append(cls._build_group(
                        part, [dfas[i] for i in part], [ids[i] for i in part],
                        mode, [sfas.get(i) for i in part], plan, mesh,
                    ))
        obs.counter("engine.compiles").inc()
        scanner = cls(ids, dfas, groups, plan, single, mesh, report)
        scanner.last_trace_id = trace_id
        return scanner

    @staticmethod
    def _build_group(indices, dfas, gids, mode, sfas, plan, mesh) -> PatternGroup:
        bank = PatternBank.from_dfas(dfas, gids)
        g = PatternGroup(
            indices=np.asarray(indices, dtype=np.int64), bank=bank, mode=mode
        )
        g.tables = jnp.asarray(bank.tables)
        if mode == "sfa":
            deltas, maps, sizes = _stack_sfas(sfas, bank.n_max)
            g.deltas = jnp.asarray(deltas)
            g.sfa_maps = jnp.asarray(maps)
            g.sfa_states = sizes
        if mesh is not None:
            g._dist_fn = X.distributed_doc_mappings_fn(
                mesh, plan.data_axis, plan.chunking.n_chunks,
                sfa_mode=(mode == "sfa"),
            )
            if mode == "speculative":
                g._spec_dist_fn = distributed_speculative_finals_fn(
                    mesh, plan.data_axis, plan.chunking.n_chunks,
                    plan.speculation.max_repair_rounds,
                )
        return g

    # -- encoding helpers ---------------------------------------------------

    def encode(self, text: str) -> np.ndarray:
        sym = {c: i for i, c in enumerate(self.alphabet)}
        return np.asarray([sym[c] for c in text], dtype=np.int32)

    def _encode_docs(self, docs) -> list:
        if isinstance(docs, str):
            docs = [docs]
        if isinstance(docs, np.ndarray) and docs.ndim == 2:
            return [np.asarray(row, dtype=np.int32) for row in docs]
        out = []
        for d in docs:
            out.append(self.encode(d) if isinstance(d, str)
                       else np.asarray(d, dtype=np.int32))
        return out

    # -- the chunk-function core -------------------------------------------

    def _group_doc_mappings(self, g: PatternGroup, corpus: np.ndarray
                            ) -> np.ndarray:
        """Final mapping of every (pattern-in-group, doc): -> (Pg, D, n).

        The chunk-parallel backend handles the head (the largest prefix
        divisible by ``n_chunks``); any ragged tail is composed sequentially
        in NumPy — cheap (< one chunk per doc) and exact.
        """
        n_chunks = self.plan.chunking.n_chunks
        D, L = corpus.shape
        head_len = L - (L % n_chunks)
        Pg, n = len(g.indices), g.n

        if head_len:
            head = corpus[:, :head_len]
            maps = self._head_mappings(g, head, n_chunks)
        else:
            maps = np.broadcast_to(
                np.arange(n, dtype=np.int32), (Pg, D, n)
            ).copy()

        if head_len < L:
            if not maps.flags.writeable:
                maps = maps.copy()
            for d in range(D):
                maps[:, d, :] = X.compose_sequential(
                    g.bank.tables, maps[:, d, :], corpus[d, head_len:]
                )
        return maps

    def _head_mappings(self, g: PatternGroup, head: np.ndarray,
                       n_chunks: int) -> np.ndarray:
        backend = self.plan.backend
        corpus_j = jnp.asarray(head)
        if self.mesh is not None:
            D = head.shape[0]
            n_dev = int(np.prod(list(self.mesh.shape.values())))
            if D % n_dev:
                raise ValueError(
                    f"shard_map distribution needs doc count ({D}) divisible "
                    f"by the mesh's {self.plan.data_axis} size ({n_dev})"
                )
            if g.mode == "sfa":
                out = g._dist_fn(g.deltas, g.sfa_maps, corpus_j)
            else:
                out = g._dist_fn(g.tables, corpus_j)
            return np.asarray(out)
        if backend == "reference":
            return _reference_doc_mappings(g.bank.tables, head)
        if backend == "pallas":
            if g.mode == "sfa":
                out = X.bank_doc_mappings_sfa_pallas(
                    g.deltas, g.sfa_maps, corpus_j, n_chunks
                )
            else:
                out = X.bank_doc_mappings_pallas(g.tables, corpus_j, n_chunks)
            return np.asarray(out)
        # xla
        if g.mode == "sfa":
            out = X.bank_doc_mappings_sfa(g.deltas, g.sfa_maps, corpus_j, n_chunks)
        else:
            out = X.bank_doc_mappings(g.tables, corpus_j, n_chunks)
        return np.asarray(out)

    # -- the speculative core ----------------------------------------------

    def _speculation_sample(self, corpus: np.ndarray) -> np.ndarray:
        """The profiler's symbol sample: a prefix of the flattened corpus
        sized by the policy's ``sample_frac`` / ``max_sample``."""
        pol = self.plan.speculation
        flat = corpus.reshape(-1)
        s = min(pol.max_sample, max(1, int(pol.sample_frac * flat.size)))
        return flat[:s]

    def _explicit_profile_states(self, g: PatternGroup, src) -> np.ndarray:
        """Explicit ``profile_source``: a mapping {pattern id: states} or one
        state sequence for every pattern. The adversarial-testing hook — any
        states are *correct* (misspeculation only costs repairs)."""
        pol = self.plan.speculation
        if hasattr(src, "keys"):
            rows = []
            for i in g.indices:
                pid = self.ids[i]
                if pid not in src:
                    raise ValueError(
                        f"explicit speculation profile is missing pattern "
                        f"{pid!r}"
                    )
                rows.append(np.asarray(src[pid], dtype=np.int32))
        else:
            rows = [np.asarray(src, dtype=np.int32)] * len(g.indices)
        for r in rows:
            if r.ndim != 1 or not r.size:
                raise ValueError(
                    "explicit speculation profiles must be non-empty 1-D "
                    "state sequences"
                )
        profs = [
            HotStateProfile(
                states=r, weights=np.zeros(len(r), dtype=np.float64),
                sample_len=0,
            )
            for r in rows
        ]
        return stack_profile_states(profs, pol.m, g.n)

    def _speculation_profile(self, g: PatternGroup, corpus: np.ndarray
                             ) -> np.ndarray:
        """Resolve one group's (Pg, m) speculated boundary states.

        ``"sample"`` profiles the first scanned corpus (a bounded
        ``max_sample``-symbol walk) and memoizes the result on the group:
        the profiler is a sequential host-side pass, and paying it once per
        *scanner* instead of once per scan is what keeps speculation ahead
        of enumeration on repeat scans. A profile is advisory — reusing it
        on later, differently-distributed corpora costs repair rounds,
        never correctness. ``"store"`` consults the plan's persistent
        :class:`~repro.scanservice.ArtifactStore` by ``dfa_cache_key``
        first, samples on a miss, and persists what it learned; explicit
        sources bypass profiling entirely.
        """
        pol = self.plan.speculation
        src = pol.profile_source
        if not isinstance(src, str):
            return self._explicit_profile_states(g, src)
        if g._spec_profile is not None:
            return g._spec_profile
        store = self.plan.construction.resolve_store() if src == "store" \
            else None
        profiles: list = [None] * len(g.indices)
        keys = None
        if store is not None and hasattr(store, "get_profile"):
            from ..construction import dfa_cache_key

            keys = [dfa_cache_key(self._dfas[i]) for i in g.indices]
            for j, key in enumerate(keys):
                meta = store.get_profile(key)
                if meta is not None:
                    profiles[j] = HotStateProfile.from_json(meta)
        need = [j for j, pr in enumerate(profiles) if pr is None]
        if need:
            sample = self._speculation_sample(corpus)
            fresh = profile_hot_states(
                g.bank.tables[need], g.bank.starts[need], sample, pol.m
            )
            for j, pr in zip(need, fresh):
                profiles[j] = pr
                if keys is not None and hasattr(store, "put_profile"):
                    store.put_profile(keys[j], pr.to_json())
        states = stack_profile_states(profiles, pol.m, g.n)
        g._spec_profile = states
        return states

    def _group_doc_finals(self, g: PatternGroup, corpus: np.ndarray) -> tuple:
        """Speculative path: exact final states of every (pattern-in-group,
        doc) from each pattern's start — (Pg, D) int32 plus the group's
        :class:`~repro.speculative.SpeculationStats`.

        Bit-identical to reading the enumeration mappings off at the start
        states: the executor only adopts chunk results whose entry state it
        verified exactly, and any lane the repair bound leaves unresolved is
        recomputed here through the enumeration executor (always the local
        XLA one — exactness makes the backend choice invisible, and the
        fallback subset's ragged doc count doesn't fit the mesh contract).
        The ragged tail advances the finals sequentially, mirroring
        ``_group_doc_mappings``.
        """
        pol = self.plan.speculation
        n_chunks = self.plan.chunking.n_chunks
        D, L = corpus.shape
        head_len = L - (L % n_chunks)
        starts = g.bank.starts.astype(np.int32)
        Pg = len(g.indices)
        stats = SpeculationStats()
        with obs.span("speculative.scan", patterns=Pg, docs=D):
            if head_len:
                spec = self._speculation_profile(g, corpus)
                head = corpus[:, :head_len]
                if self.mesh is not None:
                    n_dev = int(np.prod(list(self.mesh.shape.values())))
                    if D % n_dev:
                        raise ValueError(
                            f"shard_map distribution needs doc count ({D}) "
                            f"divisible by the mesh's {self.plan.data_axis} "
                            f"size ({n_dev})"
                        )
                    out = g._spec_dist_fn(
                        g.tables, jnp.asarray(spec), jnp.asarray(starts),
                        jnp.asarray(head),
                    )
                else:
                    out = speculative_bank_finals(
                        g.tables, jnp.asarray(spec), jnp.asarray(starts),
                        jnp.asarray(head), n_chunks=n_chunks,
                        max_rounds=pol.max_repair_rounds,
                    )
                finals, resolved, hit_n, repaired, rounds = (
                    np.asarray(x) for x in out
                )
                stats = SpeculationStats(
                    total_chunks=Pg * D * n_chunks,
                    hit_chunks=int(hit_n),
                    repaired_chunks=int(repaired),
                    repair_rounds=int(rounds),
                    fallback_lanes=int(np.sum(~resolved)),
                )
                if not resolved.all():
                    finals = np.array(finals)  # device views are read-only
                    bad = np.flatnonzero(~resolved.all(axis=0))
                    with obs.span("speculative.fallback", lanes=len(bad)):
                        maps = np.asarray(X.bank_doc_mappings(
                            g.tables,
                            jnp.asarray(np.ascontiguousarray(head[bad])),
                            n_chunks,
                        ))
                    exact = np.take_along_axis(
                        maps, starts[:, None, None].astype(np.int64), axis=2
                    )[:, :, 0]
                    finals[:, bad] = np.where(
                        resolved[:, bad], finals[:, bad], exact
                    )
            else:
                finals = np.repeat(starts[:, None], D, axis=1)
            if head_len < L:
                finals = X.advance_states_sequential(
                    g.bank.tables, finals, corpus[:, head_len:]
                )
        obs.counter("speculative.total_chunks").inc(stats.total_chunks)
        obs.counter("speculative.hit_chunks").inc(stats.hit_chunks)
        obs.counter("speculative.repaired_chunks").inc(stats.repaired_chunks)
        obs.counter("speculative.repair_rounds").inc(stats.repair_rounds)
        obs.counter("speculative.fallback_lanes").inc(stats.fallback_lanes)
        if stats.total_chunks:
            obs.gauge("speculative.hit_rate").set(stats.hit_rate)
        return finals, stats

    # -- public scan API ----------------------------------------------------

    def scan(self, docs) -> ScanResult:
        """Match a corpus against the bank -> :class:`ScanResult` (P, D)."""
        enc = self._encode_docs(docs)
        D = len(enc)
        hits = np.zeros((self.n_patterns, D), dtype=bool)
        spec_stats: SpeculationStats | None = None
        with obs.span("scanner.scan", patterns=self.n_patterns, docs=D):
            self.last_trace_id = obs.current_trace_id() or self.last_trace_id
            # Batch docs of equal length together (one fixed-shape program
            # each).
            by_len: dict = {}
            for d, e in enumerate(enc):
                by_len.setdefault(len(e), []).append(d)
            for L, idxs in sorted(by_len.items()):
                corpus = np.stack([enc[d] for d in idxs]) if L else \
                    np.zeros((len(idxs), 0), dtype=np.int32)
                for g in self.groups:
                    if g.mode == "speculative" and L:
                        finals, st = self._group_doc_finals(g, corpus)
                        spec_stats = st if spec_stats is None \
                            else spec_stats.merged(st)
                    else:
                        if L:
                            maps = self._group_doc_mappings(g, corpus)
                        else:
                            maps = np.broadcast_to(
                                np.arange(g.n, dtype=np.int32),
                                (len(g.indices), len(idxs), g.n),
                            )
                        starts = g.bank.starts                  # (Pg,)
                        finals = np.take_along_axis(
                            maps, starts[:, None, None].astype(np.int64),
                            axis=2
                        )[:, :, 0]                              # (Pg, Dg)
                    acc = np.take_along_axis(
                        g.bank.accepting, finals.astype(np.int64), axis=1
                    )
                    hits[np.ix_(g.indices, np.asarray(idxs))] = acc
        obs.counter("engine.scans").inc()
        obs.counter("engine.docs_scanned").inc(D)
        self.last_speculation = spec_stats
        return ScanResult(hits=hits, ids=self.ids, speculation=spec_stats)

    def census(self, docs) -> np.ndarray:
        """Per-pattern hit counts over a corpus, (P,) int32."""
        return self.scan(docs).counts

    def census_windows(self, seq, window: int, stride: int | None = None
                       ) -> ScanResult:
        """Prefix-scan census of all sliding windows of one sequence.

        ``scan`` on materialized windows recomputes every shared symbol's
        chunk function once per overlapping window; here the sequence is cut
        into ``stride``-symbol blocks, each block's transition function is
        computed **once**, and all window compositions come out of two
        :func:`repro.core.monoid.scan` passes per tile
        (:func:`repro.engine.executors.sliding_window_mappings`). Function
        composition is exactly associative, so ``hits`` is bit-identical to
        ``scan([seq[i*stride : i*stride + window] for i ...])``.

        ``stride`` must divide ``window`` (default: ``stride = window``,
        i.e. disjoint blocks). Returns a :class:`ScanResult` whose "docs"
        are the ``(len(seq) - window) // stride + 1`` full windows.
        """
        stride = window if stride is None else stride
        if window < 1 or stride < 1:
            raise ValueError("window and stride must be >= 1")
        if window % stride:
            raise ValueError(
                f"stride ({stride}) must divide window ({window}): the "
                "prefix-scan census composes whole stride-blocks"
            )
        enc = self._encode_docs([seq])[0]
        L = len(enc)
        m = window // stride
        W = (L - window) // stride + 1 if L >= window else 0
        hits = np.zeros((self.n_patterns, W), dtype=bool)
        if W == 0:
            return ScanResult(hits=hits, ids=self.ids)
        B = W + m - 1
        blocks = np.ascontiguousarray(enc[: B * stride].reshape(B, stride))
        if self.mesh is not None:
            # Blocks are the "docs" of the shard_map path: pad the block
            # axis up to the mesh size with throwaway rows, cropped below.
            n_dev = int(np.prod(list(self.mesh.shape.values())))
            pad_rows = -B % n_dev
            if pad_rows:
                blocks = np.concatenate(
                    [blocks, np.zeros((pad_rows, stride), dtype=np.int32)]
                )
        for g in self.groups:
            maps = self._group_doc_mappings(g, blocks)[:, :B]  # (Pg, B, n)
            wmaps = np.asarray(X.sliding_window_mappings(
                jnp.asarray(maps), m
            ))                                              # (Pg, W, n)
            finals = np.take_along_axis(
                wmaps, g.bank.starts[:, None, None].astype(np.int64), axis=2
            )[:, :, 0]
            acc = np.take_along_axis(
                g.bank.accepting, finals.astype(np.int64), axis=1
            )
            hits[g.indices, :] = acc
        return ScanResult(hits=hits, ids=self.ids)

    def mapping(self, doc) -> np.ndarray:
        """Transition function of one whole input under every pattern,
        (P, n_max) int32 on the scanner's padded layout (identity beyond
        each pattern's true state count).

        Speculative-mode groups compute their mapping through the
        enumeration executor here: a full transition function inherently
        needs all n states, so there is nothing for speculation to skip.
        ``scan``/``stream`` are the speculative fast paths.
        """
        enc = self._encode_docs([doc])[0]
        out = np.broadcast_to(
            np.arange(self.n_max, dtype=np.int32),
            (self.n_patterns, self.n_max),
        ).copy()
        corpus = enc[None, :]
        for g in self.groups:
            maps = self._group_doc_mappings(g, corpus)[:, 0, :]  # (Pg, n_g)
            out[g.indices, : g.n] = maps
        return out

    def accepts(self, doc):
        """Accept flags of one input: bool for a single-pattern scanner,
        (P,) bool for a bank."""
        flags = self.scan([doc]).hits[:, 0]
        return bool(flags[0]) if self.single else flags

    def locate(self, doc, pattern=None) -> np.ndarray:
        """Per-position accept flags of one doc under one pattern (two-pass
        chunk-parallel match localization). ``pattern`` is an id or index;
        defaults to the only pattern of a single-pattern scanner."""
        if pattern is None:
            if not self.single:
                raise ValueError("bank scanner: pass pattern=<id or index>")
            p = 0
        else:
            p = (self.ids.index(pattern) if isinstance(pattern, str)
                 else int(pattern))
        d = self._dfas[p]
        enc = self._encode_docs([doc])[0]
        n_chunks = self.plan.chunking.n_chunks
        head_len = len(enc) - (len(enc) % n_chunks)
        flags = np.zeros(len(enc), dtype=bool)
        if head_len:
            flags[:head_len] = np.asarray(X.find_matches_parallel(
                jnp.asarray(d.table), jnp.asarray(d.accepting),
                jnp.asarray(enc[:head_len]), d.start, n_chunks,
            ))
        # sequential tail from the head's final state
        s = d.run(enc[:head_len]) if head_len else d.start
        for i in range(head_len, len(enc)):
            s = int(d.table[s, enc[i]])
            flags[i] = bool(d.accepting[s])
        return flags

    # -- serving ------------------------------------------------------------

    @classmethod
    def service(cls, store_dir=None, plan: ScanPlan | None = None,
                **kwargs):
        """The serving layer's front door: a
        :class:`repro.scanservice.ScanService` whose compiles run through a
        persistent artifact store at ``store_dir`` (when given) and whose
        ``submit``/``flush`` coalesce concurrent requests into one bank
        compile + one fused scan. See :mod:`repro.scanservice`.
        """
        from ..scanservice import ScanService

        return ScanService(store_dir=store_dir, plan=plan, **kwargs)

    # -- streaming ----------------------------------------------------------

    def open_stream(self) -> StreamSession:
        """Push API: feed chunk blocks incrementally, then ``finish()``."""
        return StreamSession(self)

    def stream(self, blocks) -> StreamResult:
        """Scan one logically-concatenated input delivered as an iterable of
        blocks (strings or encoded int arrays) without whole-corpus
        residency. Equivalent to ``scan`` on the concatenation; the running
        function-monoid prefix carries across fixed-shape block calls."""
        sess = self.open_stream()
        for b in blocks:
            sess.feed(b)
        return sess.finish()

    # -- introspection ------------------------------------------------------

    def describe(self) -> str:
        r = self.construction_report
        lines = [
            f"Scanner: {self.n_patterns} pattern(s), alphabet |Σ|="
            f"{len(self.alphabet)}, plan=({self.plan.mode}/"
            f"{self.plan.backend}/{self.plan.distribution}, "
            f"n_chunks={self.plan.chunking.n_chunks})",
            f"  construction: {r.rounds} round(s) via {r.method}, "
            f"cache {r.cache_hits} hit(s) / {r.cache_misses} miss(es), "
            f"{r.constructed} built, {r.blown} blown",
        ]
        for g in self.groups:
            extra = ""
            if g.mode == "sfa":
                extra = f", S_max={int(g.deltas.shape[1])}"
            elif g.mode == "speculative":
                extra = (f", m={self.plan.speculation.m}, "
                         f"source={self.plan.speculation.profile_source!r}"
                         if isinstance(self.plan.speculation.profile_source,
                                       str)
                         else f", m={self.plan.speculation.m}, "
                              f"source=explicit")
            lines.append(
                f"  group[{g.mode}]: {len(g.indices)} pattern(s), "
                f"n_max={g.n}{extra}"
            )
        s = self.last_speculation
        if s is not None:
            lines.append(
                f"  speculation: hit rate {s.hit_rate:.3f} "
                f"({s.hit_chunks}/{s.total_chunks} chunks), "
                f"{s.repaired_chunks} repaired in {s.repair_rounds} "
                f"round(s), {s.fallback_lanes} fallback lane(s)"
            )
        if self.last_trace_id is not None:
            summ = obs.trace_summary(self.last_trace_id)
            if summ["spans"]:
                lines.append(
                    f"  last trace {summ['trace_id']}: "
                    f"{len(summ['spans'])} span(s), "
                    f"wall {summ['wall_s'] * 1e3:.2f} ms"
                )
                for sp in summ["spans"][:8]:
                    lines.append(
                        f"    {sp['name']}: {sp['wall_s'] * 1e3:.2f} ms "
                        f"{sp['attrs'] or ''}".rstrip()
                    )
        return "\n".join(lines)


def _reference_doc_mappings(tables: np.ndarray, corpus: np.ndarray) -> np.ndarray:
    """Pure-NumPy oracle: compose each doc's transition function symbol by
    symbol over all states at once. (Pg, n, k), (D, L) -> (Pg, D, n)."""
    Pg, n, _ = tables.shape
    D, _ = corpus.shape
    out = np.empty((Pg, D, n), dtype=np.int32)
    ident = np.broadcast_to(np.arange(n, dtype=np.int32), (Pg, n))
    for d in range(D):
        out[:, d] = X.compose_sequential(tables, ident, corpus[d])
    return out
