"""Execution plans: the declarative half of the ``Scanner`` engine API.

A :class:`ScanPlan` says *how* to run a scan — matching mode, backend,
distribution, and chunking — while the :class:`~repro.engine.Scanner` facade
says *what* to scan. Splitting the two keeps every matching configuration the
repo supports (DFA vs SFA mode, single pattern vs bank, one device vs a mesh,
XLA vs Pallas inner loops) behind one entry point, which is the paper's own
framing: chunk transition functions combined by one associative monoid serve
them all.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field, replace
from typing import Any

MODES = ("auto", "sfa", "enumeration", "speculative")
BACKENDS = ("reference", "xla", "pallas")
SPECULATION_SOURCES = ("sample", "store")
DISTRIBUTIONS = ("local", "shard_map")
CONSTRUCTION_METHODS = ("auto", "batched", "loop")
CONSTRUCTION_ENGINES = ("vectorized", "sequential", "jax")
CONSTRUCTION_FP_BACKENDS = ("auto", "xla", "pallas")
CONSTRUCTION_EXPAND_BACKENDS = ("auto", "xla", "pallas")
CONSTRUCTION_BUCKETINGS = ("auto", "size", "off")

#: Default SFA state budget for ``mode="auto"``: patterns whose exact SFA
#: closes within this many states get the paper's single-lookup inner loop;
#: the rest fall back to enumeration (Mytkowicz-style n-wide gathers). 512
#: splits the bundled PROSITE bank into a representative mix of both.
DEFAULT_SFA_STATE_BUDGET = 512


@dataclass(frozen=True)
class ChunkPolicy:
    """How inputs are cut into the paper's parallel chunks.

    ``n_chunks``
        chunk-level parallelism per document (and per stream block) — the
        paper's thread count.
    ``block_len``
        symbols per chunk in the streaming path; one stream block is a fixed
        ``(n_chunks, block_len)`` array, so every block reuses one compiled
        program (and one VMEM-resident table in the Pallas inner loop).
    ``bucket`` / ``bucket_edges``
        size-bucketing of the pattern bank: patterns are grouped so no
        pattern pays gathers more than ~2x wider than its own automaton
        (``core.multipattern.bucket_by_size``'s padding argument).
    """

    n_chunks: int = 8
    block_len: int = 256
    bucket: bool = False
    bucket_edges: tuple = (8, 16, 32, 64, 128, 256, 1024)

    def validate(self) -> "ChunkPolicy":
        if self.n_chunks < 1:
            raise ValueError(f"n_chunks must be >= 1, got {self.n_chunks}")
        if self.block_len < 1:
            raise ValueError(f"block_len must be >= 1, got {self.block_len}")
        if self.bucket and not self.bucket_edges:
            raise ValueError("bucket=True requires non-empty bucket_edges")
        return self


@dataclass(frozen=True)
class ConstructionPolicy:
    """How ``Scanner.compile`` builds the SFAs its plan needs.

    ``method``
        ``"batched"`` constructs every cache-missing pattern in one
        :func:`repro.construction.construct_bank` call (all frontiers advance
        simultaneously in jitted bulk-synchronous rounds — the paper's
        task-level construction parallelism); ``"loop"`` is the per-pattern
        sequential loop (``engine=`` picks the single-pattern engine);
        ``"auto"`` batches when at least 4 patterns miss the cache and loops
        otherwise (a bank round has to amortize its XLA compilation).
    ``cache``
        ``"shared"`` (the process-wide content-addressed
        :class:`repro.construction.SFACache` — recompiling the same patterns
        performs zero construction rounds), ``"off"``, or an explicit
        :class:`~repro.construction.SFACache` instance (isolated caches for
        tests and multi-tenant serving).
    ``store``
        optional persistent tier under the cache: a directory path (wrapped
        in :class:`repro.scanservice.ArtifactStore`) or any object speaking
        the backing protocol. Attached to the resolved cache, so SFAs
        persist across processes — a fresh process compiling previously-seen
        patterns performs zero construction rounds. Ignored when
        ``cache="off"``.
    ``distribution``
        ``"shard_map"`` shards the *pattern* axis of the batched construction
        buffers over ``mesh`` (default: a fresh 1-axis mesh named
        ``pattern_axis``); ``"local"`` keeps construction on one device.
    ``tile`` / ``max_retries``
        frontier states processed per pattern per round, and the per-pattern
        polynomial retry budget on a detected fingerprint collision.
    ``fingerprint_backend``
        the batched round's fingerprint stage: ``"xla"`` (fused clmul fold),
        ``"pallas"`` (the ``kernels.ops.fingerprint_bank`` Rabin kernel —
        bit-identical), or ``"auto"`` (pallas on a TPU runtime, xla
        elsewhere).
    ``expand_backend``
        the batched round's frontier-expansion stage: ``"xla"`` (fused
        ``jnp.take`` gather), ``"pallas"`` (the
        ``kernels.ops.expand_frontier_bank`` one-hot MXU gather —
        bit-identical), or ``"auto"`` (pallas on a TPU runtime, xla
        elsewhere).
    ``bucketing``
        size-bucketed construction banks: ``"size"`` partitions a batched
        bank by DFA state count so small patterns stop paying the widest
        pattern's frontier rows and sort lengths (the P=64 lever),
        ``"off"`` keeps one padded bank, ``"auto"`` buckets only when the
        bank is big and skewed enough to pay. Bit-identical either way.
    ``bucket_growth``
        active-set bucket shrink factor of the construction shape schedule
        (``repro.construction.round_schedule``): larger compiles fewer round
        shapes at the cost of more padding in mid-size rounds.
    """

    method: str = "auto"
    engine: str = "vectorized"
    tile: int = 128
    cache: Any = "shared"
    store: Any = None
    distribution: str = "local"
    mesh: Any = None
    pattern_axis: str = "pattern"
    max_retries: int = 4
    fingerprint_backend: str = "auto"
    expand_backend: str = "auto"
    bucketing: str = "auto"
    bucket_growth: int = 4

    def validate(self) -> "ConstructionPolicy":
        if self.method not in CONSTRUCTION_METHODS:
            raise ValueError(
                f"construction method must be one of {CONSTRUCTION_METHODS}, "
                f"got {self.method!r}"
            )
        if self.engine not in CONSTRUCTION_ENGINES:
            raise ValueError(
                f"construction engine must be one of {CONSTRUCTION_ENGINES}, "
                f"got {self.engine!r}"
            )
        if self.tile < 1:
            raise ValueError(f"construction tile must be >= 1, got {self.tile}")
        if self.max_retries < 1:
            raise ValueError("construction max_retries must be >= 1")
        if self.distribution not in DISTRIBUTIONS:
            raise ValueError(
                f"construction distribution must be one of {DISTRIBUTIONS}, "
                f"got {self.distribution!r}"
            )
        if self.fingerprint_backend not in CONSTRUCTION_FP_BACKENDS:
            raise ValueError(
                "construction fingerprint_backend must be one of "
                f"{CONSTRUCTION_FP_BACKENDS}, got {self.fingerprint_backend!r}"
            )
        if self.expand_backend not in CONSTRUCTION_EXPAND_BACKENDS:
            raise ValueError(
                "construction expand_backend must be one of "
                f"{CONSTRUCTION_EXPAND_BACKENDS}, got {self.expand_backend!r}"
            )
        if self.bucketing not in CONSTRUCTION_BUCKETINGS:
            raise ValueError(
                "construction bucketing must be one of "
                f"{CONSTRUCTION_BUCKETINGS}, got {self.bucketing!r}"
            )
        if self.bucket_growth < 2:
            raise ValueError(
                f"construction bucket_growth must be >= 2, "
                f"got {self.bucket_growth}"
            )
        from ..construction import SFACache

        if not (self.cache in ("shared", "off", None)
                or isinstance(self.cache, SFACache)):
            raise ValueError(
                "construction cache must be 'shared', 'off', None, or an "
                f"SFACache instance, got {self.cache!r}"
            )
        if not (self.store is None
                or isinstance(self.store, (str, os.PathLike))
                or (hasattr(self.store, "get")
                    and hasattr(self.store, "put_sfa"))):
            raise ValueError(
                "construction store must be None, a directory path, or an "
                "object with the ArtifactStore backing protocol "
                f"(get/put_sfa/put_blowup), got {self.store!r}"
            )
        return self

    def resolve_store(self):
        """-> the backing store object, or None. Paths wrap lazily in an
        :class:`repro.scanservice.ArtifactStore`."""
        if self.store is None:
            return None
        if isinstance(self.store, (str, os.PathLike)):
            from ..scanservice.store import ArtifactStore

            return ArtifactStore(self.store)
        return self.store

    def resolve_cache(self):
        """-> the SFACache to consult (with any configured backing store
        attached), or None when caching is off."""
        from ..construction import SFACache, shared_cache

        cache = None
        if isinstance(self.cache, SFACache):
            cache = self.cache
        elif self.cache == "shared":
            cache = shared_cache()
        if cache is not None:
            cache.attach_backing(self.resolve_store())
        return cache

    def with_(self, **overrides) -> "ConstructionPolicy":
        return replace(self, **overrides).validate()


@dataclass(frozen=True)
class SpeculationPolicy:
    """How ``mode="speculative"`` (and auto's speculative tier) speculates.

    ``m``
        speculated boundary states per pattern — every chunk runs from all
        ``m`` at once (a stacked ``(m, chunks)`` state axis), so cost scales
        with ``m`` where enumeration scales with the automaton's ``n``.
    ``sample_frac`` / ``max_sample``
        how much of the input the hot-state profiler reads when the profile
        comes from sampling: ``min(max_sample, sample_frac · corpus_size)``
        symbols off the corpus prefix.
    ``max_repair_rounds``
        convergence bound of the executor's validate/repair loop. Each round
        re-scans exactly one chunk per broken (pattern, doc) lane from its
        now-known entry state; lanes still unresolved at the bound fall back
        to full enumeration — results stay bit-identical either way, the
        bound only caps how long the cheap path keeps trying.
    ``profile_source``
        ``"sample"`` (profile the first scanned input, memoized per
        scanner — the profile is advisory, so reuse costs repairs at
        worst, never correctness),
        ``"store"`` (look up a persisted profile in the plan's
        ``construction.store`` by the pattern's ``dfa_cache_key``, sampling
        and persisting on a miss — the scan-service path), a mapping
        ``{pattern id: state sequence}``, or one explicit state sequence
        applied to every pattern (the adversarial-testing hook).
    ``auto_states``
        the ``auto``-mode tier threshold: a pattern whose SFA blows the
        state budget routes to speculation only when its DFA has at least
        this many states; smaller blowup patterns keep the enumeration
        fallback (their n-wide gathers are already cheap). 128 is a
        conservative bound on the measured crossover
        (``BENCH_speculative.json``): warm repeat scans win well below it,
        but a first scan also pays the sequential profiling pass.
    """

    m: int = 8
    sample_frac: float = 0.05
    max_sample: int = 4096
    max_repair_rounds: int = 8
    profile_source: Any = "sample"
    auto_states: int = 128

    def validate(self) -> "SpeculationPolicy":
        if self.m < 1:
            raise ValueError(f"speculation m must be >= 1, got {self.m}")
        if not (0.0 < self.sample_frac <= 1.0):
            raise ValueError(
                f"speculation sample_frac must be in (0, 1], "
                f"got {self.sample_frac}"
            )
        if self.max_sample < 1:
            raise ValueError("speculation max_sample must be >= 1")
        if self.max_repair_rounds < 1:
            raise ValueError("speculation max_repair_rounds must be >= 1")
        if self.auto_states < 1:
            raise ValueError("speculation auto_states must be >= 1")
        src = self.profile_source
        if isinstance(src, str):
            if src not in SPECULATION_SOURCES:
                raise ValueError(
                    f"speculation profile_source must be one of "
                    f"{SPECULATION_SOURCES}, a mapping, or a state sequence; "
                    f"got {src!r}"
                )
        elif not (hasattr(src, "keys") or hasattr(src, "__len__")
                  or hasattr(src, "__iter__")):
            raise ValueError(
                "speculation profile_source must be 'sample', 'store', a "
                f"mapping, or a state sequence, got {src!r}"
            )
        return self

    def with_(self, **overrides) -> "SpeculationPolicy":
        return replace(self, **overrides).validate()


@dataclass(frozen=True)
class ScanPlan:
    """One execution plan for a compiled :class:`~repro.engine.Scanner`.

    ``mode``
        ``"sfa"`` forces the paper's SFA matching (construction must fit the
        budget for *every* pattern, else ``StateBlowup`` propagates);
        ``"enumeration"`` forces the related-work all-states gather mode;
        ``"speculative"`` forces the hot-state speculation executor
        (:mod:`repro.speculative` — m speculated boundary states per chunk,
        validate + repair, bit-identical to enumeration by construction);
        ``"auto"`` attempts SFA construction per pattern under
        ``sfa_state_budget`` and, on ``StateBlowup``, falls back to
        speculation when the DFA has at least ``speculation.auto_states``
        states and to enumeration otherwise — the three-tier criterion.
    ``backend``
        ``"reference"`` (pure NumPy oracle), ``"xla"`` (jitted vmapped
        chunk matchers), or ``"pallas"`` (the ``match_bank_chunks_pallas``
        inner loop with VMEM-resident transposed tables). All three produce
        bit-identical results; they differ only in execution strategy.
    ``distribution``
        ``"local"`` or ``"shard_map"`` (documents shard over ``data_axis``
        of ``mesh``; a 1-device mesh is built when ``mesh`` is None).
    ``chunking``
        a :class:`ChunkPolicy`.
    ``construction``
        a :class:`ConstructionPolicy`: how the SFAs behind ``mode="sfa"`` /
        ``"auto"`` get built (batched bank rounds vs per-pattern loop,
        content-addressed caching, pattern-sharded construction meshes).
    ``speculation``
        a :class:`SpeculationPolicy`: the speculative tier's knobs (state
        count ``m``, profile sampling, repair bound, auto threshold).
    """

    mode: str = "auto"
    backend: str = "xla"
    distribution: str = "local"
    chunking: ChunkPolicy = field(default_factory=ChunkPolicy)
    construction: ConstructionPolicy = field(default_factory=ConstructionPolicy)
    speculation: SpeculationPolicy = field(default_factory=SpeculationPolicy)
    sfa_state_budget: int = DEFAULT_SFA_STATE_BUDGET
    mesh: Any = None
    data_axis: str = "data"

    def validate(self) -> "ScanPlan":
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {self.backend!r}"
            )
        if self.distribution not in DISTRIBUTIONS:
            raise ValueError(
                f"distribution must be one of {DISTRIBUTIONS}, "
                f"got {self.distribution!r}"
            )
        if self.sfa_state_budget < 1:
            raise ValueError("sfa_state_budget must be >= 1")
        if self.distribution == "shard_map" and self.backend != "xla":
            raise ValueError(
                "distribution='shard_map' currently requires backend='xla' "
                "(the reference backend has no mesh story and the Pallas "
                "inner loop is local-only for now)"
            )
        self.chunking.validate()
        self.construction.validate()
        self.speculation.validate()
        return self

    def with_(self, **overrides) -> "ScanPlan":
        """Functional update (``dataclasses.replace`` with validation)."""
        return replace(self, **overrides).validate()
