"""Execution plans: the declarative half of the ``Scanner`` engine API.

A :class:`ScanPlan` says *how* to run a scan — matching mode, backend,
distribution, and chunking — while the :class:`~repro.engine.Scanner` facade
says *what* to scan. Splitting the two keeps every matching configuration the
repo supports (DFA vs SFA mode, single pattern vs bank, one device vs a mesh,
XLA vs Pallas inner loops) behind one entry point, which is the paper's own
framing: chunk transition functions combined by one associative monoid serve
them all.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

MODES = ("auto", "sfa", "enumeration")
BACKENDS = ("reference", "xla", "pallas")
DISTRIBUTIONS = ("local", "shard_map")

#: Default SFA state budget for ``mode="auto"``: patterns whose exact SFA
#: closes within this many states get the paper's single-lookup inner loop;
#: the rest fall back to enumeration (Mytkowicz-style n-wide gathers). 512
#: splits the bundled PROSITE bank into a representative mix of both.
DEFAULT_SFA_STATE_BUDGET = 512


@dataclass(frozen=True)
class ChunkPolicy:
    """How inputs are cut into the paper's parallel chunks.

    ``n_chunks``
        chunk-level parallelism per document (and per stream block) — the
        paper's thread count.
    ``block_len``
        symbols per chunk in the streaming path; one stream block is a fixed
        ``(n_chunks, block_len)`` array, so every block reuses one compiled
        program (and one VMEM-resident table in the Pallas inner loop).
    ``bucket`` / ``bucket_edges``
        size-bucketing of the pattern bank: patterns are grouped so no
        pattern pays gathers more than ~2x wider than its own automaton
        (``core.multipattern.bucket_by_size``'s padding argument).
    """

    n_chunks: int = 8
    block_len: int = 256
    bucket: bool = False
    bucket_edges: tuple = (8, 16, 32, 64, 128, 256, 1024)

    def validate(self) -> "ChunkPolicy":
        if self.n_chunks < 1:
            raise ValueError(f"n_chunks must be >= 1, got {self.n_chunks}")
        if self.block_len < 1:
            raise ValueError(f"block_len must be >= 1, got {self.block_len}")
        if self.bucket and not self.bucket_edges:
            raise ValueError("bucket=True requires non-empty bucket_edges")
        return self


@dataclass(frozen=True)
class ScanPlan:
    """One execution plan for a compiled :class:`~repro.engine.Scanner`.

    ``mode``
        ``"sfa"`` forces the paper's SFA matching (construction must fit the
        budget for *every* pattern, else ``StateBlowup`` propagates);
        ``"enumeration"`` forces the related-work all-states gather mode;
        ``"auto"`` attempts SFA construction per pattern under
        ``sfa_state_budget`` and falls back to enumeration per pattern on
        ``StateBlowup`` — the crisp criterion the paper implies.
    ``backend``
        ``"reference"`` (pure NumPy oracle), ``"xla"`` (jitted vmapped
        chunk matchers), or ``"pallas"`` (the ``match_bank_chunks_pallas``
        inner loop with VMEM-resident transposed tables). All three produce
        bit-identical results; they differ only in execution strategy.
    ``distribution``
        ``"local"`` or ``"shard_map"`` (documents shard over ``data_axis``
        of ``mesh``; a 1-device mesh is built when ``mesh`` is None).
    ``chunking``
        a :class:`ChunkPolicy`.
    """

    mode: str = "auto"
    backend: str = "xla"
    distribution: str = "local"
    chunking: ChunkPolicy = field(default_factory=ChunkPolicy)
    sfa_state_budget: int = DEFAULT_SFA_STATE_BUDGET
    mesh: Any = None
    data_axis: str = "data"

    def validate(self) -> "ScanPlan":
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")
        if self.backend not in BACKENDS:
            raise ValueError(
                f"backend must be one of {BACKENDS}, got {self.backend!r}"
            )
        if self.distribution not in DISTRIBUTIONS:
            raise ValueError(
                f"distribution must be one of {DISTRIBUTIONS}, "
                f"got {self.distribution!r}"
            )
        if self.sfa_state_budget < 1:
            raise ValueError("sfa_state_budget must be >= 1")
        if self.distribution == "shard_map" and self.backend != "xla":
            raise ValueError(
                "distribution='shard_map' currently requires backend='xla' "
                "(the reference backend has no mesh story and the Pallas "
                "inner loop is local-only for now)"
            )
        self.chunking.validate()
        return self

    def with_(self, **overrides) -> "ScanPlan":
        """Functional update (``dataclasses.replace`` with validation)."""
        return replace(self, **overrides).validate()
