"""Jitted execution primitives behind the :class:`~repro.engine.Scanner`.

This module is the single home of every parallel matching routine: the
single-pattern chunk matchers, the banked (multi-automaton) matchers in both
enumeration and stacked-SFA form, the Pallas inner-loop variants, and the
``shard_map`` distributed builders. They were moved here from
``core/matching.py`` / ``core/multipattern.py`` in the engine redesign — the
old names survive there as thin deprecated shims that delegate to this
module, so nothing downstream breaks while the :class:`Scanner` facade
becomes the public contract.

Layout conventions (shared with ``core.multipattern.PatternBank``):

* enumeration tables are ``(P, n, k)`` int32, padded rows are self-loops;
* stacked SFA tables are ``deltas (P, S, k)`` + ``sfa_maps (P, S, n)`` —
  per-pattern SFA transition tables and state->mapping lookup stacks padded
  the same way (delta padding rows self-loop, mapping padding is identity),
  so the SFA path's chunk functions are *bit-identical* to enumeration's on
  the padded layout;
* chunk functions combine with ``monoid.function_monoid`` everywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from ..compat import shard_map as compat_shard_map
from ..construction import SFA
from ..core import monoid as M
from ..core.dfa import DFA
from ..core.matching import (
    chunk_accept_trace,
    chunk_mapping_enumeration,
    chunk_state_sfa,
)

FN = M.function_monoid()


# --------------------------------------------------------------------------
# Single-pattern parallel matching (ex core/matching.py)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def match_parallel_enumeration(table: jnp.ndarray, symbols: jnp.ndarray,
                               n_chunks: int = 8) -> jnp.ndarray:
    """Parallel match via enumeration; returns the mapping of the whole input.

    The input length must be divisible by ``n_chunks`` (callers pad; padding
    symbols would corrupt the composed function otherwise).
    """
    L = symbols.shape[0]
    assert L % n_chunks == 0, "pad input to a multiple of n_chunks"
    chunks = symbols.reshape(n_chunks, L // n_chunks)
    mappings = jax.vmap(lambda c: chunk_mapping_enumeration(table, c))(chunks)
    return M.reduce(FN, mappings, axis=0)


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def match_parallel_sfa(delta_s: jnp.ndarray, sfa_mappings: jnp.ndarray,
                       symbols: jnp.ndarray, n_chunks: int = 8) -> jnp.ndarray:
    """Parallel match via the SFA (paper's method); returns the input mapping."""
    L = symbols.shape[0]
    assert L % n_chunks == 0
    chunks = symbols.reshape(n_chunks, L // n_chunks)
    final_states = jax.vmap(lambda c: chunk_state_sfa(delta_s, c))(chunks)
    mappings = sfa_mappings[final_states]  # (n_chunks, n)
    return M.reduce(FN, mappings, axis=0)


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def find_matches_parallel(table: jnp.ndarray, accepting: jnp.ndarray,
                          symbols: jnp.ndarray, start: int,
                          n_chunks: int = 8) -> jnp.ndarray:
    """Per-position accept flags, computed in two parallel passes:
    (1) chunk functions + exclusive scan -> entry state per chunk;
    (2) per-chunk accept traces from the entry states."""
    L = symbols.shape[0]
    assert L % n_chunks == 0
    chunks = symbols.reshape(n_chunks, L // n_chunks)
    mappings = jax.vmap(lambda c: chunk_mapping_enumeration(table, c))(chunks)
    prefix = M.exclusive_scan(FN, mappings, axis=0)      # (n_chunks, n)
    entry = prefix[:, start]                              # (n_chunks,)
    flags = jax.vmap(lambda c, e: chunk_accept_trace(table, accepting, c, e))(
        chunks, entry
    )
    return flags.reshape(L)


def accepts_parallel(dfa: DFA, text: str, n_chunks: int = 8,
                     sfa: SFA | None = None) -> bool:
    """End-to-end helper: does ``text`` match? (pads to chunk multiple)."""
    symbols = jnp.asarray(dfa.encode(text))
    L = symbols.shape[0]
    if L % n_chunks:
        # The unpadded tail is processed sequentially — cheap (< chunk_len).
        head_len = L - (L % n_chunks)
        head = symbols[:head_len]
        tail = symbols[head_len:]
    else:
        head, tail = symbols, symbols[:0]
    if head.shape[0]:
        if sfa is not None:
            mapping = match_parallel_sfa(
                jnp.asarray(sfa.delta), jnp.asarray(sfa.mappings), head, n_chunks
            )
        else:
            mapping = match_parallel_enumeration(jnp.asarray(dfa.table), head, n_chunks)
        state = int(mapping[dfa.start])
    else:
        state = dfa.start
    state = dfa.run(np.asarray(tail), state=state)
    return bool(dfa.accepting[state])


def distributed_match_fn(mesh: Mesh, table_shape: tuple, axis_name: str = "data"):
    """Build a pjit-able distributed matcher for a given mesh.

    Input ``symbols`` (L,) is sharded over ``axis_name``; each device runs
    enumeration matching on its shard (vectorized over sub-chunks for VPU
    utilization), then per-device functions combine via ``shard_reduce``
    (one all_gather of n-int vectors — the paper's result reduction).
    Returns ``mapping`` (n,) replicated.
    """

    def local_match(table, sym_shard, sub_chunks: int):
        L = sym_shard.shape[0]
        chunks = sym_shard.reshape(sub_chunks, L // sub_chunks)
        mappings = jax.vmap(lambda c: chunk_mapping_enumeration(table, c))(chunks)
        local = M.reduce(FN, mappings, axis=0)
        return M.shard_reduce(FN, local[None], axis_name)[0]

    @functools.partial(jax.jit, static_argnames=("sub_chunks",))
    def matcher(table, symbols, sub_chunks: int = 8):
        fn = compat_shard_map(
            functools.partial(local_match, sub_chunks=sub_chunks),
            mesh=mesh,
            in_specs=(P(), P(axis_name)),
            out_specs=P(),
            check_vma=False,
        )
        return fn(table, symbols)

    return matcher


def throughput_matcher(mesh: Mesh, start: int = 0, axis_name: str = "data"):
    """Batched many-strings matcher: (B, L) inputs sharded over ``axis_name``
    on the batch axis, each row matched independently (the network-security
    style throughput workload from the related work, for completeness)."""

    def local(table, accepting, batch):
        def per_row(row):
            mapping = chunk_mapping_enumeration(table, row)
            return accepting[mapping[start]]

        return jax.vmap(per_row)(batch)

    @jax.jit
    def matcher(table, accepting, batch):
        fn = compat_shard_map(
            local,
            mesh=mesh,
            in_specs=(P(), P(), P(axis_name)),
            out_specs=P(axis_name),
            check_vma=False,
        )
        return fn(table, accepting, batch)

    return matcher


# --------------------------------------------------------------------------
# Sequential composition (NumPy; ragged tails, reference backend, streams)
# --------------------------------------------------------------------------


def compose_sequential(tables: np.ndarray, mapping: np.ndarray,
                       syms: np.ndarray) -> np.ndarray:
    """Extend per-pattern transition functions by ``syms``, one symbol at a
    time: ``m'[p, q] = tables[p, m[p, q], sym]``. (Pg, n, k), (Pg, n), (L,)
    -> (Pg, n). The exact NumPy twin of the chunk matchers — every ragged
    tail, stream remainder, and reference-backend path funnels through here
    so the bit-identity contract has a single sequential implementation.
    """
    rows = np.arange(tables.shape[0])[:, None]
    m = mapping
    for sym in np.asarray(syms):
        m = tables[rows, m, int(sym)]
    return m


def advance_states_sequential(tables: np.ndarray, states: np.ndarray,
                              tail: np.ndarray) -> np.ndarray:
    """Advance per-(pattern, doc) *states* through per-doc tail symbols:
    ``s'[p, d] = tables[p, s[p, d], tail[d, t]]`` folded over ``t``.
    (Pg, n, k), (Pg, D), (D, T) -> (Pg, D). The state-vector twin of
    :func:`compose_sequential` for the speculative path, whose head
    executor produces final *states* rather than whole mappings — ragged
    tails advance here, one vectorized gather per tail symbol.
    """
    rows = np.arange(tables.shape[0])[:, None]
    tail = np.asarray(tail)
    s = np.asarray(states, dtype=np.int64)
    for t in range(tail.shape[1]):
        s = tables[rows, s, tail[None, :, t]]
    return s.astype(np.int32)


# --------------------------------------------------------------------------
# Banked matchers, enumeration mode (ex core/multipattern.py)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def match_bank_parallel(tables: jnp.ndarray, symbols: jnp.ndarray,
                        n_chunks: int = 8) -> jnp.ndarray:
    """Final mappings of one input under every pattern: (P, n, k), (L,) -> (P, n)."""
    L = symbols.shape[0]
    assert L % n_chunks == 0, "pad input to a multiple of n_chunks"
    chunks = symbols.reshape(n_chunks, L // n_chunks)
    mappings = jax.vmap(
        lambda t: jax.vmap(lambda c: chunk_mapping_enumeration(t, c))(chunks)
    )(tables)                                  # (P, n_chunks, n)
    return M.reduce(FN, mappings, axis=1)      # (P, n)


def _bank_doc_mappings(tables, corpus, n_chunks):
    """Enumeration final mapping of every (pattern, doc): -> (P, D, n).

    All (pattern, doc, chunk) cells compute in one doubly-vmapped batch over
    the flattened ``(D * n_chunks)`` chunk axis; composition is one monoid
    reduce over the chunk axis, batched over patterns x docs.
    """
    D, L = corpus.shape
    chunks = corpus.reshape(D * n_chunks, L // n_chunks)
    fns = jax.vmap(
        lambda t: jax.vmap(lambda c: chunk_mapping_enumeration(t, c))(chunks)
    )(tables)                                  # (P, D * n_chunks, n)
    Pn, _, n = fns.shape
    return M.reduce(FN, fns.reshape(Pn, D, n_chunks, n), axis=2)


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def bank_doc_mappings(tables: jnp.ndarray, corpus: jnp.ndarray,
                      n_chunks: int = 8) -> jnp.ndarray:
    return _bank_doc_mappings(tables, corpus, n_chunks)


def _hits_of_mappings(maps, accepting, starts):
    """(P, D, n) final mappings -> (P, D) accept flags."""

    def per_pattern(m, acc, start):
        return acc[m[:, start]]

    return jax.vmap(per_pattern)(maps, accepting, starts)


def _bank_hits(tables, accepting, starts, corpus, n_chunks):
    maps = _bank_doc_mappings(tables, corpus, n_chunks)
    return _hits_of_mappings(maps, accepting, starts)


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def bank_hits(tables: jnp.ndarray, accepting: jnp.ndarray, starts: jnp.ndarray,
              corpus: jnp.ndarray, n_chunks: int = 8) -> jnp.ndarray:
    """Hit matrix of a corpus against the bank: (D, L) int32 -> (P, D) bool."""
    return _bank_hits(tables, accepting, starts, corpus, n_chunks)


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def census_bank(tables: jnp.ndarray, accepting: jnp.ndarray, starts: jnp.ndarray,
                corpus: jnp.ndarray, n_chunks: int = 8) -> jnp.ndarray:
    """Per-pattern hit counts over a corpus: (P,) int32 — the ScanProsite
    census (how many database sequences carry each signature)."""
    hits = _bank_hits(tables, accepting, starts, corpus, n_chunks)
    return jnp.sum(hits, axis=1, dtype=jnp.int32)


# --------------------------------------------------------------------------
# Banked matchers, stacked-SFA mode (the paper's single-lookup inner loop,
# lifted to the bank axis — ROADMAP "SFA-mode bank matching")
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def match_bank_parallel_sfa(deltas: jnp.ndarray, sfa_maps: jnp.ndarray,
                            symbols: jnp.ndarray, n_chunks: int = 8
                            ) -> jnp.ndarray:
    """SFA-mode bank matching: (P, S, k) deltas + (P, S, n) mapping stacks.

    Each chunk runs every pattern's SFA like a DFA (one lookup per char) from
    SFA state 0 (identity), then the chunk's transition function is read off
    the final SFA state — the paper's method, vmapped over the pattern axis.
    Returns (P, n), bit-identical to :func:`match_bank_parallel` on the same
    padded layout.
    """
    L = symbols.shape[0]
    assert L % n_chunks == 0, "pad input to a multiple of n_chunks"
    chunks = symbols.reshape(n_chunks, L // n_chunks)
    finals = jax.vmap(
        lambda d: jax.vmap(lambda c: chunk_state_sfa(d, c))(chunks)
    )(deltas)                                    # (P, n_chunks)
    mappings = jax.vmap(lambda m, f: m[f])(sfa_maps, finals)  # (P, n_chunks, n)
    return M.reduce(FN, mappings, axis=1)


def _bank_doc_mappings_sfa(deltas, sfa_maps, corpus, n_chunks):
    D, L = corpus.shape
    chunks = corpus.reshape(D * n_chunks, L // n_chunks)
    finals = jax.vmap(
        lambda d: jax.vmap(lambda c: chunk_state_sfa(d, c))(chunks)
    )(deltas)                                    # (P, D * n_chunks)
    mapped = jax.vmap(lambda m, f: m[f])(sfa_maps, finals)  # (P, D*n_chunks, n)
    Pn, _, n = mapped.shape
    return M.reduce(FN, mapped.reshape(Pn, D, n_chunks, n), axis=2)


@functools.partial(jax.jit, static_argnames=("n_chunks",))
def bank_doc_mappings_sfa(deltas: jnp.ndarray, sfa_maps: jnp.ndarray,
                          corpus: jnp.ndarray, n_chunks: int = 8) -> jnp.ndarray:
    """SFA-mode final mapping of every (pattern, doc): -> (P, D, n)."""
    return _bank_doc_mappings_sfa(deltas, sfa_maps, corpus, n_chunks)


# --------------------------------------------------------------------------
# Pallas inner-loop variants (match_bank_chunks_pallas wired in — ROADMAP)
# --------------------------------------------------------------------------


def bank_doc_mappings_pallas(tables: jnp.ndarray, corpus: jnp.ndarray,
                             n_chunks: int = 8, *, block_b: int = 8,
                             interpret: bool | None = None) -> jnp.ndarray:
    """Enumeration doc mappings with the Pallas multi-automaton kernel as the
    chunk-function inner loop: (P, n, k), (D, L) -> (P, D, n). The kernel's
    grid iterates (pattern, chunk-block) with the VMEM-resident transposed
    table swapped once per pattern."""
    from ..kernels import ops

    D, L = corpus.shape
    chunks = corpus.reshape(D * n_chunks, L // n_chunks)
    fns = ops.match_bank_chunks(tables, chunks, block_b=block_b,
                                interpret=interpret)   # (P, D*n_chunks, n)
    Pn, _, n = fns.shape
    return M.reduce(FN, fns.reshape(Pn, D, n_chunks, n), axis=2)


def bank_doc_mappings_sfa_pallas(deltas: jnp.ndarray, sfa_maps: jnp.ndarray,
                                 corpus: jnp.ndarray, n_chunks: int = 8, *,
                                 block_b: int = 8,
                                 interpret: bool | None = None) -> jnp.ndarray:
    """SFA-mode doc mappings through the same Pallas kernel: the SFA delta
    *is* a DFA table, so the kernel computes each chunk's transition function
    over SFA states; row 0 (the identity start) is the chunk's final SFA
    state, and the mapping stack turns it into the DFA-state function."""
    from ..kernels import ops

    D, L = corpus.shape
    chunks = corpus.reshape(D * n_chunks, L // n_chunks)
    fns = ops.match_bank_chunks(deltas, chunks, block_b=block_b,
                                interpret=interpret)   # (P, D*n_chunks, S)
    finals = fns[..., 0]                               # (P, D*n_chunks)
    mapped = jax.vmap(lambda m, f: m[f])(sfa_maps, finals)
    Pn, _, n = mapped.shape
    return M.reduce(FN, mapped.reshape(Pn, D, n_chunks, n), axis=2)


# --------------------------------------------------------------------------
# Distributed builders (shard_map over the mesh)
# --------------------------------------------------------------------------


def distributed_bank_matcher(mesh: Mesh, pattern_axis: str = "model",
                             data_axis: str = "data"):
    """Build a jitted matcher distributing patterns x chunks over ``mesh``.

    ``tables`` (P, n, k) shards over ``pattern_axis``; ``symbols`` (L,)
    shards over ``data_axis``. Each device computes the chunk functions of
    its pattern shard on its data shard, then a single fused monoid
    reduction — ``shard_reduce`` batched over the local pattern axis, i.e.
    ONE all_gather of (P_local, n) int vectors along ``data_axis`` — yields
    the whole-input mapping per pattern. Output: (P, n), P-sharded over
    ``pattern_axis`` and replicated along ``data_axis``.
    """

    def local_match(tables, sym_shard, sub_chunks: int):
        Lc = sym_shard.shape[0]
        chunks = sym_shard.reshape(sub_chunks, Lc // sub_chunks)
        mappings = jax.vmap(
            lambda t: jax.vmap(lambda c: chunk_mapping_enumeration(t, c))(chunks)
        )(tables)                                    # (P_local, sub_chunks, n)
        local = M.reduce(FN, mappings, axis=1)       # (P_local, n)
        return M.shard_reduce(FN, local, data_axis)  # fused over data axis

    @functools.partial(jax.jit, static_argnames=("sub_chunks",))
    def matcher(tables, symbols, sub_chunks: int = 8):
        fn = compat_shard_map(
            functools.partial(local_match, sub_chunks=sub_chunks),
            mesh=mesh,
            in_specs=(P(pattern_axis), P(data_axis)),
            out_specs=P(pattern_axis),
            check_vma=False,
        )
        return fn(tables, symbols)

    return matcher


def distributed_census_fn(mesh: Mesh, pattern_axis: str = "model",
                          data_axis: str = "data", n_chunks: int = 8):
    """Distributed census: corpus rows shard over ``data_axis``, patterns
    over ``pattern_axis``; per-device partial counts combine with one psum."""

    def local(tables, accepting, starts, corpus_shard):
        hits = _bank_hits(tables, accepting, starts, corpus_shard, n_chunks)
        counts = jnp.sum(hits, axis=1, dtype=jnp.int32)
        return jax.lax.psum(counts, data_axis)

    @jax.jit
    def census(tables, accepting, starts, corpus):
        fn = compat_shard_map(
            local,
            mesh=mesh,
            in_specs=(P(pattern_axis), P(pattern_axis), P(pattern_axis),
                      P(data_axis)),
            out_specs=P(pattern_axis),
            check_vma=False,
        )
        return fn(tables, accepting, starts, corpus)

    return census


def distributed_doc_mappings_fn(mesh: Mesh, data_axis: str = "data",
                                n_chunks: int = 8, sfa_mode: bool = False):
    """Scanner's shard_map path: docs shard over ``data_axis`` (patterns
    replicated — bank stacks are small next to corpora), each device computes
    its doc shard's final mappings locally, and the doc axis is gathered back.
    Returns a jitted ``fn(arrays..., corpus) -> (P, D, n)`` replicated.
    """

    if sfa_mode:
        def local(deltas, sfa_maps, corpus_shard):
            maps = _bank_doc_mappings_sfa(deltas, sfa_maps, corpus_shard, n_chunks)
            return jax.lax.all_gather(maps, data_axis, axis=1, tiled=True)

        @jax.jit
        def fn(deltas, sfa_maps, corpus):
            return compat_shard_map(
                local, mesh=mesh,
                in_specs=(P(), P(), P(data_axis)),
                out_specs=P(),
                check_vma=False,
            )(deltas, sfa_maps, corpus)

        return fn

    def local(tables, corpus_shard):
        maps = _bank_doc_mappings(tables, corpus_shard, n_chunks)
        return jax.lax.all_gather(maps, data_axis, axis=1, tiled=True)

    @jax.jit
    def fn(tables, corpus):
        return compat_shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(data_axis)),
            out_specs=P(),
            check_vma=False,
        )(tables, corpus)

    return fn


# --------------------------------------------------------------------------
# Prefix-scan census: sliding windows without recomputing shared blocks
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("m",))
def sliding_window_mappings(block_maps: jnp.ndarray, m: int) -> jnp.ndarray:
    """All length-``m`` sliding-window compositions of consecutive block
    transition functions — ``(Pg, B, n) -> (Pg, B - m + 1, n)`` where output
    ``w`` is ``block w ∘then∘ ... ∘then∘ block w+m-1``.

    ``Scanner.census`` on materialized windows recomputes every block's
    function ``m`` times; this is the Gil–Werman trick on the function
    monoid instead: tile the block axis into groups of ``m``, run one
    *suffix* :func:`repro.core.monoid.scan` and one *prefix* scan per tile
    (each block's function enters exactly two log-depth scans), and stitch
    window ``w = t·m + j`` as ``suffix[t, j] ∘then∘ prefix[t+1, j-1]``
    (identity when ``j = 0``). Function composition is exactly associative
    on int32 gathers, so results are bit-identical to the naive per-window
    composition no matter how the tiling falls.
    """
    Pg, B, n = block_maps.shape
    W = B - m + 1
    assert W >= 1, "need at least m blocks"
    if m == 1:
        return block_maps
    T = -(-B // m)  # tiles of m blocks, last one padded with identities
    ident = jnp.broadcast_to(jnp.arange(n, dtype=block_maps.dtype), (Pg, 1, n))
    pad = jnp.broadcast_to(ident, (Pg, T * m - B, n))
    x = jnp.concatenate([block_maps, pad], axis=1).reshape(Pg, T, m, n)
    # A reverse scan folds the right end in first, so the suffix combine
    # "block j then j+1 then ..." needs the argument-flipped monoid.
    flipped = M.Monoid(lambda a, b: FN.combine(b, a), FN.identity, FN.name)
    suffix = M.scan(flipped, x, axis=2, reverse=True)  # [t,j] = tm+j..tm+m-1
    prefix = M.scan(FN, x, axis=2)                     # [t,j] = tm..tm+j
    # prefix, shifted one block right within each tile (j=0 -> identity) and
    # one whole tile down: flat index w + m lands on tile t+1, offset j.
    shifted = jnp.concatenate(
        [jnp.broadcast_to(ident[:, None], (Pg, T, 1, n)), prefix[:, :, :-1]],
        axis=2,
    )
    extra = jnp.broadcast_to(ident[:, None], (Pg, 1, m, n))
    shifted = jnp.concatenate([shifted, extra], axis=1)    # (Pg, T+1, m, n)
    s_flat = suffix.reshape(Pg, T * m, n)[:, :W]
    q_flat = shifted.reshape(Pg, (T + 1) * m, n)[:, m:m + W]
    return FN.combine(s_flat, q_flat)
