"""Streaming input: corpora far larger than memory through the bank.

The paper's matching algorithm needs only each chunk's *transition function*
and an associative combine — nothing about it requires the whole input to be
resident. :class:`StreamSession` exploits that: callers feed arbitrary-sized
pieces (strings or encoded int arrays) of one logically-concatenated input;
the session buffers them into fixed-shape ``(n_chunks, block_len)`` chunk
blocks, pushes each block through the plan's backend inner loop (the Pallas
``match_bank_chunks_pallas`` kernel when ``backend="pallas"`` — the ROADMAP
"wire the kernel in" item), and folds the block's transition function into a
running function-monoid prefix. Memory high-water mark is one block plus the
``(P, n)`` prefix, independent of corpus length; fixed shapes mean every
block reuses one compiled program (and one VMEM-resident table block).

``StreamSession.finish()`` composes any ragged tail sequentially (exact,
< one block of work) and returns a :class:`StreamResult` whose mapping is
bit-identical to ``Scanner.mapping`` of the concatenated input.

This is also the corpus-job path for long documents:
:func:`repro.scanservice.scan_shard` routes any document at or above the
job's ``stream_threshold`` through a stream session, so shard memory stays
bounded by one block regardless of document length.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Any

import jax.numpy as jnp
import numpy as np

from ..speculative import SpeculationStats, speculative_bank_finals
from . import executors as X

if TYPE_CHECKING:  # pragma: no cover
    from .scanner import PatternGroup, Scanner


@dataclass(frozen=True)
class StreamResult:
    """Outcome of a streamed scan over one concatenated input.

    ``mapping`` is the input's whole transition function — except when any
    pattern group ran speculatively, where it is ``None``: the speculative
    executor tracks exact *states*, not whole functions (that is the
    saving), so only ``final_states``/``accepted`` are available. They are
    bit-identical to the enumeration stream's; the corpus-job streaming
    path (:func:`repro.scanservice.scan_shard`) consumes ``accepted`` only.
    ``speculation`` carries the stream's aggregated
    :class:`~repro.speculative.SpeculationStats` (None without speculation).
    """

    mapping: np.ndarray | None  # (P, n_max) — None under speculation
    final_states: np.ndarray  # (P,) — exact final state per pattern
    accepted: np.ndarray      # (P,) bool
    n_symbols: int
    ids: tuple
    single: bool = False
    speculation: Any = None

    @property
    def accepts(self):
        """bool for a single-pattern scanner, (P,) bool for a bank."""
        return bool(self.accepted[0]) if self.single else self.accepted


class StreamSession:
    """Incremental (push-style) scan; create via ``Scanner.open_stream()``."""

    def __init__(self, scanner: "Scanner"):
        self.scanner = scanner
        pol = scanner.plan.chunking
        self.n_chunks = pol.n_chunks
        self.block_len = pol.block_len
        self.super_len = self.n_chunks * self.block_len
        self._buf = np.zeros(0, dtype=np.int32)
        self._n_symbols = 0
        self._finished = False
        # Running prefix per group: the function-monoid fold of everything
        # consumed so far, carried across block calls. Speculative groups
        # carry exact running *states* instead of whole functions — the
        # executor validates each block's chunks against them directly, so
        # the stream never pays the n-wide function composition.
        self._prefix = [
            np.broadcast_to(
                np.arange(g.n, dtype=np.int32), (len(g.indices), g.n)
            ).copy()
            for g in scanner.groups
        ]
        self._state = [
            g.bank.starts.astype(np.int32).copy()
            if g.mode == "speculative" else None
            for g in scanner.groups
        ]
        self._spec_prof = [None] * len(scanner.groups)
        self._spec_stats: SpeculationStats | None = None
        self._has_spec = any(
            g.mode == "speculative" for g in scanner.groups
        )

    # -- feeding ------------------------------------------------------------

    def feed(self, piece) -> None:
        """Append one piece of the input (str or 1-D int array)."""
        if self._finished:
            raise RuntimeError("stream already finished")
        enc = (self.scanner.encode(piece) if isinstance(piece, str)
               else np.asarray(piece, dtype=np.int32))
        if enc.ndim != 1:
            raise ValueError("stream pieces must be 1-D (one input's symbols)")
        self._n_symbols += len(enc)
        self._buf = np.concatenate([self._buf, enc]) if len(self._buf) else enc
        while len(self._buf) >= self.super_len:
            block = self._buf[: self.super_len]
            self._buf = self._buf[self.super_len:]
            self._advance(block)

    def _advance(self, block: np.ndarray) -> None:
        """Fold one full (n_chunks * block_len) block into the prefix."""
        for gi, g in enumerate(self.scanner.groups):
            if g.mode == "speculative":
                self._advance_speculative(gi, g, block)
                continue
            bm = self._block_mapping(g, block)              # (Pg, n)
            # combine(prefix, block): apply prefix first, then the block.
            self._prefix[gi] = np.take_along_axis(bm, self._prefix[gi], axis=1)

    def _advance_speculative(self, gi: int, g: "PatternGroup",
                             block: np.ndarray) -> None:
        """Advance a speculative group's exact running states through one
        block: the block is one D=1 "document" whose per-pattern start
        states are the stream's current states. Unresolved lanes fall back
        to the block's enumeration mapping applied at the entry state —
        exact either way. The hot-state profile is resolved once per
        session, from the first block (it is advisory; staleness only
        costs repairs)."""
        sc = self.scanner
        pol = sc.plan.speculation
        prof = self._spec_prof[gi]
        if prof is None:
            prof = sc._speculation_profile(g, block[None, :])
            self._spec_prof[gi] = prof
        out = speculative_bank_finals(
            g.tables, jnp.asarray(prof), jnp.asarray(self._state[gi]),
            jnp.asarray(block[None, :]), n_chunks=self.n_chunks,
            max_rounds=pol.max_repair_rounds,
        )
        finals, resolved, hit_n, repaired, rounds = (
            np.asarray(x) for x in out
        )
        st = SpeculationStats(
            total_chunks=len(g.indices) * self.n_chunks,
            hit_chunks=int(hit_n),
            repaired_chunks=int(repaired),
            repair_rounds=int(rounds),
            fallback_lanes=int(np.sum(~resolved)),
        )
        states = finals[:, 0].astype(np.int32)
        if not resolved.all():
            bm = np.asarray(X.match_bank_parallel(
                g.tables, jnp.asarray(block), self.n_chunks
            ))
            rows = np.arange(len(g.indices))
            exact = bm[rows, self._state[gi]]
            bad = ~resolved[:, 0]
            states[bad] = exact[bad]
        self._state[gi] = states
        self._spec_stats = st if self._spec_stats is None \
            else self._spec_stats.merged(st)

    def _block_mapping(self, g: "PatternGroup", block: np.ndarray) -> np.ndarray:
        backend = self.scanner.plan.backend
        if backend == "reference":
            from .scanner import _reference_doc_mappings

            return _reference_doc_mappings(g.bank.tables, block[None, :])[:, 0]
        if backend == "pallas":
            corpus = jnp.asarray(block[None, :])
            if g.mode == "sfa":
                out = X.bank_doc_mappings_sfa_pallas(
                    g.deltas, g.sfa_maps, corpus, self.n_chunks
                )
            else:
                out = X.bank_doc_mappings_pallas(g.tables, corpus, self.n_chunks)
            return np.array(out[:, 0, :])
        # xla (shard_map distribution still computes blocks locally: a block
        # is one device's worth of work by construction)
        syms = jnp.asarray(block)
        if g.mode == "sfa":
            out = X.match_bank_parallel_sfa(
                g.deltas, g.sfa_maps, syms, self.n_chunks
            )
        else:
            out = X.match_bank_parallel(g.tables, syms, self.n_chunks)
        return np.array(out)

    # -- finishing ----------------------------------------------------------

    def finish(self) -> StreamResult:
        """Compose the ragged tail, read off accepts, and close the stream."""
        if self._finished:
            raise RuntimeError("stream already finished")
        self._finished = True
        sc = self.scanner
        if len(self._buf):
            for gi, g in enumerate(sc.groups):
                if g.mode == "speculative":
                    self._state[gi] = X.advance_states_sequential(
                        g.bank.tables, self._state[gi][:, None],
                        self._buf[None, :],
                    )[:, 0]
                else:
                    self._prefix[gi] = X.compose_sequential(
                        g.bank.tables, self._prefix[gi], self._buf
                    )
            self._buf = np.zeros(0, dtype=np.int32)

        mapping = None if self._has_spec else np.broadcast_to(
            np.arange(sc.n_max, dtype=np.int32), (sc.n_patterns, sc.n_max)
        ).copy()
        final_states = np.zeros(sc.n_patterns, dtype=np.int32)
        accepted = np.zeros(sc.n_patterns, dtype=bool)
        for gi, g in enumerate(sc.groups):
            rows = np.arange(len(g.indices))
            if g.mode == "speculative":
                finals = self._state[gi]
            else:
                pref = self._prefix[gi]                      # (Pg, n_g)
                if mapping is not None:
                    mapping[g.indices, : g.n] = pref
                finals = pref[rows, g.bank.starts]
            final_states[g.indices] = finals
            accepted[g.indices] = g.bank.accepting[rows, finals]
        sc.last_speculation = self._spec_stats or sc.last_speculation
        return StreamResult(
            mapping=mapping,
            final_states=final_states,
            accepted=accepted,
            n_symbols=self._n_symbols,
            ids=sc.ids,
            single=sc.single,
            speculation=self._spec_stats,
        )
