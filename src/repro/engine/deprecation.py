"""Warn-once bookkeeping for the legacy entry points shimmed onto the engine.

Every pre-engine public function (``match_parallel_enumeration``,
``match_bank_parallel``, ``distributed_bank_matcher``, ...) still works, but
delegates to :mod:`repro.engine.executors` and announces itself exactly once
per process so long-running scans aren't spammed.
"""

from __future__ import annotations

import warnings

_SEEN: set = set()


def warn_once(name: str, replacement: str) -> None:
    """Emit a single ``DeprecationWarning`` for ``name`` per process."""
    if name in _SEEN:
        return
    _SEEN.add(name)
    warnings.warn(
        f"{name} is deprecated; use {replacement} (see repro.engine.Scanner)",
        DeprecationWarning,
        stacklevel=3,
    )


def reset() -> None:
    """Forget which names already warned (test helper)."""
    _SEEN.clear()
