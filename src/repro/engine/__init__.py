"""The scan engine: one entry point for every matching configuration.

    from repro.engine import Scanner, ScanPlan

    scanner = Scanner.compile(["PS00016", "PS00017"], ScanPlan(mode="auto"))
    hits = scanner.scan(proteins)          # (P, D) hit matrix
    counts = scanner.census(proteins)      # ScanProsite census
    result = scanner.stream(chunk_blocks)  # larger-than-memory inputs

``Scanner.compile`` resolves mode (SFA vs enumeration, per pattern, under a
state budget), backend (reference / xla / pallas), distribution (local /
shard_map), and chunking from a :class:`ScanPlan`; every configuration
produces bit-identical results. The pre-engine free functions in
``repro.core.matching`` / ``repro.core.multipattern`` are deprecated shims
over :mod:`repro.engine.executors`.
"""

from .plan import BACKENDS, DISTRIBUTIONS, MODES, ChunkPolicy, ScanPlan
from .scanner import PatternGroup, Scanner, ScanResult
from .streaming import StreamResult, StreamSession

__all__ = [
    "BACKENDS",
    "DISTRIBUTIONS",
    "MODES",
    "ChunkPolicy",
    "PatternGroup",
    "ScanPlan",
    "ScanResult",
    "Scanner",
    "StreamResult",
    "StreamSession",
]
