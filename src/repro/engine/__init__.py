"""The scan engine: one entry point for every matching configuration.

    from repro.engine import Scanner, ScanPlan

    scanner = Scanner.compile(["PS00016", "PS00017"], ScanPlan(mode="auto"))
    hits = scanner.scan(proteins)          # (P, D) hit matrix
    counts = scanner.census(proteins)      # ScanProsite census
    result = scanner.stream(chunk_blocks)  # larger-than-memory inputs

``Scanner.compile`` resolves mode (SFA vs enumeration, per pattern, under a
state budget), backend (reference / xla / pallas), distribution (local /
shard_map), chunking, and construction (batched bank rounds + the
content-addressed SFA cache — recompiling the same patterns performs zero
construction rounds) from a :class:`ScanPlan`; every configuration produces
bit-identical results. :mod:`repro.engine.executors` is the single home of
the parallel entry points (the pre-engine shims in ``repro.core`` were
removed after the PR-2 deprecation window).
"""

from ..speculative import SpeculationStats
from .plan import (
    BACKENDS,
    CONSTRUCTION_ENGINES,
    CONSTRUCTION_METHODS,
    DISTRIBUTIONS,
    MODES,
    SPECULATION_SOURCES,
    ChunkPolicy,
    ConstructionPolicy,
    ScanPlan,
    SpeculationPolicy,
)
from .scanner import ConstructionReport, PatternGroup, Scanner, ScanResult
from .streaming import StreamResult, StreamSession

__all__ = [
    "BACKENDS",
    "CONSTRUCTION_ENGINES",
    "CONSTRUCTION_METHODS",
    "DISTRIBUTIONS",
    "MODES",
    "SPECULATION_SOURCES",
    "ChunkPolicy",
    "ConstructionPolicy",
    "ConstructionReport",
    "PatternGroup",
    "ScanPlan",
    "ScanResult",
    "Scanner",
    "SpeculationPolicy",
    "SpeculationStats",
    "StreamResult",
    "StreamSession",
]
