"""Protein-sequence corpus with SFA-based labeling — the paper's technique
as a first-class data-pipeline stage.

Sequences are synthetic amino-acid strings with PROSITE motifs planted at a
controlled rate. The *labeling/filter* stage runs the constructed SFA over
every sequence (chunk-parallel matching, ``core.matching``): exactly the
ScanProsite workload the paper evaluates, feeding an LM training pipeline
(e.g. a protein language model that trains on motif-bearing sequences only).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.dfa import DFA
from repro.core.prosite import PROSITE_SAMPLES, compile_prosite
from repro.core.regex import AMINO_ACIDS
from repro.core.sfa import SFA, construct_sfa

# token ids: 0 = pad/bos, 1..20 = amino acids
VOCAB = len(AMINO_ACIDS) + 1


@dataclass
class ProteinCorpus:
    pattern_id: str = "PS00016"          # RGD cell-attachment (tiny DFA)
    plant_rate: float = 0.5
    dfa: DFA = field(default=None, repr=False)
    sfa: SFA = field(default=None, repr=False)

    def __post_init__(self):
        if self.dfa is None:
            self.dfa = compile_prosite(PROSITE_SAMPLES[self.pattern_id])
        if self.sfa is None:
            self.sfa = construct_sfa(self.dfa, engine="vectorized", max_states=200_000)

    def sample(self, rng: np.random.Generator, length: int) -> tuple:
        seq = rng.integers(0, len(AMINO_ACIDS), size=length).astype(np.int32)
        planted = rng.random() < self.plant_rate
        if planted:
            motif = self._motif_instance(rng)
            pos = rng.integers(0, max(length - len(motif), 1))
            seq[pos : pos + len(motif)] = motif
        # label via the SFA (single table walk; chunk-parallel in benches)
        state = self.sfa.run(seq)
        label = bool(self.sfa.accepting_states()[state])
        return seq, label

    def _motif_instance(self, rng) -> np.ndarray:
        # concrete instance of the pattern (for the bundled simple patterns
        # we plant the literal backbone, e.g. R-G-D)
        from repro.core.prosite import translate

        out = []
        tr = translate(PROSITE_SAMPLES[self.pattern_id])
        i = 0
        regex = tr.regex
        sym = {c: i for i, c in enumerate(AMINO_ACIDS)}
        while i < len(regex):
            c = regex[i]
            if c == "[":
                j = regex.index("]", i)
                members = [m for m in regex[i + 1 : j] if m in sym and regex[i+1] != "^"]
                out.append(sym[members[0]] if members else 0)
                i = j + 1
            elif c == "." :
                out.append(int(rng.integers(0, len(AMINO_ACIDS))))
                i += 1
            elif c == "{":
                j = regex.index("}", i)
                n = int(regex[i + 1 : j].split(",")[0])
                for _ in range(n - 1):
                    out.append(out[-1])
                i = j + 1
            elif c in sym:
                out.append(sym[c])
                i += 1
            else:
                i += 1
        return np.asarray(out, dtype=np.int32)


_CORPUS_CACHE: dict = {}


def protein_batch(cfg, step: int) -> dict:
    """Batch format matches the LM pipeline: tokens/labels shifted, with
    amino-acid ids offset by 1 (0 = bos)."""
    key = ("PS00016",)
    if key not in _CORPUS_CACHE:
        _CORPUS_CACHE[key] = ProteinCorpus()
    corpus = _CORPUS_CACHE[key]
    rows = cfg.global_batch if cfg.rows_local < 0 else cfg.rows_local
    toks = np.zeros((rows, cfg.seq_len + 1), dtype=np.int32)
    match = np.zeros((rows,), dtype=bool)
    from .pipeline import _rng_for

    for r in range(rows):
        rng = _rng_for(cfg.seed, step, cfg.row_start + r)
        seq, label = corpus.sample(rng, cfg.seq_len)
        toks[r, 1:] = (seq + 1) % cfg.vocab_size
        match[r] = label
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:], "motif_label": match}


def protein_batch_stream(cfg, start_step: int = 0):
    step = start_step
    while True:
        yield protein_batch(cfg, step)
        step += 1
