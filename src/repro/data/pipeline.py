"""Deterministic, shardable, checkpointable data pipeline.

Batches are generated stateless-deterministically from ``(seed, step)`` with
a counter-based RNG (numpy Philox), so:
  * any host can produce exactly its shard of any step (shardable, no
    coordination, elastic to host-count changes);
  * the iterator "state" is just the step counter — checkpoints store one
    integer, restarts resume mid-epoch exactly (fault tolerance);
  * a background prefetch thread hides generation latency.

Two sources: pure synthetic LM tokens (zipf-ish unigram mix), and the
protein corpus (see ``data/protein.py``) whose labeling stage runs the
paper's SFA matcher — the technique embedded in the training stack.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass, field

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"          # synthetic | protein
    # sharding: this host produces rows [row_start, row_start + rows_local)
    row_start: int = 0
    rows_local: int = -1               # -1 = all rows
    prefetch: int = 2


def _rng_for(seed: int, step: int, row: int) -> np.random.Generator:
    return np.random.default_rng(np.random.Philox(key=seed, counter=[step, row, 0, 0]))


def synthetic_batch(cfg: DataConfig, step: int) -> dict:
    rows = cfg.global_batch if cfg.rows_local < 0 else cfg.rows_local
    toks = np.empty((rows, cfg.seq_len + 1), dtype=np.int32)
    for r in range(rows):
        rng = _rng_for(cfg.seed, step, cfg.row_start + r)
        # zipf-flavoured unigram stream with short repeated motifs so the
        # tiny-LM examples have learnable structure
        base = rng.zipf(1.3, size=cfg.seq_len + 1) % cfg.vocab_size
        motif = rng.integers(0, cfg.vocab_size, size=8)
        pos = rng.integers(0, cfg.seq_len - 8, size=max(cfg.seq_len // 64, 1))
        for p in pos:
            base[p : p + 8] = motif
        toks[r] = base.astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass
class DataIterator:
    cfg: DataConfig
    step: int = 0
    _q: queue.Queue = field(default_factory=lambda: queue.Queue(maxsize=4), repr=False)
    _thread: threading.Thread | None = field(default=None, repr=False)
    _stop: threading.Event = field(default_factory=threading.Event, repr=False)

    def _make(self, step: int) -> dict:
        if self.cfg.source == "synthetic":
            return synthetic_batch(self.cfg, step)
        if self.cfg.source == "protein":
            from .protein import protein_batch

            return protein_batch(self.cfg, step)
        raise ValueError(self.cfg.source)

    # -- prefetching ---------------------------------------------------------
    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self):
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
            self._thread = None
        # drain
        while not self._q.empty():
            self._q.get_nowait()

    def __next__(self) -> dict:
        if self._thread is None:
            batch = self._make(self.step)
            self.step += 1
            return batch
        while True:
            step, batch = self._q.get()
            if step == self.step:        # discard stale prefetches after restore
                self.step += 1
                return batch

    def __iter__(self):
        return self

    # -- checkpointable state ---------------------------------------------------
    def state(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def restore(self, state: dict):
        self.stop()
        self.step = int(state["step"])
        assert state.get("seed", self.cfg.seed) == self.cfg.seed, "seed mismatch"
        return self


def make_pipeline(cfg: DataConfig, *, prefetch: bool = True) -> DataIterator:
    it = DataIterator(cfg)
    if prefetch:
        it.start()
    return it
