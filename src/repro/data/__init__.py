from .pipeline import DataConfig, DataIterator, make_pipeline
from .protein import ProteinCorpus, protein_batch_stream

__all__ = ["DataConfig", "DataIterator", "make_pipeline", "ProteinCorpus",
           "protein_batch_stream"]
