"""Beyond-paper table: the LM dry-run roofline summary (reads the cached
results/dryrun artifacts; never recompiles)."""

from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def run(emit) -> None:
    cells = sorted(RESULTS.glob("*__pod.json"))
    if not cells:
        emit("roofline/missing", 0.0, "run repro.launch.dryrun --all first")
        return
    for f in cells:
        d = json.loads(f.read_text())
        name = f"roofline/{d['arch']}/{d['shape']}"
        if d.get("status") == "skipped":
            emit(name, 0.0, "skipped_full_attention_500k")
            continue
        if d.get("status") != "ok":
            emit(name, 0.0, "FAILED")
            continue
        r = d["roofline"]
        dom_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(name, dom_s * 1e6,
             f"dominant={r['dominant']},useful={r['useful_ratio']:.2f},"
             f"fits16={d['memory']['fits_16gb']}")
