"""Kernel-layer microbenchmarks (paper §III-A hot spots).

Pallas kernels execute in interpret mode on this CPU container (correctness
only; their TPU cost model lives in the roofline analysis), so wall-times
here compare the three *fingerprint implementations* that all realize the
paper's Barrett/CLMUL pipeline: pure-python ints, vectorized NumPy limbs,
and jitted JAX limbs — i.e. the paper's "ILP from PCLMULQDQ" story retold as
data-parallel width.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks import _config
from repro.core.fingerprint import (
    BarrettConstants,
    fingerprint_int,
    fingerprint_states,
    fingerprint_states_np,
)

CONSTS = BarrettConstants.create()


def run(emit) -> None:
    rng = np.random.default_rng(0)
    B, n = _config.scaled(4096, 256), 64
    states = rng.integers(0, 1 << 16, size=(B, n)).astype(np.int32)

    # pure-python reference (scaled down 64x)
    sub = states[: max(B // 64, 1)]
    packed = (sub.astype(np.uint32)[:, 0::2] & 0xFFFF) | (
        (sub.astype(np.uint32)[:, 1::2] & 0xFFFF) << 16
    )
    t0 = time.perf_counter()
    for row in packed:
        fingerprint_int(row, CONSTS)
    t_int = (time.perf_counter() - t0) * 64
    emit("kernels/fingerprint_int_python", t_int / B * 1e6, f"per_vector,n={n}")

    t0 = time.perf_counter()
    fingerprint_states_np(states, CONSTS)
    t_np = time.perf_counter() - t0
    emit("kernels/fingerprint_numpy", t_np / B * 1e6,
         f"per_vector,{t_int / t_np:.0f}x_vs_python")

    import jax

    jfp = jax.jit(lambda s: fingerprint_states(s, CONSTS))
    js = jnp.asarray(states)
    jfp(js).block_until_ready()
    t0 = time.perf_counter()
    jfp(js).block_until_ready()
    t_jax = time.perf_counter() - t0
    emit("kernels/fingerprint_jax_jit", t_jax / B * 1e6,
         f"per_vector,{t_int / t_jax:.0f}x_vs_python")

    # Pallas kernels: correctness-checked in interpret mode (see tests/);
    # emit their block geometry for the record.
    emit("kernels/pallas_fingerprint", 0.0, "interpret_mode_validated,block_b=256")
    emit("kernels/pallas_compose", 0.0, "interpret_mode_validated,block_q=256")
    emit("kernels/pallas_match_scan", 0.0, "interpret_mode_validated,table_in_vmem")
