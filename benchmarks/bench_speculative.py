"""Speculative vs enumeration scanning in the blowup regime (beyond-paper).

Enumeration pays ``O(L·n)`` gathers per pattern because a chunk's entry
state is unknown until its predecessor finishes; speculation pays ``O(L·m)``
(m speculated entry states, default 8) plus validation and the occasional
repair. The crossover is therefore governed by the automaton's state count
``n`` — this benchmark measures it on exactly the patterns the speculative
tier exists for:

* synthetic blowup patterns at n ≈ 128 / 256 / 512 — long-literal search
  DFAs (a length-``n-1`` literal compiles to ``n`` states, and on random
  text the boundary-state distribution concentrates near the start state,
  i.e. a *realistic* favourable hot-state profile);
* the worst bundled PROSITE signature, PS00010 (87 states) — below the
  default ``auto_states`` threshold, included to show where the crossover
  actually sits.

Every row times a warm ``Scanner.scan`` under ``mode="speculative"`` vs
``mode="enumeration"`` on the same corpus, checks the hit matrices are
bit-identical, and records the scan's :class:`SpeculationStats`. The
comparison is written to ``BENCH_speculative.json``.
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from benchmarks import _config
from repro.core.dfa import AMINO_ACIDS, compile_dfa
from repro.core.prosite import PROSITE_EXTRA, compile_prosite
from repro.engine import ChunkPolicy, ScanPlan, Scanner

N_CHUNKS = 8
STATE_LADDER = (128, 256, 512)


def _blowup_pattern(n_states: int, seed: int):
    """A search DFA with exactly ``n_states`` states: a random length
    ``n_states - 1`` literal (KMP-style failure transitions keep random
    text hovering near the start state — the hot-state concentration the
    profiler feeds on)."""
    rng = np.random.default_rng(seed)
    literal = "".join(rng.choice(list(AMINO_ACIDS), size=n_states - 1))
    return compile_dfa(literal, AMINO_ACIDS, search=True)


def _time_scan(sc, corpus) -> tuple:
    sc.scan(corpus)  # warmup/compile (also resolves the sampled profile)
    t0 = time.perf_counter()
    result = sc.scan(corpus)
    return time.perf_counter() - t0, result


def run(emit) -> None:
    rng = np.random.default_rng(7)
    corpus_docs = _config.scaled(16, 4)
    doc_len = _config.scaled(4096, 512)
    corpus = rng.integers(0, 20, size=(corpus_docs, doc_len)).astype(np.int32)
    chars = corpus_docs * doc_len

    cases = [(f"SYN_n{n}", _blowup_pattern(n, seed=n)) for n in STATE_LADDER]
    cases.append(("PS00010", compile_prosite(PROSITE_EXTRA["PS00010"])))

    report: dict = {
        "corpus": {"docs": corpus_docs, "doc_len": doc_len},
        "n_chunks": N_CHUNKS,
        "rows": [],
    }
    for name, dfa in cases:
        chunking = ChunkPolicy(n_chunks=N_CHUNKS)
        t_spec, r_spec = _time_scan(
            Scanner.compile({name: dfa},
                            ScanPlan(mode="speculative", chunking=chunking)),
            corpus,
        )
        t_enum, r_enum = _time_scan(
            Scanner.compile({name: dfa},
                            ScanPlan(mode="enumeration", chunking=chunking)),
            corpus,
        )
        exact = bool(np.array_equal(r_spec.hits, r_enum.hits))
        stats = r_spec.speculation
        speedup = t_enum / t_spec if t_spec > 0 else float("inf")
        emit(f"speculative/{name}", t_spec * 1e6,
             f"n={dfa.n_states},enum_us={t_enum * 1e6:.1f},"
             f"speedup={speedup:.2f}x,hit_rate={stats.hit_rate:.3f},"
             f"rounds={stats.repair_rounds},exact={exact},"
             f"Mchar_s={chars / t_spec / 1e6:.1f}")
        report["rows"].append({
            "pattern": name,
            "n_states": dfa.n_states,
            "speculative_s": t_spec,
            "enumeration_s": t_enum,
            "speedup": speedup,
            "mchar_per_s": chars / t_spec / 1e6,
            "exact": exact,
            "speculation": dataclasses.asdict(stats),
        })

    out = Path(__file__).resolve().parents[1] / "BENCH_speculative.json"
    out.write_text(json.dumps(report, indent=2) + "\n")
    emit("speculative/report", 0.0, f"written={out.name}")


if __name__ == "__main__":
    def _emit(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")

    run(_emit)
