"""Observability overhead benchmark: warm scans, metrics on vs off.

The obs subsystem claims to be cheap enough to leave **enabled by default**:
every instrumented site is a counter increment or a ``perf_counter`` span
around work that is orders of magnitude heavier. This bench puts a number
on that claim and *asserts* it: the same warm (fully compiled, cached)
scan is timed with observability enabled and disabled in interleaved
repetitions, and the median overhead must stay under
``MAX_OVERHEAD`` (2% at full size; the smoke bound is looser because a
CI runner's scheduling jitter on millisecond scans exceeds 2% on its own).
The whole timed section runs with a periodic
:class:`~repro.obs.FlightRecorder` ticking in the background — the
overhead budget covers the full production telemetry configuration
(metrics + flight trail), not just bare counters.

Bit-identity is asserted on the way — the disabled path must be a true
no-op, not a different code path.

Writes ``BENCH_obs.json`` (timings, overhead fraction, the disabled-mode
per-increment cost) next to the other BENCH reports.
"""

from __future__ import annotations

import json
import statistics
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks import _config
from repro import obs
from repro.construction import SFACache
from repro.core.prosite import synthetic_protein
from repro.engine import ConstructionPolicy, ScanPlan, Scanner

BANK = ["PS00016", "PS00005", "PS00001", "PS00006", "PS00009", "PS00004"]
SMOKE_BANK = ["PS00016", "PS00005", "PS00001"]

N_DOCS, SMOKE_DOCS = 64, 16
DOC_LEN = 2048
REPS, SMOKE_REPS = 30, 8

#: Overhead budget for the enabled-vs-disabled median: the acceptance bound
#: at full size, a looser bound under --smoke (short scans on shared CI
#: runners jitter more than 2% with obs out of the picture entirely).
MAX_OVERHEAD, SMOKE_MAX_OVERHEAD = 0.02, 0.25

_REPORT_PATH = Path(__file__).resolve().parent.parent / "BENCH_obs.json"


def _median_scan_s(scanner, docs, reps: int) -> tuple:
    """-> (median enabled, median disabled), interleaved so drift (thermal,
    noisy neighbors) hits both modes equally."""
    on, off = [], []
    for _ in range(reps):
        obs.enable()
        t0 = time.perf_counter()
        scanner.scan(docs)
        on.append(time.perf_counter() - t0)
        obs.disable()
        t0 = time.perf_counter()
        scanner.scan(docs)
        off.append(time.perf_counter() - t0)
    obs.enable()
    return statistics.median(on), statistics.median(off)


def _disabled_inc_ns(iters: int = 200_000) -> float:
    """Per-call cost of a disabled counter increment (the no-op claim)."""
    obs.disable()
    try:
        c = obs.counter("benchmarks.obs.noop_probe")
        t0 = time.perf_counter()
        for _ in range(iters):
            c.inc()
        return (time.perf_counter() - t0) / iters * 1e9
    finally:
        obs.enable()


def run(emit) -> None:
    bank = _config.scaled(BANK, SMOKE_BANK)
    n_docs = _config.scaled(N_DOCS, SMOKE_DOCS)
    reps = _config.scaled(REPS, SMOKE_REPS)
    budget = _config.scaled(MAX_OVERHEAD, SMOKE_MAX_OVERHEAD)
    docs = [synthetic_protein(DOC_LEN, seed=s) for s in range(n_docs)]

    was_enabled = obs.enabled()
    try:
        plan = ScanPlan(construction=ConstructionPolicy(
            cache=SFACache(), method="batched"))
        scanner = Scanner.compile(bank, plan)

        # Bit-identity first: obs off must change nothing but bookkeeping.
        obs.enable()
        hits_on = scanner.scan(docs).hits
        obs.disable()
        hits_off = scanner.scan(docs).hits
        obs.enable()
        assert np.array_equal(hits_on, hits_off), \
            "observability changed scan results"

        scanner.scan(docs)   # warm the jit/exec caches out of the timings
        # Time with the flight recorder's periodic thread live: the budget
        # is for the full telemetry configuration a serving process runs.
        with tempfile.TemporaryDirectory() as td:
            with obs.FlightRecorder(Path(td) / "flight.jsonl",
                                    interval_s=0.05, label="bench_obs") as fr:
                fr.start()
                t_on, t_off = _median_scan_s(scanner, docs, reps)
        overhead = t_on / t_off - 1.0
        inc_ns = _disabled_inc_ns()

        emit(f"obs/warm_scan_enabled/P={len(bank)}", t_on * 1e6,
             f"docs={n_docs}")
        emit(f"obs/warm_scan_disabled/P={len(bank)}", t_off * 1e6,
             f"overhead={overhead * 100:.2f}%")
        emit("obs/disabled_counter_inc", inc_ns / 1e3, "per-call ns noop")

        _REPORT_PATH.write_text(json.dumps({
            "suite": "obs_overhead",
            "patterns": len(bank), "docs": n_docs, "doc_len": DOC_LEN,
            "reps": reps,
            "enabled_s": t_on, "disabled_s": t_off,
            "overhead": overhead, "budget": budget,
            "disabled_inc_ns": inc_ns,
            "smoke": _config.SMOKE,
        }, indent=1))

        assert overhead < budget, (
            f"observability overhead {overhead * 100:.2f}% exceeds the "
            f"{budget * 100:.0f}% budget (enabled {t_on * 1e3:.2f} ms vs "
            f"disabled {t_off * 1e3:.2f} ms median of {reps})")
    finally:
        obs.configure(enabled=was_enabled)
