"""Paper Fig. 6: SFA matching throughput and scaling with parallelism.

The paper matches a 10^10-char input across pthreads; here the same chunked
algorithm runs data-parallel under jit, sweeping the chunk count (the
paper's thread count) on a CPU-sized input. Both matching modes are timed:
SFA-table walks (the paper's) and enumeration (related-work baseline that
needs no SFA), plus the sequential python baseline.
"""

from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import matching as mt
from repro.core.dfa import example_fa
from repro.core.prosite import PROSITE_SAMPLES, compile_prosite
from repro.core.sfa import construct_sfa

LENGTH = 2_000_000


def run(emit) -> None:
    dfa = compile_prosite(PROSITE_SAMPLES["PS00016"])
    sfa = construct_sfa(dfa)
    rng = np.random.default_rng(0)
    syms = jnp.asarray(rng.integers(0, dfa.n_symbols, size=LENGTH).astype(np.int32))
    table = jnp.asarray(dfa.table)
    delta = jnp.asarray(sfa.delta)
    mappings = jnp.asarray(sfa.mappings)

    # sequential python baseline (scaled down, extrapolated linearly)
    scale = 50
    sub = np.asarray(syms[: LENGTH // scale])
    t0 = time.perf_counter()
    dfa.run(sub)
    t_seq = (time.perf_counter() - t0) * scale
    emit("fig6/sequential_python_s", t_seq * 1e6, f"len={LENGTH},extrapolated_{scale}x")

    want = dfa.run(np.asarray(syms))
    for n_chunks in [1, 2, 4, 8, 16, 32, 64]:
        fn = lambda: mt.match_parallel_sfa(delta, mappings, syms, n_chunks)
        fn()  # compile
        t0 = time.perf_counter()
        out = fn()
        out.block_until_ready()
        t = time.perf_counter() - t0
        assert int(out[dfa.start]) == want
        emit(f"fig6/sfa_match_chunks{n_chunks}", t * 1e6,
             f"{t_seq / t:.1f}x_vs_seq,throughput={LENGTH / t / 1e6:.1f}Mchar_s")

    for n_chunks in [8, 64]:
        fn = lambda: mt.match_parallel_enumeration(table, syms, n_chunks)
        fn()
        t0 = time.perf_counter()
        out = fn()
        out.block_until_ready()
        t = time.perf_counter() - t0
        assert int(out[dfa.start]) == want
        emit(f"fig6/enumeration_match_chunks{n_chunks}", t * 1e6,
             f"n_states_wide_gathers,throughput={LENGTH / t / 1e6:.1f}Mchar_s")


def run_sfa_size_ladder(emit) -> None:
    """Fig. 6's size dimension: matching cost vs SFA size (table locality)."""
    rng = np.random.default_rng(1)
    syms_small = jnp.asarray(rng.integers(0, 20, size=200_000).astype(np.int32))
    for pid in ["PS00016", "PS00017", "PS00008"]:
        dfa = compile_prosite(PROSITE_SAMPLES[pid])
        sfa = construct_sfa(dfa, max_states=500_000)
        delta = jnp.asarray(sfa.delta)
        mappings = jnp.asarray(sfa.mappings)
        fn = lambda: mt.match_parallel_sfa(delta, mappings, syms_small, 16)
        fn()
        t0 = time.perf_counter()
        fn().block_until_ready()
        t = time.perf_counter() - t0
        table_mb = sfa.delta.nbytes / 1e6
        emit(f"fig6b/{pid}/sfa_match_s", t * 1e6,
             f"sfa_states={sfa.n_states},table={table_mb:.1f}MB")
