"""Paper Fig. 6: SFA matching throughput and scaling with parallelism,
measured through the ``Scanner`` engine API.

The paper matches a 10^10-char input across pthreads; here the same chunked
algorithm runs data-parallel under jit, sweeping the chunk count (the
paper's thread count) on a CPU-sized input. Both matching modes are timed —
SFA-table walks (the paper's, ``ScanPlan(mode="sfa")``) and enumeration
(related-work baseline that needs no SFA) — plus the sequential python
baseline, all through one compiled ``Scanner`` per plan.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import _config
from repro.core.prosite import PROSITE_SAMPLES, compile_prosite
from repro.engine import ChunkPolicy, ScanPlan, Scanner


def run(emit) -> None:
    length = _config.scaled(2_000_000, 64_000)
    dfa = compile_prosite(PROSITE_SAMPLES["PS00016"])
    rng = np.random.default_rng(0)
    syms = rng.integers(0, dfa.n_symbols, size=length).astype(np.int32)

    # sequential python baseline (scaled down, extrapolated linearly)
    scale = _config.scaled(50, 8)
    sub = syms[: length // scale]
    t0 = time.perf_counter()
    dfa.run(sub)
    t_seq = (time.perf_counter() - t0) * scale
    emit("fig6/sequential_python_s", t_seq * 1e6,
         f"len={length},extrapolated_{scale}x")

    want = dfa.run(syms)
    chunk_sweep = _config.scaled([1, 2, 4, 8, 16, 32, 64], [1, 8, 64])
    for n_chunks in chunk_sweep:
        sc = Scanner.compile(
            dfa, ScanPlan(mode="sfa", sfa_state_budget=100_000,
                          chunking=ChunkPolicy(n_chunks=n_chunks)))
        sc.mapping(syms)  # compile
        t0 = time.perf_counter()
        out = sc.mapping(syms)
        t = time.perf_counter() - t0
        assert int(out[0, dfa.start]) == want
        emit(f"fig6/sfa_match_chunks{n_chunks}", t * 1e6,
             f"{t_seq / t:.1f}x_vs_seq,throughput={length / t / 1e6:.1f}Mchar_s")

    for n_chunks in [8, 64]:
        sc = Scanner.compile(
            dfa, ScanPlan(mode="enumeration",
                          chunking=ChunkPolicy(n_chunks=n_chunks)))
        sc.mapping(syms)
        t0 = time.perf_counter()
        out = sc.mapping(syms)
        t = time.perf_counter() - t0
        assert int(out[0, dfa.start]) == want
        emit(f"fig6/enumeration_match_chunks{n_chunks}", t * 1e6,
             f"n_states_wide_gathers,throughput={length / t / 1e6:.1f}Mchar_s")

    # streaming path: same input fed as bounded-memory blocks through the
    # engine's fixed-shape inner loop (the larger-than-memory story, timed)
    n_chunks = 16
    block_len = _config.scaled(4096, 512)
    sc = Scanner.compile(
        dfa, ScanPlan(mode="sfa", sfa_state_budget=100_000,
                      chunking=ChunkPolicy(n_chunks=n_chunks,
                                           block_len=block_len)))
    piece = n_chunks * block_len
    # crop to whole blocks: a ragged tail would be composed in a Python
    # per-symbol loop and dominate the timing of the block path
    stream_len = (length // piece) * piece
    head = syms[:stream_len]
    sc.stream(syms[i: i + piece] for i in range(0, piece, piece))  # compile
    sc.mapping(head)  # compile the batch twin used as the oracle below
    t0 = time.perf_counter()
    res = sc.stream(head[i: i + piece] for i in range(0, stream_len, piece))
    t = time.perf_counter() - t0
    assert int(res.final_states[0]) == int(sc.mapping(head)[0, dfa.start])
    emit("fig6/sfa_stream_s", t * 1e6,
         f"block={n_chunks}x{block_len},len={stream_len},"
         f"throughput={stream_len / t / 1e6:.1f}Mchar_s")


def run_sfa_size_ladder(emit) -> None:
    """Fig. 6's size dimension: matching cost vs SFA size (table locality)."""
    rng = np.random.default_rng(1)
    length = _config.scaled(200_000, 20_000)
    syms_small = rng.integers(0, 20, size=length).astype(np.int32)
    for pid in _config.scaled(["PS00016", "PS00017", "PS00008"], ["PS00016"]):
        sc = Scanner.compile(
            pid, ScanPlan(mode="sfa", sfa_state_budget=500_000,
                          chunking=ChunkPolicy(n_chunks=16)))
        g = sc.groups[0]
        sc.mapping(syms_small)  # compile
        t0 = time.perf_counter()
        sc.mapping(syms_small)
        t = time.perf_counter() - t0
        sfa_states = int(g.deltas.shape[1])
        table_mb = g.deltas.size * 4 / 1e6
        emit(f"fig6b/{pid}/sfa_match_s", t * 1e6,
             f"sfa_states={sfa_states},table={table_mb:.1f}MB")
