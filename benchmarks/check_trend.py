"""Bench-trend gate: fail loudly on a batched-construction regression.

CI's bench-smoke job stashes the *committed* ``BENCH_construction.json``
baseline, reruns the harness, and then compares the fresh file against the
stash with this script: for every bank size ``P`` present in both, the
fresh ``batched_speedup`` (warm batched vs sequential loop — a same-machine
ratio, so it transfers across runner generations far better than absolute
seconds) must be within ``--max-regression`` (default 2x) of the baseline's.

Exit codes: 0 = within tolerance, 1 = regression (or nothing comparable —
an empty comparison is itself a regression of the gate), 2 = unusable
input files.

Usage::

    python benchmarks/check_trend.py BASELINE.json FRESH.json [--max-regression 2.0]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _rows_by_p(path: Path) -> dict:
    try:
        report = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        print(f"ERROR: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    rows = {}
    for row in report.get("results", []):
        if "P" in row and "batched_speedup" in row:
            rows[int(row["P"])] = float(row["batched_speedup"])
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", type=Path)
    ap.add_argument("fresh", type=Path)
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="fail when baseline_speedup / fresh_speedup exceeds "
                         "this factor for any comparable bank size")
    args = ap.parse_args()

    base = _rows_by_p(args.baseline)
    fresh = _rows_by_p(args.fresh)
    shared = sorted(set(base) & set(fresh))
    if not shared:
        print(f"ERROR: no comparable bank sizes between {args.baseline} "
              f"(P={sorted(base)}) and {args.fresh} (P={sorted(fresh)}) — "
              "the trend gate compared nothing", file=sys.stderr)
        sys.exit(1)

    failed = False
    print(f"{'P':>4} {'baseline':>10} {'fresh':>10} {'ratio':>7}")
    for P in shared:
        ratio = base[P] / fresh[P] if fresh[P] > 0 else float("inf")
        verdict = "OK" if ratio <= args.max_regression else "REGRESSION"
        print(f"{P:>4} {base[P]:>9.2f}x {fresh[P]:>9.2f}x {ratio:>6.2f}x  {verdict}")
        if verdict != "OK":
            failed = True
    if failed:
        print(f"ERROR: batched-vs-loop speedup regressed by more than "
              f"{args.max_regression}x — see rows above", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
