"""Bench-trend gate: fail loudly on a benchmark regression.

CI's bench-smoke job stashes the *committed* baseline JSON, reruns the
harness, and compares the fresh file against the stash with this script.
Two report kinds are recognized by shape:

* ``BENCH_construction.json`` (``"results"`` rows) — for every bank size
  ``P`` present in both, the fresh ``batched_speedup`` (warm batched vs
  sequential loop) must be within ``--max-regression`` of the baseline's;
* ``BENCH_engine.json`` (``"modes"`` table) — for every mode present in
  both, the fresh throughput *relative to the same run's enumeration mode*
  must be within ``--max-regression`` of the baseline's relative figure.

Both gates compare same-machine **ratios**, never absolute seconds, so they
transfer across runner generations; mixing report kinds between baseline
and fresh is an input error.

Exit codes: 0 = within tolerance, 1 = regression (or nothing comparable —
an empty comparison is itself a regression of the gate), 2 = unusable
input files.

Usage::

    python benchmarks/check_trend.py BASELINE.json FRESH.json [--max-regression 2.0]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _load(path: Path) -> dict:
    try:
        report = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        print(f"ERROR: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    if not isinstance(report, dict):
        print(f"ERROR: {path} is not a JSON report object", file=sys.stderr)
        sys.exit(2)
    return report


def _rows(path: Path) -> tuple:
    """-> (kind, {label: gated ratio}). Construction reports gate the
    per-P batched speedup; engine reports gate each mode's throughput
    relative to the same run's enumeration row."""
    report = _load(path)
    if "modes" in report:
        modes = report["modes"]
        base = modes.get("enumeration", {}).get("mchar_pattern_per_s")
        if not base:
            print(f"ERROR: {path} has no enumeration row to normalize "
                  "against", file=sys.stderr)
            sys.exit(2)
        return "engine", {
            mode: float(row["mchar_pattern_per_s"]) / float(base)
            for mode, row in modes.items()
            if isinstance(row, dict) and "mchar_pattern_per_s" in row
        }
    rows = {}
    for row in report.get("results", []):
        if "P" in row and "batched_speedup" in row:
            rows[f"P={int(row['P'])}"] = float(row["batched_speedup"])
    return "construction", rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", type=Path)
    ap.add_argument("fresh", type=Path)
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="fail when baseline_ratio / fresh_ratio exceeds "
                         "this factor for any comparable row")
    args = ap.parse_args()

    base_kind, base = _rows(args.baseline)
    fresh_kind, fresh = _rows(args.fresh)
    if base_kind != fresh_kind:
        print(f"ERROR: report kinds differ: {args.baseline} is {base_kind}, "
              f"{args.fresh} is {fresh_kind}", file=sys.stderr)
        sys.exit(2)
    shared = sorted(set(base) & set(fresh))
    if not shared:
        print(f"ERROR: no comparable rows between {args.baseline} "
              f"({sorted(base)}) and {args.fresh} ({sorted(fresh)}) — "
              "the trend gate compared nothing", file=sys.stderr)
        sys.exit(1)

    failed = False
    width = max(len(k) for k in shared)
    print(f"{'row':<{width}} {'baseline':>10} {'fresh':>10} {'ratio':>7}")
    for k in shared:
        ratio = base[k] / fresh[k] if fresh[k] > 0 else float("inf")
        verdict = "OK" if ratio <= args.max_regression else "REGRESSION"
        print(f"{k:<{width}} {base[k]:>9.2f}x {fresh[k]:>9.2f}x "
              f"{ratio:>6.2f}x  {verdict}")
        if verdict != "OK":
            failed = True
    if failed:
        print(f"ERROR: {base_kind} trend regressed by more than "
              f"{args.max_regression}x — see rows above", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
