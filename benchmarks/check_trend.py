"""Bench-trend gate: fail loudly on a benchmark regression.

CI's bench-smoke job stashes the *committed* baseline JSON, reruns the
harness, and compares the fresh file against the stash with this script.
Four report kinds are recognized by shape:

* ``BENCH_construction.json`` (``"results"`` rows keyed by ``P``) — for
  every bank size ``P`` present in both, the fresh ``batched_speedup``
  (warm batched vs sequential loop) must be within ``--max-regression``
  of the baseline's;
* ``BENCH_engine.json`` (``"modes"`` table) — for every mode present in
  both, the fresh throughput *relative to the same run's enumeration mode*
  must be within ``--max-regression`` of the baseline's relative figure;
* ``BENCH_service.json`` (``"suite": "scan_service"``) — every named bench
  row (``cold_vs_warm``, ``coalesced_vs_sequential``) gates its own
  ``speedup`` ratio: cold compile vs warm artifact-store start, and a
  request burst served coalesced vs one-by-one;
* ``BENCH_speculative.json`` (``"rows"`` ladder) — for every automaton
  size ``n`` present in both, the fresh speculative-vs-enumeration
  ``speedup`` must be within ``--max-regression`` of the baseline's.

All gates compare same-machine **ratios**, never absolute seconds, so they
transfer across runner generations; mixing report kinds between baseline
and fresh is an input error.

Every row present in the baseline must also be present in the fresh report:
a fresh run that silently drops a row (say, smoke stops running P=64) would
otherwise turn the gate off for exactly the regression it was added to
catch. Rows only the fresh report has are fine (new benchmarks don't need a
baseline yet).

``--min-speedup ROW=VALUE`` (repeatable) adds an *absolute* floor on top of
the relative gate: the fresh ratio for ``ROW`` must be at least ``VALUE``
regardless of what the baseline says — the "P=4 win must not mask a P=64
loss" guard, pinned to a hard number instead of a drifting baseline.

Exit codes: 0 = within tolerance, 1 = regression (or nothing comparable —
an empty comparison is itself a regression of the gate), 2 = unusable
input files.

Usage::

    python benchmarks/check_trend.py BASELINE.json FRESH.json \
        [--max-regression 2.0] [--min-speedup P=64=1.1]
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _load(path: Path) -> dict:
    try:
        report = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        print(f"ERROR: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)
    if not isinstance(report, dict):
        print(f"ERROR: {path} is not a JSON report object", file=sys.stderr)
        sys.exit(2)
    return report


def _rows(path: Path) -> tuple:
    """-> (kind, {label: gated ratio}). Construction reports gate the
    per-P batched speedup; engine reports gate each mode's throughput
    relative to the same run's enumeration row; service reports gate each
    named bench's speedup; speculative reports gate the per-n
    speculative-vs-enumeration speedup."""
    report = _load(path)
    if "modes" in report:
        modes = report["modes"]
        base = modes.get("enumeration", {}).get("mchar_pattern_per_s")
        if not base:
            print(f"ERROR: {path} has no enumeration row to normalize "
                  "against", file=sys.stderr)
            sys.exit(2)
        return "engine", {
            mode: float(row["mchar_pattern_per_s"]) / float(base)
            for mode, row in modes.items()
            if isinstance(row, dict) and "mchar_pattern_per_s" in row
        }
    if report.get("suite") == "scan_service":
        return "service", {
            str(row["bench"]): float(row["speedup"])
            for row in report.get("results", [])
            if isinstance(row, dict) and "bench" in row and "speedup" in row
        }
    if "rows" in report:
        return "speculative", {
            f"n={int(row['n_states'])}": float(row["speedup"])
            for row in report["rows"]
            if isinstance(row, dict)
            and "n_states" in row and "speedup" in row
        }
    rows = {}
    for row in report.get("results", []):
        if "P" in row and "batched_speedup" in row:
            rows[f"P={int(row['P'])}"] = float(row["batched_speedup"])
    return "construction", rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", type=Path)
    ap.add_argument("fresh", type=Path)
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="fail when baseline_ratio / fresh_ratio exceeds "
                         "this factor for any comparable row")
    ap.add_argument("--min-speedup", action="append", default=[],
                    metavar="ROW=VALUE",
                    help="absolute floor on one row's fresh ratio, e.g. "
                         "'P=64=1.1' (repeatable; row must exist)")
    args = ap.parse_args()

    floors = {}
    for spec in args.min_speedup:
        label, _, value = spec.rpartition("=")
        try:
            floors[label] = float(value)
        except ValueError:
            label = ""
        if not label:
            print(f"ERROR: --min-speedup wants ROW=VALUE, got {spec!r}",
                  file=sys.stderr)
            sys.exit(2)

    base_kind, base = _rows(args.baseline)
    fresh_kind, fresh = _rows(args.fresh)
    if base_kind != fresh_kind:
        print(f"ERROR: report kinds differ: {args.baseline} is {base_kind}, "
              f"{args.fresh} is {fresh_kind}", file=sys.stderr)
        sys.exit(2)
    missing = sorted(set(base) - set(fresh)) + sorted(set(floors) - set(fresh))
    if missing:
        print(f"ERROR: rows {missing} are gated (baseline or --min-speedup) "
              f"but absent from {args.fresh} — a dropped row is a dropped "
              "gate", file=sys.stderr)
        sys.exit(1)
    shared = sorted(set(base) & set(fresh))
    if not shared:
        print(f"ERROR: no comparable rows between {args.baseline} "
              f"({sorted(base)}) and {args.fresh} ({sorted(fresh)}) — "
              "the trend gate compared nothing", file=sys.stderr)
        sys.exit(1)

    failed = False
    width = max(len(k) for k in shared)
    print(f"{'row':<{width}} {'baseline':>10} {'fresh':>10} {'ratio':>7} "
          f"{'floor':>7}")
    for k in shared:
        ratio = base[k] / fresh[k] if fresh[k] > 0 else float("inf")
        floor = floors.get(k)
        verdict = "OK"
        if ratio > args.max_regression:
            verdict = "REGRESSION"
        elif floor is not None and fresh[k] < floor:
            verdict = "BELOW FLOOR"
        floor_s = f"{floor:.2f}x" if floor is not None else "-"
        print(f"{k:<{width}} {base[k]:>9.2f}x {fresh[k]:>9.2f}x "
              f"{ratio:>6.2f}x {floor_s:>7}  {verdict}")
        if verdict != "OK":
            failed = True
    if failed:
        print(f"ERROR: {base_kind} trend regressed (>{args.max_regression}x "
              "vs baseline, or under a --min-speedup floor) — see rows "
              "above", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
