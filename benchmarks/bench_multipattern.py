"""Multi-pattern bank matching: patterns/sec of the batched engine vs the
sequential per-pattern loop (paper §IV task parallelism, measured).

For bank sizes {4, 16, 64} (banks above the bundled signature count are
padded out with size-graded random DFAs) the benchmark scans one corpus and
reports, per bank size:

  * ``seq_loop``  — python loop over patterns, each matched with the jitted
    single-pattern chunk matcher (the pre-bank status quo);
  * ``bank``      — one ``census_bank`` call (all patterns in one padded
    stack — pays n_max-wide gathers for every pattern);
  * ``bucketed``  — ``census_bank`` per size bucket (``bucket_by_size``),
    bounding padding waste to ~2x per bucket;
  * patterns/sec for each, and the resulting speedups.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import matching as mt
from repro.core import monoid as M
from repro.core import multipattern as mp
from repro.core.dfa import random_dfa
from repro.core.prosite import PROSITE_EXTRA, PROSITE_SAMPLES, compile_prosite, synthetic_protein

BANK_SIZES = (4, 16, 64)
CORPUS_DOCS = 32
DOC_LEN = 1024
N_CHUNKS = 8
FN = M.function_monoid()


def _build_bank(size: int) -> mp.PatternBank:
    pool = {**PROSITE_SAMPLES, **PROSITE_EXTRA}
    ids = sorted(pool.keys())[:size]
    dfas = [compile_prosite(pool[i]) for i in ids]
    # Larger banks than the bundled corpus: pad with size-graded random DFAs
    # over the same alphabet (states 4..24 — the spread real signatures show).
    while len(dfas) < size:
        i = len(dfas)
        dfas.append(random_dfa(4 + (i % 21), 20, seed=i))
        ids.append(f"RND{i:05d}")
    return mp.PatternBank.from_dfas(dfas[:size], ids[:size])


@jax.jit
def _single_census(table, acc, start, corpus_chunks):
    def per_doc(doc_chunks):
        mappings = jax.vmap(lambda c: mt.chunk_mapping_enumeration(table, c))(
            doc_chunks
        )
        mapping = M.reduce(FN, mappings, axis=0)
        return acc[mapping[start]]

    return jnp.sum(jax.vmap(per_doc)(corpus_chunks), dtype=jnp.int32)


def run(emit) -> None:
    rng = np.random.default_rng(0)
    corpus = rng.integers(0, 20, size=(CORPUS_DOCS, DOC_LEN)).astype(np.int32)
    corpus_j = jnp.asarray(corpus)
    corpus_chunks = corpus_j.reshape(CORPUS_DOCS, N_CHUNKS, DOC_LEN // N_CHUNKS)

    for size in BANK_SIZES:
        bank = _build_bank(size)
        tables, accepting, starts = bank.device_arrays()

        # -- sequential per-pattern loop (tables unbatched, same chunking) --
        per_tbl = [jnp.asarray(bank.dfa(p).table) for p in range(size)]
        per_acc = [jnp.asarray(bank.dfa(p).accepting) for p in range(size)]

        def seq_loop():
            return [
                _single_census(per_tbl[p], per_acc[p], int(bank.starts[p]),
                               corpus_chunks)
                for p in range(size)
            ]

        for x in seq_loop():  # warmup/compile (one compile per table shape)
            x.block_until_ready()
        t0 = time.perf_counter()
        seq_res = seq_loop()
        for x in seq_res:
            x.block_until_ready()
        t_seq = time.perf_counter() - t0
        ref = np.asarray([int(x) for x in seq_res])

        # -- batched bank census -------------------------------------------
        mp.census_bank(tables, accepting, starts, corpus_j,
                       N_CHUNKS).block_until_ready()
        t0 = time.perf_counter()
        counts = mp.census_bank(tables, accepting, starts, corpus_j, N_CHUNKS)
        counts.block_until_ready()
        t_bank = time.perf_counter() - t0

        exact = np.array_equal(np.asarray(counts), ref)

        # -- size-bucketed banks (padding waste bounded per bucket) --------
        dfas = [bank.dfa(p) for p in range(size)]
        buckets = mp.bucket_by_size(dfas, bank.ids)
        bucket_args = [b.device_arrays() for b in buckets]

        def bucketed():
            return [
                mp.census_bank(t, a, s, corpus_j, N_CHUNKS)
                for (t, a, s) in bucket_args
            ]

        for x in bucketed():
            x.block_until_ready()
        t0 = time.perf_counter()
        bkt_res = bucketed()
        for x in bkt_res:
            x.block_until_ready()
        t_bkt = time.perf_counter() - t0
        bkt_counts = dict(zip(
            (i for b in buckets for i in b.ids),
            (int(c) for x in bkt_res for c in np.asarray(x)),
        ))
        exact_bkt = all(bkt_counts[bank.ids[p]] == ref[p] for p in range(size))

        emit(f"multipattern/seq_loop_P{size}", t_seq * 1e6,
             f"patterns_per_s={size / t_seq:.1f}")
        emit(f"multipattern/bank_P{size}", t_bank * 1e6,
             f"patterns_per_s={size / t_bank:.1f},speedup={t_seq / t_bank:.2f}x,"
             f"exact_match={exact},n_max={bank.n_max}")
        emit(f"multipattern/bucketed_P{size}", t_bkt * 1e6,
             f"patterns_per_s={size / t_bkt:.1f},speedup={t_seq / t_bkt:.2f}x,"
             f"exact_match={exact_bkt},buckets={len(buckets)}")


if __name__ == "__main__":
    def _emit(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")

    run(_emit)
