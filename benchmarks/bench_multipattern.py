"""Multi-pattern bank matching: patterns/sec of the batched engine vs the
sequential per-pattern loop (paper §IV task parallelism, measured), plus the
auto-vs-forced-mode comparison of the ``Scanner`` engine.

For bank sizes {4, 16, 64} (banks above the bundled signature count are
padded out with size-graded random DFAs) the benchmark scans one corpus and
reports, per bank size:

  * ``seq_loop``  — python loop over patterns, each matched with the jitted
    single-pattern chunk matcher (the pre-bank status quo);
  * ``bank``      — one ``Scanner.census`` call (all patterns in one padded
    stack — pays n_max-wide gathers for every pattern);
  * ``bucketed``  — the same plan with size-bucketing on, bounding padding
    waste to ~2x per bucket;
  * patterns/sec for each, and the resulting speedups.

``run_engine_modes`` measures the SFA-bank vs enumeration-bank vs
speculative crossover on the bundled PROSITE bank (auto and the three
forced plans) and writes the comparison to ``BENCH_engine.json``.
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import _config
from repro.core import matching as mt
from repro.core import monoid as M
from repro.core.dfa import random_dfa
from repro.core.prosite import PROSITE_EXTRA, PROSITE_SAMPLES, compile_prosite, load_bank
from repro.engine import ChunkPolicy, ScanPlan, Scanner

CORPUS_DOCS = 32
DOC_LEN = 1024
N_CHUNKS = 8
FN = M.function_monoid()


def _build_dfas(size: int):
    pool = {**PROSITE_SAMPLES, **PROSITE_EXTRA}
    ids = sorted(pool.keys())[:size]
    dfas = [compile_prosite(pool[i]) for i in ids]
    # Larger banks than the bundled corpus: pad with size-graded random DFAs
    # over the same alphabet (states 4..24 — the spread real signatures show).
    while len(dfas) < size:
        i = len(dfas)
        dfas.append(random_dfa(4 + (i % 21), 20, seed=i))
        ids.append(f"RND{i:05d}")
    return dfas[:size], ids[:size]


@jax.jit
def _single_census(table, acc, start, corpus_chunks):
    def per_doc(doc_chunks):
        mappings = jax.vmap(lambda c: mt.chunk_mapping_enumeration(table, c))(
            doc_chunks
        )
        mapping = M.reduce(FN, mappings, axis=0)
        return acc[mapping[start]]

    return jnp.sum(jax.vmap(per_doc)(corpus_chunks), dtype=jnp.int32)


def run(emit) -> None:
    rng = np.random.default_rng(0)
    corpus_docs = _config.scaled(CORPUS_DOCS, 8)
    doc_len = _config.scaled(DOC_LEN, 256)
    corpus = rng.integers(0, 20, size=(corpus_docs, doc_len)).astype(np.int32)
    corpus_chunks = jnp.asarray(corpus).reshape(
        corpus_docs, N_CHUNKS, doc_len // N_CHUNKS
    )

    for size in _config.scaled((4, 16, 64), (4, 16)):
        dfas, ids = _build_dfas(size)
        plan = ScanPlan(mode="enumeration",
                        chunking=ChunkPolicy(n_chunks=N_CHUNKS))
        sc = Scanner.compile(dict(zip(ids, dfas)), plan)

        # -- sequential per-pattern loop (tables unbatched, same chunking) --
        per_tbl = [jnp.asarray(d.table) for d in dfas]
        per_acc = [jnp.asarray(d.accepting) for d in dfas]

        def seq_loop():
            return [
                _single_census(per_tbl[p], per_acc[p], dfas[p].start,
                               corpus_chunks)
                for p in range(size)
            ]

        for x in seq_loop():  # warmup/compile (one compile per table shape)
            x.block_until_ready()
        t0 = time.perf_counter()
        seq_res = seq_loop()
        for x in seq_res:
            x.block_until_ready()
        t_seq = time.perf_counter() - t0
        ref = np.asarray([int(x) for x in seq_res])

        # -- batched bank census through the engine -------------------------
        sc.census(corpus)  # warmup/compile
        t0 = time.perf_counter()
        counts = sc.census(corpus)
        t_bank = time.perf_counter() - t0
        exact = np.array_equal(counts, ref)

        # -- size-bucketed plan (padding waste bounded per bucket) ----------
        sc_bkt = Scanner.compile(dfas, plan.with_(
            chunking=ChunkPolicy(n_chunks=N_CHUNKS, bucket=True)))
        sc_bkt.census(corpus)
        t0 = time.perf_counter()
        bkt_counts = sc_bkt.census(corpus)
        t_bkt = time.perf_counter() - t0
        exact_bkt = np.array_equal(bkt_counts, ref)

        n_max = max(d.n_states for d in dfas)
        emit(f"multipattern/seq_loop_P{size}", t_seq * 1e6,
             f"patterns_per_s={size / t_seq:.1f}")
        emit(f"multipattern/bank_P{size}", t_bank * 1e6,
             f"patterns_per_s={size / t_bank:.1f},speedup={t_seq / t_bank:.2f}x,"
             f"exact_match={exact},n_max={n_max}")
        emit(f"multipattern/bucketed_P{size}", t_bkt * 1e6,
             f"patterns_per_s={size / t_bkt:.1f},speedup={t_seq / t_bkt:.2f}x,"
             f"exact_match={exact_bkt},buckets={len(sc_bkt.groups)}")


def run_engine_modes(emit) -> None:
    """Auto vs forced modes on the bundled bank: where do the SFA-bank,
    enumeration-bank, and speculative crossovers sit, and what does auto
    actually pick? (bench_speculative sweeps the blowup-regime state
    ladder; this row shows speculation on the realistic mixed bank.)"""
    rng = np.random.default_rng(1)
    corpus_docs = _config.scaled(32, 8)
    doc_len = _config.scaled(1024, 256)
    bank = load_bank()
    corpus = rng.integers(0, 20, size=(corpus_docs, doc_len)).astype(np.int32)

    report: dict = {
        "bank": {"patterns": bank.n_patterns, "n_max": bank.n_max},
        "corpus": {"docs": corpus_docs, "doc_len": doc_len},
        "modes": {},
    }
    ref = None
    for mode in ("auto", "sfa", "enumeration", "speculative"):
        budget = 200_000 if mode == "sfa" else ScanPlan().sfa_state_budget
        t0 = time.perf_counter()
        sc = Scanner.compile(bank, ScanPlan(
            mode=mode, sfa_state_budget=budget,
            chunking=ChunkPolicy(n_chunks=N_CHUNKS)))
        t_compile = time.perf_counter() - t0
        sc.census(corpus)  # warmup (also resolves the speculation profile)
        t0 = time.perf_counter()
        counts = sc.census(corpus)
        t_scan = time.perf_counter() - t0
        if ref is None:
            ref = counts
        n_sfa = sum(1 for m in sc.pattern_modes.values() if m == "sfa")
        chars = corpus_docs * doc_len * bank.n_patterns
        emit(f"engine/census_{mode}", t_scan * 1e6,
             f"sfa_patterns={n_sfa}/{bank.n_patterns},"
             f"compile_s={t_compile:.2f},exact={np.array_equal(counts, ref)},"
             f"Mchar_pattern_s={chars / t_scan / 1e6:.1f}")
        report["modes"][mode] = {
            "compile_s": t_compile,
            "scan_s": t_scan,
            "sfa_patterns": n_sfa,
            "mchar_pattern_per_s": chars / t_scan / 1e6,
            "counts_match_auto": bool(np.array_equal(counts, ref)),
        }
        if sc.last_speculation is not None:
            report["modes"][mode]["speculation"] = dataclasses.asdict(
                sc.last_speculation
            )

    out = Path(__file__).resolve().parents[1] / "BENCH_engine.json"
    out.write_text(json.dumps(report, indent=2) + "\n")
    emit("engine/report", 0.0, f"written={out.name}")


if __name__ == "__main__":
    def _emit(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}")

    run(_emit)
    run_engine_modes(_emit)
