"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Figure mapping:
  fig4    bench_construction          (fingerprints + hashing ablation)
  bank    bench_construction.run_bank (batched bank closure vs per-pattern
          loop @ P=4/16/64, writes BENCH_construction.json)
  fig5    bench_parallel_construction (parallel vs best sequential)
  fig6    bench_matching              (chunk-parallel matching scaling)
  census  bench_census                (PROSITE DFA -> SFA growth, §IV)
  kernels bench_kernels               (fingerprint pipeline micro)
  roofline bench_roofline             (LM dry-run cells, beyond-paper)
  multipattern bench_multipattern     (batched bank vs per-pattern loop, §IV)
  engine  bench_multipattern.run_engine_modes (auto vs forced Scanner modes,
          also writes BENCH_engine.json)
  speculative bench_speculative       (speculative vs enumeration in the
          blowup regime, writes BENCH_speculative.json)
  service bench_service               (cold vs warm start through the
          artifact store; coalesced vs sequential submits; writes
          BENCH_service.json)
  obs     bench_obs                   (observability overhead: warm scans
          with metrics enabled vs disabled, writes BENCH_obs.json)

``--smoke`` caps sizes/iterations (see benchmarks/_config.py) so CI can run
the whole harness as a smoke job without burning minutes on full figures.
``--profile`` wraps each module in ``jax.profiler.trace`` and writes one
trace directory per module under ``BENCH_traces/`` (the profiling harness:
open in TensorBoard/Perfetto to see where a bench's wall time went; the
bench-smoke CI job uploads the smoke-size traces as an artifact), turns on
``obs.configure(xla_annotations=True)`` so engine/construction spans land
on the same timeline, and writes a machine-readable per-module summary
(status, wall seconds, trace path) to ``BENCH_traces/summary.json``.

Every sweep also records each module's *metric footprint*: the delta of the
process-wide ``repro.obs`` registry snapshot across the module's run,
appended as one JSONL record per module to ``BENCH_metrics.jsonl`` next to
the BENCH JSONs (uploaded as a CI artifact) — construction rounds, cache
hit/miss counts, speculative repair totals per benchmark, correlating the
BENCH timings with what the code actually did. The log survives across
sweeps (so local before/after comparisons keep history) but is trimmed to
the newest ``METRICS_KEEP`` records at sweep start — it never grows
without bound.

``--serve-telemetry [PORT]`` additionally runs the sweep behind a live
:class:`repro.scanservice.TelemetryServer` (``PORT`` 0 = ephemeral) and
self-scrapes ``GET /metrics`` over real HTTP after every module,
re-parsing the exposition text with ``obs.parse_prometheus`` — a scrape
that fails to parse fails the sweep, which is exactly the guarantee the
CI bench-smoke job wants: the endpoint Prometheus would poll is validated
mid-sweep, under the same process load as the benchmarks themselves.
A benchmark module that fails to *import* (missing optional dep, broken
bench) is skipped with a warning — it costs its own suites, never the sweep.
But a sweep where **every** module failed to import ran nothing at all:
that exits 2, so CI's bench-smoke job cannot silently go green with zero
benchmarks run. Suites that import but *fail at runtime* exit 1. Either
way the sweep ends with a per-module summary table (status + wall time),
so a long CI log still answers "what ran, what broke, what was slow" at
a glance.
"""

from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

#: Newest metric-footprint records kept in BENCH_metrics.jsonl across
#: sweeps (one record per module per sweep, so ~20 sweeps of history).
METRICS_KEEP = 200

#: (module, suite function names) — resolved one by one so an unimportable
#: module skips with a warning instead of aborting the whole sweep.
SUITES = [
    ("bench_construction", ("run", "run_bank")),
    ("bench_parallel_construction", ("run", "run_jax_engine")),
    ("bench_matching", ("run", "run_sfa_size_ladder")),
    ("bench_census", ("run", "run_synthetic_ladder")),
    ("bench_kernels", ("run",)),
    ("bench_roofline", ("run",)),
    ("bench_multipattern", ("run", "run_engine_modes")),
    ("bench_speculative", ("run",)),
    ("bench_service", ("run", "run_coalesced")),
    ("bench_obs", ("run",)),
]


def _resolve_suites() -> tuple:
    """-> ([(module name, callables)], skipped module names). Import errors
    warn and skip — the *caller* decides whether anything at all resolved."""
    modules = []
    skipped = []
    for mod_name, fn_names in SUITES:
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
        except Exception:
            skipped.append(mod_name)
            print(f"WARNING: skipping benchmarks.{mod_name} "
                  "(import failed):", file=sys.stderr)
            traceback.print_exc()
            continue
        modules.append((mod_name, [getattr(mod, fn) for fn in fn_names]))
    return modules, skipped


def _trim_metrics_log(path, keep: int = METRICS_KEEP) -> None:
    """Truncate the JSONL metrics log to its newest ``keep`` records.
    Torn or non-JSON lines (a killed sweep's last append) are dropped."""
    from repro.obs.aggregate import read_records

    if not path.exists():
        return
    records = read_records(path)
    if len(records) <= keep:
        return
    from repro import obs

    tmp = path.with_suffix(".jsonl.tmp")
    tmp.unlink(missing_ok=True)
    obs.write_jsonl(tmp, records[-keep:])
    tmp.replace(path)


def _scrape_metrics(url: str):
    """GET ``url``/metrics over real HTTP and re-parse the exposition
    text. -> parsed snapshot dict; raises on HTTP or parse failure."""
    from urllib.request import urlopen

    from repro import obs

    with urlopen(f"{url}/metrics", timeout=10) as resp:
        if resp.status != 200:
            raise RuntimeError(f"/metrics returned HTTP {resp.status}")
        text = resp.read().decode("utf-8")
    return obs.parse_prometheus(text)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down sizes/iterations (CI smoke job)")
    ap.add_argument("--profile", action="store_true",
                    help="wrap each bench module in jax.profiler.trace, "
                         "writing one trace directory per module under "
                         "BENCH_traces/ (open with TensorBoard or Perfetto)")
    ap.add_argument("--serve-telemetry", nargs="?", const=0, default=None,
                    type=int, metavar="PORT",
                    help="serve /metrics over HTTP for the sweep's duration "
                         "(PORT omitted or 0 = ephemeral) and self-scrape + "
                         "parse it after every module; a scrape that fails "
                         "to parse fails the sweep")
    args = ap.parse_args()

    from pathlib import Path

    from benchmarks import _config
    from repro import obs

    if args.smoke:
        _config.set_smoke(True)

    repo_root = Path(__file__).resolve().parents[1]
    metrics_path = repo_root / "BENCH_metrics.jsonl"
    _trim_metrics_log(metrics_path)   # bounded history, not a fresh unlink

    telemetry = None
    if args.serve_telemetry is not None:
        from repro.scanservice import TelemetryServer

        telemetry = TelemetryServer(port=args.serve_telemetry).start()
        print(f"telemetry: serving {telemetry.url}/metrics", file=sys.stderr)

    trace_root = None
    if args.profile:
        trace_root = repo_root / "BENCH_traces"
        trace_root.mkdir(exist_ok=True)
        # Bridge obs spans onto the XLA profiler's host timeline so the
        # engine/construction spans show up inside each module's trace.
        obs.configure(xla_annotations=True)

    modules, skipped = _resolve_suites()
    if not modules:
        print(f"ERROR: all {len(skipped)} benchmark modules failed to "
              "import; no benchmarks were run", file=sys.stderr)
        sys.exit(2)

    print("name,us_per_call,derived")

    def emit(name: str, us: float, derived: str = "") -> None:
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    summary = [(name, "SKIPPED (import)", 0.0) for name in skipped]
    failures = 0
    for mod_name, suites in modules:
        status = "ok"
        before = obs.snapshot()
        t0 = time.perf_counter()

        def run_suites():
            nonlocal failures, status
            for suite in suites:
                try:
                    suite(emit)
                except Exception:  # keep the harness going; report at the end
                    failures += 1
                    status = "FAILED"
                    traceback.print_exc()

        if trace_root is not None:
            import jax

            # One trace directory per module: a whole-sweep trace would be
            # unreadably long, and a failed module still leaves the others'
            # traces intact.
            with jax.profiler.trace(str(trace_root / mod_name)):
                run_suites()
        else:
            run_suites()
        wall = time.perf_counter() - t0
        if telemetry is not None:
            # Mid-sweep scrape over real HTTP: the exposition text the
            # endpoint serves under benchmark load must stay parseable.
            try:
                _scrape_metrics(telemetry.url)
            except Exception:
                failures += 1
                status = "FAILED (scrape)"
                traceback.print_exc()
        summary.append((mod_name, status, wall))
        # The module's metric footprint: what the registry counted while it
        # ran (bench_obs resets the registry mid-run on purpose — its delta
        # is the post-reset residue, still useful, just not cumulative).
        obs.write_jsonl(metrics_path, [obs.snapshot_record(
            obs.snapshot_delta(before, obs.snapshot()), label=mod_name,
        )])

    width = max(len(name) for name, _, _ in summary)
    print("\n== sweep summary ==")
    for name, status, wall in sorted(summary, key=lambda r: -r[2]):
        print(f"{name:<{width}}  {status:<16} {wall:8.1f}s")
    sys.stdout.flush()
    if trace_root is not None:
        import json

        # Machine-readable sweep outcome next to the traces: what ran, how
        # long, where its trace went — the profiling run's index file.
        (trace_root / "summary.json").write_text(json.dumps({
            "smoke": _config.SMOKE,
            "modules": [
                {"module": name, "status": status, "wall_s": wall,
                 "trace": (str(trace_root / name)
                           if (trace_root / name).is_dir() else None)}
                for name, status, wall in summary
            ],
        }, indent=1))
    if telemetry is not None:
        telemetry.close()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
