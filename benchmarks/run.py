"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Figure mapping:
  fig4    bench_construction          (fingerprints + hashing ablation)
  bank    bench_construction.run_bank (batched bank closure vs per-pattern
          loop @ P=4/16/64, writes BENCH_construction.json)
  fig5    bench_parallel_construction (parallel vs best sequential)
  fig6    bench_matching              (chunk-parallel matching scaling)
  census  bench_census                (PROSITE DFA -> SFA growth, §IV)
  kernels bench_kernels               (fingerprint pipeline micro)
  roofline bench_roofline             (LM dry-run cells, beyond-paper)
  multipattern bench_multipattern     (batched bank vs per-pattern loop, §IV)
  engine  bench_multipattern.run_engine_modes (auto vs forced Scanner modes,
          also writes BENCH_engine.json)

``--smoke`` caps sizes/iterations (see benchmarks/_config.py) so CI can run
the whole harness as a smoke job without burning minutes on full figures.
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down sizes/iterations (CI smoke job)")
    args = ap.parse_args()

    from benchmarks import _config

    if args.smoke:
        _config.set_smoke(True)

    from benchmarks import (
        bench_census,
        bench_construction,
        bench_kernels,
        bench_matching,
        bench_multipattern,
        bench_parallel_construction,
        bench_roofline,
    )

    print("name,us_per_call,derived")

    def emit(name: str, us: float, derived: str = "") -> None:
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    suites = [
        bench_construction.run,
        bench_construction.run_bank,
        bench_parallel_construction.run,
        bench_parallel_construction.run_jax_engine,
        bench_matching.run,
        bench_matching.run_sfa_size_ladder,
        bench_census.run,
        bench_census.run_synthetic_ladder,
        bench_kernels.run,
        bench_roofline.run,
        bench_multipattern.run,
        bench_multipattern.run_engine_modes,
    ]
    failures = 0
    for suite in suites:
        try:
            suite(emit)
        except Exception:  # keep the harness going; report at the end
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
