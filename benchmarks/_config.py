"""Shared benchmark configuration.

``SMOKE`` is flipped by ``benchmarks/run.py --smoke`` (the CI smoke job):
suites then pick their scaled-down problem sizes via :func:`scaled`, so the
bench scripts stay import-clean and runnable end-to-end in minutes without
silently rotting between releases.
"""

from __future__ import annotations

SMOKE = False


def set_smoke(on: bool = True) -> None:
    global SMOKE
    SMOKE = on


def scaled(full, smoke):
    """Pick the full-size or smoke-size value for the active run."""
    return smoke if SMOKE else full
