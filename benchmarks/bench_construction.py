"""Paper Fig. 4: speedup of fingerprints and hashing over the sequential
baseline SFA construction — plus the bank-construction suite.

Three sequential variants (baseline exhaustive-compare, +fingerprints,
+fingerprints+hashing) run over a ladder of PROSITE-derived DFAs; reported
exactly as the paper plots it: fp-vs-baseline and hash-vs-fp speedups.

``run_bank`` measures the batched bank closure
(:func:`repro.construction.construct_bank`: all ``P`` frontiers advance in
one jitted bulk-synchronous round) against the sequential per-pattern loop
for bank sizes {4, 16, 64}, and writes the comparison to
``BENCH_construction.json`` (uploaded as a CI artifact by the bench-smoke
job).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from benchmarks import _config
from repro.construction import construct_bank
from repro.core.dfa import DFA, compile_dfa
from repro.core.prosite import PROSITE_EXTRA, PROSITE_SAMPLES, compile_prosite
from repro.core.sfa import construct_sfa_sequential

# small-to-medium patterns that the O(|Q_s|^2) baseline can still finish;
# the fingerprint/hash advantage GROWS with SFA size (paper Fig. 4's shape) —
# PS00008 (515 states) and PS00017 (1122) are the demonstrative tail.
BENCH_PATTERNS = ["PS00016", "PS00005", "PS00004", "PS00006", "PS00009",
                  "PS00001", "PS00008", "PS00017"]
SMOKE_PATTERNS = ["PS00016", "PS00005"]


def _time(fn, repeat: int = 1) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(emit) -> None:
    for pid in _config.scaled(BENCH_PATTERNS, SMOKE_PATTERNS):
        dfa = compile_prosite(PROSITE_SAMPLES[pid])
        s_hash = construct_sfa_sequential(dfa, use_fingerprints=True, use_hashing=True)
        n_sfa = s_hash.n_states

        t_base = _time(lambda: construct_sfa_sequential(
            dfa, use_fingerprints=False, use_hashing=False))
        t_fp = _time(lambda: construct_sfa_sequential(
            dfa, use_fingerprints=True, use_hashing=False))
        t_hash = _time(lambda: construct_sfa_sequential(
            dfa, use_fingerprints=True, use_hashing=True))

        emit(f"fig4/{pid}/baseline_s", t_base * 1e6, f"dfa={dfa.n_states},sfa={n_sfa}")
        emit(f"fig4/{pid}/fingerprint_speedup", t_fp * 1e6,
             f"{t_base / t_fp:.2f}x_vs_baseline")
        emit(f"fig4/{pid}/hashing_speedup", t_hash * 1e6,
             f"{t_fp / t_hash:.2f}x_vs_fingerprints,total={t_base / t_hash:.2f}x")


# --------------------------------------------------------------------------
# Bank construction: batched bulk-synchronous rounds vs sequential loop
# --------------------------------------------------------------------------

BANK_SIZES = (4, 16, 64)
# Smoke runs every bank size the trend gate covers: the gate compares rows
# individually (a P=4 win must not mask a P=64 regression), so dropping
# P=64 from smoke would silently drop it from CI's gate too.
SMOKE_BANK_SIZES = (4, 16, 64)
BANK_BUDGET = 512          # the Scanner's default SFA state budget
BANK_TILE = 64

# Banks are drawn from the bundled tractable signatures, cycled (with a
# distinct suffix) past the roster size; patterns whose SFA blows the budget
# stay in the mix — a realistic bank is a blend of closers and blowers.
_BANK_ROSTER = [
    "PS00016", "PS00005", "PS00001", "PS00006", "PS00009", "PS00004",
    "SYN00001", "SYN00008", "PS00002", "SYN00005", "SYN00010", "SYN00006",
    "PS00014", "PS00342", "SYN00004", "SYN00002", "SYN00009", "SYN00007",
    "PS00008", "SYN00003", "PS00017", "PS00007", "PS00010",
]


def _bank_dfas(P: int) -> list:
    pool = {**PROSITE_SAMPLES, **PROSITE_EXTRA}
    return [
        compile_prosite(pool[_BANK_ROSTER[i % len(_BANK_ROSTER)]])
        for i in range(P)
    ]


def run_bank(emit) -> None:
    report = {
        "suite": "bank_construction",
        "budget": BANK_BUDGET,
        "tile": BANK_TILE,
        "smoke": _config.SMOKE,
        "results": [],
    }
    for P in _config.scaled(BANK_SIZES, SMOKE_BANK_SIZES):
        dfas = _bank_dfas(P)
        t_loop = _time(lambda: construct_bank(
            dfas, method="loop", max_states=BANK_BUDGET))
        last = {}

        def batched():
            last["res"] = construct_bank(
                dfas, method="batched", max_states=BANK_BUDGET, tile=BANK_TILE)

        # The first batched call pays any XLA compiles this process has not
        # already cached (reported as the cold row); the warm best-of is the
        # round cost a long-lived scanner service sees, and is what the
        # ``batched_speedup`` trend gate compares.
        t_cold = _time(batched)
        t_batched = min(t_cold, _time(batched, repeat=2))
        res = last["res"]
        row = {
            "P": P,
            "loop_s": t_loop,
            "batched_cold_s": t_cold,
            "batched_s": t_batched,
            "loop_patterns_per_s": P / t_loop,
            "batched_patterns_per_s": P / t_batched,
            "batched_speedup": t_loop / t_batched,
            "rounds": int(res.stats.rounds),
            "blown": int(res.blown.sum()),
            # Per-size-bucket rounds/blown: a P=64 row that says "13 rounds,
            # 10 blown" hides *which* size class blew up and where the
            # rounds went; the bucketed driver accounts both per bucket.
            "buckets": [bs.to_json() for bs in res.stats.buckets],
        }
        report["results"].append(row)
        emit(f"bank/P{P}/loop_s", t_loop * 1e6,
             f"{row['loop_patterns_per_s']:.1f}_patterns_per_s")
        emit(f"bank/P{P}/batched_cold_s", t_cold * 1e6, "first_call")
        emit(f"bank/P{P}/batched_s", t_batched * 1e6,
             f"{row['batched_speedup']:.2f}x_vs_loop,"
             f"rounds={row['rounds']},blown={row['blown']}")
        for bs in res.stats.buckets:
            emit(f"bank/P{P}/bucket_le{bs.edge}", bs.wall_time_s * 1e6,
                 f"patterns={bs.n_patterns},n_max={bs.n_max},"
                 f"rounds={bs.rounds},blown={bs.blown}")
    out = Path(__file__).resolve().parents[1] / "BENCH_construction.json"
    out.write_text(json.dumps(report, indent=2) + "\n")
