"""Paper Fig. 4: speedup of fingerprints and hashing over the sequential
baseline SFA construction.

Three sequential variants (baseline exhaustive-compare, +fingerprints,
+fingerprints+hashing) run over a ladder of PROSITE-derived DFAs; reported
exactly as the paper plots it: fp-vs-baseline and hash-vs-fp speedups.
"""

from __future__ import annotations

import time

from benchmarks import _config
from repro.core.dfa import DFA, compile_dfa
from repro.core.prosite import PROSITE_SAMPLES, compile_prosite
from repro.core.sfa import construct_sfa_sequential

# small-to-medium patterns that the O(|Q_s|^2) baseline can still finish;
# the fingerprint/hash advantage GROWS with SFA size (paper Fig. 4's shape) —
# PS00008 (515 states) and PS00017 (1122) are the demonstrative tail.
BENCH_PATTERNS = ["PS00016", "PS00005", "PS00004", "PS00006", "PS00009",
                  "PS00001", "PS00008", "PS00017"]
SMOKE_PATTERNS = ["PS00016", "PS00005"]


def _time(fn, repeat: int = 1) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(emit) -> None:
    for pid in _config.scaled(BENCH_PATTERNS, SMOKE_PATTERNS):
        dfa = compile_prosite(PROSITE_SAMPLES[pid])
        s_hash = construct_sfa_sequential(dfa, use_fingerprints=True, use_hashing=True)
        n_sfa = s_hash.n_states

        t_base = _time(lambda: construct_sfa_sequential(
            dfa, use_fingerprints=False, use_hashing=False))
        t_fp = _time(lambda: construct_sfa_sequential(
            dfa, use_fingerprints=True, use_hashing=False))
        t_hash = _time(lambda: construct_sfa_sequential(
            dfa, use_fingerprints=True, use_hashing=True))

        emit(f"fig4/{pid}/baseline_s", t_base * 1e6, f"dfa={dfa.n_states},sfa={n_sfa}")
        emit(f"fig4/{pid}/fingerprint_speedup", t_fp * 1e6,
             f"{t_base / t_fp:.2f}x_vs_baseline")
        emit(f"fig4/{pid}/hashing_speedup", t_hash * 1e6,
             f"{t_fp / t_hash:.2f}x_vs_fingerprints,total={t_base / t_hash:.2f}x")
