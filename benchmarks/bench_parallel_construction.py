"""Paper Fig. 5: parallel (vectorized/jitted) SFA construction speedup over
the best sequential implementation (fingerprints + hashing).

The paper's pthread parallelism maps to data-parallel frontier expansion
here (DESIGN.md §2): the 'parallel' engine is the vectorized bulk-frontier
algorithm, plus the jitted JAX engine that runs the same algorithm on
accelerators.
"""

from __future__ import annotations

import time

from benchmarks import _config
from repro.core.prosite import PROSITE_SAMPLES, compile_prosite
from repro.core.sfa import construct_sfa_sequential, construct_sfa_vectorized

BENCH_PATTERNS = ["PS00016", "PS00004", "PS00006", "PS00001", "PS00008",
                  "PS00017"]
SMOKE_PATTERNS = ["PS00016", "PS00004"]


def run(emit) -> None:
    for pid in _config.scaled(BENCH_PATTERNS, SMOKE_PATTERNS):
        dfa = compile_prosite(PROSITE_SAMPLES[pid])
        t0 = time.perf_counter()
        ref = construct_sfa_sequential(dfa, use_fingerprints=True, use_hashing=True)
        t_seq = time.perf_counter() - t0

        t0 = time.perf_counter()
        vec = construct_sfa_vectorized(dfa)
        t_vec = time.perf_counter() - t0
        assert vec.n_states == ref.n_states

        emit(f"fig5/{pid}/best_sequential_s", t_seq * 1e6,
             f"dfa={dfa.n_states},sfa={ref.n_states}")
        emit(f"fig5/{pid}/vectorized_speedup", t_vec * 1e6,
             f"{t_seq / t_vec:.2f}x_vs_best_seq")


def run_jax_engine(emit) -> None:
    """The jitted engine on one small pattern (compile time excluded)."""
    from repro.core.sfa import construct_sfa

    dfa = compile_prosite(PROSITE_SAMPLES["PS00016"])
    ref = construct_sfa_sequential(dfa, use_fingerprints=True, use_hashing=True)
    # warm-up builds + compiles; second run measures steady state
    construct_sfa(dfa, engine="jax", max_states=ref.n_states + 64, tile=256)
    t0 = time.perf_counter()
    out = construct_sfa(dfa, engine="jax", max_states=ref.n_states + 64, tile=256)
    t_jax = time.perf_counter() - t0
    assert out.n_states == ref.n_states
    emit("fig5/PS00016/jax_engine_s", t_jax * 1e6, f"sfa={out.n_states}")
