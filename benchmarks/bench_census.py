"""Paper §IV dataset table: DFA -> SFA state growth census over the bundled
PROSITE selection (the paper's 1062-pattern census, scaled to the bundled
sample set + synthetic ladder)."""

from __future__ import annotations

import time

from benchmarks import _config
from repro.core.dfa import random_dfa
from repro.core.prosite import PROSITE_HARD, PROSITE_SAMPLES, compile_prosite
from repro.core.sfa import StateBlowup, construct_sfa


def run(emit) -> None:
    items = sorted(PROSITE_SAMPLES.items())
    items = _config.scaled(items, items[:4])
    max_states = _config.scaled(300_000, 20_000)
    for pid, pat in items:
        dfa = compile_prosite(pat)
        t0 = time.perf_counter()
        try:
            sfa = construct_sfa(dfa, max_states=max_states)
            t = time.perf_counter() - t0
            emit(f"census/{pid}", t * 1e6,
                 f"dfa={dfa.n_states},sfa={sfa.n_states},growth={sfa.n_states / dfa.n_states:.1f}x")
        except StateBlowup:
            t = time.perf_counter() - t0
            emit(f"census/{pid}", t * 1e6,
                 f"dfa={dfa.n_states},sfa=BLOWUP(>{max_states})")
    for pid in sorted(PROSITE_HARD):
        # exponential subset construction — the paper hit the same wall (§I)
        emit(f"census/{pid}", 0.0, "intractable_search_DFA_documented")


def run_synthetic_ladder(emit) -> None:
    """Random-DFA ladder — the exponential-growth regime the paper fights."""
    max_states = _config.scaled(300_000, 20_000)
    for n in _config.scaled([4, 6, 8, 10], [4, 6]):
        dfa = random_dfa(n, 8, seed=n)
        t0 = time.perf_counter()
        try:
            sfa = construct_sfa(dfa, max_states=max_states)
            t = time.perf_counter() - t0
            emit(f"census/random_n{n}", t * 1e6, f"sfa={sfa.n_states}")
        except StateBlowup:
            t = time.perf_counter() - t0
            emit(f"census/random_n{n}", t * 1e6, f"sfa=BLOWUP(>{max_states})")
