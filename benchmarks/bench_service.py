"""Scan service benchmarks: cold vs warm start, coalesced vs sequential.

Two suites, both writing ``BENCH_service.json`` (uploaded as a CI artifact
by the bench-smoke job):

* ``run`` — **cold vs warm start**: compile a pattern bank with a fresh
  in-memory ``SFACache`` over an empty artifact store (cold: full
  construction + write-through), then again with *another* fresh cache over
  the now-populated store (warm: zero construction rounds, pure disk reads).
  The ratio is the cold-start cost the persistent tier deletes.
* ``run_coalesced`` — **coalesced vs sequential submits**: the same burst of
  small overlapping requests served one-by-one (flush after every submit)
  vs coalesced into one fused bank scan (single flush), bit-identity
  asserted on the way.
"""

from __future__ import annotations

import json
import shutil
import tempfile
import time
from pathlib import Path

import numpy as np

from benchmarks import _config
from repro.construction import SFACache
from repro.engine import ConstructionPolicy, ScanPlan, Scanner
from repro.core.prosite import synthetic_protein
from repro.scanservice import ArtifactStore, BatchScheduler

BANK = ["PS00016", "PS00005", "PS00001", "PS00006", "PS00009", "PS00004",
        "SYN00001", "SYN00008", "PS00002", "SYN00005", "SYN00010", "SYN00006"]
SMOKE_BANK = ["PS00016", "PS00005", "PS00001", "PS00006"]

N_REQUESTS, SMOKE_REQUESTS = 16, 4
DOC_LEN = 240

_REPORT_PATH = Path(__file__).resolve().parent.parent / "BENCH_service.json"
_report: dict = {"suite": "scan_service", "results": []}


def _flush_report() -> None:
    _report["smoke"] = _config.SMOKE
    _REPORT_PATH.write_text(json.dumps(_report, indent=1))


def _store_plan(store_dir) -> ScanPlan:
    return ScanPlan(construction=ConstructionPolicy(
        cache=SFACache(backing=ArtifactStore(store_dir)), method="batched"))


def run(emit) -> None:
    """Cold vs warm process start through the artifact store."""
    bank = _config.scaled(BANK, SMOKE_BANK)
    root = tempfile.mkdtemp(prefix="bench-scan-store-")
    try:
        t0 = time.perf_counter()
        sc_cold = Scanner.compile(bank, _store_plan(root))
        t_cold = time.perf_counter() - t0
        r_cold = sc_cold.construction_report

        t0 = time.perf_counter()
        sc_warm = Scanner.compile(bank, _store_plan(root))   # fresh cache!
        t_warm = time.perf_counter() - t0
        r_warm = sc_warm.construction_report
        assert r_warm.rounds == 0, "warm start must perform zero rounds"

        emit(f"service/cold_start/P={len(bank)}", t_cold * 1e6,
             f"rounds={r_cold.rounds},built={r_cold.constructed}")
        emit(f"service/warm_start/P={len(bank)}", t_warm * 1e6,
             f"rounds=0,disk_hits={len(bank)},"
             f"speedup={t_cold / t_warm:.1f}x")
        _report["results"].append({
            "bench": "cold_vs_warm", "patterns": len(bank),
            "cold_s": t_cold, "warm_s": t_warm,
            "cold_rounds": r_cold.rounds, "speedup": t_cold / t_warm,
        })
        _flush_report()
    finally:
        shutil.rmtree(root, ignore_errors=True)


def run_coalesced(emit) -> None:
    """Coalesced vs sequential request serving (bit-identity asserted)."""
    bank = _config.scaled(BANK, SMOKE_BANK)
    n_req = _config.scaled(N_REQUESTS, SMOKE_REQUESTS)
    rng = np.random.default_rng(0)
    requests = []
    for _ in range(n_req):
        pats = [str(p) for p in rng.choice(bank, size=2, replace=False)]
        docs = [synthetic_protein(DOC_LEN, seed=int(rng.integers(1 << 16)))
                for _ in range(3)]
        requests.append((pats, docs))

    cache = SFACache()
    plan = ScanPlan(construction=ConstructionPolicy(cache=cache,
                                                    method="batched"))
    Scanner.compile(bank, plan)   # construction out of the timings

    def sequential():
        sched = BatchScheduler(plan)
        out = []
        for pats, docs in requests:
            t = sched.submit(pats, docs)
            sched.flush()                     # every request its own scan
            out.append(t.result())
        return out

    def coalesced():
        sched = BatchScheduler(plan, max_batch=len(requests) + 1)
        tickets = [sched.submit(p, d) for p, d in requests]
        sched.flush()                         # one fused scan
        return [t.result() for t in tickets]

    sequential(), coalesced()                 # warm both paths' jit caches
    t0 = time.perf_counter()
    seq = sequential()
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    coal = coalesced()
    t_coal = time.perf_counter() - t0
    for a, b in zip(seq, coal):
        assert np.array_equal(a.hits, b.hits), "coalescing changed results"

    emit(f"service/sequential/{n_req}req", t_seq * 1e6, "1 scan per request")
    emit(f"service/coalesced/{n_req}req", t_coal * 1e6,
         f"1 fused scan,speedup={t_seq / t_coal:.2f}x")
    _report["results"].append({
        "bench": "coalesced_vs_sequential", "requests": n_req,
        "sequential_s": t_seq, "coalesced_s": t_coal,
        "speedup": t_seq / t_coal,
    })
    _flush_report()
